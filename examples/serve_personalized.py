"""Personalized batched serving (the decode path of the dry-run).

Two federated clients each serve their own personalized gemma2-family
model with batched requests, rolling-window + global KV caches.

  PYTHONPATH=src python examples/serve_personalized.py
"""
import sys

from repro.launch import serve


def main():
    sys.argv = [
        "serve", "--arch", "gemma2-9b", "--smoke", "--clients", "2",
        "--batch", "2", "--prompt-len", "24", "--decode-tokens", "12",
    ]
    serve.main()


if __name__ == "__main__":
    main()
