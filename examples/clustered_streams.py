"""Trading wireless resources for personalization (§IV-B/C + §V-D).

Runs the clustered variant for several stream counts m_t, uses the
silhouette score (Alg. 2) to pick m_t automatically, and prices each
configuration's round time under the paper's wireless model.

  PYTHONPATH=src python examples/clustered_streams.py
"""
import jax
import numpy as np

from repro.core import FedConfig, clustering, comm_model as cm, ucfl
from repro.data import synthetic
from repro.federated import simulation
from repro.models import lenet


def main():
    key = jax.random.PRNGKey(1)
    dkey, mkey, skey = jax.random.split(key, 3)
    m, groups = 12, 4
    data = synthetic.covariate_label_shift(dkey, m=m, n=200, n_test=50,
                                           num_classes=8, alpha=8.0,
                                           groups=groups, hw=(16, 16))
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=8)
    cfg = FedConfig(batch_size=50)

    collab = ucfl.compute_collaboration(lenet.apply, params0, data,
                                        var_batch_size=50)

    print("silhouette sweep (Alg. 2):")
    best_k, results = clustering.choose_num_streams(
        jax.random.PRNGKey(2), collab["W"], k_max=8)
    for k, (s, score, _) in sorted(results.items()):
        marker = " <-- chosen" if k == best_k else ""
        print(f"  k={k}: silhouette={s:+.3f} tradeoff={score:+.3f}{marker}")

    sysp = cm.SystemParams(m=m, rho=4.0, inv_mu=1.0)
    for k in [1, best_k, m]:
        if k == 1:
            strat = ucfl.make_ucfl(lenet.apply, params0, cfg, num_streams=1,
                                   var_batch_size=50)
            scheme, streams = "broadcast", 1
        elif k == m:
            strat = ucfl.make_ucfl(lenet.apply, params0, cfg,
                                   var_batch_size=50)
            scheme, streams = "unicast", m
        else:
            strat = ucfl.make_ucfl(lenet.apply, params0, cfg, num_streams=k,
                                   var_batch_size=50)
            scheme, streams = "groupcast", k
        h = simulation.run(strat, lenet.apply, data, skey, rounds=10,
                           eval_every=10)
        rt = cm.round_time(sysp, scheme, streams)
        print(f"streams={k:3d}: avg_acc={h.final_avg:.3f} "
              f"round_time={rt:.1f}·T_dl  "
              f"(acc/time={h.final_avg / rt:.4f})")


if __name__ == "__main__":
    main()
