"""Quickstart: user-centric federated learning in ~60 lines.

Builds a concept-shift federated problem (two groups of clients with
permuted labels — collaboration across groups is poisonous), computes the
paper's collaboration coefficients in one special round, trains with
user-centric aggregation, and compares against FedAvg.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import FedConfig, REGISTRY, ucfl
from repro.data import synthetic
from repro.federated import simulation
from repro.models import lenet


def main():
    key = jax.random.PRNGKey(0)
    dkey, mkey, skey = jax.random.split(key, 3)

    # 8 clients in 2 concept groups (label permutations), synthetic images
    data = synthetic.concept_shift(dkey, m=8, n=200, n_test=50,
                                   num_classes=8, groups=2, hw=(16, 16),
                                   channels=1, noise=0.9)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=8)
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=50)

    # ---- the paper's special round: gradient-similarity weights (Eq. 9/10)
    collab = ucfl.compute_collaboration(lenet.apply, params0, data,
                                        var_batch_size=50)
    print("collaboration matrix W (rows = clients):")
    print(np.array_str(np.asarray(collab["W"]), precision=2,
                       suppress_small=True))

    # ---- train: user-centric aggregation vs FedAvg
    for name, strat in [
        ("user-centric", ucfl.make_ucfl(lenet.apply, params0, cfg,
                                        var_batch_size=50)),
        ("fedavg", REGISTRY["fedavg"](lenet.apply, params0, cfg)),
    ]:
        h = simulation.run(strat, lenet.apply, data, skey, rounds=10,
                           eval_every=5, verbose=True)
        print(f"--> {name}: avg={h.final_avg:.3f} worst={h.final_worst:.3f}\n")


if __name__ == "__main__":
    main()
