"""Quickstart: user-centric federated learning in ~100 lines.

Builds a concept-shift federated problem (two groups of clients with
permuted labels — collaboration across groups is poisonous), computes the
paper's collaboration coefficients in one special round, trains with
user-centric aggregation vs FedAvg, then tours the round-engine knobs a
wireless deployment cares about:

  * partial participation — a fixed-shape padded cohort per round
    (``ParticipationConfig``), so jit compiles the round once;
  * a quantized uplink (``FedConfig.transport``) — int8 deltas + error
    feedback, ~3.9x fewer uplink bytes at matched accuracy;
  * a two-tier topology (``FedConfig.topology``) — clients upload to
    edge aggregators, only per-edge aggregates reach the server
    (``E·k`` PS-side streams instead of the cohort's ``c``);
  * Pareto-biased selection (``SelectionConfig``) — cohorts tilted
    toward fast clients, with a fairness lane so nobody starves.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import FedConfig, REGISTRY, comm_model, ucfl
from repro.data import synthetic
from repro.federated import simulation
from repro.federated.participation import (ParticipationConfig,
                                           SelectionConfig)
from repro.federated.topology import Topology
from repro.federated.transport import TransportConfig
from repro.models import lenet


def main():
    key = jax.random.PRNGKey(0)
    dkey, mkey, skey = jax.random.split(key, 3)

    # 8 clients in 2 concept groups (label permutations), synthetic images
    m = 8
    data = synthetic.concept_shift(dkey, m=m, n=200, n_test=50,
                                   num_classes=8, groups=2, hw=(16, 16),
                                   channels=1, noise=0.9)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=8)
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=50)

    # ---- the paper's special round: gradient-similarity weights (Eq. 9/10)
    collab = ucfl.compute_collaboration(lenet.apply, params0, data,
                                        var_batch_size=50)
    print("collaboration matrix W (rows = clients):")
    print(np.array_str(np.asarray(collab["W"]), precision=2,
                       suppress_small=True))

    # ---- train: user-centric aggregation vs FedAvg
    for name, strat in [
        ("user-centric", ucfl.make_ucfl(lenet.apply, params0, cfg,
                                        var_batch_size=50)),
        ("fedavg", REGISTRY["fedavg"](lenet.apply, params0, cfg)),
    ]:
        h = simulation.run(strat, lenet.apply, data, skey, rounds=10,
                           eval_every=5, verbose=True)
        print(f"--> {name}: avg={h.final_avg:.3f} worst={h.final_worst:.3f}\n")

    # ---- partial participation + quantized uplink: half the clients per
    # round (one compiled round shape — pad slots are masked), int8 deltas
    # with error feedback on the wire
    part = ParticipationConfig(cohort_size=m // 2, seed=7)
    qcfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=50,
                     transport=TransportConfig("int8"))
    strat = ucfl.make_ucfl(lenet.apply, params0, qcfg, var_batch_size=50)
    h = simulation.run(strat, lenet.apply, data, skey, rounds=10,
                       eval_every=5, participation=part)
    ul = comm_model.uplink_bytes_per_round(
        1, "unicast", m, cohort_size=m // 2,
        transport=qcfg.transport, schema=strat.wire_schema)
    raw = comm_model.uplink_bytes_per_round(
        1, "unicast", m, cohort_size=m // 2, schema=strat.wire_schema)
    print(f"--> cohort=4 + int8 uplink: avg={h.final_avg:.3f} "
          f"(uplink {raw / ul:.2f}x smaller)\n")

    # ---- two-tier topology: clients report to 2 edge aggregators; only
    # the per-edge partial aggregates cross the edge<->PS backhaul. The
    # tiered mix factorizes the flat rule exactly (same accuracy), while
    # the PS ingests E*k aggregate streams instead of c client uploads.
    topo = Topology.contiguous(m, 2)
    tcfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=50,
                     topology=topo)
    strat = ucfl.make_ucfl(lenet.apply, params0, tcfg, num_streams=2,
                           var_batch_size=50)
    tpart = ParticipationConfig(cohort_size=6, seed=7)
    h = simulation.run(strat, lenet.apply, data, skey, rounds=10,
                       eval_every=5, participation=tpart)
    flat_b = comm_model.ps_uplink_bytes_per_round(
        1, "groupcast", m, num_streams=2, cohort_size=6,
        schema=strat.wire_schema)
    hier_b = comm_model.ps_uplink_bytes_per_round(
        1, "groupcast", m, num_streams=2, cohort_size=6,
        num_edges=2, schema=strat.wire_schema)
    print(f"--> two-tier (E=2, k=2): avg={h.final_avg:.3f} "
          f"(PS uplink {flat_b / hier_b:.2f}x smaller)\n")

    # ---- Pareto-biased selection: favor fast clients (here: a 16x
    # compute-speed spread), fairness lane on so slow clients still train
    sel = SelectionConfig(compute=np.geomspace(0.25, 4.0, m), bias=2.0)
    h = simulation.run(strat, lenet.apply, data, skey, rounds=10,
                       eval_every=5, participation=part, selection=sel)
    print(f"--> pareto selection (bias=2): avg={h.final_avg:.3f} "
          f"worst={h.final_worst:.3f}")


if __name__ == "__main__":
    main()
