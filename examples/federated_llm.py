"""User-centric FL on a transformer-zoo architecture (end-to-end driver).

Federates a reduced mamba2 LM across 4 clients whose token streams follow
two different hidden Markov chains (concept shift in LM-land), computes
the collaboration matrix on real LM gradients, and trains with the same
train_step that the multi-pod dry-run lowers for TPU.

  PYTHONPATH=src python examples/federated_llm.py
"""
import sys

from repro.launch import train


def main():
    sys.argv = [
        "train", "--arch", "mamba2-1.3b", "--smoke", "--clients", "4",
        "--groups", "2", "--rounds", "15", "--batch", "4", "--seq", "64",
        "--agg", "user_centric",
    ]
    train.main()


if __name__ == "__main__":
    main()
