"""Paper Fig. 4 — silhouette score of k-means over W vs k, per scenario.

Expected: monotone decrease for label shift (no cluster structure);
peak at the true group count for covariate/concept shift.
"""
from __future__ import annotations

import time

import jax

from benchmarks import common
from repro.core import clustering, ucfl
from repro.models import lenet


def run(scale) -> list[str]:
    rows = []
    for scen in ["label_shift", "covariate_label_shift", "concept_shift"]:
        key = jax.random.PRNGKey(11)
        dkey, mkey = jax.random.split(key)
        data = common.scenario_data(scen, dkey, scale)
        params0 = common.make_params0(
            mkey, scale, common.num_classes_for(scen, scale))
        t0 = time.time()
        collab = ucfl.compute_collaboration(lenet.apply, params0, data,
                                            var_batch_size=scale.var_batch)
        dt = (time.time() - t0) * 1e6
        for k in range(2, min(scale.m, 9)):
            res = clustering.kmeans(jax.random.PRNGKey(k), collab["W"], k)
            s = float(clustering.silhouette_score(collab["W"], res.labels))
            rows.append(common.csv_row(f"fig4/{scen}/k={k}", dt,
                                       f"silhouette={s:.4f}"))
            print(rows[-1], flush=True)
    return rows
