"""Paper Fig. 5 — accuracy vs wall-clock under the Sec. V-D comm model.

Three systems: wireless slow-UL (rho=4, stragglers), wireless fast-UL
(rho=2, reliable), wired (rho=1, reliable). Streams: FedAvg=1 broadcast,
UCFL=m unicast, UCFL-k4=4 groupcast, FedFomo=client mixing (m models DL).

Also emits the partial-participation comm sweep: round time and downlink
bytes for each algorithm at several cohort fractions (the O(cohort) round
cost the participation engine buys) — each row twice, raw f32 wire and
the int8 WireSchema wire (``transport``/``schema`` threaded into
``cm.round_time`` and ``cm.downlink_bytes_per_round``), so the Tdl
frontier shows what per-stream compression buys per algorithm: fedavg's
delta broadcast and ucfl's per-client delta rows shrink ~3.9x, the k=4
raw centroids and FedFomo's relayed peer models move by their own
codings.
"""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import comm_model as cm
from repro.federated.transport import TransportConfig

SYSTEMS = {
    "wireless_slow_ul": dict(rho=4.0, inv_mu=1.0),
    "wireless_fast_ul": dict(rho=2.0, inv_mu=0.0),
    "wired": dict(rho=1.0, inv_mu=0.0),
}
ALGOS = {
    "fedavg": ("broadcast", None),
    "ucfl": ("unicast", None),
    "ucfl_k4": ("groupcast", 4),
    "fedfomo": ("client_mixing", None),
}
FRACTIONS = (1.0, 0.5, 0.25, 0.1)


def _algo_schemas(scale):
    """Each fig5 algo's declared WireSchema (from the real constructors —
    duplicating the stream declarations here would drift)."""
    import jax

    params0 = common.make_params0(jax.random.PRNGKey(0), scale)
    tr = TransportConfig("int8")
    out = {}
    for algo in ALGOS:
        name = "ucfl_k4" if algo == "ucfl_k4" else algo
        strat = common.make_strategy(name, params0, scale, transport=tr)
        out[algo] = strat.wire_schema
    return params0, out


def sweep_participation(scale, *, model_bytes: int | None = None) -> list[str]:
    """Round-time / DL-bytes rows for ≥3 participation fractions.

    Every (fraction, algo) cell is priced on the raw f32 wire AND the
    int8 schema wire — the schema comes from the algo's own strategy
    constructor, so the frontier prices exactly the streams the engine
    ships.
    """
    params0, schemas = _algo_schemas(scale)
    if model_bytes is None:
        from repro.core.pytree import tree_count_params
        model_bytes = 4 * tree_count_params(params0)
    rows = []
    p = cm.SystemParams(m=scale.m, rho=4.0, inv_mu=1.0)
    wires = (("", None, None),
             ("_int8", TransportConfig("int8"), schemas))
    for frac in FRACTIONS:
        c = max(1, round(frac * scale.m))
        for algo, (scheme, k) in ALGOS.items():
            for tag, tr, sch in wires:
                schema = sch[algo] if sch else None
                rt = cm.round_time(p, scheme, k, cohort_size=c,
                                   transport=tr, schema=schema)
                dl = cm.downlink_bytes_per_round(
                    model_bytes, scheme, scale.m, k, cohort_size=c,
                    transport=tr, schema=schema)
                rows.append(common.csv_row(
                    f"fig5/participation/{algo}_f{frac}{tag}", 0.0,
                    f"cohort={c};t_round={rt:.2f}Tdl;dl_bytes={dl}"))
                print(rows[-1], flush=True)
    return rows


def run(scale) -> list[str]:
    rows = []
    hists = {}
    for algo in ALGOS:
        t0 = time.time()
        res = common.run_trials("covariate_label_shift", algo, scale)
        hists[algo] = res["hists"][0]
        dt = (time.time() - t0) * 1e6 / max(scale.rounds * scale.trials, 1)
        rows.append(common.csv_row(f"fig5/train/{algo}", dt,
                                   f"final={res['avg']:.4f}"))
        print(rows[-1], flush=True)
    for sysname, kw in SYSTEMS.items():
        p = cm.SystemParams(m=scale.m, **kw)
        for algo, (scheme, k) in ALGOS.items():
            h = hists[algo]
            times = cm.rounds_to_time(p, scheme, len(h.rounds), k)
            # time to reach 90% of the algo's own best accuracy
            target = 0.9 * max(h.avg_acc)
            t_hit = next((t for t, a in zip(times, h.avg_acc)
                          if a >= target), float("inf"))
            rows.append(common.csv_row(
                f"fig5/{sysname}/{algo}", 0.0,
                f"t90={t_hit:.1f}Tdl;final={h.avg_acc[-1]:.4f}"))
            print(rows[-1], flush=True)
    rows.extend(sweep_participation(scale))
    return rows
