"""Aggregate the dry-run JSON artifacts into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common

RESULT_GLOB = os.environ.get("DRYRUN_GLOB", "results/dryrun/*.json")


def run(scale) -> list[str]:
    rows = []
    for path in sorted(glob.glob(RESULT_GLOB)):
        with open(path) as f:
            d = json.load(f)
        name = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}/{d['agg']}"
        derived = (
            f"comp_ms={d['compute_s'] * 1e3:.2f};"
            f"mem_ms={d['memory_s'] * 1e3:.2f};"
            f"coll_ms={d['collective_s'] * 1e3:.2f};"
            f"dom={d['dominant']};useful={d['useful_flops_ratio']:.3f}"
        )
        rows.append(common.csv_row(name, d.get("t_compile_s", 0) * 1e6,
                                   derived))
        print(rows[-1], flush=True)
    if not rows:
        print("roofline/NO_RESULTS,0.00,run repro.launch.dryrun first",
              flush=True)
    return rows
