"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the repo convention).

  PYTHONPATH=src python -m benchmarks.run            # fast scale (CPU)
  PYTHONPATH=src python -m benchmarks.run --full     # paper scale
  PYTHONPATH=src python -m benchmarks.run --only table1,fig4
  PYTHONPATH=src python benchmarks/run.py ...        # script form works too
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/run.py` script execution:
    # put the repo root on sys.path so `from benchmarks import ...` resolves
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale m/rounds/trials (slow)")
    ap.add_argument("--only", default="all")
    args = ap.parse_args()

    from benchmarks import (common, fig4_silhouette, fig5_comm_efficiency,
                            fig6_parallel_ucfl, fig7_minibatch, kernel_bench,
                            participation_sweep, roofline_report,
                            round_engine, table1_accuracy, table2_worst_user)

    class _Suite:
        """Adapter exposing a bare row function as a suite module."""

        def __init__(self, fn):
            self.run = fn

    scale = common.FULL if args.full else common.FAST
    suites = {
        "kernel": kernel_bench,
        "roofline": roofline_report,
        # also emits BENCH_round_engine.json (steady-state round walltime,
        # dense vs cohort vs padded-availability) at the repo root
        "round_engine": round_engine,
        "table1": table1_accuracy,
        "table2": table2_worst_user,
        "fig4": fig4_silhouette,
        "fig5": fig5_comm_efficiency,
        "fig6": fig6_parallel_ucfl,
        "fig7": fig7_minibatch,
        "participation": participation_sweep,
        # two-tier topology replay + Pareto selection sweep; its own
        # suite (not inside participation.run) so `all` runs each once
        "hier": _Suite(participation_sweep.run_hier),
    }
    only = None if args.only == "all" else set(args.only.split(","))
    print("name,us_per_call,derived")
    t0 = time.time()
    all_rows = []
    for name, mod in suites.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---", file=sys.stderr, flush=True)
        all_rows.extend(mod.run(scale))
    print(f"# total {len(all_rows)} rows in {time.time() - t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
