"""Partial-participation engine benchmark.

Trains ucfl + fedavg at several cohort fractions (uniform sampler, plus
one weighted and one round-robin row) with a client chunk bound, and
reports accuracy alongside the cohort-aware §V-D round cost — the
accuracy-vs-wireless-resources trade this PR's engine opens up.

The ``participation/ucfl_w_{stale,refreshed}`` rows replay a
deterministic LOW-availability trace (a rare tail of clients is up in
only one phase of the cycle, so their Δ/σ² stats go maximally stale)
with the streaming W refresh off vs on — same data, same seeds, same
cohorts. The refreshed run re-estimates W from the uploads the cohort
already sends, so the row also prints the §V-D per-round uplink bytes of
both runs: they are identical by construction (the comm-model regression
test pins this), making the refresh a pure accuracy win on the wireless
budget.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks import common
from repro.core import comm_model as cm
from repro.core.similarity import RefreshConfig
from repro.federated.participation import ParticipationConfig

FRACTIONS = (1.0, 0.5, 0.25)
ALGOS = {"fedavg": ("broadcast", None), "ucfl": ("unicast", None)}


def low_availability_trace(m: int, period: int = 4) -> np.ndarray:
    """Deterministic (m, period) trace with a rarely-available tail.

    The first half of the clients ("reliable") is up in every phase; rare
    client ``i`` (second half) is up in exactly ONE phase per cycle
    (``(i − m/2) % period``). The rare tail is therefore sampled a
    handful of times per run — enough that its personalized model trains
    at all (a never-sampled client's model never updates, which would
    make the worst-node comparison vacuous), rare enough that without
    the streaming refresh its W statistics stay frozen at the special
    round's θ⁰ estimates between appearances. Splitting by halves (not
    parity) keeps each rare client's closest collaborators reliable, so
    the mixes it receives on its rare appearances actually matter.
    """
    trace = np.zeros((m, period), bool)
    trace[: m // 2, :] = True
    for j, i in enumerate(range(m // 2, m)):
        trace[i, j % period] = True
    return trace


def run(scale) -> list[str]:
    rows = []
    p = cm.SystemParams(m=scale.m, rho=4.0, inv_mu=1.0)
    chunk = max(2, scale.m // 4)
    for algo, (scheme, k) in ALGOS.items():
        for frac in FRACTIONS:
            part = (None if frac == 1.0
                    else ParticipationConfig(fraction=frac))
            # the config's own (ceil) rule, not a re-derivation of it
            c = scale.m if part is None else part.resolve_size(scale.m)
            t0 = time.time()
            res = common.run_trials("covariate_label_shift", algo, scale,
                                    participation=part, chunk_size=chunk)
            dt = (time.time() - t0) * 1e6 / max(scale.rounds * scale.trials, 1)
            rt = cm.round_time(p, scheme, k, cohort_size=c)
            rows.append(common.csv_row(
                f"participation/{algo}_f{frac}", dt,
                f"cohort={c};chunk={chunk};acc={res['avg']:.4f};"
                f"t_round={rt:.2f}Tdl"))
            print(rows[-1], flush=True)
    for sampler in ("weighted", "round_robin"):
        part = ParticipationConfig(fraction=0.5, sampler=sampler)
        res = common.run_trials("covariate_label_shift", "ucfl", scale,
                                participation=part, chunk_size=chunk)
        rows.append(common.csv_row(
            f"participation/ucfl_{sampler}", 0.0,
            f"fraction=0.5;acc={res['avg']:.4f}"))
        print(rows[-1], flush=True)

    # stale vs refreshed W under a low-availability replay (same data,
    # seeds, and cohort sequence; only FedConfig.w_refresh differs).
    # label_shift's graded Dirichlet heterogeneity is where the θ⁰ W is
    # imperfect enough for staleness to bite (on clean-block concept
    # shift the special round is already near-perfect and refresh can
    # only tie); ≥ 12 rounds lets each rare client surface a few times.
    lscale = dataclasses.replace(scale, rounds=max(12, scale.rounds))
    c = max(2, lscale.m // 2)
    avail = ParticipationConfig(
        cohort_size=c, sampler="availability",
        availability=low_availability_trace(lscale.m))
    ul = cm.uplink_bytes_per_round(1, "unicast", lscale.m, cohort_size=c)
    for label, refresh in (("stale", None), ("refreshed", RefreshConfig())):
        res = common.run_trials("label_shift", "ucfl", lscale,
                                participation=avail, chunk_size=chunk,
                                w_refresh=refresh)
        rows.append(common.csv_row(
            f"participation/ucfl_w_{label}", 0.0,
            f"cohort={c};avail=low;avg={res['avg']:.4f};"
            f"worst={res['worst']:.4f};ul_models_per_round={ul}"))
        print(rows[-1], flush=True)
    return rows
