"""Partial-participation engine benchmark.

Trains ucfl + fedavg at several cohort fractions (uniform sampler, plus
one weighted and one round-robin row) with a client chunk bound, and
reports accuracy alongside the cohort-aware §V-D round cost — the
accuracy-vs-wireless-resources trade this PR's engine opens up.

The ``participation/async_vs_sync`` row replays a diurnal availability
trace (same data, seeds, cohorts) under the barrier engine vs the
buffered-async server (``FedConfig.async_buffer``) and prices both with
the §V-D comm model: the async engine must reach the barrier run's best
average accuracy at strictly lower simulated wall-clock (it waits for
the flush_k-th arrival, not the cohort max) with worst-node accuracy
within 0.02 — the accuracy-vs-communication-time trade of Fig. 5.

The ``participation/byz_*`` rows replay a 20%-sign-flip Byzantine
population (``FedConfig.faults``) on noisy grouped concept shift — same
data, seeds, and cohort sequence across runs; only the faults/robust
knobs differ. (Concept shift, not label shift: under strong label shift
Eq. 9's W is near-diagonal — every client already trusts only itself —
so poisoning cannot propagate and the quarantine question is vacuous;
the grouped high-noise regime is where W genuinely mixes and an attacker
a client listens to can hurt it.) They
answer two questions at once: (1) graceful degradation — trimmed-mean /
multi-Krum (``FedConfig.robust``) must recover ~the clean run's honest
average accuracy while the unguarded run degrades; (2) W-quarantine —
does the user-centric mixing matrix isolate poisoners on its own? Each
row reports the honest→attacker mixing mass
(:func:`repro.core.similarity.attacker_mixing_mass`) PER ROUND (it only
moves when the streaming W refresh is on), plus the §V-D round price and
its straggler-deadline-censored variant.

The ``participation/ucfl_w_{stale,refreshed}`` rows replay a
deterministic LOW-availability trace (a rare tail of clients is up in
only one phase of the cycle, so their Δ/σ² stats go maximally stale)
with the streaming W refresh off vs on — same data, same seeds, same
cohorts. The refreshed run re-estimates W from the uploads the cohort
already sends, so the row also prints the §V-D per-round uplink bytes of
both runs: they are identical by construction (the comm-model regression
test pins this), making the refresh a pure accuracy win on the wireless
budget.

The ``participation/quant_uplink`` row replays label shift under ucfl
with the quantized uplink transport off vs on (int8 per-chunk-scaled
deltas + error feedback, ``FedConfig.transport``) — same data, seeds,
and cohort sequence, matched rounds. It prices both wires with the
dtype-aware comm model (``uplink_bytes_per_round(..., transport=...)``
and the transport-scaled ``round_time`` Tdl frontier) and asserts the
trade the transport exists to buy: ≥ 3.5x fewer uplink bytes per round
at matched accuracy (average within ±1% absolute of the float32 run).

The ``hier`` suite (``run.py --only hier``, kept out of the
``participation`` suite so ``all`` runs each row once) adds the two-tier
rows:

  * ``participation/hier_replay`` — clustered ucfl (k=2) flat vs under a
    two-edge ``FedConfig.topology`` (same data, seeds, and cohort
    sequence; the tiered mix factorizes the flat rule exactly, so
    accuracy must match up to float association) reporting the PS-side
    backhaul bytes (``cm.ps_uplink_bytes_per_round``): flat ships the
    cohort's c client uploads through the PS link, tiered ships
    ``E·k`` edge aggregates — the ≥ 2x PS-traffic reduction the
    topology exists to buy, plus the honest per-tier ``round_time``
    and PS downlink counters.
  * ``participation/select_*`` — Pareto-biased cohort selection
    (``FedConfig.selection`` / the ``pareto`` sampler) swept over the
    bias exponent on the accuracy-vs-Tdl frontier: sharper compute bias
    picks faster cohorts (the realized straggler term shrinks — priced
    from each round's actual min member speed) at the cost of the
    rarely-picked slow clients' personalized accuracy; the fairness
    lane bounds their starvation (``min_sel`` ≥ 1).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks import common
from repro.core import comm_model as cm
from repro.core import similarity
from repro.core.aggregation import RobustConfig
from repro.core.similarity import RefreshConfig
from repro.federated import faults as fl
from repro.federated import participation as pp
from repro.federated.async_buffer import AsyncConfig
from repro.federated.participation import ParticipationConfig

FRACTIONS = (1.0, 0.5, 0.25)
ALGOS = {"fedavg": ("broadcast", None), "ucfl": ("unicast", None)}


def low_availability_trace(m: int, period: int = 4) -> np.ndarray:
    """Deterministic (m, period) trace with a rarely-available tail.

    The first half of the clients ("reliable") is up in every phase; rare
    client ``i`` (second half) is up in exactly ONE phase per cycle
    (``(i − m/2) % period``). The rare tail is therefore sampled a
    handful of times per run — enough that its personalized model trains
    at all (a never-sampled client's model never updates, which would
    make the worst-node comparison vacuous), rare enough that without
    the streaming refresh its W statistics stay frozen at the special
    round's θ⁰ estimates between appearances. Splitting by halves (not
    parity) keeps each rare client's closest collaborators reliable, so
    the mixes it receives on its rare appearances actually matter.
    """
    trace = np.zeros((m, period), bool)
    trace[: m // 2, :] = True
    for j, i in enumerate(range(m // 2, m)):
        trace[i, j % period] = True
    return trace


def run(scale) -> list[str]:
    rows = []
    p = cm.SystemParams(m=scale.m, rho=4.0, inv_mu=1.0)
    chunk = max(2, scale.m // 4)
    for algo, (scheme, k) in ALGOS.items():
        for frac in FRACTIONS:
            part = (None if frac == 1.0
                    else ParticipationConfig(fraction=frac))
            # the config's own (ceil) rule, not a re-derivation of it
            c = scale.m if part is None else part.resolve_size(scale.m)
            t0 = time.time()
            res = common.run_trials("covariate_label_shift", algo, scale,
                                    participation=part, chunk_size=chunk)
            dt = (time.time() - t0) * 1e6 / max(scale.rounds * scale.trials, 1)
            rt = cm.round_time(p, scheme, k, cohort_size=c)
            rows.append(common.csv_row(
                f"participation/{algo}_f{frac}", dt,
                f"cohort={c};chunk={chunk};acc={res['avg']:.4f};"
                f"t_round={rt:.2f}Tdl"))
            print(rows[-1], flush=True)
    for sampler in ("weighted", "round_robin"):
        part = ParticipationConfig(fraction=0.5, sampler=sampler)
        res = common.run_trials("covariate_label_shift", "ucfl", scale,
                                participation=part, chunk_size=chunk)
        rows.append(common.csv_row(
            f"participation/ucfl_{sampler}", 0.0,
            f"fraction=0.5;acc={res['avg']:.4f}"))
        print(rows[-1], flush=True)

    # stale vs refreshed W under a low-availability replay (same data,
    # seeds, and cohort sequence; only FedConfig.w_refresh differs).
    # label_shift's graded Dirichlet heterogeneity is where the θ⁰ W is
    # imperfect enough for staleness to bite (on clean-block concept
    # shift the special round is already near-perfect and refresh can
    # only tie); ≥ 12 rounds lets each rare client surface a few times.
    lscale = dataclasses.replace(scale, rounds=max(12, scale.rounds))
    c = max(2, lscale.m // 2)
    avail = ParticipationConfig(
        cohort_size=c, sampler="availability",
        availability=low_availability_trace(lscale.m))
    ul = cm.uplink_bytes_per_round(1, "unicast", lscale.m, cohort_size=c)
    for label, refresh in (("stale", None), ("refreshed", RefreshConfig())):
        res = common.run_trials("label_shift", "ucfl", lscale,
                                participation=avail, chunk_size=chunk,
                                w_refresh=refresh)
        rows.append(common.csv_row(
            f"participation/ucfl_w_{label}", 0.0,
            f"cohort={c};avail=low;avg={res['avg']:.4f};"
            f"worst={res['worst']:.4f};ul_models_per_round={ul}"))
        print(rows[-1], flush=True)

    rows.extend(async_replay_rows(scale, chunk))
    rows.extend(byzantine_replay_rows(scale, chunk))
    rows.extend(quant_replay_rows(scale, chunk))
    return rows


def run_hier(scale) -> list[str]:
    """The two-tier suite: hierarchical replay + selection-bias sweep."""
    chunk = max(2, scale.m // 4)
    rows = hier_replay_rows(scale, chunk)
    rows.extend(selection_sweep_rows(scale, chunk))
    return rows


def hier_replay_rows(scale, chunk) -> list[str]:
    """Hierarchical replay: flat vs two-tier clustered ucfl, PS bytes.

    Same data, seeds, and cohort sequence — only ``FedConfig.topology``
    differs (None vs a two-edge contiguous assignment). The tiered round
    factorizes the flat clustered mix exactly (per-edge partial centroid
    sums + one tier-2 normalize), so the accuracies must match up to
    float association; what changes is WHERE the traffic flows. The row
    prices the edge↔PS backhaul with ``cm.ps_uplink_bytes_per_round``:
    flat, all c cohort uploads transit the PS link; tiered, each of the
    E active edges ships its k aggregate streams once — ``c/(E·k)``
    fewer PS-side bytes (3x at this scale's c=12, E=2, k=2; the ≥ 2x
    bar is the acceptance gate). The per-tier ``round_time`` (default
    backhaul budget) and the PS DOWNLINK counter are reported too —
    broadcast replication across E backhaul links makes the latter
    LARGER than flat, and hiding it would oversell the topology.
    """
    import jax

    from repro.core.pytree import tree_count_params
    from repro.data import synthetic
    from repro.federated import simulation
    from repro.federated.topology import Topology
    from repro.models import lenet

    k, num_edges = 2, 2
    # m=16/c=12 keeps the replay CPU-cheap while giving the PS-byte
    # ratio c/(E·k) = 3 a real margin over the 2x acceptance bar
    lscale = dataclasses.replace(scale, m=max(16, scale.m),
                                 rounds=max(10, scale.rounds))
    m = lscale.m
    c = min(m, 12)
    part = ParticipationConfig(cohort_size=c, seed=17)
    topo = Topology.contiguous(m, num_edges)

    key = jax.random.PRNGKey(41)
    dkey, mkey, skey = jax.random.split(key, 3)
    # noise=2.0 keeps accuracy off the 1.0 ceiling so "matched" is a
    # real statement, not a saturated one
    data = synthetic.concept_shift(
        dkey, m=m, n=lscale.n, n_test=lscale.n_test,
        num_classes=max(lscale.num_classes, 6), groups=2, hw=lscale.hw,
        channels=1, noise=2.0)
    params0 = common.make_params0(mkey, lscale,
                                  max(lscale.num_classes, 6))
    model_bytes = 4 * tree_count_params(params0)

    res = {}
    for label, tp in (("flat", None), ("hier", topo)):
        strat = common.make_strategy("ucfl_k2", params0, lscale,
                                     chunk_size=chunk, topology=tp)
        schema = strat.wire_schema
        h = simulation.run(strat, lenet.apply, data, skey,
                           rounds=lscale.rounds, eval_every=2,
                           participation=part)
        edges = None if tp is None else num_edges
        p = cm.SystemParams(
            m=m, rho=4.0, inv_mu=1.0,
            tiers=None if tp is None else cm.TierParams(num_edges))
        avg, worst = h.paired_best
        res[label] = {
            "avg": avg, "worst": worst,
            "ps_ul": cm.ps_uplink_bytes_per_round(
                model_bytes, "groupcast", m, num_streams=k, cohort_size=c,
                num_edges=edges, schema=schema),
            "ps_dl": cm.ps_downlink_bytes_per_round(
                model_bytes, "groupcast", m, num_streams=k, cohort_size=c,
                num_edges=edges, schema=schema),
            "t_round": cm.round_time(p, "groupcast", k, cohort_size=c),
        }
    ratio = res["flat"]["ps_ul"] / max(res["hier"]["ps_ul"], 1)
    dacc = res["hier"]["avg"] - res["flat"]["avg"]
    row = common.csv_row(
        "participation/hier_replay", 0.0,
        f"cohort={c};edges={num_edges};k={k};rounds={lscale.rounds};"
        f"avg_flat={res['flat']['avg']:.4f};"
        f"avg_hier={res['hier']['avg']:.4f};"
        f"worst_flat={res['flat']['worst']:.4f};"
        f"worst_hier={res['hier']['worst']:.4f};"
        f"ps_ul_flat={res['flat']['ps_ul']}B;"
        f"ps_ul_hier={res['hier']['ps_ul']}B;"
        f"ps_ul_ratio={ratio:.2f}x;"
        f"ps_dl_flat={res['flat']['ps_dl']}B;"
        f"ps_dl_hier={res['hier']['ps_dl']}B;"
        f"t_flat={res['flat']['t_round']:.2f}Tdl;"
        f"t_hier={res['hier']['t_round']:.2f}Tdl;"
        f"acc_matched={abs(dacc) <= 0.02};ps_ok={ratio >= 2.0}")
    print(row, flush=True)
    return [row]


def selection_sweep_rows(scale, chunk) -> list[str]:
    """Pareto-biased selection sweep on the accuracy-vs-Tdl frontier.

    One shared data/seed draw; rows differ only in the cohort sampler:
    uniform vs ``SelectionConfig(compute=speeds, bias=b)`` at rising
    bias exponents (``simulation.run(selection=...)`` rewrites the
    policy to the ``pareto`` sampler — the same seam FedConfig.selection
    drivers use). Per-client compute speeds span a 16x geometric range;
    the §V-D straggler term is priced from each round's REALIZED cohort
    (``t_min + H_c/(μ·min speed)`` — the slowest member sets the
    barrier), so sharper bias visibly buys wall-clock on the Tdl axis
    while the rarely-selected slow clients pay in personalized accuracy
    (``worst``). ``min_sel`` counts the least-selected client's draws
    over the replay: the fairness lane keeps it ≥ 1 well before the
    ``n_pos``-round worst-case bound.
    """
    import jax

    from repro.federated import simulation
    from repro.federated.participation import SelectionConfig
    from repro.models import lenet

    lscale = dataclasses.replace(scale, rounds=max(12, scale.rounds))
    m = lscale.m
    c = max(2, m // 4)
    speeds = np.geomspace(0.25, 4.0, m)
    p = cm.SystemParams(m=m, rho=4.0, inv_mu=1.0)

    key = jax.random.PRNGKey(43)
    dkey, mkey, skey = jax.random.split(key, 3)
    data = common.scenario_data("label_shift", dkey, lscale)
    params0 = common.make_params0(mkey, lscale)
    part = ParticipationConfig(cohort_size=c, seed=31)

    rows = []
    sweeps = [("uniform", None)] + [
        (f"b{b:g}", SelectionConfig(compute=speeds, bias=b))
        for b in (1.0, 2.0, 4.0)]
    for label, sel in sweeps:
        strat = common.make_strategy("ucfl", params0, lscale,
                                     chunk_size=chunk, selection=sel)
        h = simulation.run(strat, lenet.apply, data, skey,
                           rounds=lscale.rounds, eval_every=2,
                           participation=part, selection=sel)
        sched = pp.cohort_schedule(pp.with_selection(part, sel),
                                   lscale.rounds, m)
        counts = np.zeros(m, int)
        t_rounds = []
        for co in sched:
            counts[co.members] += 1
            # realized straggler barrier: the slowest member's rate
            # scales the exponential tail of the c-way compute max
            t_comp = p.t_min + cm.harmonic(len(co)) * p.inv_mu / \
                float(speeds[co.members].min())
            t_rounds.append(len(co) * p.t_dl + t_comp + p.rho * p.t_dl)
        avg, worst = h.paired_best
        rows.append(common.csv_row(
            f"participation/select_{label}", 0.0,
            f"cohort={c};rounds={lscale.rounds};avg={avg:.4f};"
            f"worst={worst:.4f};t_round_eff={np.mean(t_rounds):.2f}Tdl;"
            f"min_sel={int(counts.min())};max_sel={int(counts.max())}"))
        print(rows[-1], flush=True)
    return rows


def quant_replay_rows(scale, chunk) -> list[str]:
    """Quantized-uplink replay: float32 wire vs int8 transport.

    Same data, seeds, and uniform cohort sequence — only
    ``FedConfig.transport`` differs. The int8 run uploads 1 B/param +
    one f32 scale per 128-param chunk with per-client error feedback;
    the row prices both wires per round (dtype-aware
    ``cm.uplink_bytes_per_round``) and on the §V-D Tdl axis
    (transport-scaled ``cm.round_time``), and reports whether the
    byte win arrived at matched accuracy:

      * ``bytes_ratio`` — raw/int8 uplink bytes per round; must be
        ≥ 3.5 (it is ~3.88 by construction: (1 + 4/128)/4 per param).
      * ``total_ratio`` — raw/int8 TOTAL wire bytes per round, uplink
        PLUS the schema-priced downlink (ucfl's personalized rows are a
        ``delta`` stream, so the unicast downlink compresses too); must
        be ≥ 3.0.
      * ``acc_matched`` — |avg_int8 − avg_raw| ≤ 0.01 at each run's
        argmax-average round (matched round budget).
    """
    import jax

    from repro.core.pytree import tree_count_params
    from repro.federated import simulation
    from repro.federated.transport import TransportConfig
    from repro.models import lenet

    lscale = dataclasses.replace(scale, rounds=max(12, scale.rounds))
    m = lscale.m
    c = max(2, m // 2)
    part = ParticipationConfig(cohort_size=c, seed=7)
    p = cm.SystemParams(m=m, rho=4.0, inv_mu=1.0)

    key = jax.random.PRNGKey(29)
    dkey, mkey, skey = jax.random.split(key, 3)
    data = common.scenario_data("label_shift", dkey, lscale)
    params0 = common.make_params0(mkey, lscale)
    model_bytes = 4 * tree_count_params(params0)

    res = {}
    for label, tr in (("raw", None), ("int8", TransportConfig("int8"))):
        strat = common.make_strategy("ucfl", params0, lscale,
                                     chunk_size=chunk, transport=tr)
        schema = strat.wire_schema
        h = simulation.run(strat, lenet.apply, data, skey,
                           rounds=lscale.rounds, eval_every=2,
                           participation=part)
        avg, worst = h.paired_best
        res[label] = {
            "avg": avg, "worst": worst,
            "ul": cm.uplink_bytes_per_round(model_bytes, "unicast", m,
                                            cohort_size=c, transport=tr,
                                            schema=schema),
            "dl": cm.downlink_bytes_per_round(model_bytes, "unicast", m,
                                              cohort_size=c, transport=tr,
                                              schema=schema),
            "t_round": cm.round_time(p, "unicast", cohort_size=c,
                                     transport=tr, schema=schema),
        }
    ratio = res["raw"]["ul"] / max(res["int8"]["ul"], 1)
    total_ratio = (res["raw"]["ul"] + res["raw"]["dl"]) / \
        max(res["int8"]["ul"] + res["int8"]["dl"], 1)
    dacc = res["int8"]["avg"] - res["raw"]["avg"]
    row = common.csv_row(
        "participation/quant_uplink", 0.0,
        f"cohort={c};rounds={lscale.rounds};"
        f"avg_raw={res['raw']['avg']:.4f};avg_int8={res['int8']['avg']:.4f};"
        f"worst_raw={res['raw']['worst']:.4f};"
        f"worst_int8={res['int8']['worst']:.4f};"
        f"ul_raw={res['raw']['ul']}B;ul_int8={res['int8']['ul']}B;"
        f"dl_raw={res['raw']['dl']}B;dl_int8={res['int8']['dl']}B;"
        f"bytes_ratio={ratio:.2f}x;total_ratio={total_ratio:.2f}x;"
        f"t_round_raw={res['raw']['t_round']:.2f}Tdl;"
        f"t_round_int8={res['int8']['t_round']:.2f}Tdl;"
        f"acc_matched={abs(dacc) <= 0.01};bytes_ok={ratio >= 3.5};"
        f"total_ok={total_ratio >= 3.0}")
    print(row, flush=True)
    return [row]


def byzantine_replay_rows(scale, chunk) -> list[str]:
    """20%-attacker sign-flip replay: robust rules + W-quarantine mass.

    Five runs share data, seeds, and the full-participation cohort
    sequence; only ``FedConfig.faults`` / ``robust`` / ``w_refresh``
    differ:

      * ``clean``   — no faults (the recovery target).
      * ``plain``   — attackers on, no defense (must degrade).
      * ``trimmed`` — attackers + coordinate trimmed-mean.
      * ``krum``    — attackers + multi-Krum.
      * ``refresh`` — attackers + streaming W refresh, NO robust rule:
        isolates whether re-estimated similarity weights quarantine
        poisoners by themselves (their wild uploads blow up their σ²/Δ
        stats, which should drive their mixing mass toward 0).

    Accuracy is averaged over HONEST clients only (an attacker's own
    accuracy is meaningless), paired at the argmax-average eval round.
    ``recovered`` flags best ≥ 90% of the clean run's best — the
    robustness acceptance bar. The W quarantine mass is reported per
    round (init + after every round); static-W runs keep the init value
    by construction and compress to ``(const)``.
    """
    import jax

    from repro.federated.client import evaluate
    from repro.models import lenet

    # var_batch must leave ≥ a few minibatches for the σ² estimate: one
    # batch gives σ²=0 exactly, and Eq. 9 then degenerates every client
    # to local training (W = I) — vacuously "quarantined"
    lscale = dataclasses.replace(scale, rounds=max(12, scale.rounds),
                                 var_batch=max(10, scale.n // 5))
    m = lscale.m
    n_atk = max(1, int(round(0.2 * m)))
    # full participation, but through the MASKED engine (an explicit
    # cohort array): faults/robust are cohort-slot rewrites by contract
    full_cohort = np.arange(m, dtype=np.int32)

    # §V-D pricing of the replay's round, plus the straggler-censored
    # variant: a deadline at the (m-1)-th expected arrival drops the
    # slowest client and prices the round by the deadline instead of the
    # cohort max (the engine flips the dropped slot's mask post-SGD)
    p = cm.SystemParams(m=m, rho=4.0, inv_mu=1.0)
    t_round = cm.round_time(p, "unicast", cohort_size=m)
    deadline = cm.expected_kth_compute_time(p, m - 1, m)
    t_dead, dropped = cm.deadline_round_time(p, "unicast", cohort_size=m,
                                             deadline=deadline)

    from repro.data import synthetic

    key = jax.random.PRNGKey(23)
    dkey, mkey, skey = jax.random.split(key, 3)
    # high noise makes within-client minibatch variance comparable to the
    # between-group gradient distance, so Eq. 9's W mixes inside groups
    # (~0.7 off-diagonal row mass) instead of collapsing to the identity
    data = synthetic.concept_shift(
        dkey, m=m, n=lscale.n, n_test=lscale.n_test,
        num_classes=lscale.num_classes, groups=2, hw=lscale.hw,
        channels=1, noise=2.0)
    params0 = common.make_params0(mkey, lscale)

    # adversarial attacker placement: scan the FaultConfig seed for the
    # attacker set the honest clients listen to MOST at init — the
    # hardest placement for ucfl.
    probe = common.make_strategy("ucfl", params0, lscale, chunk_size=chunk)
    _, ikey0 = jax.random.split(skey)
    w0 = probe.init(ikey0, data)["W"]

    def _init_mass(seed: int) -> float:
        cfg = fl.FaultConfig(seed=seed, byzantine_frac=0.2)
        return float(similarity.attacker_mixing_mass(
            w0, np.asarray(fl.attacker_mask(cfg, m))))

    best_seed = max(range(32), key=_init_mass)
    # attack_scale=50: per-round updates are small at bench scale, so
    # the default ×10 flip dilutes below eval granularity after the W
    # mix; ×50 makes the unguarded degradation actually measurable
    fcfg = fl.FaultConfig(seed=best_seed, byzantine_frac=0.2,
                          attack="sign_flip", attack_scale=50.0)
    atk = np.asarray(fl.attacker_mask(fcfg, m))
    honest = ~atk

    runs = {
        "clean": {},
        "plain": {"faults": fcfg},
        "trimmed": {"faults": fcfg,
                    "robust": RobustConfig(rule="trimmed_mean",
                                           trim_k=n_atk)},
        "krum": {"faults": fcfg,
                 "robust": RobustConfig(rule="multi_krum", f=n_atk)},
        "refresh": {"faults": fcfg, "w_refresh": RefreshConfig()},
    }
    results = {}
    for label, kw in runs.items():
        strat = common.make_strategy("ucfl", params0, lscale,
                                     chunk_size=chunk, **kw)
        rkeys = skey
        rkeys, ikey = jax.random.split(rkeys)
        state = strat.init(ikey, data)
        masses = [float(similarity.attacker_mixing_mass(state["W"], atk))]
        best, worst_at_best = 0.0, 0.0
        for rnd in range(1, lscale.rounds + 1):
            rkeys, rkey = jax.random.split(rkeys)
            state, _ = strat.round(state, data, rkey, full_cohort)
            masses.append(float(similarity.attacker_mixing_mass(
                state["W"], atk)))
            if rnd % 2 == 0 or rnd == lscale.rounds:
                accs = np.asarray(evaluate(
                    lenet.apply, strat.eval_params(state),
                    data.x_test, data.y_test))
                avg_h = float(accs[honest].mean())
                if avg_h >= best:
                    best, worst_at_best = avg_h, float(accs[honest].min())
        results[label] = (best, worst_at_best, masses)

    clean_best = results["clean"][0]
    rows = []
    for label, (best, worst, masses) in results.items():
        extra = ""
        if label in ("trimmed", "krum"):
            extra = (f";recovered={best >= 0.9 * clean_best}"
                     f";vs_clean={best / max(clean_best, 1e-9):.3f}")
        # per-round quarantine trajectory (static-W runs stay constant
        # by construction, so compress those to init=final)
        traj = "|".join(f"{v:.3f}" for v in masses)
        if len(set(f"{v:.3f}" for v in masses)) == 1:
            traj = f"{masses[0]:.3f}(const)"
        rows.append(common.csv_row(
            f"participation/byz_{label}", 0.0,
            f"m={m};attackers={n_atk};attack=sign_flip;"
            f"avg_honest={best:.4f};worst_honest={worst:.4f};"
            f"w_mass_per_round={traj};"
            f"t_round={t_round:.2f}Tdl;"
            f"t_deadline={t_dead:.2f}Tdl(drop={int(dropped.sum())})"
            f"{extra}"))
        print(rows[-1], flush=True)
    return rows


def _async_applied_schedule(schedule, flush_k: int) -> list[int]:
    """Host replay of the buffer dynamics: uploads applied per round.

    Mirrors the device engine exactly — each round deposits the cohort's
    real members (a client already pending re-deposits in place), and a
    flush applies the WHOLE buffer once at least ``flush_k`` pend.
    Returns 0 for deposit-only rounds. Deterministic given the cohort
    schedule, so the §V-D pricing needs no device round-trip.
    """
    pending: set = set()
    applied = []
    for co in schedule:
        if co is None or len(co) == 0:
            applied.append(0)
            continue
        pending |= set(co.members.tolist())
        if len(pending) >= flush_k:
            applied.append(len(pending))
            pending = set()
        else:
            applied.append(0)
    return applied


def _cum_round_times(schedule, p, flush_k: int, scheme: str = "unicast"):
    """Cumulative §V-D time axes (barrier vs buffered-async) for a replay.

    Rounds nobody attends cost 0 in BOTH engines (the server idles); a
    deposit-only async round still spans its arrivals (no downlink), and
    a flush round is priced by the K-th arrival + the applied batch's
    downlink instead of the cohort max + full cohort downlink.
    """
    applied = _async_applied_schedule(schedule, flush_k)
    sync_t, async_t = [], []
    for co, b in zip(schedule, applied):
        sz = 0 if co is None else len(co)
        if sz == 0:
            sync_t.append(0.0)
            async_t.append(0.0)
            continue
        sync_t.append(cm.round_time(p, scheme, cohort_size=sz))
        async_t.append(cm.async_round_time(p, scheme, cohort_size=sz,
                                           flush_k=flush_k, applied=b))
    return np.cumsum(sync_t), np.cumsum(async_t)


def async_replay_rows(scale, chunk) -> list[str]:
    """Diurnal availability replay: barrier vs buffered-async engine.

    Same data, seeds, and cohort sequence — only the server rule differs
    (``FedConfig.async_buffer``). The row reports TIME-TO-ACCURACY under
    the §V-D comm model: the simulated wall-clock at which each engine
    first reaches the barrier run's best average accuracy (the async
    engine must get there strictly earlier — it stops paying the
    straggler max — with worst-node accuracy within 0.02).
    """
    import jax

    from repro.federated import simulation
    from repro.models import lenet

    lscale = dataclasses.replace(scale, rounds=max(16, 2 * scale.rounds))
    m = lscale.m
    c = max(2, m // 2)
    flush_k = max(2, c // 2)
    trace = pp.diurnal_trace(m, period=6, peak=0.95, trough=0.15, seed=5)
    avail = ParticipationConfig(cohort_size=c, sampler="availability",
                                availability=trace, seed=3)
    # heavy straggler tail (inv_mu=4): the regime where waiting for the
    # K-th of c arrivals instead of the c-th actually buys wall-clock
    p = cm.SystemParams(m=m, rho=4.0, inv_mu=4.0)
    schedule = pp.cohort_schedule(avail, lscale.rounds, m)
    sync_cum, async_cum = _cum_round_times(schedule, p, flush_k)

    key = jax.random.PRNGKey(11)
    dkey, mkey, skey = jax.random.split(key, 3)
    data = common.scenario_data("label_shift", dkey, lscale)
    params0 = common.make_params0(mkey, lscale)
    hists = {}
    for label, acfg in (("sync", None), ("async", AsyncConfig(
            flush_k=flush_k, alpha=0.5))):
        strat = common.make_strategy("ucfl", params0, lscale,
                                     chunk_size=chunk, async_buffer=acfg)
        hists[label] = simulation.run(strat, lenet.apply, data, skey,
                                      rounds=lscale.rounds, eval_every=2,
                                      participation=avail)

    sync_h, async_h = hists["sync"], hists["async"]
    best = int(np.argmax(sync_h.avg_acc))
    target = sync_h.avg_acc[best]
    t_sync = float(sync_cum[sync_h.rounds[best] - 1])
    reached = [i for i, a in enumerate(async_h.avg_acc) if a >= target]
    rows = []
    if reached:
        i = reached[0]
        t_async = float(async_cum[async_h.rounds[i] - 1])
        rows.append(common.csv_row(
            "participation/async_vs_sync", 0.0,
            f"cohort={c};flush_k={flush_k};avail=diurnal;"
            f"acc_target={target:.4f};t_sync={t_sync:.1f}Tdl;"
            f"t_async={t_async:.1f}Tdl;"
            f"speedup={t_sync / max(t_async, 1e-9):.2f}x;"
            f"worst_sync={sync_h.worst_acc[best]:.4f};"
            f"worst_async={async_h.worst_acc[i]:.4f}"))
    else:
        rows.append(common.csv_row(
            "participation/async_vs_sync", 0.0,
            f"cohort={c};flush_k={flush_k};avail=diurnal;"
            f"acc_target={target:.4f};t_sync={t_sync:.1f}Tdl;"
            f"t_async=UNREACHED;async_best={max(async_h.avg_acc):.4f}"))
    print(rows[-1], flush=True)
    return rows
