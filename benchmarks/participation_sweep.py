"""Partial-participation engine benchmark.

Trains ucfl + fedavg at several cohort fractions (uniform sampler, plus
one weighted and one round-robin row) with a client chunk bound, and
reports accuracy alongside the cohort-aware §V-D round cost — the
accuracy-vs-wireless-resources trade this PR's engine opens up.
"""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import comm_model as cm
from repro.federated.participation import ParticipationConfig

FRACTIONS = (1.0, 0.5, 0.25)
ALGOS = {"fedavg": ("broadcast", None), "ucfl": ("unicast", None)}


def run(scale) -> list[str]:
    rows = []
    p = cm.SystemParams(m=scale.m, rho=4.0, inv_mu=1.0)
    chunk = max(2, scale.m // 4)
    for algo, (scheme, k) in ALGOS.items():
        for frac in FRACTIONS:
            part = (None if frac == 1.0
                    else ParticipationConfig(fraction=frac))
            c = max(1, round(frac * scale.m))
            t0 = time.time()
            res = common.run_trials("covariate_label_shift", algo, scale,
                                    participation=part, chunk_size=chunk)
            dt = (time.time() - t0) * 1e6 / max(scale.rounds * scale.trials, 1)
            rt = cm.round_time(p, scheme, k, cohort_size=c)
            rows.append(common.csv_row(
                f"participation/{algo}_f{frac}", dt,
                f"cohort={c};chunk={chunk};acc={res['avg']:.4f};"
                f"t_round={rt:.2f}Tdl"))
            print(rows[-1], flush=True)
    for sampler in ("weighted", "round_robin"):
        part = ParticipationConfig(fraction=0.5, sampler=sampler)
        res = common.run_trials("covariate_label_shift", "ucfl", scale,
                                participation=part, chunk_size=chunk)
        rows.append(common.csv_row(
            f"participation/ucfl_{sampler}", 0.0,
            f"fraction=0.5;acc={res['avg']:.4f}"))
        print(rows[-1], flush=True)
    return rows
