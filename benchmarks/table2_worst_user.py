"""Paper Table II — worst-user accuracy across algorithms."""
from __future__ import annotations

import time

from benchmarks import common

ALGOS = ["ditto", "fedavg", "oracle", "cfl", "fedfomo", "pfedme", "ucfl",
         "ucfl_k4"]
SCENARIOS = ["label_shift", "covariate_label_shift", "concept_shift"]


def run(scale) -> list[str]:
    rows = []
    for scen in SCENARIOS:
        for algo in ALGOS:
            if scen == "label_shift" and algo == "oracle":
                continue
            t0 = time.time()
            res = common.run_trials(scen, algo, scale)
            dt = (time.time() - t0) * 1e6 / max(scale.rounds * scale.trials, 1)
            rows.append(common.csv_row(
                f"table2/{scen}/{algo}", dt,
                f"worst_acc={res['worst']:.4f}"))
            print(rows[-1], flush=True)
    return rows
