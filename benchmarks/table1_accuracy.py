"""Paper Table I — average test accuracy, 3 scenarios x algorithms."""
from __future__ import annotations

import time

from benchmarks import common

ALGOS = ["ucfl", "ucfl_k4", "fedavg", "fedprox", "scaffold", "ditto",
         "pfedme", "local", "oracle"]
SCENARIOS = ["label_shift", "covariate_label_shift", "concept_shift"]


def run(scale) -> list[str]:
    rows = []
    for scen in SCENARIOS:
        for algo in ALGOS:
            if scen == "label_shift" and algo == "oracle":
                continue  # paper: no oracle for label shift (no true groups)
            t0 = time.time()
            res = common.run_trials(scen, algo, scale)
            dt = (time.time() - t0) * 1e6 / max(scale.rounds * scale.trials, 1)
            rows.append(common.csv_row(
                f"table1/{scen}/{algo}", dt,
                f"avg_acc={res['avg']:.4f}±{res['avg_std']:.4f};"
                f"worst_acc={res['worst']:.4f}±{res['worst_std']:.4f}"))
            print(rows[-1], flush=True)
    return rows
