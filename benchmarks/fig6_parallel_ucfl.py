"""Paper Fig. 6 — proposed UCFL vs parallel (exact Eq. 4) user-centric FL."""
from __future__ import annotations

import time

from benchmarks import common

ALGOS = ["ucfl", "ucfl_parallel", "fedavg", "local", "oracle"]


def run(scale) -> list[str]:
    rows = []
    for algo in ALGOS:
        t0 = time.time()
        res = common.run_trials("concept_shift", algo, scale)
        dt = (time.time() - t0) * 1e6 / max(scale.rounds * scale.trials, 1)
        rows.append(common.csv_row(
            f"fig6/concept_shift/{algo}", dt,
            f"avg_acc={res['avg']:.4f}"))
        print(rows[-1], flush=True)
    return rows
