"""Benchmark regression gate over ``BENCH_round_engine.json``.

Turns the ROADMAP's engine targets into enforced checks:

  * shape stability — the ``availability`` regime (eligible-set size
    varies per round) must stay within ``--max-ratio`` (default 1.2) of
    the fixed-size ``cohort`` regime's steady-state round time. A ratio
    above the gate means padded availability cohorts stopped reusing the
    fixed cohort's compiled round shape — the regression the fixed-shape
    masked engine exists to prevent.
  * refresh overhead — the ``refresh`` regime (streaming W refresh on,
    ``FedConfig.w_refresh``) must stay within ``--max-refresh-ratio``
    (default 1.2) of the plain cohort round. The refresh runs inside the
    same jitted fixed-shape round; a ratio above the gate means it broke
    the one-compilation guarantee or grew the round body past the cheap
    on-device buffer-fold it is specified to be.
  * async overhead — the ``async`` regime (buffered-async server on,
    ``FedConfig.async_buffer``, flushing every measured round) must stay
    within ``--max-async-ratio`` (default 1.2) of the barrier cohort
    round. Deposit + staleness-weighted flush are one jitted fixed-shape
    round with donated buffers; a ratio above the gate means a
    recompile, a host sync, or a flush that stopped reusing the fused
    masked mix-scatter path. (The §V-D wall-clock WIN of async is priced
    by the comm model in ``participation_sweep.py`` — this gate only
    bounds its host-compute overhead.)
  * faults overhead — the ``faults`` regime (fault injection + finite
    guard + trimmed-mean robust rule, ``FedConfig.faults`` /
    ``FedConfig.robust``) must stay within ``--max-faults-ratio``
    (default 1.2) of the plain cohort round. The whole
    inject→guard→robust upload stage is traced into the same jitted
    fixed-shape round; a ratio above the gate means the stage introduced
    a recompile, a host sync, or an O(c²·d)-heavy rule on the default
    path.
  * flat-tree overhead — the ``flat_tree`` regime (UCFL on a LeNet whose
    every leaf is split in half: 2x the pytree leaves, identical FLOPs)
    must stay within ``--max-flat-ratio`` (default 1.2) of the plain
    cohort round. The flat-slab layout ravels any pytree into one
    (m, d_aligned) matrix at construction, so leaf count must be
    invisible to the mix/scatter; a ratio above the gate means some
    round component regressed to per-leaf work.
  * quant overhead — the ``quant`` regime (int8 quantized uplink
    transport + error feedback, ``FedConfig.transport``) must stay
    within ``--max-quant-ratio`` (default 1.3) of the plain cohort
    round. Quantize→dequantize→EF is traced into the same jitted
    fixed-shape round with a donated EF slab; a ratio above the gate
    means a recompile, a host sync, or EF traffic that outgrew the
    cheap elementwise stage it is specified to be. (The ~3.88x UL byte
    win it buys is asserted by ``participation_sweep.py``'s
    quantized-uplink replay, not here.)
  * quant-multi overhead — the ``quant_multi`` regime (scaffold's
    two-stream uplink wire + compressed two-stream downlink, int8 on
    every delta stream) must stay within ``--max-quant-multi-ratio``
    (default 1.3) of the ``multi`` regime — the SAME scaffold config
    with ``transport=None`` — so the gate isolates the per-stream
    WireSchema stage cost. A ratio above the gate means the per-slice
    fold over the concatenated wire slab stopped being a cheap
    elementwise stage inside the one jitted round.
  * hier overhead — the ``hier`` regime (clustered ucfl k=2 under a
    two-edge ``FedConfig.topology``: tier-1 per-edge partial sums,
    tier-2 combine) must stay within ``--max-hier-ratio`` (default 1.3)
    of the plain cohort round. The whole two-tier mix is traced into
    the same jitted fixed-shape round over the donated slab; a ratio
    above the gate means the edge partition introduced a recompile, a
    host sync, or per-edge work that outgrew the O(c·d + E·k·d) mix it
    is specified to be. (The PS-side byte win the tier buys is asserted
    by ``participation_sweep.py``'s hierarchical replay, not here.)
  * m-scaling — a fixed-cohort round must cost O(c·d), not O(m·d). The
    ``m_scaling_ratio`` (round time at m=512 over m=8, same cohort size)
    must stay within ``--max-mscale-ratio`` (default 1.3); above it some
    server component regressed to touching every client row per round.

Run the benchmark first, then the gate::

    PYTHONPATH=src python benchmarks/run.py --only round_engine
    PYTHONPATH=src python benchmarks/check_regression.py --max-ratio 1.2

Exit status 0 = within both gates, 1 = regression (or missing/invalid
JSON). CI's ``bench-smoke`` job runs exactly this pair and uploads the
JSON as a workflow artifact.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_round_engine.json"


def _gate(payload, key, baseline: str, regime: str, max_ratio: float,
          why: str, section: str = "results") -> bool:
    """Print one ratio against its gate; True = within the gate."""
    ratio = float(payload[key])
    base = payload.get(section, {}).get(baseline, {}).get("round_us")
    reg = payload.get(section, {}).get(regime, {}).get("round_us")
    print(f"{key} = {ratio:.3f} ({regime} {reg} us / {baseline} {base} us; "
          f"gate <= {max_ratio})")
    if ratio > max_ratio:
        print(f"check_regression: FAIL — {key} {ratio:.3f} exceeds the "
              f"{max_ratio} gate ({why})", file=sys.stderr)
        return False
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", type=pathlib.Path, default=DEFAULT_JSON,
                    help="path to BENCH_round_engine.json")
    ap.add_argument("--max-ratio", type=float, default=1.2,
                    help="gate on availability_over_cohort_ratio")
    ap.add_argument("--max-refresh-ratio", type=float, default=1.2,
                    help="gate on refresh_over_cohort_ratio")
    ap.add_argument("--max-async-ratio", type=float, default=1.2,
                    help="gate on async_over_cohort_ratio")
    ap.add_argument("--max-faults-ratio", type=float, default=1.2,
                    help="gate on faults_over_cohort_ratio")
    ap.add_argument("--max-flat-ratio", type=float, default=1.2,
                    help="gate on flat_tree_over_cohort_ratio")
    ap.add_argument("--max-quant-ratio", type=float, default=1.3,
                    help="gate on quant_over_cohort_ratio")
    ap.add_argument("--max-quant-multi-ratio", type=float, default=1.3,
                    help="gate on quant_multi_over_multi_ratio (scaffold "
                         "two-stream wire + compressed downlink over the "
                         "same scaffold config with transport off)")
    ap.add_argument("--max-hier-ratio", type=float, default=1.3,
                    help="gate on hier_over_cohort_ratio (clustered ucfl "
                         "under a two-edge topology over the plain cohort "
                         "round)")
    ap.add_argument("--max-mscale-ratio", type=float, default=1.3,
                    help="gate on m_scaling_ratio (fixed-cohort round "
                         "time at m=512 over m=8)")
    args = ap.parse_args(argv)

    try:
        payload = json.loads(args.json.read_text())
        ok = _gate(payload, "availability_over_cohort_ratio", "cohort",
                   "availability", args.max_ratio,
                   "the availability sampler's padded cohorts are no "
                   "longer reusing the fixed cohort's compiled round")
        ok &= _gate(payload, "refresh_over_cohort_ratio", "cohort",
                    "refresh", args.max_refresh_ratio,
                    "the streaming W refresh is no longer a cheap "
                    "in-round buffer fold — check for a recompile or a "
                    "host sync in the refresh path")
        ok &= _gate(payload, "async_over_cohort_ratio", "cohort",
                    "async", args.max_async_ratio,
                    "the buffered-async round is no longer a cheap "
                    "deposit + cond-flush on top of the barrier mix — "
                    "check for a recompile, a host sync, or a flush "
                    "path that stopped reusing the fused mix-scatter")
        ok &= _gate(payload, "faults_over_cohort_ratio", "cohort",
                    "faults", args.max_faults_ratio,
                    "the fault-injection + robust-aggregation upload "
                    "stage is no longer a cheap in-round slab transform "
                    "— check for a recompile, a host sync, or a robust "
                    "rule that left the fused masked mix-scatter path")
        ok &= _gate(payload, "flat_tree_over_cohort_ratio", "cohort",
                    "flat_tree", args.max_flat_ratio,
                    "a fragmented (2x-leaf) pytree slowed the round — "
                    "the flat-slab layout is supposed to make leaf "
                    "count invisible to the mix/scatter; check for "
                    "per-leaf work that crept back into the round body")
        ok &= _gate(payload, "quant_over_cohort_ratio", "cohort",
                    "quant", args.max_quant_ratio,
                    "the quantized-uplink transport stage is no longer "
                    "a cheap in-round elementwise quantize→dequantize→"
                    "EF fold — check for a recompile, a host sync, or "
                    "an EF path that left the fused masked mix-scatter")
        ok &= _gate(payload, "quant_multi_over_multi_ratio", "multi",
                    "quant_multi", args.max_quant_multi_ratio,
                    "the multi-stream wire (scaffold's model + control "
                    "uplink streams and the compressed two-stream "
                    "downlink) is no longer a cheap per-slice "
                    "quantize→dequantize→EF fold over the concatenated "
                    "wire slab — check for a recompile, a host sync, or "
                    "per-stream work that left the one jitted round")
        ok &= _gate(payload, "hier_over_cohort_ratio", "cohort",
                    "hier", args.max_hier_ratio,
                    "the two-tier hierarchical mix is no longer a cheap "
                    "in-round partition + per-edge partial-sum fold — "
                    "check for a recompile, a host sync, or an edge "
                    "partition that left the one jitted round")
        ok &= _gate(payload, "m_scaling_ratio", "m8", "m512",
                    args.max_mscale_ratio,
                    "a fixed-cohort round's time grew with the client "
                    "count m — some server component regressed to "
                    "O(m·d): a broadcast mix, a padding copy of the "
                    "stacked state, or a host sync touching every row",
                    section="m_scaling")
    except (OSError, KeyError, ValueError) as e:
        print(f"check_regression: cannot read ratios from {args.json}: {e}",
              file=sys.stderr)
        return 1

    if not ok:
        return 1
    print("check_regression: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
