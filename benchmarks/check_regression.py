"""Benchmark regression gate over ``BENCH_round_engine.json``.

Turns the ROADMAP's shape-stability target into an enforced check: the
``availability`` regime (eligible-set size varies per round) must stay
within ``--max-ratio`` (default 1.2) of the fixed-size ``cohort``
regime's steady-state round time. A ratio above the gate means padded
availability cohorts stopped reusing the fixed cohort's compiled round
shape — the regression the fixed-shape masked engine exists to prevent.

Run the benchmark first, then the gate::

    PYTHONPATH=src python benchmarks/run.py --only round_engine
    PYTHONPATH=src python benchmarks/check_regression.py --max-ratio 1.2

Exit status 0 = within the gate, 1 = regression (or missing/invalid
JSON). CI's ``bench-smoke`` job runs exactly this pair and uploads the
JSON as a workflow artifact.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_round_engine.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", type=pathlib.Path, default=DEFAULT_JSON,
                    help="path to BENCH_round_engine.json")
    ap.add_argument("--max-ratio", type=float, default=1.2,
                    help="gate on availability_over_cohort_ratio")
    args = ap.parse_args(argv)

    try:
        payload = json.loads(args.json.read_text())
        ratio = float(payload["availability_over_cohort_ratio"])
    except (OSError, KeyError, ValueError) as e:
        print(f"check_regression: cannot read ratio from {args.json}: {e}",
              file=sys.stderr)
        return 1

    cohort = payload.get("results", {}).get("cohort", {}).get("round_us")
    avail = payload.get("results", {}).get("availability", {}).get("round_us")
    print(f"availability_over_cohort_ratio = {ratio:.3f} "
          f"(availability {avail} us / cohort {cohort} us; "
          f"gate <= {args.max_ratio})")
    if ratio > args.max_ratio:
        print(f"check_regression: FAIL — ratio {ratio:.3f} exceeds the "
              f"{args.max_ratio} shape-stability gate (the availability "
              "sampler's padded cohorts are no longer reusing the fixed "
              "cohort's compiled round)", file=sys.stderr)
        return 1
    print("check_regression: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
