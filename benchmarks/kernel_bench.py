"""PS-kernel micro-benchmarks: mix_aggregate / masked_mix_scatter /
pairwise_delta / kmeans_assign. CPU timings use the jnp reference path
(the Pallas kernels target TPU; interpret-mode timing is not meaningful),
plus the analytic HBM-bytes each kernel streams on TPU (the relevant
roofline quantity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops


def _time(fn, *args, iters=5):
    # one warm-up call (jax.block_until_ready handles tuples and pytrees)
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run(scale) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    for m, d in [(16, 1 << 20), (32, 1 << 22)]:
        w = jnp.asarray(rng.normal(size=(m, m)).astype(np.float32))
        t = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        us = _time(lambda: ops.mix_aggregate(w, t, impl="ref"))
        hbm = (m * d * 4 * 2 + m * m * 4)  # read Θ + write Θ' + W
        rows.append(common.csv_row(
            f"kernel/mix_aggregate/m{m}_d{d}", us,
            f"tpu_hbm_bytes={hbm};tpu_roofline_us={hbm / 819e9 * 1e6:.1f}"))
        print(rows[-1], flush=True)
        us = _time(lambda: ops.pairwise_delta(t, impl="ref"))
        hbm = m * d * 4 + m * m * 4
        rows.append(common.csv_row(
            f"kernel/pairwise_delta/m{m}_d{d}", us,
            f"tpu_hbm_bytes={hbm};tpu_roofline_us={hbm / 819e9 * 1e6:.1f}"))
        print(rows[-1], flush=True)
        # fused cohort mix+scatter: c = m/2 cohort slots into the (m, d)
        # state. The slab kernel streams the full state through VMEM
        # (copy-through of untouched rows) plus the theta read, so HBM
        # traffic is (2·m + c)·d floats — the fusion saves the mix-output
        # allocation and the separate scatter pass, not the state read.
        c = max(m // 2, 1)
        wc = jnp.asarray(rng.normal(size=(c, c)).astype(np.float32))
        theta = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
        idx = jnp.asarray(np.sort(rng.choice(m, size=c, replace=False))
                          .astype(np.int32))
        mask = jnp.ones((c,), bool)
        # the eager ref path is functional (allocates its output), so the
        # state buffer can be reused across timed iterations; only the
        # jitted pallas path donates it
        full_state = jnp.array(t)
        us = _time(lambda: ops.masked_mix_scatter(
            wc, theta, idx, mask, full_state, impl="ref"))
        hbm = (2 * m + c) * d * 4 + c * c * 4 + c * 8
        rows.append(common.csv_row(
            f"kernel/masked_mix_scatter/m{m}_c{c}_d{d}", us,
            f"tpu_hbm_bytes={hbm};tpu_roofline_us={hbm / 819e9 * 1e6:.1f}"))
        print(rows[-1], flush=True)
    pts = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    us = _time(lambda: ops.kmeans_assign(pts, cen, impl="ref"))
    rows.append(common.csv_row("kernel/kmeans_assign/m128_k8", us,
                               "fits_vmem=True"))
    print(rows[-1], flush=True)
    return rows
