"""PS-kernel micro-benchmarks: mix_aggregate / pairwise_delta /
kmeans_assign. CPU timings use the jnp reference path (the Pallas kernels
target TPU; interpret-mode timing is not meaningful), plus the analytic
HBM-bytes each kernel streams on TPU (the relevant roofline quantity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run(scale) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    for m, d in [(16, 1 << 20), (32, 1 << 22)]:
        w = jnp.asarray(rng.normal(size=(m, m)).astype(np.float32))
        t = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        us = _time(lambda: ops.mix_aggregate(w, t, impl="ref"))
        hbm = (m * d * 4 * 2 + m * m * 4)  # read Θ + write Θ' + W
        rows.append(common.csv_row(
            f"kernel/mix_aggregate/m{m}_d{d}", us,
            f"tpu_hbm_bytes={hbm};tpu_roofline_us={hbm / 819e9 * 1e6:.1f}"))
        print(rows[-1], flush=True)
        us = _time(lambda: ops.pairwise_delta(t, impl="ref"))
        hbm = m * d * 4 + m * m * 4
        rows.append(common.csv_row(
            f"kernel/pairwise_delta/m{m}_d{d}", us,
            f"tpu_hbm_bytes={hbm};tpu_roofline_us={hbm / 819e9 * 1e6:.1f}"))
        print(rows[-1], flush=True)
    pts = jnp.asarray(rng.normal(size=(128, 128)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    us = _time(lambda: ops.kmeans_assign(pts, cen, impl="ref"))
    rows.append(common.csv_row("kernel/kmeans_assign/m128_k8", us,
                               "fits_vmem=True"))
    print(rows[-1], flush=True)
    return rows
