"""Shared benchmark scaffolding.

Paper-scale settings (m=20/100, 5 trials, LeNet-5 on EMNIST/CIFAR) are
reproduced in *structure*; the default "fast" scale is sized for this
1-core CPU container (documented in EXPERIMENTS.md). ``--full`` restores
paper-scale m/rounds/trials.
"""
from __future__ import annotations

import dataclasses
import functools

import jax

from repro.core import FedConfig, REGISTRY, ucfl
from repro.data import synthetic
from repro.federated import simulation
from repro.models import lenet


@dataclasses.dataclass(frozen=True)
class BenchScale:
    m: int = 8
    n: int = 150
    n_test: int = 40
    num_classes: int = 8
    hw: tuple = (16, 16)
    rounds: int = 10
    trials: int = 1
    groups: int = 4
    batch_size: int = 50
    var_batch: int = 50


FAST = BenchScale()
FULL = BenchScale(m=20, n=500, n_test=100, num_classes=20, hw=(28, 28),
                  rounds=60, trials=5)


def scenario_data(name: str, key, s: BenchScale):
    if name == "label_shift":
        return synthetic.label_shift(
            key, m=s.m, n=s.n, n_test=s.n_test, num_classes=s.num_classes,
            alpha=0.4, hw=s.hw)
    if name == "covariate_label_shift":
        return synthetic.covariate_label_shift(
            key, m=s.m, n=s.n, n_test=s.n_test, num_classes=s.num_classes,
            alpha=8.0, groups=s.groups, hw=s.hw)
    if name == "concept_shift":
        return synthetic.concept_shift(
            key, m=s.m, n=s.n, n_test=s.n_test,
            num_classes=max(s.num_classes, 6) if s.hw[0] <= 16 else 10,
            groups=s.groups, hw=s.hw, channels=1, noise=0.8)
    raise ValueError(name)


def make_params0(key, s: BenchScale, num_classes=None):
    return lenet.init(key, input_hw=s.hw, channels=1,
                      num_classes=num_classes or s.num_classes)


def make_strategy(name: str, params0, s: BenchScale, *, chunk_size=None,
                  mesh=None, w_refresh=None, async_buffer=None, faults=None,
                  robust=None, transport=None, topology=None, selection=None,
                  **kw):
    cfg = FedConfig(batch_size=s.batch_size, chunk_size=chunk_size, mesh=mesh,
                    w_refresh=w_refresh, async_buffer=async_buffer,
                    faults=faults, robust=robust, transport=transport,
                    topology=topology, selection=selection)
    if name == "ucfl":
        return ucfl.make_ucfl(lenet.apply, params0, cfg,
                              var_batch_size=s.var_batch, **kw)
    if name.startswith("ucfl_k"):
        return ucfl.make_ucfl(lenet.apply, params0, cfg,
                              num_streams=int(name[6:]),
                              var_batch_size=s.var_batch, **kw)
    if name == "ucfl_parallel":
        return REGISTRY["ucfl_parallel"](lenet.apply, params0, cfg,
                                         var_batch_size=s.var_batch)
    if name in ("scaffold", "pfedme"):
        # keep each maker's paper-footnote local-solver defaults (lr,
        # momentum, epochs, batch size) but thread the ENGINE knobs —
        # dropping cfg here used to silently ignore transport/mesh/faults
        import inspect

        base = inspect.signature(REGISTRY[name]).parameters["cfg"].default
        cfg = dataclasses.replace(
            base, chunk_size=chunk_size, mesh=mesh, w_refresh=w_refresh,
            async_buffer=async_buffer, faults=faults, robust=robust,
            transport=transport, topology=topology, selection=selection)
        return REGISTRY[name](lenet.apply, params0, cfg, **kw)
    return REGISTRY[name](lenet.apply, params0, cfg, **kw)


def num_classes_for(scenario: str, s: BenchScale) -> int:
    if scenario == "concept_shift" and s.hw[0] <= 16:
        return max(s.num_classes, 6)
    return s.num_classes


def run_trials(scenario: str, strat_name: str, s: BenchScale, *, seed=0,
               participation=None, **kw):
    """Mean/std over trials of the (avg, worst) pair at the argmax-avg
    round (one model per trial, matching Tables 1/2)."""
    import numpy as np

    finals, worsts, hists = [], [], []
    for t in range(s.trials):
        key = jax.random.PRNGKey(seed + 997 * t)
        dkey, mkey, skey = jax.random.split(key, 3)
        data = scenario_data(scenario, dkey, s)
        params0 = make_params0(mkey, s, num_classes_for(scenario, s))
        strat = make_strategy(strat_name, params0, s, **kw)
        h = simulation.run(strat, lenet.apply, data, skey, rounds=s.rounds,
                           eval_every=max(s.rounds // 4, 1),
                           participation=participation)
        avg, worst = h.paired_best
        finals.append(avg)
        worsts.append(worst)
        hists.append(h)
    return {
        "avg": float(np.mean(finals)), "avg_std": float(np.std(finals)),
        "worst": float(np.mean(worsts)),
        # the worst-node headline needs its spread alongside avg_std
        "worst_std": float(np.std(worsts)), "hists": hists,
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
