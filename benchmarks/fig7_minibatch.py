"""Paper Fig. 7 — effect of the variance-estimation minibatch size."""
from __future__ import annotations

import dataclasses
import time

from benchmarks import common


def run(scale) -> list[str]:
    rows = []
    sizes = [10, 25, 50, 100]
    for scen in ["label_shift", "covariate_label_shift"]:
        for nb in sizes:
            if nb > scale.n:
                continue
            t0 = time.time()
            s2 = dataclasses.replace(scale, var_batch=nb)
            res = common.run_trials(scen, "ucfl", s2)
            dt = (time.time() - t0) * 1e6 / max(scale.rounds * scale.trials, 1)
            rows.append(common.csv_row(
                f"fig7/{scen}/var_batch={nb}", dt,
                f"avg_acc={res['avg']:.4f}"))
            print(rows[-1], flush=True)
    return rows
