"""Round-engine steady-state benchmark: dense vs cohort vs padded-availability.

Measures the per-round wall time of the jitted round in three regimes:

  * ``dense``          — full participation (the PR 1 legacy path; the
                         donated/fused cohort engine must not slow it).
  * ``cohort``         — fixed-size uniform cohort (one compiled shape).
  * ``availability``   — a diurnal-style trace whose eligible-set size
                         varies per round. Pre-padding, every distinct
                         size re-jitted the round inside the timed
                         region; the fixed-shape masked engine compiles
                         once, so this should sit within ~1.2x of the
                         fixed-size cohort round.
  * ``refresh``        — the fixed-size cohort regime with the streaming
                         W refresh on (``FedConfig.w_refresh``). The
                         refresh runs inside the same jitted round (one
                         compiled shape, donated buffers), so it must
                         also sit within ~1.2x of the plain cohort round
                         — the second ratio the CI gate enforces.
  * ``faults``         — the fixed-size cohort regime with fault
                         injection AND a robust rule on
                         (``FedConfig.faults`` 25% sign-flip attackers +
                         10% upload drops, ``FedConfig.robust``
                         trimmed-mean). Injection, finite guard and the
                         trimmed-mean stage all run inside the same
                         jitted round (one compiled shape), so this too
                         must sit within ~1.2x of the plain cohort round
                         — the fourth CI ratio gate.
  * ``flat_tree``      — the fixed-size cohort regime on a FRAGMENTED
                         LeNet: every parameter leaf is split in half
                         along axis 0 (2x the leaves, identical FLOPs —
                         the apply recombines with ``jnp.concatenate``).
                         The flat-slab state layout ravels any pytree
                         into ONE (m, d_aligned) matrix at strategy
                         construction, so leaf count must not leak into
                         the round: same fused masked mix-scatter, same
                         compiled shape, within ~1.2x of the plain
                         cohort round (the fifth CI ratio gate). Before
                         the slab, each extra leaf added a gather +
                         scatter pair per round.
  * ``quant``          — the fixed-size cohort regime with quantized
                         uplink transport on (``FedConfig.transport``,
                         int8 per-chunk-scaled deltas + error
                         feedback). Quantize→dequantize→EF runs inside
                         the same jitted round (one compiled shape,
                         donated params + EF slab), so host compute
                         must stay within ~1.3x of the plain cohort
                         round (the sixth CI ratio gate; the slightly
                         looser gate covers the extra EF slab traffic).
                         The WIRE win it buys (~3.88x fewer UL bytes)
                         is priced by the comm model in
                         ``participation_sweep.py``, not here.
  * ``quant_multi``    — the multi-STREAM wire: SCAFFOLD with int8 on
                         both uplink streams (model delta + control
                         delta, each with its own EF slice) AND the
                         compressed two-stream downlink (server-side EF
                         row). Ratioed against ``multi`` — the same
                         scaffold config with ``transport=None`` — so
                         the gate isolates the per-stream stage cost
                         from scaffold-vs-ucfl differences. Must stay
                         within ~1.3x (the seventh CI ratio gate).
  * ``hier``           — the fixed-size cohort regime on CLUSTERED ucfl
                         (k=2) with a two-edge ``FedConfig.topology``:
                         the tier-1 per-edge partial sums, the tier-2
                         combine and the edge one-hot partition all run
                         inside the same jitted round (one compiled
                         shape, donated slab), so the tiered round must
                         stay within ~1.3x of the plain cohort round —
                         the ``--max-hier-ratio`` CI gate. The PS-side
                         byte win the tier buys (E·k edge aggregates vs
                         c client uploads on the backhaul) is priced by
                         the comm model in ``participation_sweep.py``,
                         not here.
  * ``async``          — the fixed-size cohort regime with the
                         buffered-async server on
                         (``FedConfig.async_buffer``, flush_k = half the
                         cohort so every round deposits AND flushes —
                         the most expensive dynamics). Deposit + cond
                         flush run inside the same jitted round (one
                         compiled shape, donated params + buffer), so
                         this too must sit within ~1.2x of the barrier
                         cohort round — the third CI ratio gate. Note
                         this measures HOST compute per round; the §V-D
                         win async buys (flush time replacing the
                         straggler max) is priced by the comm model in
                         ``participation_sweep.py``, not here.

When the host exposes multiple devices (e.g. under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the CI
``bench-smoke`` recipe), the fixed-size cohort regime is additionally
measured at each power-of-two shard count (``FedConfig(mesh=n)``) so the
shard-scaling trajectory is visible PR-over-PR. Forced CPU "devices"
share the same cores, so these rows track sharding *overhead* shape
stability, not real speedup — the speedup story needs real chips.

Besides the CSV rows, :func:`run` dumps ``BENCH_round_engine.json`` at
the repo root; ``benchmarks/check_regression.py`` turns its
``availability_over_cohort_ratio`` into the CI regression gate.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import FedConfig, REGISTRY, ucfl
from repro.core.aggregation import RobustConfig
from repro.core.similarity import RefreshConfig
from repro.federated import participation as part
from repro.federated import simulation
from repro.federated.async_buffer import AsyncConfig
from repro.federated.faults import FaultConfig
from repro.federated.topology import Topology
from repro.federated.transport import TransportConfig
from repro.models import lenet

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_round_engine.json"


def _diurnal_trace(m: int, period: int = 6) -> np.ndarray:
    """Deterministic availability trace with a varying eligible count."""
    rng = np.random.default_rng(7)
    trace = np.zeros((m, period), bool)
    for t in range(period):
        up = max(1, int(m * (0.3 + 0.6 * abs(np.sin(np.pi * t / period)))))
        trace[rng.choice(m, size=up, replace=False), t] = True
    return trace


def _interleaved_rounds_us(entries, data, rounds: int) -> dict:
    """Interleaved MIN wall time per round for several regimes.

    ``entries`` is a list of ``(name, strategy, participation)``. Round
    r of EVERY regime is timed back-to-back inside the same wall-clock
    window (each sample bracketed by ``block_until_ready``), so a slow
    machine phase on a shared runner inflates all regimes alike and the
    cohort/availability *ratio* the CI gate enforces stays robust —
    sequential per-regime windows wobbled the ratio up to ~2x under
    contention. The order within the window ROTATES by one slot each
    round: a fixed order gave each regime a fixed predecessor, and
    running right after an identical compiled program (availability
    after cohort) measured systematically warmer than running after a
    different one, skewing the gated ratio by up to ~1.8x. Each regime
    reports its min: the round is deterministic
    compute, so the fastest observation is the best estimate of the
    uncontended cost. No eval pass in the timed region (simulation.run
    evaluates at least once inside its timer), and compilation is
    excluded via warm-ups on state copies (the masked round donates its
    buffers).
    """
    m = data.num_clients
    states, keys = {}, {}
    for name, strat, pcfg in entries:
        key = jax.random.PRNGKey(1)
        key, ikey = jax.random.split(key)
        states[name] = strat.init(ikey, data)
        keys[name] = key
        wcohort = part.sample_cohort(pcfg, 1, m, data.n)
        wstate, _ = strat.round(
            simulation.donation_safe_copy(states[name]), data,
            jax.random.fold_in(key, 0x5EED), wcohort)
        jax.block_until_ready(wstate)
        del wstate
    samples = {name: [] for name, _, _ in entries}
    for rnd in range(1, rounds + 1):
        offset = rnd % len(entries)
        for name, strat, pcfg in entries[offset:] + entries[:offset]:
            keys[name], rkey = jax.random.split(keys[name])
            cohort = part.sample_cohort(pcfg, rnd, m, data.n)
            if cohort is not None and len(cohort) == 0:
                continue
            t0 = time.time()
            states[name], _ = strat.round(states[name], data, rkey, cohort)
            jax.block_until_ready(states[name])
            samples[name].append(time.time() - t0)
    return {name: float(np.min(ts)) * 1e6 for name, ts in samples.items()}


def _fragmented_lenet(params0):
    """LeNet with every leaf split in half along axis 0 — 2x the leaves.

    Identical arithmetic (the apply recombines the halves with
    ``jnp.concatenate`` before calling the real LeNet forward), but a
    much more fragmented pytree. The ``flat_tree`` regime runs UCFL on
    this model: the flat-slab layout must keep it on the one-matrix
    fused mix path, so leaf count shows up only in the (cheap) per-leaf
    unravel/ravel at the apply boundary, never in the mix/scatter.
    """
    leaves, treedef = jax.tree.flatten(params0)
    frag = {}
    for i, leaf in enumerate(leaves):
        half = leaf.shape[0] // 2 if leaf.ndim else 0
        if half:
            frag[f"leaf{i:02d}"] = {"a": leaf[:half], "b": leaf[half:]}
        else:
            frag[f"leaf{i:02d}"] = {"a": leaf}

    def _defrag(fp):
        out = []
        for i in range(len(leaves)):
            piece = fp[f"leaf{i:02d}"]
            out.append(jnp.concatenate([piece["a"], piece["b"]], axis=0)
                       if "b" in piece else piece["a"])
        return jax.tree.unflatten(treedef, out)

    def apply(fp, x):
        return lenet.apply(_defrag(fp), x)

    return frag, apply


def _git_commit() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=pathlib.Path(__file__).resolve().parent.parent)
        return out.stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


M_SCALING = (8, 64, 512)
M_SCALING_COHORT = 4


def _m_scaling_us(s, base_rounds: int) -> dict[int, float]:
    """Round time at a FIXED cohort size while m grows 8 -> 512.

    The server-side cost of a cohort round is O(c·d) — gather, (c, c)
    mix, scatter all touch only cohort rows — so the round time must be
    ~flat in m (the gate allows 1.3x for cache/allocator noise). A ratio
    above that means some round component regressed to O(m·d): a
    broadcast mix, a padding copy of the stacked state, or a host sync
    touching every row. Same interleaved-min discipline as
    :func:`_interleaved_rounds_us`, but each m needs its own dataset so
    the rotation runs over (m, strategy, data) triples.
    """
    pcfg = part.ParticipationConfig(cohort_size=M_SCALING_COHORT)
    rounds = max(6, base_rounds // 2)
    entries = []
    for mm in M_SCALING:
        sm = dataclasses.replace(s, m=mm)
        data = common.scenario_data(
            "label_shift", jax.random.fold_in(jax.random.PRNGKey(11), mm),
            sm)
        params0 = common.make_params0(jax.random.PRNGKey(12), s)
        entries.append((mm, common.make_strategy("ucfl", params0, sm), data))
    states, keys = {}, {}
    samples = {mm: [] for mm, _, _ in entries}
    for mm, strat, data in entries:
        key = jax.random.PRNGKey(1)
        key, ikey = jax.random.split(key)
        states[mm] = strat.init(ikey, data)
        keys[mm] = key
        wcohort = part.sample_cohort(pcfg, 1, mm, data.n)
        wstate, _ = strat.round(
            simulation.donation_safe_copy(states[mm]), data,
            jax.random.fold_in(key, 0x5EED), wcohort)
        jax.block_until_ready(wstate)
        del wstate
    for rnd in range(1, rounds + 1):
        offset = rnd % len(entries)
        for mm, strat, data in entries[offset:] + entries[:offset]:
            keys[mm], rkey = jax.random.split(keys[mm])
            cohort = part.sample_cohort(pcfg, rnd, mm, data.n)
            t0 = time.time()
            states[mm], _ = strat.round(states[mm], data, rkey, cohort)
            jax.block_until_ready(states[mm])
            samples[mm].append(time.time() - t0)
    return {mm: float(np.min(ts)) * 1e6 for mm, ts in samples.items()}


def run(scale) -> list[str]:
    rows = []
    s = scale
    key = jax.random.PRNGKey(0)
    dkey, mkey = jax.random.split(key)
    data = common.scenario_data("label_shift", dkey, s)
    params0 = common.make_params0(mkey, s)
    rounds = max(10, s.rounds)
    cohort = max(2, s.m // 2)
    chunk = max(2, s.m // 4)

    cohort_cfg = part.ParticipationConfig(cohort_size=cohort)
    regimes = {
        "dense": None,
        "cohort": cohort_cfg,
        "availability": part.ParticipationConfig(
            cohort_size=cohort, sampler="availability",
            availability=_diurnal_trace(s.m)),
    }
    entries = [(name, common.make_strategy("ucfl", params0, s,
                                           chunk_size=chunk), pcfg)
               for name, pcfg in regimes.items()]
    entries.append(("refresh",
                    common.make_strategy("ucfl", params0, s,
                                         chunk_size=chunk,
                                         w_refresh=RefreshConfig()),
                    cohort_cfg))
    entries.append(("async",
                    common.make_strategy(
                        "ucfl", params0, s, chunk_size=chunk,
                        async_buffer=AsyncConfig(
                            flush_k=max(1, cohort // 2))),
                    cohort_cfg))
    entries.append(("faults",
                    common.make_strategy(
                        "ucfl", params0, s, chunk_size=chunk,
                        faults=FaultConfig(byzantine_frac=0.25,
                                           attack="sign_flip",
                                           drop_rate=0.1),
                        robust=RobustConfig(rule="trimmed_mean",
                                            trim_k=1)),
                    cohort_cfg))
    frag_params, frag_apply = _fragmented_lenet(params0)
    entries.append(("flat_tree",
                    ucfl.make_ucfl(
                        frag_apply, frag_params,
                        FedConfig(batch_size=s.batch_size,
                                  chunk_size=chunk),
                        var_batch_size=s.var_batch),
                    cohort_cfg))
    entries.append(("quant",
                    common.make_strategy("ucfl", params0, s,
                                         chunk_size=chunk,
                                         transport=TransportConfig("int8")),
                    cohort_cfg))
    entries.append(("hier",
                    common.make_strategy(
                        "ucfl_k2", params0, s, chunk_size=chunk,
                        topology=Topology.contiguous(s.m, 2)),
                    cohort_cfg))
    # quant_multi vs multi: identical scaffold configs except the wire
    # (epochs=1 keeps the timed local phase comparable to the other
    # regimes; the paper-footnote epochs=5 is a fidelity knob, not a
    # stage-overhead one)
    scaffold_cfg = FedConfig(lr=0.01, momentum=0.0, epochs=1,
                             batch_size=s.batch_size, chunk_size=chunk)
    entries.append(("multi",
                    REGISTRY["scaffold"](lenet.apply, params0,
                                         scaffold_cfg),
                    cohort_cfg))
    entries.append(("quant_multi",
                    REGISTRY["scaffold"](
                        lenet.apply, params0,
                        dataclasses.replace(
                            scaffold_cfg,
                            transport=TransportConfig("int8"))),
                    cohort_cfg))

    # sharded cohort regimes (only with a multi-device host platform,
    # e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8)
    ndev = jax.device_count()
    shard_counts = [n for n in (2, 4, 8) if n <= ndev]
    if ndev < 2:
        print("# round_engine: single device — sharded rows skipped (set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
              file=sys.stderr, flush=True)
    for nshard in shard_counts:
        entries.append((f"cohort_shard{nshard}",
                        common.make_strategy("ucfl", params0, s,
                                             chunk_size=chunk, mesh=nshard),
                        cohort_cfg))

    t0 = time.time()
    times = _interleaved_rounds_us(entries, data, rounds)
    mtimes = _m_scaling_us(s, rounds)
    total_s = time.time() - t0

    results, sharded = {}, {}
    for name in list(regimes) + ["refresh", "async", "faults",
                                 "flat_tree", "quant", "hier", "multi",
                                 "quant_multi"]:
        results[name] = {"round_us": times[name], "rounds": rounds}
        strat_tag = ("scaffold" if name in ("multi", "quant_multi")
                     else "ucfl_k2" if name == "hier" else "ucfl")
        rows.append(common.csv_row(
            f"round_engine/{strat_tag}_{name}", times[name],
            f"m={s.m};cohort={s.m if name == 'dense' else cohort};"
            f"rounds={rounds}"))
        print(rows[-1], flush=True)
    for nshard in shard_counts:
        us = times[f"cohort_shard{nshard}"]
        sharded[f"shard{nshard}"] = {"round_us": us, "shards": nshard,
                                     "rounds": rounds}
        rows.append(common.csv_row(
            f"round_engine/ucfl_cohort_shard{nshard}", us,
            f"m={s.m};cohort={cohort};shards={nshard};devices={ndev}"))
        print(rows[-1], flush=True)

    m_scaling = {}
    for mm in M_SCALING:
        m_scaling[f"m{mm}"] = {"round_us": mtimes[mm], "m": mm,
                               "cohort_size": M_SCALING_COHORT}
        rows.append(common.csv_row(
            f"round_engine/ucfl_mscale_m{mm}", mtimes[mm],
            f"m={mm};cohort={M_SCALING_COHORT};rounds={max(6, rounds // 2)}"))
        print(rows[-1], flush=True)
    m_ratio = mtimes[M_SCALING[-1]] / max(mtimes[M_SCALING[0]], 1e-9)

    ratio = results["availability"]["round_us"] / \
        max(results["cohort"]["round_us"], 1e-9)
    refresh_ratio = results["refresh"]["round_us"] / \
        max(results["cohort"]["round_us"], 1e-9)
    async_ratio = results["async"]["round_us"] / \
        max(results["cohort"]["round_us"], 1e-9)
    faults_ratio = results["faults"]["round_us"] / \
        max(results["cohort"]["round_us"], 1e-9)
    flat_ratio = results["flat_tree"]["round_us"] / \
        max(results["cohort"]["round_us"], 1e-9)
    quant_ratio = results["quant"]["round_us"] / \
        max(results["cohort"]["round_us"], 1e-9)
    quant_multi_ratio = results["quant_multi"]["round_us"] / \
        max(results["multi"]["round_us"], 1e-9)
    hier_ratio = results["hier"]["round_us"] / \
        max(results["cohort"]["round_us"], 1e-9)
    payload = {
        "config": {"m": s.m, "cohort_size": cohort, "rounds": rounds,
                   "model": "lenet", "scenario": "label_shift",
                   "backend": jax.default_backend(),
                   "device_count": ndev, "timed_s": total_s,
                   # provenance: PR-over-PR artifact comparisons need to
                   # know what produced the numbers
                   "jax_version": jax.__version__,
                   "git_commit": _git_commit()},
        "results": results,
        "sharded": sharded,
        "m_scaling": m_scaling,
        "availability_over_cohort_ratio": ratio,
        "refresh_over_cohort_ratio": refresh_ratio,
        "async_over_cohort_ratio": async_ratio,
        "faults_over_cohort_ratio": faults_ratio,
        "flat_tree_over_cohort_ratio": flat_ratio,
        "quant_over_cohort_ratio": quant_ratio,
        "quant_multi_over_multi_ratio": quant_multi_ratio,
        "hier_over_cohort_ratio": hier_ratio,
        "m_scaling_ratio": m_ratio,
    }
    BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    for label, r, tgt in (("availability_over_cohort", ratio, 1.2),
                          ("refresh_over_cohort", refresh_ratio, 1.2),
                          ("async_over_cohort", async_ratio, 1.2),
                          ("faults_over_cohort", faults_ratio, 1.2),
                          ("flat_tree_over_cohort", flat_ratio, 1.2),
                          ("quant_over_cohort", quant_ratio, 1.3),
                          ("quant_multi_over_multi", quant_multi_ratio,
                           1.3),
                          ("hier_over_cohort", hier_ratio, 1.3),
                          ("m_scaling_m512_over_m8", m_ratio, 1.3)):
        rows.append(common.csv_row(
            f"round_engine/{label}", r,
            f"target<={tgt};json={BENCH_JSON.name}"))
        print(rows[-1], flush=True)
    return rows
