"""Round-engine steady-state benchmark: dense vs cohort vs padded-availability.

Measures the per-round wall time of the jitted round in three regimes:

  * ``dense``          — full participation (the PR 1 legacy path; the
                         donated/fused cohort engine must not slow it).
  * ``cohort``         — fixed-size uniform cohort (one compiled shape).
  * ``availability``   — a diurnal-style trace whose eligible-set size
                         varies per round. Pre-padding, every distinct
                         size re-jitted the round inside the timed
                         region; the fixed-shape masked engine compiles
                         once, so this should sit within ~1.2x of the
                         fixed-size cohort round.

Besides the CSV rows, :func:`run` dumps ``BENCH_round_engine.json`` at
the repo root — the start of the perf trajectory for this path.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from benchmarks import common
from repro.federated import participation as part
from repro.federated import simulation
from repro.models import lenet

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_round_engine.json"


def _diurnal_trace(m: int, period: int = 6) -> np.ndarray:
    """Deterministic availability trace with a varying eligible count."""
    rng = np.random.default_rng(7)
    trace = np.zeros((m, period), bool)
    for t in range(period):
        up = max(1, int(m * (0.3 + 0.6 * abs(np.sin(np.pi * t / period)))))
        trace[rng.choice(m, size=up, replace=False), t] = True
    return trace


def _steady_round_us(strat, data, participation, rounds: int) -> float:
    """Mean wall time per round: rounds only — no eval pass in the timed
    region (simulation.run evaluates at least once inside its timer,
    which would dilute the availability/cohort regression ratio), and
    compilation excluded via a warm-up on a state copy (the masked round
    donates its buffers)."""
    m = data.num_clients
    key = jax.random.PRNGKey(1)
    key, ikey = jax.random.split(key)
    state = strat.init(ikey, data)
    wcohort = part.sample_cohort(participation, 1, m, data.n)
    wstate, _ = strat.round(simulation.donation_safe_copy(state), data,
                            jax.random.fold_in(key, 0x5EED), wcohort)
    jax.block_until_ready(wstate)
    del wstate
    t0 = time.time()
    for rnd in range(1, rounds + 1):
        key, rkey = jax.random.split(key)
        cohort = part.sample_cohort(participation, rnd, m, data.n)
        if cohort is not None and len(cohort) == 0:
            continue
        state, _ = strat.round(state, data, rkey, cohort)
    jax.block_until_ready(state)
    return (time.time() - t0) / rounds * 1e6


def run(scale) -> list[str]:
    rows = []
    s = scale
    key = jax.random.PRNGKey(0)
    dkey, mkey = jax.random.split(key)
    data = common.scenario_data("label_shift", dkey, s)
    params0 = common.make_params0(mkey, s)
    rounds = max(4, s.rounds // 2)
    cohort = max(2, s.m // 2)

    regimes = {
        "dense": None,
        "cohort": part.ParticipationConfig(cohort_size=cohort),
        "availability": part.ParticipationConfig(
            cohort_size=cohort, sampler="availability",
            availability=_diurnal_trace(s.m)),
    }
    results = {}
    for name, pcfg in regimes.items():
        strat = common.make_strategy("ucfl", params0, s,
                                     chunk_size=max(2, s.m // 4))
        t0 = time.time()
        us = _steady_round_us(strat, data, pcfg, rounds)
        results[name] = {"round_us": us, "rounds": rounds,
                         "total_s": time.time() - t0}
        rows.append(common.csv_row(
            f"round_engine/ucfl_{name}", us,
            f"m={s.m};cohort={cohort if pcfg else s.m};rounds={rounds}"))
        print(rows[-1], flush=True)

    ratio = results["availability"]["round_us"] / \
        max(results["cohort"]["round_us"], 1e-9)
    payload = {
        "config": {"m": s.m, "cohort_size": cohort, "rounds": rounds,
                   "model": "lenet", "scenario": "label_shift",
                   "backend": jax.default_backend()},
        "results": results,
        "availability_over_cohort_ratio": ratio,
    }
    BENCH_JSON.write_text(json.dumps(payload, indent=2) + "\n")
    rows.append(common.csv_row(
        "round_engine/availability_over_cohort", ratio,
        f"target<=1.2;json={BENCH_JSON.name}"))
    print(rows[-1], flush=True)
    return rows
