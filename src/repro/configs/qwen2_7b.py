"""qwen2-7b [dense] — GQA with QKV bias.

[arXiv:2407.10671] 28L, d_model 3584, 28 q heads / 4 KV, d_ff 18944
(SwiGLU), vocab 152064, rope base 1e6, untied head. 28 heads are NOT
divisible by the 16-way model axis — exercises GSPMD uneven sharding
(padding waste is visible in the §Roofline useful-FLOPs ratio).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152_064,
    qkv_bias=True,
    rope_base=1e6,
    tie_embeddings=False,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2407.10671",
)
