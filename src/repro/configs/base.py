"""Architecture config schema + input-shape suite.

Every assigned architecture gets one ``ModelConfig`` (exact, cited) plus a
``reduced()`` smoke variant (≤2 layers, d_model ≤ 512, ≤4 experts) that runs
a real forward/train step on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"
    mlp: str = "swiglu"
    qkv_bias: bool = False
    rope_base: float = 10000.0
    rope_pct: float = 1.0
    tie_embeddings: bool = True
    emb_scale: bool = False  # gemma: multiply embeddings by sqrt(d_model)
    post_norms: bool = False  # gemma2 post-attn/post-mlp norms
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    attn_pattern: Tuple[str, ...] = ("global",)  # cycled; "local" uses window
    window: Optional[int] = None
    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 2
    moe_d_ff: Optional[int] = None  # per-expert hidden
    first_dense: int = 0  # leading dense layers (kimi)
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    hybrid_group: int = 0  # zamba2: group = (hybrid_group−1) mamba + 1 shared attn
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0
    max_pos: int = 0  # learned-position table size (whisper decoder)
    # --- VLM ---
    num_patches: int = 0
    patch_embed_dim: int = 0
    # --- distribution ---
    regime: str = "federated"  # "federated" | "fedsgd_sharded"
    expert_axis: Optional[str] = None  # mesh axis for the expert dim
    long_context_ok: bool = False  # eligible for long_500k
    # deployment padding (set by .for_mesh(); 1 = no padding, CPU/smoke)
    head_pad: int = 1  # pad/replicate heads to divide the model axis
    vocab_pad: int = 1  # pad vocab rows to divide the model axis
    # --- numerics / optimizer ---
    param_dtype: str = "float32"
    act_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "full"  # "full" | "dots" (§Perf: skip dot recompute)
    momentum: float = 0.9  # kimi uses 0.0 (HBM headroom, DESIGN.md §6)
    # --- provenance ---
    source: str = ""

    # ------------------------------------------------------------ derived
    def for_mesh(self, model_axis: int = 16) -> "ModelConfig":
        """Deployment transform: exact-semantics head/vocab padding so
        every sharded dim divides the model axis (see attention.plan_heads
        and DESIGN.md §6). The padding waste is intentional and measured."""
        return dataclasses.replace(self, head_pad=model_axis,
                                   vocab_pad=model_axis)

    @property
    def padded_vocab(self) -> int:
        v, p = self.vocab_size, max(self.vocab_pad, 1)
        return -(-v // p) * p

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern_len(self) -> int:
        if self.family in ("ssm",):
            return 1
        if self.family == "hybrid":
            return self.hybrid_group
        return len(self.attn_pattern)

    @property
    def scan_layers(self) -> int:
        return self.num_layers - self.first_dense

    @property
    def num_groups(self) -> int:
        assert self.scan_layers % self.pattern_len == 0, (
            self.name, self.scan_layers, self.pattern_len)
        return self.scan_layers // self.pattern_len

    @property
    def param_jdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def act_jdtype(self):
        return jnp.dtype(self.act_dtype)

    def reduced(self, **over) -> "ModelConfig":
        """Smoke-test variant: tiny but same family/code path."""
        scan = self.pattern_len if self.pattern_len > 1 else 2
        kw = dict(
            name=self.name + "-smoke",
            num_layers=scan + self.first_dense,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            moe_num_experts=min(self.moe_num_experts, 4),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else None,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=32,
            window=min(self.window, 64) if self.window else None,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            max_pos=min(self.max_pos, 512),
            num_patches=min(self.num_patches, 8),
            patch_embed_dim=min(self.patch_embed_dim, 64),
            param_dtype="float32",
            act_dtype="float32",
            remat=False,
        )
        # keep layer count compatible with grouping
        if self.family == "hybrid":
            kw["num_layers"] = self.hybrid_group
        kw.update(over)
        return dataclasses.replace(self, **kw)
