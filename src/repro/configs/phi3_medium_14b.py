"""phi3-medium-14b [dense] — RoPE + SwiGLU + GQA.

[arXiv:2404.14219] 40L, d_model 5120, 40 q heads / 10 KV, d_ff 17920,
vocab 100352 (per the assigned table). 40 heads / 10 KV are not divisible
by the 16-way model axis — uneven-sharding padding case.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100_352,
    tie_embeddings=False,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2404.14219",
)
