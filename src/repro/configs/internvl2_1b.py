"""internvl2-1b [vlm] — InternViT (stub) + Qwen2-0.5B-style LM backbone.

[arXiv:2404.16821] LM: 24L, d_model 896, 14 q heads / 2 KV, d_ff 4864,
vocab 151655, QKV bias, tied embeddings. The vision encoder is a STUB per
the assignment carve-out: input_specs() supplies 256 precomputed patch
embeddings of dim 1024 (InternViT-300M output); the linear projector into
the LM and the full LM are implemented.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151_655,
    qkv_bias=True,
    rope_base=1e6,
    tie_embeddings=True,
    num_patches=256,
    patch_embed_dim=1024,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2404.16821",
)
