"""whisper-large-v3 [audio] — encoder-decoder, conv frontend STUB.

[arXiv:2212.04356] 32 encoder + 32 decoder layers, d_model 1280,
20 heads (MHA), d_ff 5120 (GELU), vocab 51866, LayerNorm, no RoPE,
1500 encoder frames (stub mel+conv frontend provides embeddings).
decode_32k is a beyond-spec stress shape (real cap: 448 decoder
positions) — the learned position table is sized 32768 to lower it;
long_500k is skipped (architecturally meaningless), see DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    norm="layernorm",
    mlp="gelu",
    encoder_layers=32,
    encoder_seq=1500,
    max_pos=32_768,
    tie_embeddings=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2212.04356",
)
