"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

[arXiv:2401.04088] 32L, d_model 4096, 32 q heads / 8 KV, d_ff 14336 per
expert, vocab 32000, SWA window 4096 (rolling cache ⇒ long_500k eligible).
Experts are tensor-parallel (d_ff on "model"); expert dim unsharded in the
federated regime (each client slice computes its own 8 experts).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    attn_pattern=("local",),
    window=4096,
    moe_num_experts=8,
    moe_top_k=2,
    moe_d_ff=14336,
    tie_embeddings=False,
    long_context_ok=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2401.04088",
)
