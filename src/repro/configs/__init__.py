"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401

from repro.configs.gemma2_9b import CONFIG as _gemma2
from repro.configs.stablelm_1_6b import CONFIG as _stablelm
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.qwen2_7b import CONFIG as _qwen2
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.internvl2_1b import CONFIG as _internvl2
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.mamba2_1_3b import CONFIG as _mamba2

ARCHITECTURES = {
    c.name: c
    for c in (
        _gemma2, _stablelm, _mixtral, _zamba2, _qwen2,
        _kimi, _phi3, _internvl2, _whisper, _mamba2,
    )
}


def get(name: str) -> ModelConfig:
    if name not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHITECTURES)}"
        )
    return ARCHITECTURES[name]


def applicable_shapes(cfg: ModelConfig):
    """The input shapes this arch runs (DESIGN.md skip rules)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.long_context_ok:
        shapes.append("long_500k")
    return [INPUT_SHAPES[s] for s in shapes]
