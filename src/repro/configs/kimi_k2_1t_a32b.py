"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8.

[arXiv:2501.kimi2 paper-table] 61L (first layer dense), d_model 7168,
64 q heads / 8 KV (head_dim 112), per-expert d_ff 2048, vocab 163840.
The dense first block uses d_ff 18432 (Kimi K2 model card; the assigned
table lists only the expert width).

Regime: ``fedsgd_sharded`` — one bf16 copy is ≈2 TB, so per-client
personalized copies are physically impossible on a 16-chip client slice
(DESIGN.md §6). Experts are expert-parallel over the "data" axis
(384/16 = 24 per slice) with d_ff tensor-parallel over "model"
(2048/16 = 128); gradient sync is a synchronous all-reduce (FedSGD), and
user-centric personalization applies to the tiny per-client router/norm
parameters only. Training uses momentum-free SGD (HBM headroom; recorded
in §Roofline).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=18432,
    vocab_size=163_840,
    moe_num_experts=384,
    moe_top_k=8,
    moe_d_ff=2048,
    first_dense=1,
    rope_base=50000.0,
    tie_embeddings=False,
    regime="fedsgd_sharded",
    expert_axis="data",
    momentum=0.0,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2501.kimi2 (paper-table)",
)
