"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.

[arXiv:2411.15242] 54 block slots, d_model 2560, ssm_state 64; the shared
transformer block (32 heads / 32 KV, d_ff 10240) is stored ONCE and invoked
every 6th slot (9 invocations, per-invocation KV caches). Simplification
recorded in DESIGN.md: Zamba2's concat-with-embedding input and per-
invocation LoRA deltas on the shared block are omitted; the shared-weight
structure and cache pattern are kept. SSM ⇒ long_500k eligible.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_headdim=64,
    hybrid_group=6,
    tie_embeddings=True,
    long_context_ok=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2411.15242",
)
