"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060] 48L, d_model 2048, d_inner 4096 (expand 2), 64 SSD
heads of headdim 64, ssm_state 128, vocab 50280, tied embeddings.
O(1)-state decode ⇒ long_500k eligible (the flagship long-context arch).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=64,
    num_kv_heads=64,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_headdim=64,
    tie_embeddings=True,
    long_context_ok=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2405.21060",
)
