"""gemma2-9b [dense] — local+global alternating attention, logit softcaps.

[arXiv:2408.00118] Gemma 2: 42L, d_model 3584, 16 q heads / 8 KV (GQA),
head_dim 256, d_ff 14336 (GeGLU), vocab 256000, SWA window 4096 on odd
layers, attn-logit softcap 50, final-logit softcap 30, pre+post norms,
tied + sqrt(d)-scaled embeddings. long_500k eligible via the local/global
split (global layers hold a true 500k cache; decode is linear per token).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    mlp="geglu",
    attn_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    emb_scale=True,
    tie_embeddings=True,
    long_context_ok=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="arXiv:2408.00118",
)
