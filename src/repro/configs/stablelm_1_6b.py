"""stablelm-1.6b [dense] — LayerNorm, partial rotary (25%).

[hf:stabilityai/stablelm-2-1_6b] 24L, d_model 2048, 32 heads / 32 KV (MHA),
d_ff 5632 (SwiGLU), vocab 100352, rope over 25% of head_dim, untied head.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100_352,
    norm="layernorm",
    mlp="swiglu",
    rope_pct=0.25,
    tie_embeddings=False,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
    source="hf:stabilityai/stablelm-2-1_6b",
)
