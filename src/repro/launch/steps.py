"""Step builders: the lowered programs of the dry-run and the drivers.

The paper's technique lives INSIDE ``train_step``: one local SGD step per
client (clients = slices of the mesh's client axes) followed by the PS
aggregation expressed as a collective over the client axis:

  * ``agg="fedavg"``       — Eq. 1: mean over clients (all-reduce);
  * ``agg="user_centric"`` — Eq. 8: θ_i ← Σ_j W[i,j] θ_j (all-gather+mix);
  * ``agg="clustered"``    — §IV-B: m_t centroid mixes then a gather back
                             (collective volume ∝ m_t — the paper's
                             communication saving, measured in ICI bytes);
  * ``agg="local"``        — no mixing (for A/B collective accounting).

Momentum buffers stay client-local (the paper resets the optimizer each
round; here the buffer persists but is never mixed).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import registry
from repro.models import transformer, whisper
from repro.optim import sgd_init, sgd_update


# ------------------------------------------------------------------ helpers
def _mix_user_centric(stacked, w, gather_shardings=None):
    """θ_i ← Σ_j W[i,j] θ_j on every leaf (leading client axis).

    §Perf it1: keep the COMMUNICATED operand in its storage dtype (bf16)
    and accumulate in f32 via preferred_element_type — halves the
    all-gather volume vs pre-casting to f32.
    §Perf it2: left alone, GSPMD partial-sums the contraction over the
    client axis and all-reduces the (m, shard) f32 accumulator — 4× the
    volume of gathering bf16 operands. ``gather_shardings`` (the param
    specs with the client axis relaxed to None) forces the cheap schedule:
    all-gather bf16 θ, mix locally, keep outputs client-sharded.
    """
    def mix_leaf(x, gshard=None):
        if gshard is not None:
            x = jax.lax.with_sharding_constraint(x, gshard)
        return jnp.einsum(
            "ij,j...->i...", w.astype(x.dtype), x,
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)

    if gather_shardings is None:
        return jax.tree.map(mix_leaf, stacked)
    return jax.tree.map(mix_leaf, stacked, gather_shardings)


def _mix_clustered(stacked, centroid_w, labels):
    """Two-step §IV-B mixing: m_t centroid mixes, then per-client gather."""
    def leaf(x):
        mixed = jnp.einsum(
            "kj,j...->k...", centroid_w.astype(x.dtype), x,
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)  # (m_t, ...)
        return jnp.take(mixed, labels, axis=0)
    return jax.tree.map(leaf, stacked)


def _mix_fedavg(stacked):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(
            jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True),
            x.shape,
        ).astype(x.dtype),
        stacked,
    )


# ------------------------------------------------------------------ train
def build_train_step(cfg: ModelConfig, *, n_clients: int, agg: str,
                     num_streams: int | None = None, lr: float = 0.1,
                     momentum: float = 0.9, mix_gather_shardings=None):
    """Returns train_step with signature depending on the regime.

    federated:  (params, opt, mix, batch) -> (params, opt, metrics)
                where mix = W (m,m) | (centroid_w (k,m), labels (m,)) | ()
    fedsgd:     (params, opt, batch) -> (params, opt, metrics)
    """
    model = registry.build(cfg)

    if cfg.regime == "fedsgd_sharded":
        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt = sgd_update(grads, opt, params, lr=lr,
                                     momentum=momentum)
            return params, opt, {"loss": loss}
        return train_step

    def train_step(params, opt, mix, batch):
        # per-client losses/grads — block-diagonal, communication-free
        loss, grads = jax.vmap(jax.value_and_grad(model.loss))(params, batch)
        params, opt = sgd_update(grads, opt, params, lr=lr, momentum=momentum)
        if agg == "user_centric":
            params = _mix_user_centric(params, mix, mix_gather_shardings)
        elif agg == "clustered":
            params = _mix_clustered(params, mix[0], mix[1])
        elif agg == "fedavg":
            params = _mix_fedavg(params)
        elif agg != "local":
            raise ValueError(agg)
        return params, opt, {"loss": jnp.mean(loss)}

    return train_step


def build_prefill_step(cfg: ModelConfig, *, federated: bool):
    model = registry.build(cfg)
    mod = whisper if cfg.family == "audio" else transformer

    def prefill_one(params, batch):
        logits, _aux, caches = mod.forward(params, batch, cfg,
                                           return_cache=True)
        return logits[:, -1:], caches

    if federated:
        def prefill_step(params, batch):
            return jax.vmap(prefill_one)(params, batch)
        return prefill_step
    return prefill_one


def build_serve_step(cfg: ModelConfig, *, federated: bool):
    """One-token decode with KV cache (the decode_* dry-run entry)."""
    model = registry.build(cfg)

    def serve_one(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)

    if federated:
        def serve_step(params, caches, tokens, pos):
            return jax.vmap(serve_one, in_axes=(0, 0, 0, None))(
                params, caches, tokens, pos
            )
        return serve_step
    return serve_one


# ------------------------------------------------------------------ specs
def abstract_params(cfg: ModelConfig, *, n_clients: int | None = None):
    """ShapeDtypeStruct tree of the model params (no allocation)."""
    model = registry.build(cfg)
    one = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if n_clients is None:
        return one
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_clients,) + x.shape, x.dtype), one
    )


def abstract_opt(abs_params, *, momentum: float):
    return jax.eval_shape(
        functools.partial(sgd_init, momentum=momentum), abs_params
    )


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                n_clients: int | None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape.

    n_clients=None → no client axis (fedsgd / single-request serving);
    otherwise leading (m, per_client_batch, ...) layout.
    """
    fed = n_clients is not None
    if fed:
        assert shape.global_batch % n_clients == 0, (shape, n_clients)
        b = shape.global_batch // n_clients
        lead = (n_clients, b)
    else:
        lead = (shape.global_batch,)

    i32 = jnp.int32
    act = cfg.act_jdtype

    def sds(*dims, dtype=i32):
        return jax.ShapeDtypeStruct(lead + dims, dtype)

    if shape.kind == "train":
        batch = {"tokens": sds(shape.seq_len), "labels": sds(shape.seq_len)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds(cfg.num_patches, cfg.patch_embed_dim,
                                        dtype=act)
        if cfg.family == "audio":
            batch["frames"] = sds(cfg.encoder_seq, cfg.d_model, dtype=act)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds(shape.seq_len)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds(cfg.num_patches, cfg.patch_embed_dim,
                                        dtype=act)
        if cfg.family == "audio":
            batch["frames"] = sds(cfg.encoder_seq, cfg.d_model, dtype=act)
        return batch
    if shape.kind == "decode":
        return {"tokens": sds(1)}
    raise ValueError(shape.kind)


def abstract_cache(cfg: ModelConfig, shape: InputShape, *,
                   n_clients: int | None):
    """ShapeDtypeStruct tree for the serve-step KV/SSM caches."""
    model = registry.build(cfg)
    if n_clients is not None:
        b = shape.global_batch // n_clients
    else:
        b = shape.global_batch
    one = jax.eval_shape(
        lambda: model.init_cache(b, shape.seq_len)
    )
    if n_clients is None:
        return one
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n_clients,) + x.shape, x.dtype), one
    )
