"""Regenerate the EXPERIMENTS.md roofline/dry-run tables from artifacts.

  PYTHONPATH=src python -m repro.launch.summarize results/dryrun
  PYTHONPATH=src python -m repro.launch.summarize results/dryrun --format dryrun
  PYTHONPATH=src python -m repro.launch.summarize results/dryrun results/dryrun_opt --diff
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_dir(d):
    out = {}
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(p))
        out[(r["arch"], r["shape"], r["mesh"], r["agg"])] = r
    return out


def roofline_table(rows):
    print("| arch | shape | mesh | chips | compute s | memory s | "
          "collective s | dominant | useful |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in rows.values():
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['chips']} | "
              f"{d['compute_s']:.3f} | {d['memory_s']:.3f} | "
              f"{d['collective_s']:.3f} | {d['dominant']} | "
              f"{d['useful_flops_ratio']:.3f} |")


def dryrun_table(rows):
    print("| arch | shape | mesh | params (1 copy) | N_active | HLO GF/chip "
          "| HBM GB/chip | coll GB/chip | AG/AR/RS/A2A counts | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for d in rows.values():
        c = d["collectives"]
        cnt = "/".join(
            str(int(c.get(k, {}).get("count", 0)))
            for k in ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all"))
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
              f"{d['param_count'] / 1e9:.2f}B | "
              f"{d['active_params'] / 1e9:.2f}B | "
              f"{d['hlo_flops_per_chip'] / 1e9:.0f} | "
              f"{d['hlo_bytes_per_chip'] / 1e9:.0f} | "
              f"{d['collective_bytes_per_chip'] / 1e9:.1f} | {cnt} | "
              f"{d.get('t_compile_s', 0):.0f} |")


def diff_table(base, opt):
    print("| arch | shape | mesh | term | baseline s | optimized s | × |")
    print("|---|---|---|---|---|---|---|")
    for key, o in opt.items():
        arch, shape, mesh, _ = key
        b = next((v for k, v in base.items()
                  if k[0] == arch and k[1] == shape and k[2] == mesh), None)
        if b is None:
            continue
        for term in ("compute_s", "memory_s", "collective_s"):
            bv, ov = b[term], o[term]
            if bv <= 0:
                continue
            print(f"| {arch} | {shape} | {mesh} | {term[:-2]} | "
                  f"{bv:.2f} | {ov:.2f} | {bv / max(ov, 1e-12):.1f}x |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("dirs", nargs="+")
    ap.add_argument("--format", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--diff", action="store_true")
    args = ap.parse_args()
    if args.diff:
        assert len(args.dirs) == 2
        diff_table(load_dir(args.dirs[0]), load_dir(args.dirs[1]))
        return
    rows = {}
    for d in args.dirs:
        rows.update(load_dir(d))
    (roofline_table if args.format == "roofline" else dryrun_table)(rows)


if __name__ == "__main__":
    main()
