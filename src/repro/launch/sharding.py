"""PartitionSpec rules for every parameter/activation/cache leaf.

Rules are keyed by leaf name (the params dicts use stable, well-known
keys); the leading axes are composed per context:

  federated regime: (client_axes,) + (group-scan None,) + rule
  fedsgd_sharded:                    (group-scan None,) + rule
  single-serve (long_500k):          same as fedsgd for params

"model" shards attention heads / d_ff / vocab; uneven dims (28 q heads on
a 16-way axis, odd vocabs) rely on GSPMD padding — the waste shows up in
the §Roofline useful-FLOPs ratio.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# rule: spec for the leaf's own dims (no client/scan prefixes), keyed by name
_BASE_RULES = {
    # embeddings / readout
    "table": ("model", None),  # (V, D)
    "pos_embed": (None, None),
    # attention
    "wq": (None, "model", None),  # (D, H, Dh)
    "wk": (None, "model", None),
    "wv": (None, "model", None),
    "wo": ("model", None),  # (H*Dh, D)
    "bq": ("model", None),
    "bk": ("model", None),
    "bv": ("model", None),
    # dense MLPs
    "w_gate": (None, "model"),  # (D, F)
    "w_up": (None, "model"),
    "w_down": ("model", None),  # (F, D)
    "b_up": ("model",),
    "b_down": (None,),
    # SSM
    "in_proj": (None, "model"),
    "conv_w": (None, None),
    "conv_b": (None,),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    "out_proj": ("model", None),
    # norms
    "scale": (None,),
    "bias": (None,),
    # MoE router
    "router": (None, None),
}

_SCAN_CONTAINERS = ("blocks", "enc_blocks", "dec_blocks")


def _rule_for(path_keys, base_ndim, cfg: ModelConfig):
    name = path_keys[-1]
    if name in ("w_gate", "w_up") and base_ndim == 3:
        return (cfg.expert_axis, None, "model")  # MoE (E, D, F)
    if name == "w_down" and base_ndim == 3:
        return (cfg.expert_axis, "model", None)  # MoE (E, F, D)
    if name == "w":
        if "lm_head" in path_keys:
            return (None, "model")  # (D, V)
        return (None, None)  # projector
    if name == "b":
        return (None,)
    if name in _BASE_RULES:
        return _BASE_RULES[name]
    raise KeyError(f"no sharding rule for param leaf {'/'.join(path_keys)}")


def _scan_depth(path_keys) -> int:
    return 1 if any(k in _SCAN_CONTAINERS for k in path_keys) else 0


def _path_names(path):
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
    return out


def param_specs(abstract_params, cfg: ModelConfig, mesh, *,
                client_sharded: bool, mode: str = "tp"):
    """PartitionSpec tree matching ``abstract_params``.

    client_sharded=True: every leaf carries a leading client axis that is
    sharded over the mesh's client axes (federated regime).

    mode="tp" (baseline): Megatron tensor parallelism — heads/d_ff/vocab on
    "model", activations replicated inside a client, 2 activation
    all-reduces per layer per pass.
    mode="fsdp" (§Perf it3): ZeRO-3 inside each client slice — every large
    leaf sharded on "model" over its first divisible dim, per-client batch
    sharded on "model", weights all-gathered per layer (O(params/layer)
    traffic instead of O(activations)).
    """
    from repro.launch.mesh import client_axes

    caxes = client_axes(mesh)
    client = caxes if len(caxes) > 1 else caxes[0]
    msize = mesh.shape["model"]

    def spec(path, leaf):
        keys = _path_names(path)
        prefix = []
        if _scan_depth(keys):
            prefix.append(None)
        if client_sharded:
            prefix = [client] + prefix
        base_nd = leaf.ndim - len(prefix)
        if mode == "fsdp":
            rule = [None] * base_nd
            size = 1
            for d in leaf.shape:
                size *= d
            if size >= (1 << 20):  # shard only large leaves
                for i in range(base_nd):
                    if leaf.shape[len(prefix) + i] % msize == 0:
                        rule[i] = "model"
                        break
            rule = tuple(rule)
        else:
            rule = _rule_for(keys, base_nd, cfg)
        full = tuple(prefix) + tuple(rule)
        assert len(full) == leaf.ndim, (keys, full, leaf.shape)
        return P(*full)

    return jax.tree_util.tree_map_with_path(spec, abstract_params)


def _dp_axes(mesh):
    """Data-parallel axes: ("pod","data") on the multi-pod mesh."""
    return (("pod", "data") if "pod" in mesh.axis_names else "data")


def cache_specs(abstract_cache, cfg: ModelConfig, mesh, *,
                client_sharded: bool, batch_axis: bool = False,
                context_parallel: bool = False):
    """KV/SSM cache PartitionSpecs.

    Attention k/v: (..., B, T, Hkv, Dh) — heads on "model"; B on the DP
    axes when ``batch_axis`` (fedsgd serving); T on "data" when
    ``context_parallel`` (long_500k single-request serving).
    SSM h: (..., B, H, P, N) — heads on "model".
    """
    from repro.launch.mesh import client_axes

    caxes = client_axes(mesh)
    client = caxes if len(caxes) > 1 else caxes[0]
    seq_axis = "data" if context_parallel else None
    b_axis = _dp_axes(mesh) if batch_axis else None

    def spec(path, leaf):
        keys = _path_names(path)
        name = keys[-1]
        prefix = []
        if _scan_depth(keys) or any(k in ("self",) for k in keys):
            prefix.append(None)  # group-scan axis
        if client_sharded:
            prefix = [client] + prefix
        nd = leaf.ndim - len(prefix)
        if name in ("k", "v"):
            rule = (b_axis, seq_axis, "model", None)
        elif name == "pos":
            rule = (seq_axis,)
        elif name == "h":
            rule = (b_axis, "model", None, None)
        elif name == "conv":
            rule = (b_axis, None, None)
        elif name == "cross_kv":
            rule = (None, None, b_axis, None, "model", None)  # (L,2,B,T,H,Dh)
        else:
            raise KeyError(f"no cache rule for {'/'.join(keys)}")
        assert len(rule) == nd, (keys, rule, leaf.shape, prefix)
        return P(*(tuple(prefix) + rule))

    return jax.tree_util.tree_map_with_path(spec, abstract_cache)


def batch_specs(abstract_batch, mesh, *, client_sharded: bool,
                shard_batch: bool = True, mode: str = "tp"):
    """Token/label/frames specs: leading (client) batch dims on clients."""
    from repro.launch.mesh import client_axes

    caxes = client_axes(mesh)
    client = caxes if len(caxes) > 1 else caxes[0]

    def spec(path, leaf):
        if client_sharded:
            if mode == "fsdp" and leaf.ndim >= 2:
                # per-client batch dim also sharded over "model" (ZeRO DP)
                return P(*([client, "model"] + [None] * (leaf.ndim - 2)))
            return P(*([client] + [None] * (leaf.ndim - 1)))
        if not shard_batch:  # long_500k: global batch 1, nothing to split
            return P(*([None] * leaf.ndim))
        # fedsgd / single: shard global batch dim over the DP axes
        return P(*([_dp_axes(mesh)] + [None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec, abstract_batch)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
