"""End-to-end federated LM training driver (runs on CPU at smoke scale).

Trains a reduced transformer-zoo architecture with m federated clients on
heterogeneous synthetic LM tasks (per-group vocab-permutation chains), the
collaboration round (Eq. 9/10) computed on real gradients, and the chosen
aggregation each round. On TPU the same code runs the production mesh;
here the mesh is whatever ``jax.devices()`` offers.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b --smoke \
      --clients 4 --groups 2 --rounds 30
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import similarity
from repro.core.pytree import stacked_ravel
from repro.data import lm_synthetic
from repro.launch import steps as steplib
from repro.models import registry
from repro.optim import sgd_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--agg", default="user_centric",
                    choices=["user_centric", "fedavg", "local"])
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced(vocab_size=64, remat=False)
    m = args.clients
    model = registry.build(cfg)
    key = jax.random.PRNGKey(args.seed)
    kinit, kchain, kdata, kcollab, ktrain = jax.random.split(key, 5)

    params_one = model.init(kinit)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (m,) + x.shape) + 0.0, params_one
    )
    opt = sgd_init(params, momentum=cfg.momentum)
    chains = lm_synthetic.make_group_chains(kchain, args.groups,
                                            cfg.vocab_size)

    # ---- collaboration round (Eq. 9/10) on real LM gradients
    kparts = jax.random.split(kcollab, 4)
    grads = []
    for kp in kparts:
        batch = lm_synthetic.federated_lm_batch(kp, chains, m, args.batch,
                                                args.seq)
        g = jax.vmap(jax.grad(model.loss))(params, batch)
        grads.append(stacked_ravel(g))
    gmat = jnp.stack(grads, axis=1)  # (m, K, d)
    collab = similarity.collaboration_round(
        gmat, jnp.full((m,), args.batch * args.seq, jnp.float32))
    w = collab["W"]
    print("collaboration matrix W:")
    print(np.array_str(np.asarray(w), precision=3, suppress_small=True))

    train_step = jax.jit(steplib.build_train_step(
        cfg, n_clients=m, agg=args.agg, lr=args.lr, momentum=cfg.momentum,
    ))
    mix = w if args.agg == "user_centric" else ()

    t0 = time.time()
    for r in range(1, args.rounds + 1):
        ktrain, kb = jax.random.split(ktrain)
        batch = lm_synthetic.federated_lm_batch(kb, chains, m, args.batch,
                                                args.seq)
        params, opt, metrics = train_step(params, opt, mix, batch)
        if r % max(args.rounds // 10, 1) == 0 or r == 1:
            print(f"round {r:4d} loss={float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)")
    print(f"done: final loss {float(metrics['loss']):.4f} "
          f"in {time.time() - t0:.1f}s")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
