import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

The two lines above MUST run before any jax import (jax locks the device
count at first init); do not move them. This module is the ONLY place that
forces 512 host devices — smoke tests and benchmarks see the real device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch all --shape all --mesh both --agg user_centric \
      --out results/dryrun

Per combo: jit(step).lower(abstract inputs).compile(); record
memory_analysis + cost_analysis + parsed collective bytes into a JSON
artifact consumed by EXPERIMENTS.md §Dry-run/§Roofline.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import INPUT_SHAPES  # noqa: E402
from repro.launch import mesh as meshlib  # noqa: E402
from repro.launch import roofline, sharding, steps  # noqa: E402


def _mix_inputs(agg: str, m: int, num_streams: int):
    if agg == "user_centric":
        return jax.ShapeDtypeStruct((m, m), jnp.float32), P()
    if agg == "clustered":
        return (
            (jax.ShapeDtypeStruct((num_streams, m), jnp.float32),
             jax.ShapeDtypeStruct((m,), jnp.int32)),
            (P(), P()),
        )
    return (), ()


def lower_one(cfg, shape, mesh, *, agg: str, num_streams: int = 4,
              donate: bool = True, sharding_mode: str = "tp",
              remat_policy: str | None = None, expert_parallel: bool = True):
    """Build + lower + compile one combo. Returns (compiled, meta)."""
    from repro.models import moe as moelib

    moelib.set_ep_mesh(mesh if (expert_parallel and cfg.expert_axis)
                       else None)
    chips = meshlib.num_chips(mesh)
    m = meshlib.num_clients(mesh)
    federated = cfg.regime == "federated"
    ns = lambda spec_tree: sharding.named(mesh, spec_tree)
    # true (unpadded) param count for MODEL_FLOPS, before deployment padding
    abs_params_true = steps.abstract_params(cfg)
    cfg = cfg.for_mesh(mesh.shape["model"])
    if remat_policy is not None:
        import dataclasses as _dc

        cfg = _dc.replace(cfg, remat_policy=remat_policy)

    if shape.kind == "decode" and shape.global_batch < m:
        # long_500k: one request served by the whole pod (context parallel)
        federated_step = False
        n_clients = None
    else:
        federated_step = federated
        n_clients = m if federated else None

    abs_params = steps.abstract_params(cfg, n_clients=n_clients)
    pspecs = sharding.param_specs(abs_params, cfg, mesh,
                                  client_sharded=n_clients is not None,
                                  mode=sharding_mode)

    if shape.kind == "train":
        abs_opt = steps.abstract_opt(abs_params, momentum=cfg.momentum)
        ospecs = jax.tree.map(lambda s: s, pspecs) if cfg.momentum else ()
        batch = steps.input_specs(cfg, shape, n_clients=n_clients)
        bspecs = sharding.batch_specs(batch, mesh,
                                      client_sharded=n_clients is not None,
                                      mode=sharding_mode)
        gather_specs = jax.tree.map(
            lambda s: NamedSharding(mesh, P(*((None,) + tuple(s)[1:]))),
            pspecs, is_leaf=lambda x: isinstance(x, P),
        ) if federated_step else None
        fn = steps.build_train_step(
            cfg, n_clients=m, agg=agg, lr=0.1, momentum=cfg.momentum,
            mix_gather_shardings=gather_specs,
        )
        if federated_step:
            mix_abs, mix_spec = _mix_inputs(agg, m, num_streams)
            args = (abs_params, abs_opt, mix_abs, batch)
            in_sh = (ns(pspecs), ns(ospecs), ns(mix_spec), ns(bspecs))
        else:
            args = (abs_params, abs_opt, batch)
            in_sh = (ns(pspecs), ns(ospecs), ns(bspecs))
        jfn = jax.jit(fn, in_shardings=in_sh,
                      donate_argnums=(0, 1) if donate else ())
    elif shape.kind == "prefill":
        batch = steps.input_specs(cfg, shape, n_clients=n_clients)
        bspecs = sharding.batch_specs(batch, mesh,
                                      client_sharded=n_clients is not None)
        fn = steps.build_prefill_step(cfg, federated=federated_step)
        args = (abs_params, batch)
        jfn = jax.jit(fn, in_shardings=(ns(pspecs), ns(bspecs)))
    else:  # decode
        batch = steps.input_specs(cfg, shape, n_clients=n_clients)
        bspecs = sharding.batch_specs(
            batch, mesh, client_sharded=n_clients is not None,
            shard_batch=shape.global_batch >= mesh.shape["data"],
        )
        shard_b = (n_clients is None
                   and shape.global_batch >= mesh.shape["data"])
        caches = steps.abstract_cache(cfg, shape, n_clients=n_clients)
        cspecs = sharding.cache_specs(
            caches, cfg, mesh, client_sharded=n_clients is not None,
            batch_axis=shard_b,
            context_parallel=(n_clients is None and not shard_b),
        )
        fn = steps.build_serve_step(cfg, federated=federated_step)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        args = (abs_params, caches, batch["tokens"], pos)
        jfn = jax.jit(
            fn,
            in_shardings=(ns(pspecs), ns(cspecs), ns(bspecs["tokens"]),
                          NamedSharding(mesh, P())),
            donate_argnums=(1,) if donate else (),
        )

    t0 = time.time()
    lowered = jfn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    meta = {
        "chips": chips, "clients": m, "t_lower_s": t_lower,
        "t_compile_s": t_compile, "abs_params_one": abs_params_true,
        "federated_step": federated_step,
    }
    return compiled, meta


def run_combo(arch: str, shape_name: str, mesh_name: str, *, agg: str,
              num_streams: int, out_dir: str, skip_existing: bool,
              sharding_mode: str = "tp", remat_policy: str | None = None):
    tag = f"{arch}__{shape_name}__{mesh_name}__{agg}"
    if sharding_mode != "tp":
        tag += f"__{sharding_mode}"
    if remat_policy:
        tag += f"__{remat_policy}"
    path = os.path.join(out_dir, tag + ".json")
    if skip_existing and os.path.exists(path):
        print(f"[skip] {tag}")
        return True
    cfg = configs.get(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.long_context_ok:
        print(f"[n/a ] {tag} (full-attention arch; skip per DESIGN.md)")
        return True
    mesh = meshlib.make_production_mesh(multi_pod=(mesh_name == "multi"))
    try:
        compiled, meta = lower_one(cfg, shape, mesh, agg=agg,
                                   num_streams=num_streams,
                                   sharding_mode=sharding_mode,
                                   remat_policy=remat_policy)
        roof = roofline.analyze(
            compiled, cfg, shape, mesh_name=mesh_name,
            chips=meta["chips"], agg=agg,
            abs_params_one=meta["abs_params_one"],
        )
        d = roof.to_dict()
        d["t_lower_s"] = meta["t_lower_s"]
        d["t_compile_s"] = meta["t_compile_s"]
        d["clients"] = meta["clients"]
        d["federated_step"] = meta["federated_step"]
        os.makedirs(out_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(d, f, indent=2, default=str)
        try:  # keep the partitioned HLO for offline re-analysis
            import zstandard

            hlo = compiled.as_text().encode()
            with open(os.path.join(out_dir, tag + ".hlo.zst"), "wb") as f:
                f.write(zstandard.ZstdCompressor(level=6).compress(hlo))
        except Exception:
            pass
        print(f"[ok  ] {roofline.fmt_row(roof)} "
              f"(lower {meta['t_lower_s']:.0f}s compile "
              f"{meta['t_compile_s']:.0f}s)")
        return True
    except Exception as e:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".FAILED"), "w") as f:
            f.write(traceback.format_exc())
        print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}")
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--agg", default="user_centric",
                    choices=["user_centric", "clustered", "fedavg", "local"])
    ap.add_argument("--num-streams", type=int, default=4)
    ap.add_argument("--sharding", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--remat-policy", default=None,
                    choices=[None, "full", "dots", "save_moe"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = (sorted(configs.ARCHITECTURES) if args.arch == "all"
             else args.arch.split(","))
    shapes = (list(INPUT_SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    ok = True
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                ok &= run_combo(arch, shape, mesh_name, agg=args.agg,
                                num_streams=args.num_streams,
                                out_dir=args.out,
                                skip_existing=args.skip_existing,
                                sharding_mode=args.sharding,
                                remat_policy=args.remat_policy)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
