"""Personalized serving driver: prefill a prompt batch, decode N tokens.

Each federated client serves ITS OWN personalized model (the framework's
decode path is the one lowered by the decode_* dry-run shapes). Runs on
CPU at smoke scale; on a TPU mesh the same step functions serve the
production shapes.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
      --clients 2 --batch 2 --prompt-len 32 --decode-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import steps as steplib
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced(vocab_size=128, remat=False)
    m = args.clients
    model = registry.build(cfg)
    key = jax.random.PRNGKey(args.seed)
    kinit, kprompt = jax.random.split(key)

    params_one = model.init(kinit)
    # personalized models: perturb per client so outputs differ
    params = jax.tree.map(
        lambda x: x[None] + 0.01 * jax.random.normal(
            jax.random.PRNGKey(1), (m,) + x.shape, jnp.float32
        ).astype(x.dtype),
        params_one,
    )

    max_len = args.prompt_len + args.decode_tokens
    serve_step = jax.jit(steplib.build_serve_step(cfg, federated=True))

    # init caches + teacher-forced prefill via repeated decode (smoke scale)
    caches = jax.vmap(lambda _: model.init_cache(args.batch, max_len))(
        jnp.arange(m)
    )
    tokens = jax.random.randint(
        kprompt, (m, args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, caches = serve_step(params, caches, tokens[:, :, t: t + 1],
                                    jnp.asarray(t, jnp.int32))
    t_prefill = time.time() - t0

    out_tokens = []
    t0 = time.time()
    cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for t in range(args.prompt_len, max_len):
        logits, caches = serve_step(params, caches, cur,
                                    jnp.asarray(t, jnp.int32))
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(cur)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    total = args.decode_tokens * args.batch * m
    print(f"prefill {args.prompt_len} steps in {t_prefill:.2f}s; "
          f"decoded {total} tokens in {t_decode:.2f}s "
          f"({total / max(t_decode, 1e-9):.1f} tok/s)")
    gen = jnp.concatenate(out_tokens, axis=-1)
    print("sample (client 0, request 0):", list(map(int, gen[0, 0])))
    return gen


if __name__ == "__main__":
    main()
