"""Trip-count-aware analysis of post-SPMD HLO text.

``xla::HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) visits
a while-loop body ONCE, so any model lowered with ``lax.scan`` over layers
under-counts FLOPs/bytes/collectives by ~num_layers. This module parses
``compiled.as_text()`` into computations, builds the call graph
(while bodies, fusions, conditionals), infers scan trip counts from the
loop-condition constants, and accumulates:

  * dot_flops       — MXU FLOPs: 2 · prod(result) · prod(contracted dims)
                      (elementwise VPU FLOPs are excluded — on TPU the
                      compute roofline term is MXU-bound for these models);
  * hbm_bytes       — fusion-parameter + result bytes for fusion ops
                      (fusions are XLA's unit of HBM traffic), operand +
                      result bytes for non-fused compute ops;
  * collectives     — per-category counts/bytes with ring-algorithm moved-
                      bytes accounting, scaled by trip count.

All shapes in the partitioned module are per-chip, so every number this
module returns is per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_TRIP_COUNT_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_CALL_RE = re.compile(r"^\s*([\w\-]+)\((.*)$")


def _split_instr(line: str):
    """Parse '  %name = TYPE op(rest...' robustly.

    TYPE may be a tuple '( ... /*index=5*/ ... )' containing '=' inside
    comments, so we balance parens instead of regexing.
    """
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str, rest = rhs[: i + 1], rhs[i + 1:].lstrip()
                    break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1:].lstrip()
    m2 = _OP_CALL_RE.match(rest)
    if not m2:
        return None
    return name, type_str, m2.group(1), m2.group(2)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "while", "conditional", "call", "custom-call",
}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    total_b = 0
    elems = 1
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems = n  # last shape (for single-shape strings)
        total_b += n * _DTYPE_BYTES[dt]
    return elems, total_b


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str  # operand list + attrs (raw remainder of the line)

    @property
    def operands(self) -> List[str]:
        # operand names appear before the first "), " attr separator;
        # just take %refs in the call-paren region (attrs also carry %refs
        # to computations — excluded by the known attr patterns below).
        head = self.rest.split("), ")[0]
        return _OPERAND_RE.findall(head)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]  # instr name -> type string


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1), [], {})
                if line.strip().startswith("ENTRY"):
                    entry_name = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _split_instr(line)
        if parsed:
            name, type_str, op, rest = parsed
            inst = Instr(name, type_str, op, rest)
            cur.instrs.append(inst)
            cur.symbols[name] = type_str
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond: Computation) -> int:
    consts = []
    for inst in cond.instrs:
        mm = _CONST_RE.search(f"= {inst.type_str} {inst.op}({inst.rest}")
        if inst.op == "constant":
            m2 = re.match(r"(\d+)\)", inst.rest)
            if m2 and inst.type_str.startswith(("s32", "u32", "s64", "u64")):
                consts.append(int(m2.group(1)))
    return max(consts) if consts else 1


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return default


def _dot_flops(inst: Instr, comp: Computation) -> float:
    res_elems, _ = _shape_elems_bytes(inst.type_str)
    # contracted dims: lhs shape at lhs_contracting_dims
    ops = inst.operands
    if not ops:
        return 0.0
    lhs_type = comp.symbols.get(ops[0])
    if lhs_type is None:
        return 0.0
    lhs_dims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([^}]*)\}", inst.rest)
    contracted = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
    return 2.0 * res_elems * contracted


def _conv_flops(inst: Instr, comp: Computation) -> float:
    res_elems, _ = _shape_elems_bytes(inst.type_str)
    ops = inst.operands
    if len(ops) < 2:
        return 0.0
    rhs = comp.symbols.get(ops[1])
    if rhs is None:
        return 0.0
    kdims = _shape_dims(rhs)
    k = 1
    for d in kdims[:-1]:  # HWIO: all but output features
        k *= d
    return 2.0 * res_elems * k


def _fusion_param_read_bytes(sub: "Computation", index: int,
                             full_bytes: int) -> float:
    """Bytes a fusion actually reads from parameter ``index``.

    If every use of the parameter inside the fused computation is a
    dynamic-slice / gather / slice, the fusion streams only those slices
    (this is exactly how scan-over-layers weight access compiles); any
    other use reads the full operand.
    """
    pname = None
    for inst in sub.instrs:
        if inst.op == "parameter" and inst.rest.startswith(f"{index})"):
            pname = inst.name
            break
    if pname is None:
        return full_bytes
    total = 0.0
    for inst in sub.instrs:
        if pname in inst.operands:
            if inst.op in ("dynamic-slice", "gather", "slice"):
                total += _shape_elems_bytes(inst.type_str)[1]
            elif inst.op == "dynamic-update-slice":
                # param is the buffer being updated in place
                upd = (sub.symbols.get(inst.operands[1])
                       if len(inst.operands) > 1 else None)
                total += _shape_elems_bytes(upd)[1] if upd else full_bytes
            else:
                return full_bytes
    return min(total, full_bytes) if total else full_bytes


@dataclasses.dataclass
class Analysis:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, dict] = dataclasses.field(default_factory=dict)
    while_trip_counts: List[int] = dataclasses.field(default_factory=list)

    @property
    def collective_bytes(self) -> float:
        return sum(v["moved_bytes"] for v in self.collectives.values())


def analyze_text(text: str, *, total_chips: int = 1) -> Analysis:
    comps = parse_module(text)
    out = Analysis(collectives={
        c: {"count": 0.0, "result_bytes": 0.0, "moved_bytes": 0.0}
        for c in COLLECTIVE_OPS
    })
    if "__entry__" not in comps:
        return out

    def visit(comp: Computation, mult: float, depth=0):
        if depth > 12:
            return
        for inst in comp.instrs:
            op = inst.op
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVE_OPS:
                _, res_b = _shape_elems_bytes(inst.type_str)
                s = _group_size(inst.rest, total_chips)
                if base == "all-gather":
                    moved = res_b * (s - 1) / max(s, 1)
                elif base == "all-reduce":
                    moved = 2.0 * res_b * (s - 1) / max(s, 1)
                elif base == "reduce-scatter":
                    moved = float(res_b) * (s - 1)
                elif base == "all-to-all":
                    moved = res_b * (s - 1) / max(s, 1)
                else:
                    moved = float(res_b)
                rec = out.collectives[base]
                rec["count"] += mult
                rec["result_bytes"] += res_b * mult
                rec["moved_bytes"] += moved * mult
                # collective results also traverse HBM
                out.hbm_bytes += res_b * mult
                continue
            if op == "while":
                body = _BODY_RE.search(inst.rest)
                cond = _COND_RE.search(inst.rest)
                mt = _TRIP_COUNT_RE.search(inst.rest)
                if mt:  # XLA annotates known trip counts — most reliable
                    trips = int(mt.group(1))
                elif cond and cond.group(1) in comps:
                    trips = max(_trip_count(comps[cond.group(1)]), 1)
                else:
                    trips = 1
                out.while_trip_counts.append(trips)
                if body and body.group(1) in comps:
                    visit(comps[body.group(1)], mult * trips, depth + 1)
                continue
            if op == "conditional":
                m = _BRANCHES_RE.search(inst.rest)
                if m:
                    branches = _OPERAND_RE.findall(m.group(1))
                    for b in branches:  # worst case: sum? use max-ish: avg
                        if b in comps:
                            visit(comps[b], mult / max(len(branches), 1),
                                  depth + 1)
                continue
            if op in ("fusion", "call", "custom-call"):
                m = _CALLS_RE.search(inst.rest) or (
                    re.search(r"to_apply=%?([\w.\-]+)", inst.rest))
                sub = comps.get(m.group(1)) if m else None
                if sub is not None:
                    # count dot/conv flops inside the fused computation
                    for sinst in sub.instrs:
                        if sinst.op == "dot":
                            out.dot_flops += _dot_flops(sinst, sub) * mult
                        elif sinst.op == "convolution":
                            out.dot_flops += _conv_flops(sinst, sub) * mult
                # HBM traffic: fusion result + per-parameter read volume
                # (a param consumed only through dynamic-slice/gather reads
                #  just the slice — the scan-over-layers weight access).
                _, res_b = _shape_elems_bytes(inst.type_str)
                opd_b = 0.0
                for i, o in enumerate(inst.operands):
                    t = comp.symbols.get(o)
                    if not t:
                        continue
                    full = _shape_elems_bytes(t)[1]
                    opd_b += (_fusion_param_read_bytes(sub, i, full)
                              if sub is not None else full)
                out.hbm_bytes += (res_b + opd_b) * mult
                continue
            if op == "dot":
                out.dot_flops += _dot_flops(inst, comp) * mult
            elif op == "convolution":
                out.dot_flops += _conv_flops(inst, comp) * mult
            if op in _SKIP_BYTES_OPS:
                continue
            _, res_b = _shape_elems_bytes(inst.type_str)
            if op in ("dynamic-slice", "gather", "slice"):
                out.hbm_bytes += 2.0 * res_b * mult  # read+write slice only
                continue
            if op == "dynamic-update-slice":
                upd = (comp.symbols.get(inst.operands[1])
                       if len(inst.operands) > 1 else None)
                upd_b = _shape_elems_bytes(upd)[1] if upd else res_b
                out.hbm_bytes += 2.0 * upd_b * mult  # in-place window write
                continue
            opd_b = 0
            for o in inst.operands:
                t = comp.symbols.get(o)
                if t:
                    opd_b += _shape_elems_bytes(t)[1]
            out.hbm_bytes += (res_b + opd_b) * mult

    visit(comps["__entry__"], 1.0)
    return out
