"""Production mesh definitions.

Functions, not module-level constants: importing this module never touches
jax device state. The dry-run sets XLA_FLAGS before any jax import to get
512 host platform devices.

Mesh semantics (DESIGN.md §2):
  * "model" — tensor parallelism inside one federated client (16 chips);
  * "data"  — the FL client axis: one slice per client;
  * "pod"   — second pod; in the federated regime pod×data = 32 clients,
    and the user-centric mixing collective crosses the pod boundary (DCI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small mesh for CPU smoke tests (uses however many devices exist)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(min(model, n // data), 1)
    return jax.make_mesh((data, model), ("data", "model"))


def client_axes(mesh) -> tuple:
    """Mesh axes that enumerate federated clients."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_clients(mesh) -> int:
    total = 1
    for a in client_axes(mesh):
        total *= mesh.shape[a]
    return total


def num_chips(mesh) -> int:
    total = 1
    for a in mesh.axis_names:
        total *= mesh.shape[a]
    return total
