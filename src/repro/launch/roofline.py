"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), per the assignment:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs          [s]
  memory     = HLO_bytes_per_chip / HBM_bw              [s]
  collective = collective_bytes_per_chip / link_bw      [s]

``compiled.cost_analysis()`` (on the SPMD-partitioned module → per-chip
numbers) supplies FLOPs and bytes; collective bytes come from parsing the
partitioned HLO text and summing per-op moved bytes:

  all-gather       result − operand  (received volume)
  all-reduce       2 × operand       (ring reduce+broadcast)
  reduce-scatter   operand − result
  all-to-all       operand
  collective-permute operand

MODEL_FLOPS uses the textbook 6·N·D (train) / 2·N·D (fwd-only), with N
replaced by N_active for MoE; the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat recompute, padding waste and dispatch overheads.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict

import jax
import numpy as np

from repro.configs.base import InputShape, ModelConfig

# TPU v5e-ish constants (assignment-specified)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return default  # replica_groups={} → all partitions


def parse_collectives(hlo_text: str, *, total_chips: int = 1) -> Dict[str, dict]:
    """Per-category {count, result_bytes, moved_bytes} (per-chip bytes).

    Moved bytes follow ring-algorithm accounting over the op's group size S
    (derived from replica_groups; result shapes are per-partition):
      all-gather: res·(S−1)/S received;  all-reduce: 2·res·(S−1)/S;
      reduce-scatter: res·(S−1) sent;    all-to-all: res·(S−1)/S;
      collective-permute: res.
    """
    out = {
        c: {"count": 0, "result_bytes": 0, "moved_bytes": 0.0}
        for c in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        result_t, op, is_start = m.group(1), m.group(2), m.group(3)
        res_b = _shape_bytes(result_t)
        s = _group_size(line, total_chips)
        rec = out[op]
        rec["count"] += 1
        rec["result_bytes"] += res_b
        if op == "all-gather":
            moved = res_b * (s - 1) / max(s, 1)
        elif op == "all-reduce":
            moved = 2.0 * res_b * (s - 1) / max(s, 1)
        elif op == "reduce-scatter":
            moved = float(res_b) * (s - 1)
        elif op == "all-to-all":
            moved = res_b * (s - 1) / max(s, 1)
        else:  # collective-permute
            moved = float(res_b)
        rec["moved_bytes"] += moved
    return out


def collective_bytes(hlo_text: str, *, total_chips: int = 1) -> float:
    return sum(
        v["moved_bytes"]
        for v in parse_collectives(hlo_text, total_chips=total_chips).values()
    )


# ------------------------------------------------------------ model FLOPs
def param_count(abs_params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abs_params))


def active_param_count(cfg: ModelConfig, total: int) -> int:
    """N_active: replace full expert FLOPs by top-k experts."""
    if cfg.moe_num_experts == 0:
        return total
    per_expert = 3 * cfg.d_model * (cfg.moe_d_ff or cfg.d_ff)
    moe_layers = cfg.num_layers - cfg.first_dense
    inactive = moe_layers * (cfg.moe_num_experts - cfg.moe_top_k) * per_expert
    return total - inactive


def model_flops(cfg: ModelConfig, shape: InputShape, n_active: int) -> float:
    """6·N·D for train, 2·N·D forward-only (prefill/decode)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1  # decode: one token per request
    return 2.0 * n_active * tokens


# ------------------------------------------------------------ aggregation
@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    agg: str
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    collectives: Dict[str, dict]
    model_flops_total: float
    param_count: int
    active_params: int
    memory_analysis: dict

    @property
    def compute_s(self):
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def collective_s(self):
        return self.collective_bytes_per_chip / LINK_BW

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self):
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    def to_dict(self):
        d = dataclasses.asdict(self)
        d.update(
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, dominant=self.dominant,
            useful_flops_ratio=self.useful_flops_ratio,
        )
        return d


def analyze(compiled, cfg: ModelConfig, shape: InputShape, *, mesh_name: str,
            chips: int, agg: str, abs_params_one) -> Roofline:
    from repro.launch import hlo_analysis

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some backends return [dict]
        cost = cost[0]
    text = compiled.as_text()
    # Trip-count-aware analysis (xla cost_analysis visits scan bodies once)
    ana = hlo_analysis.analyze_text(text, total_chips=chips)
    flops = ana.dot_flops
    byts = ana.hbm_bytes
    coll_b = ana.collective_bytes
    colls = dict(ana.collectives)
    colls["_xla_cost_analysis"] = {
        "flops_once": float(cost.get("flops", 0.0)),
        "bytes_once": float(cost.get("bytes accessed", 0.0)),
        "while_trip_counts": ana.while_trip_counts,
    }
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes",
                                            None),
        }
    except Exception as e:  # pragma: no cover - backend dependent
        mem = {"error": str(e)}
    n = param_count(abs_params_one)
    na = active_param_count(cfg, n)
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        agg=agg,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=byts,
        collective_bytes_per_chip=float(coll_b), collectives=colls,
        model_flops_total=model_flops(cfg, shape, na),
        param_count=n, active_params=na, memory_analysis=mem,
    )


def save(path: str, roof: Roofline):
    with open(path, "w") as f:
        json.dump(roof.to_dict(), f, indent=2, default=str)


def fmt_row(r: Roofline) -> str:
    return (
        f"{r.arch:18s} {r.shape:12s} {r.mesh:6s} {r.agg:13s} "
        f"comp={r.compute_s*1e3:9.3f}ms mem={r.memory_s*1e3:9.3f}ms "
        f"coll={r.collective_s*1e3:9.3f}ms dom={r.dominant:10s} "
        f"useful={r.useful_flops_ratio:6.3f}"
    )
