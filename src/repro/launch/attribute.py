"""Dry-run "profiler": attribute per-chip collective/HBM volume to ops.

Since there is no real TPU to trace, the profile is the partitioned HLO:
this tool loads a saved ``results/dryrun/*.hlo.zst``, walks the call graph
with trip counts (same engine as the roofline), and prints the top
contributors with their ``metadata op_name`` source markers — enough to
form §Perf hypotheses ("the 42×4 f32 activation all-reduces from the
attention out-projection dominate", etc).

  PYTHONPATH=src python -m repro.launch.attribute \
      results/dryrun/gemma2-9b__train_4k__single__user_centric.hlo.zst
"""
from __future__ import annotations

import argparse
import re
import sys
from collections import defaultdict

import zstandard

from repro.launch import hlo_analysis as H

_META_RE = re.compile(r'op_name="([^"]*)"')


def load_hlo(path: str) -> str:
    with open(path, "rb") as f:
        data = f.read()
    if path.endswith(".zst"):
        data = zstandard.ZstdDecompressor().decompress(data)
    return data.decode()


def attribute(text: str, *, total_chips: int = 256, top: int = 25):
    comps = H.parse_module(text)
    colls = defaultdict(lambda: [0.0, 0])  # key -> [moved_bytes, count]
    bytes_by = defaultdict(lambda: [0.0, 0])
    flops_by = defaultdict(lambda: [0.0, 0])

    def meta_of(inst):
        m = _META_RE.search(inst.rest)
        name = m.group(1) if m else "(no-metadata)"
        return name[:110]

    def visit(comp, mult, depth=0):
        if depth > 12:
            return
        for inst in comp.instrs:
            op = inst.op
            base = op[:-6] if op.endswith("-start") else op
            key = f"{base:20s} {meta_of(inst)}"
            if base in H.COLLECTIVE_OPS:
                _, res_b = H._shape_elems_bytes(inst.type_str)
                s = H._group_size(inst.rest, total_chips)
                if base == "all-gather":
                    moved = res_b * (s - 1) / max(s, 1)
                elif base == "all-reduce":
                    moved = 2.0 * res_b * (s - 1) / max(s, 1)
                elif base == "reduce-scatter":
                    moved = float(res_b) * (s - 1)
                elif base == "all-to-all":
                    moved = res_b * (s - 1) / max(s, 1)
                else:
                    moved = float(res_b)
                colls[key][0] += moved * mult
                colls[key][1] += mult
                continue
            if op == "while":
                body = H._BODY_RE.search(inst.rest)
                mt = H._TRIP_COUNT_RE.search(inst.rest)
                trips = int(mt.group(1)) if mt else 1
                if body and body.group(1) in comps:
                    visit(comps[body.group(1)], mult * trips, depth + 1)
                continue
            if op in ("fusion", "call"):
                m = H._CALLS_RE.search(inst.rest)
                sub = comps.get(m.group(1)) if m else None
                if sub is not None:
                    for sinst in sub.instrs:
                        if sinst.op in ("dot", "convolution"):
                            f = (H._dot_flops(sinst, sub) if sinst.op == "dot"
                                 else H._conv_flops(sinst, sub))
                            fk = f"{sinst.op:20s} {meta_of(sinst)}"
                            flops_by[fk][0] += f * mult
                            flops_by[fk][1] += mult
                _, res_b = H._shape_elems_bytes(inst.type_str)
                opd_b = 0.0
                for i, o in enumerate(inst.operands):
                    t = comp.symbols.get(o)
                    if not t:
                        continue
                    full = H._shape_elems_bytes(t)[1]
                    opd_b += (H._fusion_param_read_bytes(sub, i, full)
                              if sub is not None else full)
                bytes_by[key][0] += (res_b + opd_b) * mult
                bytes_by[key][1] += mult
                continue
            if op == "dot":
                f = H._dot_flops(inst, comp)
                flops_by[key][0] += f * mult
                flops_by[key][1] += mult
            if op in H._SKIP_BYTES_OPS:
                continue
            _, res_b = H._shape_elems_bytes(inst.type_str)
            if op in ("dynamic-slice", "gather", "slice"):
                bytes_by[key][0] += 2.0 * res_b * mult
                bytes_by[key][1] += mult
                continue
            if op == "dynamic-update-slice":
                upd = (comp.symbols.get(inst.operands[1])
                       if len(inst.operands) > 1 else None)
                upd_b = H._shape_elems_bytes(upd)[1] if upd else res_b
                bytes_by[key][0] += 2.0 * upd_b * mult
                bytes_by[key][1] += mult
                continue
            opd_b = sum(
                H._shape_elems_bytes(comp.symbols[o])[1]
                for o in inst.operands if o in comp.symbols
            )
            bytes_by[key][0] += (res_b + opd_b) * mult
            bytes_by[key][1] += mult

    visit(comps["__entry__"], 1.0)
    return colls, bytes_by, flops_by


def report(path: str, *, total_chips=256, top=25, out=sys.stdout):
    text = load_hlo(path)
    colls, bytes_by, flops_by = attribute(text, total_chips=total_chips)
    p = lambda *a: print(*a, file=out)
    for title, table, unit, scale in (
        ("COLLECTIVE moved bytes", colls, "GB", 1e9),
        ("HBM bytes", bytes_by, "GB", 1e9),
        ("dot FLOPs", flops_by, "GF", 1e9),
    ):
        total = sum(v[0] for v in table.values())
        p(f"\n=== {title}: total {total / scale:.2f} {unit}/chip ===")
        rows = sorted(table.items(), key=lambda kv: -kv[1][0])[:top]
        for k, (val, cnt) in rows:
            p(f"  {val / scale:10.2f} {unit} x{cnt:<6.0f} {k}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo_path")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    report(args.hlo_path, total_chips=args.chips, top=args.top)


if __name__ == "__main__":
    main()
