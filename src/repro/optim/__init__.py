from repro.optim.sgd import sgd_init, sgd_update
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedules import constant, cosine, warmup_cosine

__all__ = [
    "sgd_init", "sgd_update",
    "adamw_init", "adamw_update",
    "constant", "cosine", "warmup_cosine",
]
