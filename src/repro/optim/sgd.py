"""SGD with (heavy-ball) momentum — the paper's optimizer (η=0.1, β=0.9).

Pure functions over pytrees; no optax in this offline container.
``momentum_dtype`` lets large-model configs keep the buffer in bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, *, momentum: float = 0.9, momentum_dtype=None):
    if momentum == 0.0:
        return ()
    dt = momentum_dtype

    def buf(p):
        return jnp.zeros_like(p, dtype=dt or p.dtype)

    return jax.tree.map(buf, params)


def sgd_update(grads, state, params, *, lr, momentum: float = 0.9,
               weight_decay: float = 0.0):
    """Returns (new_params, new_state)."""
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    if momentum == 0.0:
        new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new_params, ()
    new_state = jax.tree.map(
        lambda v, g: (momentum * v.astype(g.dtype) + g).astype(v.dtype),
        state, grads,
    )
    new_params = jax.tree.map(
        lambda p, v: p - lr * v.astype(p.dtype), params, new_state
    )
    return new_params, new_state
