"""AdamW — provided for the transformer-zoo configs (not used by the paper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = state["count"] + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state["nu"], grads)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}
