"""Build the functional model bundle for a ModelConfig."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.configs.base import ModelConfig
from repro.models import transformer, whisper


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    forward: Callable[..., Any]  # (params, batch) -> (logits, aux)
    loss: Callable[..., Any]  # (params, batch) -> scalar
    init_cache: Callable[..., Any]  # (batch, max_len) -> caches
    decode_step: Callable[..., Any]  # (params, caches, tokens, pos)


def build(cfg: ModelConfig) -> Model:
    mod = whisper if cfg.family == "audio" else transformer
    return Model(
        cfg=cfg,
        init=lambda key: mod.init(key, cfg),
        forward=lambda p, b: mod.forward(p, b, cfg),
        loss=lambda p, b: mod.loss_fn(p, b, cfg),
        init_cache=lambda batch, max_len: mod.init_cache(cfg, batch, max_len),
        decode_step=lambda p, c, t, pos: mod.decode_step(p, c, t, pos, cfg),
    )
