"""Shared transformer building blocks (pure functional JAX)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- init utils
def normal_init(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def fan_in_init(key, shape, dtype=jnp.float32):
    scale = shape[0] ** -0.5
    return (scale * jax.random.normal(key, shape)).astype(dtype)


# ---------------------------------------------------------------- norms
def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(p, x, *, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale): zero-init == identity
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, *, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if kind == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------- RoPE
def rope(x, positions, *, base=10000.0, rope_dim=None):
    """Rotary embedding. x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    rd = rope_dim or dh
    half = rd // 2
    freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :rd], x[..., rd:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rd < dh else out


# ---------------------------------------------------------------- MLPs
def mlp_init(key, d_model, d_ff, kind, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": fan_in_init(k1, (d_model, d_ff), dtype),
            "w_up": fan_in_init(k2, (d_model, d_ff), dtype),
            "w_down": fan_in_init(k3, (d_ff, d_model), dtype),
        }
    if kind == "gelu":  # whisper-style 2-layer MLP with bias
        return {
            "w_up": fan_in_init(k1, (d_model, d_ff), dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": fan_in_init(k2, (d_ff, d_model), dtype),
            "b_down": jnp.zeros((d_model,), dtype),
        }
    raise ValueError(kind)


def mlp_apply(p, x, kind):
    if kind == "swiglu":
        act = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        return act @ p["w_down"]
    if kind == "geglu":
        act = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
        return act @ p["w_down"]
    if kind == "gelu":
        return jax.nn.gelu(x @ p["w_up"] + p["b_up"], approximate=True) @ p[
            "w_down"] + p["b_down"]
    raise ValueError(kind)


# ---------------------------------------------------------------- softcap
def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------- embedding
def embed_init(key, vocab, d_model, dtype=jnp.float32):
    return {"table": normal_init(key, (vocab, d_model), 0.02, dtype)}


def embed_lookup(p, tokens, *, scale=None):
    y = jnp.take(p["table"], tokens, axis=0)
    if scale is not None:
        y = y * jnp.asarray(scale, y.dtype)
    return y


def embed_logits(p, h):
    """Tied read-out: (B, S, D) @ (V, D)^T."""
    return jnp.einsum("...d,vd->...v", h, p["table"])


def sinusoidal_positions(length, d_model, dtype=jnp.float32):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d_model))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
