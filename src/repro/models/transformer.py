"""Config-driven decoder LM covering dense / MoE / SSM / hybrid / VLM.

Layers are grouped into a repeating pattern (cfg.attn_pattern for dense/
MoE, one SSM layer for ssm, (g−1)·mamba + 1 shared-attention slot for
zamba2-style hybrids) and the group stack is executed with ``lax.scan`` so
the HLO stays O(1) in depth — essential for CPU-hosted 512-device dry-run
compiles. Weights of the hybrid's attention slot are SHARED (stored once,
closed over), its KV caches are per-invocation (scanned).

Params layout:
  embed, (lm_head), final_norm, first_block?, shared_attn?, projector?,
  blocks: every leaf stacked over num_groups on axis 0.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, moe, ssm
from repro.models.attention import AttnConfig
from repro.models.layers import (
    embed_init,
    embed_logits,
    embed_lookup,
    fan_in_init,
    make_norm,
    mlp_apply,
    mlp_init,
    softcap,
)


# --------------------------------------------------------------- sub-configs
def attn_config(cfg: ModelConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_base=cfg.rope_base,
        rope_pct=cfg.rope_pct,
        logit_softcap=cfg.attn_softcap,
        pad_to=cfg.head_pad,
    )


def moe_config(cfg: ModelConfig) -> moe.MoEConfig:
    return moe.MoEConfig(
        d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff or cfg.d_ff,
        num_experts=cfg.moe_num_experts,
        top_k=cfg.moe_top_k,
        capacity_factor=cfg.capacity_factor,
        ep_axis=cfg.expert_axis,
    )


def ssm_config(cfg: ModelConfig) -> ssm.SSMConfig:
    return ssm.SSMConfig(
        d_model=cfg.d_model,
        state=cfg.ssm_state,
        headdim=cfg.ssm_headdim,
        expand=cfg.ssm_expand,
        chunk=cfg.ssm_chunk,
    )


def _group_slots(cfg: ModelConfig):
    """The layer kinds inside one scanned group."""
    if cfg.family == "ssm":
        return ("mamba",)
    if cfg.family == "hybrid":
        return ("mamba",) * (cfg.hybrid_group - 1) + ("shared_attn",)
    pat = []
    for a in cfg.attn_pattern:
        pat.append(f"attn_{a}")
    return tuple(pat)


# --------------------------------------------------------------- init
def _init_attn_layer(key, cfg: ModelConfig, dtype, *, moe_mlp: bool):
    ninit, _ = make_norm(cfg.norm)
    ks = jax.random.split(key, 2)
    p: Dict[str, Any] = {
        "ln_attn": ninit(cfg.d_model, dtype),
        "attn": attention.init(ks[0], attn_config(cfg), dtype),
        "ln_mlp": ninit(cfg.d_model, dtype),
    }
    if moe_mlp:
        p["moe"] = moe.init(ks[1], moe_config(cfg), dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    if cfg.post_norms:
        p["ln_post_attn"] = ninit(cfg.d_model, dtype)
        p["ln_post_mlp"] = ninit(cfg.d_model, dtype)
    return p


def _init_mamba_layer(key, cfg: ModelConfig, dtype):
    ninit, _ = make_norm(cfg.norm)
    return {
        "ln": ninit(cfg.d_model, dtype),
        "mamba": ssm.init(key, ssm_config(cfg), dtype),
    }


def _init_group(key, cfg: ModelConfig, dtype):
    slots = _group_slots(cfg)
    p = {}
    keys = jax.random.split(key, len(slots))
    moe_mlp = cfg.family == "moe"
    for i, (slot, k) in enumerate(zip(slots, keys)):
        if slot == "mamba":
            p[f"l{i}"] = _init_mamba_layer(k, cfg, dtype)
        elif slot == "shared_attn":
            ninit, _ = make_norm(cfg.norm)
            p[f"l{i}"] = {"ln": ninit(cfg.d_model, dtype)}  # weights shared
        else:
            p[f"l{i}"] = _init_attn_layer(k, cfg, dtype, moe_mlp=moe_mlp)
    return p


def init(key, cfg: ModelConfig):
    dtype = cfg.param_jdtype
    ninit, _ = make_norm(cfg.norm)
    ks = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": ninit(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": fan_in_init(ks[1], (cfg.d_model, cfg.padded_vocab), dtype)
        }
    params["blocks"] = jax.vmap(
        lambda k: _init_group(k, cfg, dtype)
    )(jax.random.split(ks[2], cfg.num_groups))
    if cfg.first_dense:
        params["first_block"] = _init_attn_layer(ks[3], cfg, dtype,
                                                 moe_mlp=False)
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_attn_layer(ks[4], cfg, dtype,
                                                 moe_mlp=False)
    if cfg.family == "vlm":
        params["projector"] = {
            "w": fan_in_init(ks[5], (cfg.patch_embed_dim, cfg.d_model), dtype),
            "b": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


# --------------------------------------------------------------- forward
def _apply_attn_layer(p, h, positions, cfg: ModelConfig, kind: str, *,
                      cache=None, pos=None, shared=None):
    """One attention(+mlp) layer; returns (h, new_cache)."""
    _, napply = make_norm(cfg.norm)
    acfg = attn_config(cfg)
    window = cfg.window if kind.endswith("local") else None
    wp = shared if shared is not None else p
    x = napply(p["ln_attn"] if "ln_attn" in p else p["ln"], h)
    if cache is None:
        attn_out, kv = attention.forward(wp["attn"], x, positions, acfg,
                                         window=window)
        new_cache = {"k": kv[0], "v": kv[1]}
    else:
        attn_out, new_cache = attention.decode(wp["attn"], x, cache, pos,
                                               acfg, window=window)
    if cfg.post_norms:
        attn_out = napply(wp["ln_post_attn"], attn_out)
    h = h + attn_out
    aux = jnp.zeros((), jnp.float32)
    if "moe" in wp:
        mlp_out, aux = moe.apply_auto(wp["moe"], napply(wp["ln_mlp"], h),
                                      moe_config(cfg))
        # §Perf: name the MoE output so remat_policy="save_moe" keeps it —
        # recomputing it in the backward would repeat the EP dispatch
        # round-trip (2 all_to_all + psum per layer).
        from jax.ad_checkpoint import checkpoint_name

        mlp_out = checkpoint_name(mlp_out, "moe")
    else:
        mlp_out = mlp_apply(wp["mlp"], napply(wp["ln_mlp"], h), cfg.mlp)
    if cfg.post_norms:
        mlp_out = napply(wp["ln_post_mlp"], mlp_out)
    return h + mlp_out, new_cache, aux


def _apply_group(group_p, h, positions, cfg: ModelConfig, *, caches=None,
                 pos=None, shared_attn=None):
    """Apply one scanned group. caches: dict keyed like group params."""
    slots = _group_slots(cfg)
    new_caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    for i, slot in enumerate(slots):
        p = group_p[f"l{i}"]
        cache_i = None if caches is None else caches.get(f"l{i}")
        if slot == "mamba":
            _, napply = make_norm(cfg.norm)
            x = napply(p["ln"], h)
            if caches is None:
                out, nc = ssm.forward(p["mamba"], x, ssm_config(cfg))
                new_caches[f"l{i}"] = nc
            else:
                out, nc = ssm.decode(p["mamba"], x, cache_i, ssm_config(cfg))
                new_caches[f"l{i}"] = nc
            h = h + out
        elif slot == "shared_attn":
            h, nc, aux = _apply_attn_layer(
                p, h, positions, cfg, "attn_global", cache=cache_i, pos=pos,
                shared=shared_attn,
            )
            new_caches[f"l{i}"] = nc
            aux_total += aux
        else:
            h, nc, aux = _apply_attn_layer(p, h, positions, cfg, slot,
                                           cache=cache_i, pos=pos)
            new_caches[f"l{i}"] = nc
            aux_total += aux
    return h, new_caches, aux_total


def _remat(body, cfg: ModelConfig):
    """Per-layer-group remat; policy="dots" saves matmul outputs so the
    backward pass reloads instead of recomputing them (§Perf iteration)."""
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_saveable)
    if cfg.remat_policy == "save_moe":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names("moe"))
    return jax.checkpoint(body)


def _embed_inputs(params, batch, cfg: ModelConfig):
    scale = cfg.d_model ** 0.5 if cfg.emb_scale else None
    h = embed_lookup(params["embed"], batch["tokens"], scale=scale)
    h = h.astype(cfg.act_jdtype)
    if cfg.family == "vlm":
        proj = (batch["patch_embeds"].astype(cfg.act_jdtype)
                @ params["projector"]["w"].astype(cfg.act_jdtype)
                + params["projector"]["b"].astype(cfg.act_jdtype))
        h = jnp.concatenate([proj, h], axis=1)
    return h


def _readout(params, h, cfg: ModelConfig):
    _, napply = make_norm(cfg.norm)
    h = napply(params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = embed_logits(params["embed"], h)
    else:
        logits = h @ params["lm_head"]["w"]
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if cfg.padded_vocab != cfg.vocab_size:  # mask padded vocab rows exactly
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def forward(params, batch, cfg: ModelConfig, *, return_cache: bool = False):
    """Full-sequence forward -> (logits f32, aux_loss[, prefill caches])."""
    h = _embed_inputs(params, batch, cfg)
    b, s = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    out_caches = {}

    if cfg.first_dense:
        h, fc, _ = _apply_attn_layer(params["first_block"], h, positions,
                                     cfg, "attn_global")
        out_caches["first_block"] = fc

    def body(carry, group_p):
        h, aux = carry
        h, caches_g, a = _apply_group(group_p, h, positions, cfg,
                                      shared_attn=shared)
        return (h, aux + a), (caches_g if return_cache else None)

    body_fn = _remat(body, cfg)
    (h, aux_total), block_caches = jax.lax.scan(
        body_fn, (h, aux_total), params["blocks"]
    )
    if return_cache:
        out_caches["blocks"] = block_caches
        return _readout(params, h, cfg), aux_total, out_caches
    return _readout(params, h, cfg), aux_total


def loss_fn(params, batch, cfg: ModelConfig, *, aux_weight=0.01):
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    if cfg.family == "vlm":  # labels only cover the token positions
        logits = logits[:, -labels.shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + aux_weight * aux


# --------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked (num_groups, ...) caches for the scanned blocks."""
    acfg = attn_config(cfg)
    slots = _group_slots(cfg)
    cdtype = cfg.act_jdtype

    def one_group(_):
        c = {}
        for i, slot in enumerate(slots):
            if slot == "mamba":
                c[f"l{i}"] = ssm.init_cache(batch, ssm_config(cfg), cdtype)
            else:
                kind = slot if slot != "shared_attn" else "attn_global"
                length = (min(cfg.window, max_len)
                          if kind.endswith("local") and cfg.window
                          else max_len)
                c[f"l{i}"] = attention.init_cache(batch, length, acfg, cdtype)
        return c

    caches = jax.vmap(one_group)(jnp.arange(cfg.num_groups))
    out = {"blocks": caches}
    if cfg.first_dense:
        out["first_block"] = attention.init_cache(batch, max_len, acfg, cdtype)
    return out


def decode_step(params, caches, tokens, pos, cfg: ModelConfig):
    """One-token decode. tokens: (B, 1) int32; pos: scalar int32.

    Returns (logits (B, 1, V) f32, new_caches).
    """
    scale = cfg.d_model ** 0.5 if cfg.emb_scale else None
    h = embed_lookup(params["embed"], tokens, scale=scale).astype(cfg.act_jdtype)
    b = h.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    shared = params.get("shared_attn")
    new_caches = dict(caches)

    if cfg.first_dense:
        h, nc, _ = _apply_attn_layer(params["first_block"], h, positions,
                                     cfg, "attn_global",
                                     cache=caches["first_block"], pos=pos)
        new_caches["first_block"] = nc

    def body(h, xs):
        group_p, group_c = xs
        h, nc, _ = _apply_group(group_p, h, positions, cfg, caches=group_c,
                                pos=pos, shared_attn=shared)
        return h, nc

    h, block_caches = jax.lax.scan(body, h, (params["blocks"],
                                             caches["blocks"]))
    new_caches["blocks"] = block_caches
    return _readout(params, h, cfg), new_caches
