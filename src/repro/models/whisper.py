"""Whisper-style encoder-decoder transformer backbone.

Per the assignment carve-out, the mel-spectrogram + conv feature extractor
is a STUB: ``input_specs`` provides precomputed frame embeddings
(B, encoder_seq, d_model). This module implements the full transformer:
pre-LN encoder (sinusoidal positions, bidirectional), decoder with learned
positions, causal self-attention (cached), per-layer cross-attention over
encoder output, GELU MLPs, tied readout. No RoPE anywhere (faithful to
arXiv:2212.04356). ``decode_32k`` is a beyond-spec stress config (real
Whisper caps at 448 decoder positions); the learned table is sized
cfg.max_pos to make it lowerable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention
from repro.models.attention import AttnConfig
from repro.models.layers import (
    embed_init,
    embed_logits,
    embed_lookup,
    layernorm,
    layernorm_init,
    mlp_apply,
    mlp_init,
    normal_init,
    sinusoidal_positions,
)


def attn_config(cfg: ModelConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=True,
        use_rope=False,
        pad_to=cfg.head_pad,
    )


def _init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": layernorm_init(cfg.d_model, dtype),
        "attn": attention.init(k1, attn_config(cfg), dtype),
        "ln_mlp": layernorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln_self": layernorm_init(cfg.d_model, dtype),
        "self_attn": attention.init(k1, attn_config(cfg), dtype),
        "ln_cross": layernorm_init(cfg.d_model, dtype),
        "cross_attn": attention.init(k2, attn_config(cfg), dtype),
        "ln_mlp": layernorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def init(key, cfg: ModelConfig):
    dtype = cfg.param_jdtype
    ks = jax.random.split(key, 4)
    return {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "pos_embed": normal_init(ks[1], (cfg.max_pos, cfg.d_model), 0.01,
                                 dtype),
        "enc_blocks": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
            jax.random.split(ks[2], cfg.encoder_layers)
        ),
        "enc_final_norm": layernorm_init(cfg.d_model, dtype),
        "dec_blocks": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
            jax.random.split(ks[3], cfg.num_layers)
        ),
        "final_norm": layernorm_init(cfg.d_model, dtype),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, T_enc, D) stub-frontend embeddings -> (B, T_enc, D)."""
    acfg = attn_config(cfg)
    h = frames.astype(cfg.act_jdtype)
    h = h + sinusoidal_positions(h.shape[1], cfg.d_model, h.dtype)[None]
    b, t = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(h, p):
        x = layernorm(p["ln_attn"], h)
        h = h + attention.bidirectional(p["attn"], x, positions, acfg)
        h = h + mlp_apply(p["mlp"], layernorm(p["ln_mlp"], h), "gelu")
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["enc_blocks"])
    return layernorm(params["enc_final_norm"], h)


def _dec_layer(p, h, positions, enc_out, cfg, *, cache=None, pos=None,
               cross_kv=None):
    acfg = attn_config(cfg)
    x = layernorm(p["ln_self"], h)
    if cache is None:
        out, kv = attention.forward(p["self_attn"], x, positions, acfg)
        new_cache = {"k": kv[0], "v": kv[1]}
    else:
        out, new_cache = attention.decode(p["self_attn"], x, cache, pos, acfg)
    h = h + out
    x = layernorm(p["ln_cross"], h)
    kv = cross_kv if cross_kv is not None else attention.encode_kv(
        p["cross_attn"], enc_out, acfg)
    h = h + attention.cross(p["cross_attn"], x, kv, acfg)
    h = h + mlp_apply(p["mlp"], layernorm(p["ln_mlp"], h), "gelu")
    return h, new_cache


def decode_train(params, tokens, enc_out, cfg: ModelConfig, *,
                 return_cache: bool = False):
    """Teacher-forced decoder forward -> logits (B, S, V) f32."""
    h = embed_lookup(params["embed"], tokens).astype(cfg.act_jdtype)
    b, s = tokens.shape
    h = h + params["pos_embed"][None, :s].astype(h.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    acfg = attn_config(cfg)

    def body(h, p):
        h, kv = _dec_layer(p, h, positions, enc_out, cfg)
        ys = None
        if return_cache:
            cross = attention.encode_kv(p["cross_attn"], enc_out, acfg)
            ys = {"self": kv, "cross_kv": jnp.stack(cross)}
        return h, ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, caches = jax.lax.scan(body_fn, h, params["dec_blocks"])
    h = layernorm(params["final_norm"], h)
    logits = _masked_logits(params, h, cfg)
    if return_cache:
        return logits, caches
    return logits


def _masked_logits(params, h, cfg: ModelConfig):
    logits = embed_logits(params["embed"], h).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def forward(params, batch, cfg: ModelConfig, *, return_cache: bool = False):
    enc = encode(params, batch["frames"], cfg)
    aux = jnp.zeros((), jnp.float32)
    if return_cache:
        logits, caches = decode_train(params, batch["tokens"], enc, cfg,
                                      return_cache=True)
        return logits, aux, caches
    return decode_train(params, batch["tokens"], enc, cfg), aux


def loss_fn(params, batch, cfg: ModelConfig, **_):
    logits, _ = forward(params, batch, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], -1)[..., 0]
    return jnp.mean(nll)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               enc_out=None, params=None):
    """Decoder self-attn caches + (optionally precomputed) cross K/V."""
    acfg = attn_config(cfg)
    self_caches = jax.vmap(
        lambda _: attention.init_cache(batch, max_len, acfg, cfg.act_jdtype)
    )(jnp.arange(cfg.num_layers))
    if enc_out is not None:
        cross = jax.vmap(
            lambda p: jnp.stack(attention.encode_kv(p["cross_attn"], enc_out,
                                                    acfg))
        )(params["dec_blocks"])
    else:
        cross = jnp.zeros(
            (cfg.num_layers, 2, batch, cfg.encoder_seq, acfg.hkv_eff,
             cfg.resolved_head_dim),
            cfg.act_jdtype,
        )
    return {"self": self_caches, "cross_kv": cross}


def decode_step(params, caches, tokens, pos, cfg: ModelConfig):
    """One-token decode with cached encoder cross-K/V."""
    h = embed_lookup(params["embed"], tokens).astype(cfg.act_jdtype)
    h = h + jax.lax.dynamic_slice_in_dim(
        params["pos_embed"], pos, 1, axis=0
    )[None].astype(h.dtype)
    positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)

    def body(h, xs):
        p, cache, cross = xs
        h, nc = _dec_layer(p, h, positions, None, cfg, cache=cache, pos=pos,
                           cross_kv=(cross[0], cross[1]))
        return h, nc

    h, new_self = jax.lax.scan(
        body, h, (params["dec_blocks"], caches["self"], caches["cross_kv"])
    )
    h = layernorm(params["final_norm"], h)
    logits = _masked_logits(params, h, cfg)
    return logits, {"self": new_self, "cross_kv": caches["cross_kv"]}
