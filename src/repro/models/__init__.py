from repro.models import lenet  # noqa: F401
