"""LeNet-5 in pure JAX (paper §V-A: all experiments use LeNet-5).

Functional: ``init(key, ...) -> params`` pytree, ``apply(params, x) -> logits``.
Input is NHWC; the paper's 28×28×1 (EMNIST) and 32×32×3 (CIFAR) both work.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _conv(x, w, b, *, padding):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _avg_pool(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    ) / 4.0


def _glorot(key, shape):
    fan_in = int(jnp.prod(jnp.asarray(shape[:-1])))
    fan_out = shape[-1]
    limit = (6.0 / (fan_in + fan_out)) ** 0.5
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def init(key, *, input_hw=(28, 28), channels=1, num_classes=47):
    h, w = input_hw
    ks = jax.random.split(key, 5)
    # flatten size after two valid 5x5 convs + 2x2 pools
    h1, w1 = h - 4, w - 4
    h2, w2 = h1 // 2 - 4, w1 // 2 - 4
    flat = (h2 // 2) * (w2 // 2) * 16
    return {
        "c1_w": _glorot(ks[0], (5, 5, channels, 6)),
        "c1_b": jnp.zeros((6,)),
        "c2_w": _glorot(ks[1], (5, 5, 6, 16)),
        "c2_b": jnp.zeros((16,)),
        "f1_w": _glorot(ks[2], (flat, 120)),
        "f1_b": jnp.zeros((120,)),
        "f2_w": _glorot(ks[3], (120, 84)),
        "f2_b": jnp.zeros((84,)),
        "f3_w": _glorot(ks[4], (84, num_classes)),
        "f3_b": jnp.zeros((num_classes,)),
    }


def apply(params, x):
    """x: (batch, H, W, C) float32 -> logits (batch, num_classes)."""
    y = jnp.tanh(_conv(x, params["c1_w"], params["c1_b"], padding="VALID"))
    y = _avg_pool(y)
    y = jnp.tanh(_conv(y, params["c2_w"], params["c2_b"], padding="VALID"))
    y = _avg_pool(y)
    y = y.reshape(y.shape[0], -1)
    y = jnp.tanh(y @ params["f1_w"] + params["f1_b"])
    y = jnp.tanh(y @ params["f2_w"] + params["f2_b"])
    return y @ params["f3_w"] + params["f3_b"]
