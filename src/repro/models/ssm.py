"""Mamba2 (SSD — state-space duality) block, chunked, TPU-friendly.

Follows arXiv:2405.21060: scalar per-head decay a_t = exp(Δ_t·A_h), rank-1
state update h_t = a_t·h_{t−1} + Δ_t·(x_t ⊗ B_t), readout y_t = C_t·h_t +
D·x_t, with the SSD *chunked* evaluation: intra-chunk terms become a
(Q × Q) masked matmul (MXU work, like attention), inter-chunk terms a
recurrence over chunk states carried by ``lax.scan``. Sequence parallelism
shards heads on the "model" axis; the scan carries only (B, H, P, N)
states. Decode keeps {conv window, SSM state} as the cache — O(1) in
context length, which is why `long_500k` is trivial for SSM archs.

Structure per block: in_proj → short depthwise causal conv (width 4) on
(x, B, C) → SSD → gated RMSNorm (silu(z)) → out_proj.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import fan_in_init, rmsnorm, rmsnorm_init


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    state: int = 128  # N
    headdim: int = 64  # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def num_heads(self):
        return self.d_inner // self.headdim

    @property
    def conv_channels(self):
        return self.d_inner + 2 * self.state


def init(key, cfg: SSMConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    di, n, h = cfg.d_inner, cfg.state, cfg.num_heads
    # in_proj emits [z, x, B, C, dt]
    return {
        "in_proj": fan_in_init(ks[0], (cfg.d_model, 2 * di + 2 * n + h), dtype),
        "conv_w": fan_in_init(ks[1], (cfg.conv_width, cfg.conv_channels), dtype),
        "conv_b": jnp.zeros((cfg.conv_channels,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": fan_in_init(ks[2], (di, cfg.d_model), dtype),
    }


def _split_proj(p, x, cfg: SSMConfig):
    di, n, h = cfg.d_inner, cfg.state, cfg.num_heads
    zxbcdt = x @ p["in_proj"]
    z, xc, b, c, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (.., S, H)
    return z, xc, b, c, dt


def _causal_conv(xbc, conv_w, conv_b, *, prev=None):
    """Depthwise causal conv along S. xbc: (B, S, C); prev: (B, W−1, C)."""
    w = conv_w.shape[0]
    pad = prev if prev is not None else jnp.zeros(
        (xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype
    )
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        full[:, i: i + xbc.shape[1], :] * conv_w[i][None, None, :]
        for i in range(w)
    )
    return jax.nn.silu(out + conv_b), full[:, -(w - 1):, :]


def _ssd_chunked(xh, b, c, dt, a_log, cfg: SSMConfig, h0=None):
    """Chunked SSD scan.

    xh: (B, S, H, P); b/c: (B, S, N); dt: (B, S, H).
    Returns (y (B,S,H,P), h_final (B,H,P,N)).

    §Perf (mamba2 memory hillclimb): the intra-chunk tensors (decay mask M
    is (B, nc, Q, Q, H) — B·S·Q·H elements, LINEAR in the chunk size Q)
    dominate HBM traffic. They are therefore materialized in the model's
    compute dtype (bf16 at scale) with f32 accumulation on the MXU; the
    decay *cumsum* and the inter-chunk state recurrence stay f32 (the
    recurrence is the numerically-sensitive part). Chunk=128 keeps the
    matmuls lane-aligned while halving M traffic vs 256.
    """
    B, S, H, P = xh.shape
    N, Q = cfg.state, min(cfg.chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    A = -jnp.exp(a_log)  # (H,)
    cdt = xh.dtype  # compute dtype for the big intra-chunk tensors

    def resh(t, tail):
        return t.reshape((B, nc, Q) + tail)

    xc_ = resh(xh, (H, P))
    b_ = resh(b.astype(cdt), (N,))
    c_ = resh(c.astype(cdt), (N,))
    dt_ = resh(dt, (H,))  # f32 (from softplus)
    l = dt_ * A[None, None, None, :]  # (B,nc,Q,H) log-decay, f32
    cum = jnp.cumsum(l, axis=2)  # inclusive cumsum within chunk, f32

    # intra-chunk: M[t,s] = exp(cum_t − cum_s)·(C_t·B_s)·dt_s, s ≤ t
    cb = jnp.einsum("bqtn,bqsn->bqts", c_, b_,
                    preferred_element_type=jnp.float32)  # (B,nc,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    # Mask the *exponent*, not exp's output: acausal entries have decay > 0
    # and can overflow to inf, which exp's VJP turns into inf·0 = NaN even
    # though the forward value is masked away.
    decay = jnp.where(causal[None, None, :, :, None], decay, -jnp.inf)
    m = (jnp.exp(decay) * cb[..., None] * dt_[:, :, None, :, :]).astype(cdt)
    y_intra = jnp.einsum("bqtsh,bqshp->bqthp", m, xc_,
                         preferred_element_type=jnp.float32)

    # chunk summaries: S_c = Σ_s exp(cumQ − cum_s)·dt_s·(x_s ⊗ B_s)
    tail_decay = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H) f32
    s_chunk = jnp.einsum(
        "bqsh,bqshp,bqsn->bqhpn", (tail_decay * dt_).astype(cdt), xc_, b_,
        preferred_element_type=jnp.float32,
    )  # (B,nc,H,P,N) f32
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) f32

    def scan_fn(h, inp):
        s_c, dec = inp  # (B,H,P,N), (B,H)
        h_new = h * dec[:, :, None, None] + s_c
        return h_new, h

    h_init = h0 if h0 is not None else jnp.zeros((B, H, P, N), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        scan_fn,
        h_init,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,H,P,N) state entering chunk

    # inter-chunk readout: y_t += C_t · (exp(cum_t)·h_prev)
    y_inter = jnp.einsum(
        "bqtn,bqth,bqhpn->bqthp", c_, jnp.exp(cum).astype(cdt),
        h_prev.astype(cdt), preferred_element_type=jnp.float32,
    )
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(xh.dtype), h_last


def forward(p, x, cfg: SSMConfig, *, h0=None, conv_prev=None):
    """Full-sequence SSD. x: (B, S, D) -> (y, cache)."""
    z, xc, b, c, dt = _split_proj(p, x, cfg)
    xbc = jnp.concatenate([xc, b, c], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], prev=conv_prev)
    di, n = cfg.d_inner, cfg.state
    xc, b, c = jnp.split(xbc, [di, di + n], axis=-1)
    xh = xc.reshape(x.shape[0], x.shape[1], cfg.num_heads, cfg.headdim)
    y, h = _ssd_chunked(xh, b, c, dt, p["A_log"], cfg, h0=h0)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(x.shape[0], x.shape[1], di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], {"h": h, "conv": conv_state}


def init_cache(batch, cfg: SSMConfig, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.num_heads, cfg.headdim, cfg.state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_channels), dtype),
    }


def decode(p, x, cache, cfg: SSMConfig):
    """One-token step. x: (B, 1, D) -> (y (B,1,D), new_cache)."""
    z, xc, b, c, dt = _split_proj(p, x, cfg)
    xbc = jnp.concatenate([xc, b, c], axis=-1)
    xbc, conv_state = _causal_conv(
        xbc, p["conv_w"], p["conv_b"], prev=cache["conv"].astype(xbc.dtype)
    )
    di, n = cfg.d_inner, cfg.state
    xc, b, c = jnp.split(xbc, [di, di + n], axis=-1)
    B = x.shape[0]
    xh = xc.reshape(B, cfg.num_heads, cfg.headdim).astype(jnp.float32)
    bt = b[:, 0].astype(jnp.float32)  # (B, N)
    ct = c[:, 0].astype(jnp.float32)
    dtt = dt[:, 0]  # (B, H)
    a = jnp.exp(dtt * (-jnp.exp(p["A_log"]))[None, :])  # (B, H)
    h = cache["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtt, xh, bt
    )
    y = jnp.einsum("bhpn,bn->bhp", h, ct) + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"], {"h": h, "conv": conv_state}
