"""Mixture-of-Experts layer with sort-based grouped dispatch.

The classic Mesh-TF one-hot dispatch materializes an (N, E, C) tensor with
C ≈ k·N/E — O(N²k) memory, unusable at 32k–500k tokens. Instead we sort the
(token, expert) assignment pairs by expert id and scatter each expert's
tokens into a fixed-capacity (E, C, D) buffer:

  1. router top-k → ids (N, k), weights (N, k);
  2. stable argsort of flattened ids groups tokens by expert;
  3. slot-in-expert = rank − segment_start (via searchsorted);
  4. scatter tokens into (E, C+1, D); slot ≥ C overflows into a discard
     column (token dropped — capacity_factor controls drop rate);
  5. per-expert SwiGLU via einsum over the (E, C, D) buffer (MXU-friendly);
  6. gather + weighted combine back to (N, D).

Memory is O(k·N·cf·D) — linear in tokens. Router uses f32 softmax; aux
load-balancing loss (Switch-style) is returned for training.

Sharding: the expert axis E of the buffers/weights takes the config's
``expert_axis`` mesh axis ("data" for kimi's 384-expert EP, None for
mixtral's 8 tensor-parallel experts); d_ff takes "model".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import fan_in_init, normal_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int  # per-expert hidden
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_softcap: float | None = None
    ep_axis: str | None = None  # mesh axis for expert parallelism


# Deployment context for the shard_map expert-parallel path (set by the
# launcher; None on CPU/smoke where the dense sort-dispatch path runs).
_EP_MESH = None


def set_ep_mesh(mesh):
    global _EP_MESH
    _EP_MESH = mesh


def init(key, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    return {
        "router": normal_init(ks[0], (d, e), 0.02, jnp.float32),
        "w_gate": fan_in_init(ks[1], (e, d, f), dtype),
        "w_up": fan_in_init(ks[2], (e, d, f), dtype),
        "w_down": jax.vmap(lambda k: fan_in_init(k, (f, d), dtype))(
            jax.random.split(ks[3], e)
        ),
    }


def capacity(num_tokens: int, cfg: MoEConfig) -> int:
    c = int(cfg.top_k * num_tokens * cfg.capacity_factor / cfg.num_experts)
    return max(c - c % -8, 8)  # round up to 8


def apply(p, x, cfg: MoEConfig):
    """x: (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(n, cfg)
    xt = x.reshape(n, d)

    logits = (xt.astype(jnp.float32) @ p["router"])  # (N, E)
    if cfg.router_softcap:
        logits = cfg.router_softcap * jnp.tanh(logits / cfg.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)  # (N, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss.
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(top_ids[:, 0], e, dtype=jnp.float32), axis=0
    )
    aux = e * jnp.sum(me * ce)

    flat_ids = top_ids.reshape(-1)  # (N·k,)
    flat_w = top_w.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_ids, stable=True)
    s_ids = flat_ids[order]
    s_tok = tok_idx[order]
    seg_start = jnp.searchsorted(s_ids, jnp.arange(e), side="left")
    slot = jnp.arange(n * k) - seg_start[s_ids]
    slot_c = jnp.where(slot < c, slot, c)  # overflow -> discard column

    # dispatch: (E, C+1, D); discard column c collects dropped tokens.
    buf = jnp.zeros((e, c + 1, d), x.dtype)
    buf = buf.at[s_ids, slot_c].set(xt[s_tok], mode="drop")
    hidden = buf[:, :c, :]

    act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hidden, p["w_gate"]))
    act = act * jnp.einsum("ecd,edf->ecf", hidden, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", act, p["w_down"])  # (E, C, D)

    # combine: gather each assignment's expert output, weight, scatter-add.
    out_pad = jnp.concatenate([out, jnp.zeros((e, 1, d), out.dtype)], axis=1)
    gathered = out_pad[s_ids, slot_c]  # (N·k, D); dropped rows are zero
    weighted = gathered * flat_w[order][:, None].astype(gathered.dtype)
    y = jnp.zeros((n, d), x.dtype).at[s_tok].add(weighted)
    return y.reshape(b, s, d), aux


def _round8(c: int) -> int:
    return max(c - c % -8, 8)


def apply_expert_parallel(p, x, cfg: MoEConfig, *, cf2: float = 1.5):
    """shard_map expert-parallel MoE (§Perf, kimi hillclimb).

    GSPMD auto-partitioning of the sort-dispatch scatter/gather across a
    data-sharded expert buffer lowers to full-result all-reduces (measured
    162 TB/chip/step on kimi train_4k). This path makes the communication
    explicit and minimal:

      1. per data-rank: route local tokens, bucket by owner rank
         (capacity C = k·n·cf/R), `all_to_all` over the expert axis;
      2. per owner: group received rows by local expert (capacity
         C2 = R·C·cf2/E_loc), run the TP experts (d_ff sharded on
         "model"), `psum("model")` the F-shard partial outputs in bf16;
      3. `all_to_all` rows back, weighted scatter-add at the source.

    Per-layer per-chip volume ≈ 2·(kN/R)·cf·D·bytes (a2a) +
    2·(kN/R)·cf·D·2B (psum) — O(dispatched tokens), not O(buffer).
    Requires ``set_ep_mesh(mesh)`` and cfg.ep_axis (kimi: "data").
    """
    mesh = _EP_MESH
    assert mesh is not None and cfg.ep_axis is not None
    from jax.sharding import PartitionSpec as P

    data_axis = cfg.ep_axis
    model_axis = "model"
    R = mesh.shape[data_axis]
    M = mesh.shape[model_axis]
    e, k, d = cfg.num_experts, cfg.top_k, cfg.d_model
    e_loc = e // R
    b, s, _ = x.shape
    n = (b // R) * s  # local tokens per data rank (per pod)
    pod = ("pod",) if "pod" in mesh.axis_names else ()
    dp = (pod + (data_axis,)) if pod else data_axis
    if pod:
        n = n // mesh.shape["pod"]
    cap = _round8(int(k * n * cfg.capacity_factor / R))
    cap2 = _round8(min(int(R * cap * cf2 / e_loc), R * cap))

    def local_fn(router, wg, wu, wd, xs):
        b_loc, s_, d_ = xs.shape
        nn = b_loc * s_
        xt = xs.reshape(nn, d_)
        logits = xt.astype(jnp.float32) @ router  # (n, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_ids = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        me = jax.lax.pmean(jnp.mean(probs, axis=0), data_axis)
        ce = jax.lax.pmean(
            jnp.mean(jax.nn.one_hot(top_ids[:, 0], e, dtype=jnp.float32), 0),
            data_axis,
        )
        aux = e * jnp.sum(me * ce)

        # ---- bucket assignments by destination data-rank
        dst = (top_ids // e_loc).reshape(-1)  # (n·k,)
        eloc = (top_ids % e_loc).reshape(-1)
        w_flat = top_w.reshape(-1)
        order = jnp.argsort(dst, stable=True)
        sd = dst[order]
        st = order // k  # source token of each sorted assignment
        seg = jnp.searchsorted(sd, jnp.arange(R), side="left")
        slot = jnp.arange(nn * k) - seg[sd]
        slot_c = jnp.where(slot < cap, slot, cap)  # cap column = discard

        send_x = jnp.zeros((R, cap + 1, d_), xs.dtype)
        send_x = send_x.at[sd, slot_c].set(xt[st], mode="drop")
        send_e = jnp.full((R, cap + 1), -1, jnp.int32)
        send_e = send_e.at[sd, slot_c].set(eloc[order], mode="drop")

        recv_x = jax.lax.all_to_all(send_x[:, :cap], data_axis, 0, 0)
        recv_e = jax.lax.all_to_all(send_e[:, :cap], data_axis, 0, 0)
        rx = recv_x.reshape(R * cap, d_)
        re_ = recv_e.reshape(R * cap)

        # ---- group received rows by local expert
        key2 = jnp.where(re_ >= 0, re_, e_loc)  # empties sort to the end
        order2 = jnp.argsort(key2, stable=True)
        se = key2[order2]
        seg2 = jnp.searchsorted(se, jnp.arange(e_loc), side="left")
        slot2 = jnp.arange(R * cap) - seg2[jnp.minimum(se, e_loc - 1)]
        slot2_c = jnp.where(slot2 < cap2, slot2, cap2)

        buf = jnp.zeros((e_loc, cap2 + 1, d_), xs.dtype)
        buf = buf.at[se, slot2_c].set(rx[order2], mode="drop")  # se=e_loc drops
        hidden = buf[:, :cap2]

        act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hidden, wg,
                                     preferred_element_type=jnp.float32))
        act = act * jnp.einsum("ecd,edf->ecf", hidden, wu,
                               preferred_element_type=jnp.float32)
        out = jnp.einsum("ecf,efd->ecd", act.astype(xs.dtype), wd,
                         preferred_element_type=jnp.float32)  # partial (F-shard)

        # un-group, reduce the F-shards in bf16, send back
        out_pad = jnp.zeros((e_loc + 1, cap2 + 1, d_), xs.dtype)
        out_pad = out_pad.at[:e_loc, :cap2].set(out.astype(xs.dtype))
        rows_sorted = out_pad[jnp.minimum(se, e_loc), slot2_c]
        rows = jnp.zeros((R * cap, d_), xs.dtype).at[order2].set(rows_sorted)
        rows = jax.lax.psum(rows, model_axis)
        ret = jax.lax.all_to_all(rows.reshape(R, cap, d_), data_axis, 0, 0)

        # ---- weighted combine at the source
        ret_pad = jnp.concatenate(
            [ret, jnp.zeros((R, 1, d_), ret.dtype)], axis=1)
        contrib = ret_pad[sd, slot_c].astype(jnp.float32)
        ws = w_flat[order][:, None]
        y = jnp.zeros((nn, d_), jnp.float32).at[st].add(contrib * ws)
        return y.reshape(b_loc, s_, d_).astype(xs.dtype), aux

    if hasattr(jax, "shard_map"):  # jax >= 0.6
        smap = jax.shard_map
        relax = {"check_vma": False}
    else:  # jax 0.4/0.5: experimental API, `check_rep` spelling
        from jax.experimental.shard_map import shard_map as smap
        relax = {"check_rep": False}
    y, aux = smap(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(None, None),  # router replicated
            P(data_axis, None, model_axis),  # wg (E, D, F)
            P(data_axis, None, model_axis),  # wu
            P(data_axis, model_axis, None),  # wd (E, F, D)
            P(dp, None, None),  # x batch-sharded
        ),
        out_specs=(P(dp, None, None), P()),
        **relax,
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return y, aux


def apply_auto(p, x, cfg: MoEConfig):
    """Pick the EP shard_map path when deployed with an expert axis."""
    if cfg.ep_axis is not None and _EP_MESH is not None:
        return apply_expert_parallel(p, x, cfg)
    return apply(p, x, cfg)


def apply_reference(p, x, cfg: MoEConfig):
    """O(E·N) oracle: every expert on every token, masked combine.

    Used only in tests to validate the sort-based dispatch (drops aside).
    """
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    if cfg.router_softcap:
        logits = cfg.router_softcap * jnp.tanh(logits / cfg.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    def expert(eidx):
        act = jax.nn.silu(xt @ p["w_gate"][eidx]) * (xt @ p["w_up"][eidx])
        return act @ p["w_down"][eidx]  # (N, D)

    all_out = jax.vmap(expert)(jnp.arange(cfg.num_experts))  # (E, N, D)
    w_full = jnp.zeros((xt.shape[0], cfg.num_experts), jnp.float32)
    w_full = jax.vmap(lambda w, i, row: row.at[i].set(w))(top_w, top_ids, w_full)
    y = jnp.einsum("ne,end->nd", w_full, all_out.astype(jnp.float32))
    return y.reshape(b, s, d).astype(x.dtype)
