"""Grouped-query attention with RoPE, sliding windows, softcap, KV caches.

Three entry points:
  * ``forward``  — training / prefill self-attention (causal or windowed);
  * ``decode``   — one new token against a (possibly rolling) KV cache;
  * ``cross``    — encoder-decoder cross attention (whisper).

Cache convention: ``{"k": (B, W, Hkv, Dh), "v": ..., "pos": (W,) int32}``
where ``pos[w]`` is the absolute position stored in slot ``w`` (−1 = empty).
Global-attention layers use W = max context; sliding-window layers use
W = window and write at slot ``pos % W`` (rolling buffer, Mistral-style) —
this is what makes `long_500k` affordable for SWA architectures.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.models.layers import fan_in_init, rope, softcap


@functools.lru_cache(maxsize=None)
def plan_heads(num_heads: int, num_kv: int, pad_to: int):
    """Head-padding plan for tensor-parallel deployment.

    jax requires explicitly-sharded dims to be divisible by the mesh axis,
    so GQA head counts that don't divide the 16-way "model" axis must be
    transformed EXACTLY:

      * repeat-KV: replicate each kv head r times (identical attention
        function, r× KV cache) when r = pad_to/gcd is cheap;
      * zero-pad: append zero kv heads attended only by zero q heads
        (their wo rows are zero ⇒ contribution is exactly 0).

    Picks whichever wastes less KV cache. Returns
      (hq_eff, hkv_eff, q_of_slot, kv_of_slot)
    where *_of_slot map padded slots to original head indices (−1 = zero
    slot). The waste is architecture-visible and shows up in §Roofline's
    useful-FLOPs ratio — that is intentional.
    """
    if pad_to <= 1 or num_kv % pad_to == 0:
        return (num_heads, num_kv, tuple(range(num_heads)),
                tuple(range(num_kv)))
    g0 = num_heads // num_kv
    r_rep = pad_to // math.gcd(num_kv, pad_to)
    cost_rep = r_rep  # cache multiplier
    nkv_pad = -(-num_kv // pad_to) * pad_to
    cost_pad = nkv_pad / num_kv
    if cost_rep <= cost_pad:
        hkv = num_kv * r_rep
        g = -(-g0 // r_rep)
        kv_of = tuple(j // r_rep for j in range(hkv))
        q_of = [-1] * (hkv * g)
        for k in range(num_kv):
            for i in range(g0):
                t, gg = i % r_rep, i // r_rep
                q_of[(k * r_rep + t) * g + gg] = k * g0 + i
    else:
        hkv = nkv_pad
        g = g0
        kv_of = tuple(k if k < num_kv else -1 for k in range(hkv))
        q_of = [-1] * (hkv * g)
        for k in range(num_kv):
            for gg in range(g0):
                q_of[k * g + gg] = k * g0 + gg
    return hkv * g, hkv, tuple(q_of), kv_of


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_base: float = 10000.0
    rope_pct: float = 1.0  # stablelm2 uses partial rotary (25%)
    logit_softcap: float | None = None
    use_rope: bool = True
    pad_to: int = 1  # model-axis size the deployment pads heads for

    @property
    def plan(self):
        return plan_heads(self.num_heads, self.num_kv_heads, self.pad_to)

    @property
    def hq_eff(self):
        return self.plan[0]

    @property
    def hkv_eff(self):
        return self.plan[1]

    @property
    def q_groups(self):
        return self.hq_eff // self.hkv_eff

    @property
    def rope_dim(self):
        rd = int(self.head_dim * self.rope_pct)
        return rd - rd % 2


def _expand_heads(w, of_slot, axis):
    """Scatter original heads into padded slots (−1 → zeros). Exact."""
    slots = jnp.asarray([max(s, 0) for s in of_slot])
    mask_shape = [1] * w.ndim
    mask_shape[axis] = len(of_slot)
    mask = jnp.asarray([s >= 0 for s in of_slot], w.dtype).reshape(mask_shape)
    return jnp.take(w, slots, axis=axis) * mask


def init(key, cfg: AttnConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    hq, hkv, q_of, kv_of = cfg.plan
    wq = fan_in_init(ks[0], (cfg.d_model, cfg.num_heads, cfg.head_dim), dtype)
    wk = fan_in_init(ks[1], (cfg.d_model, cfg.num_kv_heads, cfg.head_dim), dtype)
    wv = fan_in_init(ks[2], (cfg.d_model, cfg.num_kv_heads, cfg.head_dim), dtype)
    wo = fan_in_init(ks[3], (cfg.num_heads, cfg.head_dim, cfg.d_model), dtype)
    p = {
        "wq": _expand_heads(wq, q_of, 1),
        "wk": _expand_heads(wk, kv_of, 1),
        "wv": _expand_heads(wv, kv_of, 1),
        "wo": _expand_heads(wo, q_of, 0).reshape(hq * cfg.head_dim,
                                                 cfg.d_model),
    }
    if cfg.qkv_bias:
        bq = jnp.zeros((cfg.num_heads, cfg.head_dim), dtype)
        bkv = jnp.zeros((cfg.num_kv_heads, cfg.head_dim), dtype)
        p["bq"] = _expand_heads(bq, q_of, 0)
        p["bk"] = _expand_heads(bkv, kv_of, 0)
        p["bv"] = _expand_heads(bkv, kv_of, 0)
    return p


def _qkv(p, x, cfg: AttnConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.use_rope:
        q = rope(q, positions, base=cfg.rope_base, rope_dim=cfg.rope_dim)
        k = rope(k, positions, base=cfg.rope_base, rope_dim=cfg.rope_dim)
    return q, k, v


def _attend(q, k, v, mask, cfg: AttnConfig):
    """q: (B,S,Hq,Dh), k/v: (B,T,Hkv,Dh), mask: (B?,S,T) bool."""
    b, s, hq, dh = q.shape
    g = cfg.q_groups
    qg = q.reshape(b, s, cfg.hkv_eff, g, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits * (dh ** -0.5)
    logits = softcap(logits, cfg.logit_softcap)
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(mask[:, None, None, :, :], logits, neg)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, hq * dh)


def forward(p, x, positions, cfg: AttnConfig, *, window: int | None = None):
    """Training/prefill self-attention. Returns (out, (k, v))."""
    q, k, v = _qkv(p, x, cfg, positions)
    s = x.shape[1]
    i = positions[:, :, None]  # (B,S,1)
    j = positions[:, None, :]  # (B,1,S)
    mask = j <= i
    if window is not None:
        mask &= j > i - window
    out = _attend(q, k, v, mask, cfg)
    return out @ p["wo"], (k, v)


def bidirectional(p, x, positions, cfg: AttnConfig):
    """Encoder self-attention (no mask). Returns out only."""
    q, k, v = _qkv(p, x, cfg, positions)
    mask = jnp.ones((x.shape[0], x.shape[1], x.shape[1]), bool)
    return _attend(q, k, v, mask, cfg) @ p["wo"]


def init_cache(batch, length, cfg: AttnConfig, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, length, cfg.hkv_eff, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, length, cfg.hkv_eff, cfg.head_dim), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
    }


def decode(p, x, cache, pos, cfg: AttnConfig, *, window: int | None = None):
    """One-token decode. x: (B, 1, D); pos: scalar int32 absolute position.

    Returns (out (B,1,D), new_cache).
    """
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)  # k/v: (B,1,Hkv,Dh)
    length = cache["k"].shape[1]
    slot = jnp.asarray(pos % length if window is not None else pos, jnp.int32)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    new_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions[0], slot, axis=0
    )
    # validity: slot filled, causal, and within window if rolling
    valid = (new_pos >= 0) & (new_pos <= pos)
    if window is not None:
        valid &= new_pos > pos - window
    mask = jnp.broadcast_to(valid[None, None, :], (x.shape[0], 1, length))
    out = _attend(q, new_k, new_v, mask, cfg)
    return out @ p["wo"], {"k": new_k, "v": new_v, "pos": new_pos}


def cross_init(key, cfg: AttnConfig, dtype=jnp.float32):
    return init(key, cfg, dtype)


def cross(p, x, enc_kv, cfg: AttnConfig):
    """Cross-attention over precomputed encoder K/V (no mask, no rope)."""
    positions = jnp.zeros(x.shape[:2], jnp.int32)
    nocfg = dataclasses.replace(cfg, use_rope=False)
    q, _, _ = _qkv(p, x, nocfg, positions)
    k, v = enc_kv
    mask = jnp.ones((x.shape[0], x.shape[1], k.shape[1]), bool)
    out = _attend(q, k, v, mask, cfg)
    return out @ p["wo"]


def encode_kv(p, enc_out, cfg: AttnConfig):
    """Precompute cross-attention K/V from encoder output."""
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v
