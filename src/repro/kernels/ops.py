"""Jit'd dispatch layer over the Pallas kernels.

Every op picks an implementation:
  * ``impl="pallas"``      — compiled TPU kernel (requires a TPU backend),
  * ``impl="interpret"``   — Pallas interpret mode (CPU, for validation),
  * ``impl="ref"``         — pure-jnp oracle from :mod:`repro.kernels.ref`,
  * ``impl=None`` (auto)   — the ``REPRO_KERNEL_IMPL`` env var when set
    (CI uses it to force interpret mode on CPU), else pallas on TPU and
    ref elsewhere.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.masked_mix_scatter import masked_mix_scatter_pallas
from repro.kernels.mix_aggregate import mix_aggregate_pallas
from repro.kernels.pairwise_delta import gram_pallas
from repro.kernels.kmeans_assign import kmeans_assign_pallas


def _auto_impl(impl):
    if impl is not None:
        return impl
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def mix_aggregate(w, theta, *, impl=None, block_d=None):
    """out[i] = sum_j w[i,j] theta[j];  w (k, m), theta (m, d) -> (k, d)."""
    impl = _auto_impl(impl)
    if impl == "ref":
        return ref.mix_aggregate(w, theta)
    kwargs = {} if block_d is None else {"block_d": block_d}
    return mix_aggregate_pallas(w, theta, interpret=(impl == "interpret"), **kwargs)


def masked_mix_scatter(w, theta, idx, mask, full, *, impl=None, block_d=None):
    """Fused cohort mix + scatter: ``full[idx[i]] = (w @ theta)[i]`` where
    ``mask[i]``; pad slots (sentinel index, mask 0) are dropped.

    w (c, c); theta (c, d); idx/mask (c,); full (m, d) -> (m, d). The
    pallas path donates/aliases ``full`` so the stacked state is updated
    in place — callers must not reuse the input buffer afterwards.
    """
    impl = _auto_impl(impl)
    if impl == "ref":
        return ref.masked_mix_scatter(w, theta, idx, mask, full)
    kwargs = {} if block_d is None else {"block_d": block_d}
    return masked_mix_scatter_pallas(w, theta, idx, mask, full,
                                     interpret=(impl == "interpret"), **kwargs)


def pairwise_delta(g, *, impl=None, block_d=None):
    """Pairwise squared distances between rows of g (m, d) -> (m, m)."""
    impl = _auto_impl(impl)
    if impl == "ref":
        return ref.pairwise_delta(g)
    kwargs = {} if block_d is None else {"block_d": block_d}
    gr = gram_pallas(g, interpret=(impl == "interpret"), **kwargs)
    sq = jnp.diag(gr)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gr, 0.0)


def kmeans_assign(points, centroids, *, impl=None):
    """Nearest-centroid assignment -> (labels (m,), sqdist (m,))."""
    impl = _auto_impl(impl)
    if impl == "ref":
        return ref.kmeans_assign(points, centroids)
    return kmeans_assign_pallas(points, centroids, interpret=(impl == "interpret"))


def flash_attention(q, k, v, *, impl=None, **kw):
    """Block-wise fused attention (B, H, S, Dh); see kernels.flash_attention.

    ref path materializes the S×S matrix (what the kernel exists to avoid)
    — used on CPU where Mosaic is unavailable.
    """
    from repro.kernels import flash_attention as fa

    impl = _auto_impl(impl)
    if impl == "ref":
        import jax.numpy as _jnp

        g = q.shape[1] // k.shape[1]
        kx = _jnp.repeat(k, g, axis=1)
        vx = _jnp.repeat(v, g, axis=1)
        s = _jnp.einsum("bhqd,bhkd->bhqk", q.astype(_jnp.float32),
                        kx.astype(_jnp.float32)) * q.shape[-1] ** -0.5
        cap = kw.get("softcap")
        if cap:
            s = cap * _jnp.tanh(s / cap)
        rows = _jnp.arange(q.shape[2])[:, None]
        cols = _jnp.arange(k.shape[2])[None, :]
        mask = _jnp.ones((q.shape[2], k.shape[2]), bool)
        if kw.get("causal", True):
            mask &= cols <= rows
        if kw.get("window"):
            mask &= cols > rows - kw["window"]
        s = _jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return _jnp.einsum("bhqk,bhkd->bhqd", p,
                           vx.astype(_jnp.float32)).astype(q.dtype)
    return fa.flash_attention(q, k, v, interpret=(impl == "interpret"), **kw)
