"""Jit'd dispatch layer over the Pallas kernels.

Every op picks an implementation:
  * ``impl="pallas"``      — compiled TPU kernel (requires a TPU backend),
  * ``impl="interpret"``   — Pallas interpret mode (CPU, for validation),
  * ``impl="ref"``         — pure-jnp oracle from :mod:`repro.kernels.ref`,
  * ``impl=None`` (auto)   — the ``REPRO_KERNEL_IMPL`` env var when set
    (CI uses it to force interpret mode on CPU), else pallas on TPU and
    ref elsewhere.

The cohort gather/scatter ops additionally pick a *variant*: the VMEM
slab kernel or the HBM-resident DMA kernel
(:mod:`repro.kernels.masked_gather_mix_scatter`). Auto picks the slab
while it fits the VMEM budget (``masked_mix_scatter.slab_fits``) and
falls over to HBM-resident past it; the suffixes ``_slab`` / ``_hbm``
(e.g. ``impl="interpret_hbm"`` or ``REPRO_KERNEL_IMPL=pallas_hbm``)
force either side.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.masked_mix_scatter import (
    masked_mix_scatter_pallas, slab_fits,
)
from repro.kernels.masked_gather_mix_scatter import (
    cohort_gather_pallas, masked_gather_mix_scatter_pallas,
)
from repro.kernels.mix_aggregate import mix_aggregate_pallas
from repro.kernels.pairwise_delta import gram_pallas
from repro.kernels.kmeans_assign import kmeans_assign_pallas


ALIGN = 128  # TPU lane width: the last-dim tile every kernel wants


def aligned_dim(d: int) -> int:
    """Round a flat feature dim up to the 128 lane multiple.

    Flat stacked state created at this width (the async upload buffer,
    toy flat models) always takes the aliased zero-copy kernel path —
    ``masked_mix_scatter_pallas`` never has to zero-pad the state into
    an aligned buffer (see ``masked_mix_scatter.padding_copy_needed``).
    """
    return -(-int(d) // ALIGN) * ALIGN


def _auto_impl(impl):
    if impl is not None:
        return impl
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _split_variant(impl):
    """Split ``impl`` into (base, variant): ``"interpret_hbm"`` ->
    ``("interpret", "hbm")``; no suffix -> variant None (auto)."""
    for suffix in ("_hbm", "_slab"):
        if impl.endswith(suffix):
            return impl[: -len(suffix)], suffix[1:]
    return impl, None


def mix_aggregate(w, theta, *, impl=None, block_d=None):
    """out[i] = sum_j w[i,j] theta[j];  w (k, m), theta (m, d) -> (k, d)."""
    impl, _ = _split_variant(_auto_impl(impl))
    if impl == "ref":
        return ref.mix_aggregate(w, theta)
    kwargs = {} if block_d is None else {"block_d": block_d}
    return mix_aggregate_pallas(w, theta, interpret=(impl == "interpret"), **kwargs)


def masked_mix_scatter(w, theta, idx, mask, full, *, impl=None, block_d=None):
    """Fused cohort mix + scatter: ``full[idx[i]] = (w @ theta)[i]`` where
    ``mask[i]``; pad slots (sentinel index, mask 0) are dropped.

    w (c, c); theta (c, d); idx/mask (c,); full (m, d) -> (m, d). The
    pallas path donates/aliases ``full`` so the stacked state is updated
    in place — callers must not reuse the input buffer afterwards.

    Variant selection (``_slab``/``_hbm`` impl suffix, else auto): the
    VMEM-slab kernel while ``slab_fits(m, c)``, the HBM-resident DMA
    kernel past that bound — O(c·d) traffic at any m.
    """
    if theta.shape[1] != full.shape[1]:
        raise ValueError(
            f"masked_mix_scatter: upload width {theta.shape[1]} != state "
            f"width {full.shape[1]} — the layout table and the slab "
            "disagree (state rebuilt from a different params template?)")
    impl = _auto_impl(impl)
    if impl == "ref":
        return ref.masked_mix_scatter(w, theta, idx, mask, full)
    impl, variant = _split_variant(impl)
    if variant is None:
        variant = "slab" if slab_fits(full.shape[0], w.shape[0]) else "hbm"
    kwargs = {} if block_d is None else {"block_d": block_d}
    kernel = (masked_gather_mix_scatter_pallas if variant == "hbm"
              else masked_mix_scatter_pallas)
    return kernel(w, theta, idx, mask, full,
                  interpret=(impl == "interpret"), **kwargs)


def cohort_gather(full, idx, *, impl=None):
    """Round-start cohort gather: ``out[i] = full[min(idx[i], m-1)]``.

    The pallas path is the HBM-resident per-row DMA kernel
    (:func:`repro.kernels.masked_gather_mix_scatter.cohort_gather_pallas`)
    — ``full`` never leaves HBM, traffic O(c·d). ref is ``jnp.take`` on
    the clamped indices (bit-identical semantics).
    """
    impl = _auto_impl(impl)
    impl, _ = _split_variant(impl)
    if impl == "ref":
        return ref.cohort_gather(full, idx)
    return cohort_gather_pallas(full, idx, interpret=(impl == "interpret"))


def pairwise_delta(g, *, impl=None, block_d=None):
    """Pairwise squared distances between rows of g (m, d) -> (m, m)."""
    impl, _ = _split_variant(_auto_impl(impl))
    if impl == "ref":
        return ref.pairwise_delta(g)
    kwargs = {} if block_d is None else {"block_d": block_d}
    gr = gram_pallas(g, interpret=(impl == "interpret"), **kwargs)
    sq = jnp.diag(gr)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gr, 0.0)


def kmeans_assign(points, centroids, *, impl=None):
    """Nearest-centroid assignment -> (labels (m,), sqdist (m,))."""
    impl, _ = _split_variant(_auto_impl(impl))
    if impl == "ref":
        return ref.kmeans_assign(points, centroids)
    return kmeans_assign_pallas(points, centroids, interpret=(impl == "interpret"))


def flash_attention(q, k, v, *, impl=None, **kw):
    """Block-wise fused attention (B, H, S, Dh); see kernels.flash_attention.

    ref path materializes the S×S matrix (what the kernel exists to avoid)
    — used on CPU where Mosaic is unavailable.
    """
    from repro.kernels import flash_attention as fa

    impl, _ = _split_variant(_auto_impl(impl))
    if impl == "ref":
        import jax.numpy as _jnp

        g = q.shape[1] // k.shape[1]
        kx = _jnp.repeat(k, g, axis=1)
        vx = _jnp.repeat(v, g, axis=1)
        s = _jnp.einsum("bhqd,bhkd->bhqk", q.astype(_jnp.float32),
                        kx.astype(_jnp.float32)) * q.shape[-1] ** -0.5
        cap = kw.get("softcap")
        if cap:
            s = cap * _jnp.tanh(s / cap)
        rows = _jnp.arange(q.shape[2])[:, None]
        cols = _jnp.arange(k.shape[2])[None, :]
        mask = _jnp.ones((q.shape[2], k.shape[2]), bool)
        if kw.get("causal", True):
            mask &= cols <= rows
        if kw.get("window"):
            mask &= cols > rows - kw["window"]
        s = _jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return _jnp.einsum("bhqk,bhkd->bhqd", p,
                           vx.astype(_jnp.float32)).astype(q.dtype)
    return fa.flash_attention(q, k, v, interpret=(impl == "interpret"), **kw)
