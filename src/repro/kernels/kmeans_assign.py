"""Pallas TPU kernel for the K-means assignment step (Alg. 2 inner loop).

Collaboration vectors live in (m, f) with f = m <= a few thousand, so the
whole problem fits VMEM; the kernel computes the (m, k) squared-distance
matrix on the MXU in a single block and reduces to labels/min-distances.
This exists mostly to keep the full Alg.2 path on-chip when it runs on the
PS between rounds; the win over XLA is fusing the three terms of
||p - c||^2 without materializing (m, k, f) broadcasts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(p_ref, c_ref, labels_ref, dist_ref):
    p = p_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    d = (
        jnp.sum(p * p, axis=1, keepdims=True)
        + jnp.sum(c * c, axis=1)[None, :]
        - 2.0 * jnp.dot(p, c.T, preferred_element_type=jnp.float32)
    )
    d = jnp.maximum(d, 0.0)
    labels_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d, axis=1)


def _round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


@functools.partial(jax.jit, static_argnames=("interpret",))
def kmeans_assign_pallas(points, centroids, *, interpret: bool = False):
    """points (m, f), centroids (k, f) -> (labels (m,) i32, sqdist (m,) f32)."""
    m, f = points.shape
    k, f2 = centroids.shape
    assert f == f2
    m_pad = _round_up(m, 8)
    k_pad = _round_up(k, 8)
    f_pad = _round_up(f, 128)
    # Pad centroids with +inf-ish sentinel rows so argmin never picks them.
    p_p = jnp.zeros((m_pad, f_pad), points.dtype).at[:m, :f].set(points)
    c_p = jnp.full((k_pad, f_pad), 1e30, centroids.dtype).at[:k, :f].set(centroids)
    c_p = c_p.at[:k, f:].set(0.0)

    labels, dist = pl.pallas_call(
        _assign_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m_pad, f_pad), lambda i: (0, 0)),
            pl.BlockSpec((k_pad, f_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((m_pad,), lambda i: (0,)),
            pl.BlockSpec((m_pad,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad,), jnp.int32),
            jax.ShapeDtypeStruct((m_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(p_p, c_p)
    return labels[:m], dist[:m]
