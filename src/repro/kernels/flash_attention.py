"""Pallas TPU flash attention (forward) — the §Perf-identified next lever.

The gemma2 train_4k §Roofline shows ~1.5 TB/chip of HBM traffic from
materialized (S, S) f32 logits/probs tensors. This kernel computes
softmax(q·kᵀ)·v block-wise with the online-softmax recurrence so the S×S
matrix never leaves VMEM: per (batch, q-head, q-block) the kv sequence is
streamed in (BK × Dh) tiles with running (m, l, acc) carried in VMEM
scratch.

GQA without materialized KV expansion: the k/v BlockSpec index_map sends
q-head h to kv-head h // q_groups. Causal, sliding-window and logit
softcap masks are applied from block indices.

Forward-only by design: the backward pass at training time uses XLA remat
of the reference path (a flash backward is future work and is listed as
such in EXPERIMENTS.md); the serving/prefill paths are forward-only and
benefit directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q, block_k, seq_k, causal, window, softcap, scale):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, ...].astype(jnp.float32)  # (BQ, Dh)
    k = k_ref[0, 0, ...].astype(jnp.float32)  # (BK, Dh)
    v = v_ref[0, 0, ...].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = cols < seq_k
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finish():
        o_ref[0, 0, ...] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


def _round_up(x, m):
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q=128, block_k=128, interpret=False):
    """q: (B, Hq, Sq, Dh); k/v: (B, Hkv, Sk, Dh); Hq % Hkv == 0.

    Returns (B, Hq, Sq, Dh) in q.dtype. Sq/Sk are zero-padded to block
    multiples internally; masked via seq_k so padding never contributes.
    """
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = dh ** -0.5

    sq_p = _round_up(sq, block_q)
    sk_p = _round_up(sk, block_k)
    dh_p = _round_up(dh, 128)
    qp = jnp.zeros((b, hq, sq_p, dh_p), q.dtype).at[:, :, :sq, :dh].set(q)
    kp = jnp.zeros((b, hkv, sk_p, dh_p), k.dtype).at[:, :, :sk, :dh].set(k)
    vp = jnp.zeros((b, hkv, sk_p, dh_p), v.dtype).at[:, :, :sk, :dh].set(v)

    grid = (b, hq, sq_p // block_q, sk_p // block_k)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_k=sk,
        causal=causal, window=window, softcap=softcap, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh_p),
                         lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh_p),
                         lambda bb, h, qi, ki, g=g: (bb, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh_p),
                         lambda bb, h, qi, ki, g=g: (bb, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh_p),
                               lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, dh_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh_p), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :sq, :dh]
