"""HBM-resident fused cohort gather / mix / scatter Pallas TPU kernels.

The VMEM-slab kernel (:mod:`repro.kernels.masked_mix_scatter`) streams the
whole (m, d) stacked state through VMEM — HBM traffic ~(2·m + c)·d floats
per call and a hard single-call bound of a few thousand rows from the
~16 MB VMEM budget. This module is the million-client regime: ``full``
never leaves :data:`pltpu.ANY` (HBM on TPU). The kernels move exactly the
c cohort rows with async local DMA (:func:`pltpu.make_async_copy` plus a
per-slot DMA semaphore array), so HBM traffic is O(c·d) *regardless of m*:

  * :func:`cohort_gather_pallas` — the round-start gather. One DMA per
    slot copies row ``min(idx[i], m-1)`` of ``full`` into row i of the
    (c, d) output (pad slots read the clamped row, exactly like the
    ``jnp.take`` reference). No VMEM staging at all: the rows stream
    HBM -> HBM.
  * :func:`masked_gather_mix_scatter_pallas` — the round-end mix +
    scatter. The grid walks d in tiles; each step DMAs the (c, tile) slab
    of theta into VMEM scratch, multiplies by W on the MXU, and DMAs each
    *real* slot's mixed row back to its owner row of ``full`` (which is
    aliased to the output, so untouched rows never move). When d is not a
    tile multiple the last tile re-covers the tail at an unaligned
    offset — the recomputed columns are bit-identical, so the overlap is
    harmless and ``full``/theta need no d padding (and therefore no
    padding copy) at ANY d.

Slot contract (owned by :mod:`repro.federated.participation`): pad slots
carry an out-of-range sentinel index (>= m) and ``mask[i] == 0``; every
row DMA is predicated on both, so pad slots never write. Only W, theta
and the slot arrays are zero-padded (c rows — O(c·d), the traffic the
kernel already pays).

Dispatch lives in :mod:`repro.kernels.ops`: auto-selected when the slab
kernel's VMEM bound fails (``masked_mix_scatter.slab_fits``), forcible
via ``REPRO_KERNEL_IMPL=pallas_hbm`` / ``interpret_hbm``. The NumPy/jnp
oracles are :func:`repro.kernels.ref.masked_mix_scatter` and
:func:`repro.kernels.ref.cohort_gather`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.masked_mix_scatter import _round_up, _VMEM_BUDGET_FLOATS


# The HBM-resident kernel only stages (c_pad, block) tiles of theta and
# the mixed result in VMEM (never an m-row slab), so the default tile is
# wider than the slab kernel's.
DEFAULT_BLOCK_D = 8192


def _pick_block_d(block_d: int, d: int, c_pad: int) -> int:
    """Largest 128-multiple tile whose two (c_pad, block) scratch slabs
    plus the (c_pad, c_pad) mix matrix fit the VMEM budget; a d smaller
    than one tile runs as a single exact tile (no padding, any d)."""
    cap = max((_VMEM_BUDGET_FLOATS - c_pad * c_pad) // (2 * c_pad), 128)
    block = max(min(block_d, cap) // 128 * 128, 128)
    return d if d <= block else block


def _check(cond: bool, msg: str):
    # ValueError (not assert): shape contracts must survive python -O
    if not cond:
        raise ValueError(msg)


def _gather_kernel(idx_ref, full_ref, out_ref, row_sems, *, c, m):
    def row_copy(i):
        r = jnp.minimum(idx_ref[i], m - 1)
        return pltpu.make_async_copy(
            full_ref.at[pl.ds(r, 1), :],
            out_ref.at[pl.ds(i, 1), :],
            row_sems.at[i],
        )

    def start(i, carry):
        row_copy(i).start()
        return carry

    def wait(i, carry):
        row_copy(i).wait()
        return carry

    jax.lax.fori_loop(0, c, start, 0)
    jax.lax.fori_loop(0, c, wait, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cohort_gather_pallas(full, idx, *, interpret: bool = False):
    """Gather cohort rows ``full[min(idx, m-1)]`` with per-row DMA.

    Args:
      full: (m, d) stacked client state; stays in ANY/HBM.
      idx: (c,) int32 cohort indices; pad sentinels (>= m) read the
        clamped row m-1 (identical to ``ref.cohort_gather``).
    Returns:
      (c, d) cohort-stacked rows, in ``full.dtype``.
    """
    _check(full.ndim == 2, f"full must be (m, d), got {full.shape}")
    _check(idx.ndim == 1, f"idx must be (c,), got {idx.shape}")
    m, d = full.shape
    c = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[pltpu.SemaphoreType.DMA((c,))],
    )
    return pl.pallas_call(
        functools.partial(_gather_kernel, c=c, m=m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, d), full.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), full)


def _mix_scatter_kernel(idx_ref, mask_ref, w_ref, theta_ref, full_ref,
                        out_ref, theta_t, mixed_t, tile_sem, row_sems, *,
                        c_pad, m, d, block):
    j = pl.program_id(0)
    # the last tile re-covers the tail at an unaligned offset; the
    # overlap columns recompute identical values, so double-writing them
    # is harmless and d needs no padding
    off = jnp.minimum(j * block, d - block)
    tile = pltpu.make_async_copy(
        theta_ref.at[:, pl.ds(off, block)], theta_t, tile_sem)
    tile.start()
    tile.wait()
    mixed_t[...] = jnp.dot(
        w_ref[...].astype(jnp.float32), theta_t[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(mixed_t.dtype)

    def row_copy(i):
        r = idx_ref[i]
        return pltpu.make_async_copy(
            mixed_t.at[pl.ds(i, 1), :],
            out_ref.at[pl.ds(r, 1), pl.ds(off, block)],
            row_sems.at[i],
        )

    def start(i, carry):
        @pl.when((mask_ref[i] != 0) & (idx_ref[i] < m))
        def _go():
            row_copy(i).start()

        return carry

    def wait(i, carry):
        @pl.when((mask_ref[i] != 0) & (idx_ref[i] < m))
        def _go():
            row_copy(i).wait()

        return carry

    jax.lax.fori_loop(0, c_pad, start, 0)
    jax.lax.fori_loop(0, c_pad, wait, 0)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"),
                   donate_argnums=(4,))
def masked_gather_mix_scatter_pallas(w, theta, idx, mask, full, *,
                                     block_d: int = DEFAULT_BLOCK_D,
                                     interpret: bool = False):
    """HBM-resident ``ref.masked_mix_scatter``: DMA only the cohort rows.

    Args:
      w: (c, c) f32 mixing matrix (pad columns zero; pad rows arbitrary).
      theta: (c, d) cohort-stacked flat updates.
      idx: (c,) int32 target rows in ``full``; pad slots hold >= m.
      mask: (c,) bool/int, nonzero on real slots.
      full: (m, d) stacked client state, donated and aliased into the
        output; it stays in ANY/HBM — untouched rows are never read or
        written, so traffic is O(c·d) at any m.
    Returns:
      (m, d) updated state, in ``full.dtype``.
    """
    c = w.shape[0]
    _check(w.ndim == 2 and w.shape == (c, c),
           f"w must be square (c, c), got {w.shape}")
    _check(full.ndim == 2, f"full must be (m, d), got {full.shape}")
    m, d = full.shape
    _check(theta.shape == (c, d),
           f"theta must be {(c, d)} to match w {w.shape} and full "
           f"{full.shape}, got {theta.shape}")
    _check(idx.shape == (c,) and mask.shape == (c,),
           f"idx/mask must be ({c},), got {idx.shape}/{mask.shape}")
    c_pad = _round_up(c, 8)
    block = _pick_block_d(min(block_d, _round_up(d, 128)), d, c_pad)
    # only the c-row operands are padded (O(c·d)); ``full`` never is
    w_p = jnp.zeros((c_pad, c_pad), w.dtype).at[:c, :c].set(w)
    theta_p = jnp.zeros((c_pad, d), theta.dtype).at[:c, :].set(theta)
    idx_p = jnp.full((c_pad,), m, jnp.int32).at[:c].set(idx.astype(jnp.int32))
    mask_p = jnp.zeros((c_pad,), jnp.int32).at[:c].set(mask.astype(jnp.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(-(-d // block),),
        in_specs=[
            pl.BlockSpec((c_pad, c_pad), lambda j, *_: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((c_pad, block), theta.dtype),
            pltpu.VMEM((c_pad, block), full.dtype),
            pltpu.SemaphoreType.DMA(()),
            pltpu.SemaphoreType.DMA((c_pad,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mix_scatter_kernel, c_pad=c_pad, m=m, d=d,
                          block=block),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), full.dtype),
        input_output_aliases={4: 0},  # full -> out, in-place row DMA
        interpret=interpret,
    )(idx_p, mask_p, w_p, theta_p, full)
