"""Fused Pallas TPU kernel: masked cohort mix + scatter into the full state.

The padded-cohort round engine produces a cohort-stacked update matrix
theta (c, d) plus a (c, c) row-renormalized mixing matrix whose pad
columns are zero. PR 1 applied the mix with one ``mix_aggregate`` launch
per pytree leaf and then scattered the result back into the (m, d)
stacked client state as a separate XLA scatter — two full passes over the
cohort's bytes plus a kernel launch per leaf. This kernel fuses both:

  out = full;  out[idx[i]] = (W @ theta)[i]   for every slot with mask[i]

in ONE pass over the data. The grid walks the d axis; each step keeps W
resident, streams a (c, BLOCK_D) tile of theta through VMEM, multiplies
on the MXU and row-scatters the masked results into the (m, BLOCK_D)
output slab. ``full`` is aliased to the output (``input_output_aliases``)
so — together with ``donate_argnums`` at the jit level — the (m, d)
stacked state is updated without allocating a second copy.

Traffic honesty — the two regimes:

  * **VMEM slab (this kernel).** The slab formulation *streams* the full
    state through VMEM (copy-through of untouched rows), so HBM traffic
    is ~(2·m + c)·d floats per call; the fusion saves the extra
    mix-output allocation, the per-leaf launch overhead, and the
    separate XLA scatter pass — not the state read. ``block_d`` is
    clamped so the two (m_pad, BLOCK_D) slabs plus the theta tile fit
    the ~16 MB VMEM budget (:data:`_VMEM_BUDGET_FLOATS`), which bounds
    single-call m: once ``2·m_pad + 2·c_pad`` rows can't sustain even a
    128-wide block (m_pad ≈ 12k rows), the slab is infeasible.
  * **HBM-resident** (:mod:`repro.kernels.masked_gather_mix_scatter`).
    ``full`` stays in ``pltpu.ANY``/HBM and per-slot async DMA moves
    only the c cohort rows — traffic O(c·d) at any m, no m-dependent
    VMEM bound, and no d padding at all (the tail tile re-covers the
    last columns at an unaligned offset).

:func:`slab_fits` is the boundary between the regimes;
:func:`repro.kernels.ops.masked_mix_scatter` auto-dispatches on it
(``impl`` suffix ``_slab`` / ``_hbm`` forces either side, also via the
``REPRO_KERNEL_IMPL`` env var).

Alignment: tile shapes need d divisible by the block (multiple of 128)
and m_pad divisible by 8. When d is 128-aligned a divisor block is
chosen automatically and the state is used zero-copy; otherwise the
state is zero-padded into an aligned buffer (a full copy — callers with
hot unaligned states should pad d to 128 up front).

Slot contract (owned by :mod:`repro.federated.participation`): pad slots
carry an out-of-range sentinel index (>= m) and ``mask[i] == 0``; the
kernel predicates the row store on both, so pad slots never write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_D = 2048


def _round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


# keep the two (m_pad, block) slabs + (c_pad, block) theta tile + mix well
# inside the ~16 MB/core VMEM budget
_VMEM_BUDGET_FLOATS = 3 * 1 << 20


def _pick_block_d(block_d: int, d: int, m_pad: int, c_pad: int) -> int:
    cap = max(_VMEM_BUDGET_FLOATS // (2 * m_pad + 2 * c_pad), 128)
    block_d = max(min(block_d, cap) // 128 * 128, 128)
    if d % 128 == 0:
        # pick a divisor of d so the d axis needs no padding at all
        while d % block_d:
            block_d -= 128
    return block_d


def slab_fits(m: int, c: int) -> bool:
    """True when the VMEM-slab formulation is feasible for (m, c): the two
    (m_pad, block) state slabs plus the (c_pad, block) theta/mix tiles
    must sustain at least a 128-wide block inside the VMEM budget. Past
    this bound (m_pad ≈ 12k rows) :mod:`repro.kernels.ops` auto-selects
    the HBM-resident kernel."""
    m_pad = _round_up(int(m), 8)
    c_pad = _round_up(int(c), 8)
    return _VMEM_BUDGET_FLOATS // (2 * m_pad + 2 * c_pad) >= 128


def padding_copy_needed(m: int, c: int, d: int,
                        block_d: int = DEFAULT_BLOCK_D) -> bool:
    """True when :func:`masked_mix_scatter_pallas` must zero-pad ``full``
    into an aligned (m_pad, d_pad) buffer — a full O(m·d) copy that
    forfeits the aliased zero-copy path. False exactly when m is a
    multiple of 8 and d is a multiple of 128 (the alignment
    :func:`repro.kernels.ops.aligned_dim` provides at state creation)."""
    c_pad = _round_up(int(c), 8)
    m_pad = _round_up(int(m), 8)
    block = _pick_block_d(min(int(block_d), _round_up(int(d), 128)), int(d),
                          m_pad, c_pad)
    d_pad = _round_up(int(d), block)
    return (m_pad, d_pad) != (int(m), int(d))


def _kernel(idx_ref, mask_ref, w_ref, theta_ref, full_ref, out_ref, *, c, m):
    # Copy-through of the untouched rows (a no-op self-copy when the
    # output buffer aliases ``full``), then overwrite the cohort rows.
    out_ref[...] = full_ref[...]
    mix = jnp.dot(
        w_ref[...].astype(jnp.float32), theta_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)

    def body(i, carry):
        r = idx_ref[i]

        @pl.when((mask_ref[i] != 0) & (r < m))
        def _():
            out_ref[pl.ds(r, 1), :] = jax.lax.dynamic_slice_in_dim(mix, i, 1, 0)

        return carry

    jax.lax.fori_loop(0, c, body, 0)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"),
                   donate_argnums=(4,))
def masked_mix_scatter_pallas(w, theta, idx, mask, full, *,
                              block_d: int = DEFAULT_BLOCK_D,
                              interpret: bool = False):
    """Pallas implementation of ``ref.masked_mix_scatter``.

    Args:
      w: (c, c) f32 mixing matrix (pad columns zero; pad rows arbitrary).
      theta: (c, d) cohort-stacked flat updates.
      idx: (c,) int32 target rows in ``full``; pad slots hold >= m.
      mask: (c,) bool/int, nonzero on real slots.
      full: (m, d) stacked client state, donated and aliased into the
        output so unwritten rows never move through HBM.
    Returns:
      (m, d) updated state, in ``full.dtype``.
    """
    c = w.shape[0]
    # ValueError (not assert): shape contracts must survive python -O
    if w.ndim != 2 or w.shape != (c, c):
        raise ValueError(f"w must be square (c, c), got {w.shape}")
    if full.ndim != 2:
        raise ValueError(f"full must be (m, d), got {full.shape}")
    m, d = full.shape
    if theta.shape != (c, d):
        raise ValueError(
            f"theta must be {(c, d)} to match w {w.shape} and full "
            f"{full.shape}, got {theta.shape}")
    if idx.shape != (c,) or mask.shape != (c,):
        raise ValueError(
            f"idx/mask must be ({c},), got {idx.shape}/{mask.shape}")
    c_pad = _round_up(c, 8)
    m_pad = _round_up(m, 8)
    block_d = _pick_block_d(min(block_d, _round_up(d, 128)), d, m_pad, c_pad)
    d_pad = _round_up(d, block_d)
    # Zero-pad W/theta (small); ``full`` is only padded when the state is
    # not tile-aligned — aligned states take the zero-copy aliased path.
    w_p = jnp.zeros((c_pad, c_pad), w.dtype).at[:c, :c].set(w)
    theta_p = jnp.zeros((c_pad, d_pad), theta.dtype).at[:c, :d].set(theta)
    padded = (m_pad, d_pad) != (m, d)
    full_p = (jnp.zeros((m_pad, d_pad), full.dtype).at[:m, :d].set(full)
              if padded else full)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(d_pad // block_d,),
        in_specs=[
            pl.BlockSpec((c_pad, c_pad), lambda j, *_: (0, 0)),
            pl.BlockSpec((c_pad, block_d), lambda j, *_: (0, j)),
            pl.BlockSpec((m_pad, block_d), lambda j, *_: (0, j)),
        ],
        out_specs=pl.BlockSpec((m_pad, block_d), lambda j, *_: (0, j)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, c=c, m=m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_pad, d_pad), full.dtype),
        input_output_aliases={4: 0},  # full_p -> out, in-place row writes
        interpret=interpret,
    )(idx.astype(jnp.int32), mask.astype(jnp.int32), w_p, theta_p, full_p)
    return out[:m, :d] if padded else out
