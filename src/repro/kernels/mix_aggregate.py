"""Pallas TPU kernel for the PS user-centric mixing step.

The aggregation ``out[i] = sum_j W[i, j] * theta[j]`` is a tall-skinny
matmul: W is at most (32, 32) while theta is (m, d) with d up to 10^9+.
Arithmetic intensity is ~m FLOP/byte, far below the v5e ridge point
(197e12 / 819e9 ~= 240), so the op is HBM-bandwidth-bound and the kernel's
job is to stream theta through VMEM exactly once with W resident, instead
of materializing an all-gathered copy and a separate matmul.

Tiling: grid over the d axis; each step loads a (m_pad, BLOCK_D) tile of
theta into VMEM, multiplies by the (k_pad, m_pad) resident W on the MXU and
stores the (k_pad, BLOCK_D) result. m/k are zero-padded to the 8-sublane
boundary; BLOCK_D is a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_D = 2048


def _mix_kernel(w_ref, theta_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)
    t = theta_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.dot(
        w, t, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


def _round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def mix_aggregate_pallas(w, theta, *, block_d: int = DEFAULT_BLOCK_D,
                         interpret: bool = False):
    """Pallas implementation of ``ref.mix_aggregate``.

    w: (k, m); theta: (m, d) -> (k, d) in theta.dtype.
    """
    k, m = w.shape
    m2, d = theta.shape
    assert m == m2, (w.shape, theta.shape)
    if d == 0:
        # A zero-width matrix would build an empty grid the interpreter
        # can't slice. Unreachable from the strategy engine (the slab is
        # never narrower than one 128 lane tile); kept for direct callers
        # mixing arbitrary matrices.
        return jnp.zeros((k, 0), theta.dtype)
    k_pad = _round_up(k, 8)
    m_pad = _round_up(m, 8)
    block_d = max(_round_up(min(block_d, _round_up(d, 128)), 128), 128)
    d_pad = _round_up(d, block_d)
    # Zero-pad: extra rows of W are zero so padded outputs are discarded;
    # extra columns of W hit zero-padded theta rows, contributing nothing.
    w_p = jnp.zeros((k_pad, m_pad), w.dtype).at[:k, :m].set(w)
    theta_p = jnp.zeros((m_pad, d_pad), theta.dtype).at[:m, :d].set(theta)

    grid = (d_pad // block_d,)
    out = pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k_pad, m_pad), lambda j: (0, 0)),
            pl.BlockSpec((m_pad, block_d), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((k_pad, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((k_pad, d_pad), theta.dtype),
        interpret=interpret,
    )(w_p, theta_p)
    return out[:k, :d]
