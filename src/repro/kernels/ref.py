"""Pure-jnp oracles for the PS-side Pallas kernels.

These are the semantic ground truth: every Pallas kernel in this package is
tested (shape/dtype sweeps, interpret mode) against these functions, and the
CPU execution path of :mod:`repro.kernels.ops` dispatches here.
"""
from __future__ import annotations

import jax.numpy as jnp


def mix_aggregate(w, theta):
    """User-centric mixing: ``out[i] = sum_j w[i, j] * theta[j]``.

    Args:
      w: (m, m) or (k, m) float mixing matrix (rows = aggregation rules).
      theta: (m, d) stacked flat client models.
    Returns:
      (k, d) mixed models, in ``theta.dtype``.
    """
    out = jnp.einsum("kj,jd->kd", w.astype(jnp.float32), theta.astype(jnp.float32))
    return out.astype(theta.dtype)


def cohort_gather(full, idx):
    """Gather cohort rows (oracle for the HBM-resident DMA gather).

    ``out[i] = full[min(idx[i], m - 1)]`` — pad slots (sentinel index
    >= m) read the clamped last row, exactly the ``safe_gather_index``
    convention of the masked engine.

    Args:
      full: (m, d) stacked client state.
      idx: (c,) int cohort indices (sentinel m on pad slots).
    Returns:
      (c, d) cohort-stacked rows, in ``full.dtype``.
    """
    safe = jnp.minimum(idx, full.shape[0] - 1)
    return jnp.take(full, safe, axis=0)


def masked_mix_scatter(w, theta, idx, mask, full):
    """Fused masked cohort mix + scatter (oracle for the Pallas kernel).

    ``out = full`` with ``out[idx[i]] = (w @ theta)[i]`` for every cohort
    slot whose ``mask[i]`` is set. Pad slots (mask 0) carry an
    out-of-range sentinel index and are dropped by the scatter; a pad
    slot with an in-bounds index writes the row's previous value back
    (identity), so either pad convention is safe.

    Args:
      w: (c, c) float mixing matrix (row i = slot i's aggregation rule;
        pad columns must be zero).
      theta: (c, d) cohort-stacked flat updates.
      idx: (c,) int target rows in ``full``.
      mask: (c,) bool, True on real cohort slots.
      full: (m, d) stacked client state.
    Returns:
      (m, d) updated state, in ``full.dtype``.
    """
    mixed = jnp.einsum(
        "ij,jd->id", w.astype(jnp.float32), theta.astype(jnp.float32)
    ).astype(full.dtype)
    safe = jnp.minimum(idx, full.shape[0] - 1)
    upd = jnp.where(mask[:, None], mixed, jnp.take(full, safe, axis=0))
    return full.at[idx].set(upd, mode="drop")


def gram(g):
    """Gram matrix ``G G^T`` of (m, d) stacked gradients, f32 accumulate."""
    g32 = g.astype(jnp.float32)
    return g32 @ g32.T


def pairwise_delta(g):
    """Pairwise squared L2 distances between rows of ``g`` (m, d) -> (m, m)."""
    gr = gram(g)
    sq = jnp.diag(gr)
    d = sq[:, None] + sq[None, :] - 2.0 * gr
    return jnp.maximum(d, 0.0)


def kmeans_assign(points, centroids):
    """Nearest-centroid assignment.

    Args:
      points: (m, f); centroids: (k, f).
    Returns:
      labels (m,) int32, sq_dists (m,) f32 to the chosen centroid.
    """
    p = points.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    d = (
        jnp.sum(p * p, axis=1)[:, None]
        + jnp.sum(c * c, axis=1)[None, :]
        - 2.0 * (p @ c.T)
    )
    d = jnp.maximum(d, 0.0)
    labels = jnp.argmin(d, axis=1).astype(jnp.int32)
    return labels, jnp.min(d, axis=1)
