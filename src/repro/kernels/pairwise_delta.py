"""Pallas TPU kernel for pairwise gradient distances (Eq. 9 input).

Computes the Gram matrix ``G G^T`` of the (m, d) stacked client gradients in
ONE streaming pass over d (the model dimension, potentially billions),
accumulating the (m, m) product in a VMEM-resident f32 tile. The naive
formulation (m^2 row-pair passes) reads G m times; this reads it once.
Distances ``||g_i - g_j||^2 = G_ii + G_jj - 2 G_ij`` are recovered from the
Gram matrix by the ops wrapper (O(m^2), negligible).

Grid iterates sequentially over d-blocks on TPU, so the output block (same
index every step) persists in VMEM and is accumulated in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_D = 4096


def _gram_kernel(g_ref, out_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.dot(g, g.T, preferred_element_type=jnp.float32)


def _round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gram_pallas(g, *, block_d: int = DEFAULT_BLOCK_D, interpret: bool = False):
    """Streaming Gram matrix of (m, d) -> (m, m) f32."""
    m, d = g.shape
    m_pad = _round_up(m, 8)
    block_d = max(_round_up(min(block_d, _round_up(d, 128)), 128), 128)
    d_pad = _round_up(d, block_d)
    g_p = jnp.zeros((m_pad, d_pad), g.dtype).at[:m, :d].set(g)

    grid = (d_pad // block_d,)
    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m_pad, block_d), lambda j: (0, j))],
        out_specs=pl.BlockSpec((m_pad, m_pad), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, m_pad), jnp.float32),
        interpret=interpret,
    )(g_p)
    return out[:m, :m]
