"""Collaboration-coefficient computation (paper §IV-A, Eq. 9-10).

The special pre-training round: the PS broadcasts θ⁰; every client k
uploads (i) its full local gradient ∇ℓ(θ⁰, D_k) and (ii) a variance
estimate σ_k² computed by partitioning D_k into K minibatches (Eq. 10).
The PS forms pairwise squared gradient distances Δ_{i,j} and the
normalized-Gaussian-kernel mixing weights (Eq. 9):

    w_{i,j} ∝ (n_j / n_i) · exp(−Δ_{i,j} / (2 σ_i σ_j)),   Σ_j w_{i,j} = 1.

Properties encoded here and verified by tests/property tests:
  * rows are stochastic (non-negative, sum to 1);
  * for homogeneous clients (Δ→0, equal n) the rule degenerates to FedAvg;
  * as σ_i → 0 (infinite local data) it degenerates to local training
    (w_{i,i} → 1), matching the paper's limit discussion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def sigma_sq(minibatch_grads, full_grad):
    """Eq. 10 — gradient variance estimate for ONE client.

    Args:
      minibatch_grads: (K, d) per-minibatch full gradients of client i.
      full_grad: (d,) gradient over the client's entire local dataset.
    Returns:
      scalar σ_i².
    """
    diff = minibatch_grads.astype(jnp.float32) - full_grad.astype(jnp.float32)[None, :]
    return jnp.mean(jnp.sum(diff * diff, axis=-1))


def pairwise_delta(grads, *, impl=None):
    """Δ_{i,j} = ||g_i − g_j||² over stacked (m, d) client gradients."""
    return ops.pairwise_delta(grads, impl=impl)


def mixing_weights(delta, sigma_sq_vec, n, *, eps=1e-12):
    """Eq. 9 — normalized Gaussian-kernel collaboration coefficients.

    Args:
      delta: (m, m) pairwise squared gradient distances.
      sigma_sq_vec: (m,) per-client variance estimates σ_i².
      n: (m,) local dataset sizes.
    Returns:
      (m, m) row-stochastic mixing matrix W.
    """
    delta = delta.astype(jnp.float32)
    sig = jnp.sqrt(jnp.maximum(sigma_sq_vec.astype(jnp.float32), 0.0))
    n = n.astype(jnp.float32)
    # 2 σ_i σ_j denominator; guard σ→0: exponent → −inf off-diagonal,
    # 0 on the diagonal (Δ_ii = 0), recovering local training.
    denom = 2.0 * sig[:, None] * sig[None, :]
    expo = jnp.where(denom > eps, -delta / jnp.maximum(denom, eps),
                     jnp.where(delta <= eps, 0.0, -jnp.inf))
    # Row-wise max-subtraction for numerical stability (softmax-style);
    # the n_j/n_i prefactor folds into log-space. The 1/n_i factor cancels
    # in the normalization but is kept for faithfulness to Eq. 9.
    logits = expo + jnp.log(n)[None, :] - jnp.log(n)[:, None]
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    un = jnp.exp(logits)
    return un / jnp.sum(un, axis=1, keepdims=True)


def collaboration_round(per_client_minibatch_grads, n, *, impl=None):
    """Run the full special round on stacked arrays.

    Args:
      per_client_minibatch_grads: (m, K, d) minibatch gradients, K batches
        per client (the paper's variance-estimation partition).
      n: (m,) dataset sizes.
    Returns:
      dict with full_grads (m, d), sigma_sq (m,), delta (m, m), W (m, m).
    """
    g = per_client_minibatch_grads
    full = jnp.mean(g, axis=1)  # client full gradient = mean of partition grads
    sig = jax.vmap(sigma_sq)(g, full)
    delta = pairwise_delta(full, impl=impl)
    w = mixing_weights(delta, sig, n)
    return {"full_grads": full, "sigma_sq": sig, "delta": delta, "W": w}
