"""Collaboration-coefficient computation (paper §IV-A, Eq. 9-10).

The special pre-training round: the PS broadcasts θ⁰; every client k
uploads (i) its full local gradient ∇ℓ(θ⁰, D_k) and (ii) a variance
estimate σ_k² computed by partitioning D_k into K minibatches (Eq. 10).
The PS forms pairwise squared gradient distances Δ_{i,j} and the
normalized-Gaussian-kernel mixing weights (Eq. 9):

    w_{i,j} ∝ (n_j / n_i) · exp(−Δ_{i,j} / (2 σ_i σ_j)),   Σ_j w_{i,j} = 1.

Properties encoded here and verified by tests/property tests:
  * rows are stochastic (non-negative, sum to 1);
  * for homogeneous clients (Δ→0, equal n) the rule degenerates to FedAvg;
  * as σ_i → 0 (infinite local data) it degenerates to local training
    (w_{i,i} → 1), matching the paper's limit discussion.

Streaming W refresh
-------------------
The paper computes W exactly once, in the special round. Under partial
participation that leaves rarely-sampled clients with Δ/σ² estimates
frozen at θ⁰ for the whole run. :class:`RefreshConfig` +
:func:`streaming_refresh` re-estimate the *participating* clients'
statistics every cohort round from the local-SGD uploads the PS already
has (no extra communication):

  * :func:`grad_proxy` treats a cohort slot's model delta
    ``θ_pre − θ_post`` as a full-batch-gradient proxy — to first order
    it points along the average gradient of the local path (exactly the
    gradient for one plain-SGD step at θ_pre); the positive heavy-ball
    recovery scale ``(1−β)/(η·T)`` cancels in the normalized space below
    and is not applied;
  * all running statistics live in a SCALE-FREE normalized space:
    gradient *directions* ``ĝ = g/‖g‖``, distances
    ``Δ̂ = ‖ĝ_i − ĝ_j‖² = 2(1 − cos) ∈ [0, 4]``, and relative variances
    ``σ̂² = σ²/‖g‖²``. Raw proxies at each client's *personalized*
    params shrink as local models converge, so raw Δ collapses toward 0
    while drift-based σ² estimates inflate — Eq. 9's softmax temperature
    then no longer matches the statistic scale and every row flattens
    into harmful cross-task mixing (measured: concept-shift avg accuracy
    drops double digits). Directions are immune to magnitude collapse
    and are exactly what discriminates tasks; :func:`init_refresh_state`
    converts the special round's statistics into this space once, and
    every later observation lands in the same units by construction;
  * the proxy direction is EWMA-folded into a running unit-norm (m, d)
    direction buffer and the directional drift ``‖ĝ_obs − ĝ_buf‖²``
    into the running σ̂² buffer (Eq. 10's minibatch variance is
    unobservable from a single upload, so the across-round proxy
    variance stands in);
  * the cohort's rows/columns of the Δ̂ buffer are recomputed against
    the refreshed direction buffer (entries between two absent clients
    keep their last value — that is the "incremental" part);
  * W is recomputed from the buffers on device (rows untouched by the
    observations recompute to their previous values, so this equals a
    row/column refresh);
  * per-client staleness counters (rounds since a client's stats were
    last observed) ride along for round metrics.

The refresh is OPT-IN (``FedConfig.w_refresh``): with it off, every
trajectory is bit-identical to the compute-W-once engine, which is what
the paper specifies and what the dense fraction=1.0 regression tests pin
down.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.kernels import ops


def sigma_sq(minibatch_grads, full_grad):
    """Eq. 10 — gradient variance estimate for ONE client.

    Args:
      minibatch_grads: (K, d) per-minibatch full gradients of client i.
      full_grad: (d,) gradient over the client's entire local dataset.
    Returns:
      scalar σ_i².
    """
    diff = minibatch_grads.astype(jnp.float32) - full_grad.astype(jnp.float32)[None, :]
    return jnp.mean(jnp.sum(diff * diff, axis=-1))


def pairwise_delta(grads, *, impl=None):
    """Δ_{i,j} = ||g_i − g_j||² over stacked (m, d) client gradients."""
    return ops.pairwise_delta(grads, impl=impl)


def mixing_weights(delta, sigma_sq_vec, n, *, eps=1e-12):
    """Eq. 9 — normalized Gaussian-kernel collaboration coefficients.

    Args:
      delta: (m, m) pairwise squared gradient distances.
      sigma_sq_vec: (m,) per-client variance estimates σ_i².
      n: (m,) local dataset sizes.
    Returns:
      (m, m) row-stochastic mixing matrix W.
    """
    delta = delta.astype(jnp.float32)
    sig = jnp.sqrt(jnp.maximum(sigma_sq_vec.astype(jnp.float32), 0.0))
    n = n.astype(jnp.float32)
    # 2 σ_i σ_j denominator; guard σ→0: exponent → −inf off-diagonal,
    # 0 on the diagonal (Δ_ii = 0), recovering local training.
    denom = 2.0 * sig[:, None] * sig[None, :]
    expo = jnp.where(denom > eps, -delta / jnp.maximum(denom, eps),
                     jnp.where(delta <= eps, 0.0, -jnp.inf))
    # Row-wise max-subtraction for numerical stability (softmax-style);
    # the n_j/n_i prefactor folds into log-space. The 1/n_i factor cancels
    # in the normalization but is kept for faithfulness to Eq. 9.
    logits = expo + jnp.log(n)[None, :] - jnp.log(n)[:, None]
    logits = logits - jnp.max(logits, axis=1, keepdims=True)
    un = jnp.exp(logits)
    return un / jnp.sum(un, axis=1, keepdims=True)


# ---------------------------------------------------------- streaming refresh


@dataclasses.dataclass(frozen=True)
class RefreshConfig:
    """Streaming W-refresh policy (see the module docstring).

    Attributes:
      alpha: EWMA weight of a new gradient-direction observation folded
        into the running (m, d) direction buffer. 1.0 means "replace".
      sigma_alpha: EWMA weight of a new σ̂² (directional-drift)
        observation.

    The 0.25 defaults keep the special round's prior influential for the
    first few observations — proxies at per-client personalized points
    are noisier witnesses than the common-point θ⁰ statistics, and
    heavier weights measurably degrade worst-node accuracy on the
    benchmark sweep's clean-block (concept-shift) scenario.
    """

    alpha: float = 0.25
    sigma_alpha: float = 0.25

    def __post_init__(self):
        for name in ("alpha", "sigma_alpha"):
            v = getattr(self, name)
            if not 0.0 < v <= 1.0:
                raise ValueError(f"{name} must be in (0, 1], got {v}")


def unit_rows(x, eps=1e-12):
    """Normalize each row of (r, d) ``x`` to the unit sphere."""
    x = x.astype(jnp.float32)
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def init_refresh_state(collab, m, *, eps=1e-12):
    """Convert the special round's statistics into the refresh buffers.

    The buffers live in the scale-free normalized space (module
    docstring): ``grads`` holds the unit gradient *directions*
    ``ĝ = g/‖g‖``, ``delta`` the pairwise direction distances
    ``Δ̂ = 2(1 − cos)``, and ``sigma_sq`` the relative variances
    ``σ̂² = σ²/‖g‖²`` — the one unit conversion that makes every
    client's prior commensurate with the later proxy observations. The
    arrays are freshly computed (never views of ``collab``'s): the
    masked round donates them, and donation would otherwise invalidate
    ``state["collab"]``.
    """
    g = jnp.asarray(collab["full_grads"]).astype(jnp.float32)
    norm_sq = jnp.maximum(jnp.sum(g * g, axis=-1), eps)
    ghat = unit_rows(g, eps)
    return {
        "grads": ghat,
        "sigma_sq": jnp.asarray(collab["sigma_sq"]).astype(jnp.float32)
        / norm_sq,
        "delta": ops.pairwise_delta(ghat),
        "staleness": jnp.zeros((m,), jnp.int32),
    }


def grad_proxy(pre_flat, post_flat):
    """Full-batch-gradient proxy of a cohort's local-SGD uploads.

    The raw model delta ``θ_pre − θ_post``: a first-order inversion of T
    steps of heavy-ball SGD points it along ``η·T/(1−β)`` times the
    average gradient over the local path — exact for one plain-SGD step
    at θ_pre. The positive ``(1−β)/(η·T)`` recovery scale is
    deliberately NOT applied: every refresh statistic lives in the
    unit-direction normalized space (:func:`streaming_refresh` projects
    the observation onto the unit sphere first), where a positive scalar
    cancels — applying it would only add plumbing that must track the
    local batching rules.

    Args:
      pre_flat / post_flat: (c, d) raveled cohort params before/after
        local SGD.
    Returns:
      (c, d) gradient proxies, direction-faithful to the special round's
      full gradients (magnitude is in model-delta units).
    """
    return pre_flat.astype(jnp.float32) - post_flat.astype(jnp.float32)


def streaming_refresh(refresh, obs, idx, mask, n, *, cfg: RefreshConfig,
                      eps=1e-12):
    """Fold one cohort's gradient-proxy observations into the running
    Δ/σ² buffers and recompute W on device.

    Args:
      refresh: dict of running buffers (see :func:`init_refresh_state`):
        ``grads`` (m, d), ``sigma_sq`` (m,), ``delta`` (m, m),
        ``staleness`` (m,) int32.
      obs: (c, d) per-slot gradient proxies (:func:`grad_proxy`).
      idx / mask: the padded cohort's slot arrays (sentinel index m,
        mask False on pad slots — pads never touch any buffer).
      n: (m,) local dataset sizes (Eq. 9's prefactor).
      cfg: EWMA weights.
    Returns:
      ``(refresh', W')`` — the updated buffers and the refreshed
      row-stochastic (m, m) mixing matrix.

    Update order matters and is fixed: the raw proxy is projected to its
    unit direction (entering the buffers' scale-free space); σ̂² observes
    the directional drift of the new observation against the
    *pre-update* direction buffer; the direction buffer then folds the
    observation in (unit-renormalized); the Δ̂ rows/columns of the
    observed clients are recomputed against the *post-update* buffer (so
    a cohort pair's two symmetric entries agree exactly and the diagonal
    stays 0); W is recomputed last from the refreshed buffers. Entries
    of Δ̂ between two absent clients keep their previous value — their
    next refresh happens when either endpoint is sampled again.
    """
    grads, sig = refresh["grads"], refresh["sigma_sq"]
    delta, stale = refresh["delta"], refresh["staleness"]
    m = grads.shape[0]
    safe = aggregation.safe_gather_index(idx, m)
    obs = unit_rows(obs, eps)

    # σ̂² observation: squared directional drift vs the running estimate
    sig_obs = jnp.sum((obs - grads[safe]) ** 2, axis=-1)
    grads = aggregation.masked_unit_ewma_rows(grads, obs, idx, mask,
                                              cfg.alpha, eps)
    sig = aggregation.masked_ewma_rows(sig, sig_obs, idx, mask,
                                       cfg.sigma_alpha)
    delta = aggregation.masked_delta_rows(delta, grads, idx, mask)
    stale = aggregation.staleness_update(stale, idx, mask)
    new = {"grads": grads, "sigma_sq": sig, "delta": delta,
           "staleness": stale}
    return new, mixing_weights(delta, sig, n, eps=eps)


def attacker_mixing_mass(w, attacker):
    """W-quarantine metric: honest→attacker mixing mass.

    The Byzantine replay's question is whether the user-centric W
    isolates poisoners ON ITS OWN — if it does, honest rows place
    (near-)zero weight on attacker columns. Returns the mean, over
    honest rows, of the total W mass on attacker columns: 0 = perfect
    quarantine, ~k/m = the attacker share under uniform mixing.

    Args:
      w: (m, m) row-stochastic mixing matrix.
      attacker: (m,) bool attacker set
        (:func:`repro.federated.faults.attacker_mask`).
    Returns:
      scalar in [0, 1].
    """
    w = jnp.asarray(w, jnp.float32)
    atk = jnp.asarray(attacker)
    honest = (~atk).astype(jnp.float32)
    mass_per_row = jnp.sum(w * atk.astype(jnp.float32)[None, :], axis=1)
    return (jnp.sum(mass_per_row * honest)
            / jnp.maximum(jnp.sum(honest), 1.0))


def collaboration_round(per_client_minibatch_grads, n, *, impl=None):
    """Run the full special round on stacked arrays.

    Args:
      per_client_minibatch_grads: (m, K, d) minibatch gradients, K batches
        per client (the paper's variance-estimation partition).
      n: (m,) dataset sizes.
    Returns:
      dict with full_grads (m, d), sigma_sq (m,), delta (m, m), W (m, m).
    """
    g = per_client_minibatch_grads
    full = jnp.mean(g, axis=1)  # client full gradient = mean of partition grads
    sig = jax.vmap(sigma_sq)(g, full)
    delta = pairwise_delta(full, impl=impl)
    w = mixing_weights(delta, sig, n)
    return {"full_grads": full, "sigma_sq": sig, "delta": delta, "W": w}
