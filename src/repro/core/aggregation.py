"""PS-side aggregation rules (paper Eq. 1/2/8 and §IV-B clustered variant).

All rules operate on a *client-stacked* pytree (every leaf has leading axis
m) and return a stacked pytree of the same structure:

  * ``fedavg``        — Eq. 1: one convex combination, broadcast to all m.
  * ``user_centric``  — Eq. 8: θ_i ← Σ_j W[i,j] θ_j (full personalization,
                        m distinct downlink streams).
  * ``clustered``     — §IV-B: only m_t centroid rules are materialized;
                        every client in cluster C_n receives the centroid
                        mix (group-cast, m_t streams).

Partial participation (cohort) variants operate on a *cohort-stacked*
pytree (leading axis = cohort size c ≤ m) plus the sorted cohort index
array. The (m, m) mixing matrix W is sliced to the cohort's rows/columns
and **row-renormalized** so each participating client still applies a
convex combination over the uploads that actually arrived; absent clients
keep their last personalized model (the caller scatters the cohort result
back into the full stacked state):

  * ``fedavg_cohort``       — Eq. 1 restricted to the cohort, broadcast
                              back to all m (global-model semantics).
  * ``user_centric_cohort`` — Eq. 8 with W[cohort, cohort] renormalized.
  * ``clustered_cohort``    — §IV-B with centroid rules rebuilt from the
                              cohort members of each cluster.

The heavy lifting per leaf is a (rules, m) × (m, d) matmul executed by the
``mix_aggregate`` kernel (Pallas on TPU, jnp oracle on CPU).

The fixed-shape round engine uses the ``masked_*`` variants further down:
cohorts are padded to a static slot count with zero-weight masked slots,
every rule is expressed as per-slot (c, c) rows, and the mix + scatter
into the full stacked state runs as ONE fused ``masked_mix_scatter``
kernel pass over the ravel-once (c, d) update matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pytree import scatter_rows, stacked_ravel, stacked_unravel
from repro.kernels import ops


def _mix_tree(w, stacked, *, impl=None):
    """Apply mixing matrix w (k, m) to each leaf of a client-stacked tree."""

    def leaf(x):
        m = x.shape[0]
        flat = x.reshape(m, -1)
        out = ops.mix_aggregate(w, flat, impl=impl)
        return out.reshape((w.shape[0],) + x.shape[1:])

    return jax.tree.map(leaf, stacked)


def fedavg(stacked, n, *, impl=None):
    """Eq. 1 with w_i = n_i / Σ n_j, result broadcast back to all clients."""
    m = n.shape[0]
    w = (n / jnp.sum(n)).astype(jnp.float32)[None, :]  # (1, m)
    mixed = _mix_tree(w, stacked, impl=impl)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (m,) + x.shape[1:]), mixed)


def user_centric(stacked, w, *, impl=None):
    """Eq. 8 — full per-client personalization; w is the (m, m) matrix."""
    return _mix_tree(w, stacked, impl=impl)


def clustered(stacked, w, labels, num_clusters, *, impl=None):
    """§IV-B — m_t centroid aggregation rules, group-cast to members.

    Args:
      stacked: client-stacked pytree of locally-optimized models.
      w: (m, m) user-centric mixing matrix.
      labels: (m,) int cluster assignment from K-means over rows of w.
      num_clusters: static m_t.
    Returns:
      stacked tree where client i holds the mix of its cluster centroid.
    """
    m = w.shape[0]
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)  # (m, mt)
    counts = jnp.maximum(onehot.sum(axis=0), 1.0)  # (mt,)
    centroid_w = (onehot.T @ w) / counts[:, None]  # (mt, m) — centroid rules
    mixed = _mix_tree(centroid_w, stacked, impl=impl)  # (mt, ...)
    return jax.tree.map(lambda x: jnp.take(x, labels, axis=0), mixed)


def renormalize_rows(w, eps: float = 1e-12):
    """Rescale rows to sum to 1; all-zero rows stay zero (0/eps)."""
    return w / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), eps)


def cohort_mixing_matrix(w, cohort):
    """Slice W to the cohort's rows/columns and renormalize rows.

    The result is (c, c) row-stochastic (up to float error): participant i
    redistributes the mass of the absent columns proportionally across the
    uploads it did receive. A degenerate row — a participant whose W mass
    lies entirely on absent clients (possible when Eq. 9 underflows the
    off-diagonals) — falls back to the identity row, i.e. that client
    keeps its own locally-updated model instead of a zeroed mix.
    """
    wc = w[cohort][:, cohort]
    s = jnp.sum(wc, axis=1, keepdims=True)
    eye = jnp.eye(wc.shape[0], dtype=wc.dtype)
    return jnp.where(s > 1e-12, wc / jnp.maximum(s, 1e-12), eye)


def cohort_column_mixing(w, cohort):
    """Column-slice W to the cohort and renormalize every row.

    Returns ``(wc, alive)``: wc is (m, c) with rows rescaled to sum to 1,
    and alive is an (m,) bool marking rows that had any mass on the cohort
    — degenerate rows (no mass) are the caller's cue to keep the previous
    model rather than apply the (meaningless) zero mix. Shares the same
    threshold/fallback semantics as :func:`cohort_mixing_matrix`.
    """
    cols = w[:, cohort]
    s = jnp.sum(cols, axis=1, keepdims=True)
    return cols / jnp.maximum(s, 1e-12), s[:, 0] > 1e-12


def fedavg_cohort(stacked_cohort, n_cohort, m, *, impl=None):
    """Eq. 1 over the cohort's uploads; new global broadcast to all m."""
    w = (n_cohort / jnp.sum(n_cohort)).astype(jnp.float32)[None, :]  # (1, c)
    mixed = _mix_tree(w, stacked_cohort, impl=impl)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (m,) + x.shape[1:]),
                        mixed)


def user_centric_cohort(stacked_cohort, w, cohort, *, impl=None):
    """Eq. 8 restricted to the cohort; returns the cohort-stacked mix."""
    return _mix_tree(cohort_mixing_matrix(w, cohort), stacked_cohort,
                     impl=impl)


def clustered_cohort(stacked_cohort, w, labels, num_clusters, cohort, *,
                     impl=None):
    """§IV-B with centroid rules rebuilt from the cohort.

    Each centroid rule sums the W rows of its *participating* members and
    is renormalized over the cohort columns (the per-cluster member-count
    divide of :func:`clustered` would cancel against the renormalization,
    so it is omitted); clusters with no sampled member produce a zero rule
    that nobody receives. A participant whose centroid rule has no mass on
    the cohort (Eq. 9 underflow onto absent clients) keeps its own
    locally-updated model, mirroring ``cohort_mixing_matrix``'s fallback.
    """
    lc = jnp.take(labels, cohort)
    onehot = jax.nn.one_hot(lc, num_clusters, dtype=jnp.float32)  # (c, mt)
    raw = onehot.T @ w[cohort][:, cohort]  # (mt, c)
    mixed = _mix_tree(renormalize_rows(raw), stacked_cohort, impl=impl)
    alive = (jnp.sum(raw, axis=1) > 1e-12)[lc]  # (c,)
    return jax.tree.map(
        lambda x, own: jnp.where(
            alive.reshape((-1,) + (1,) * (own.ndim - 1)),
            jnp.take(x, lc, axis=0), own),
        mixed, stacked_cohort)


# --------------------------------------------------------------------------
# Padded/masked fixed-shape cohort variants.
#
# The fixed-shape engine pads every cohort to a static slot count: pad
# slots carry the sentinel index m (clamped for gathers, dropped by
# scatters) and mask == False. The rules below reproduce the cohort_*
# semantics above bit-for-bit on the real slots — the pad columns are
# zeroed before the row renormalization, so the row sums (and hence every
# mixed value) match the unpadded slicing exactly — while pad rows
# produce don't-care values that the scatter never writes. Each rule is
# expressed as a per-slot (c, c) row matrix so the whole PS step runs as
# ONE fused ``masked_mix_scatter`` kernel pass over the raveled (c, d)
# updates (see :mod:`repro.kernels.masked_mix_scatter`).
# --------------------------------------------------------------------------


def safe_gather_index(idx, m):
    """Clamp padded sentinel indices for gathers (pads read row m-1)."""
    return jnp.minimum(idx, m - 1)


def masked_cohort_matrix(w, idx, mask, weights=None):
    """Fixed-shape :func:`cohort_mixing_matrix`: (c, c) with zeroed pad
    columns, row-renormalized; degenerate rows fall back to identity.

    ``weights`` optionally replaces the binary mask as the per-slot
    COLUMN weight (the buffered-async engine passes staleness discounts
    ``(1+τ)^{-α}``, zero on empty slots); the row renormalization keeps
    every row a convex combination either way, and ``weights=None`` is
    bit-identical to the mask path.
    """
    fmask = mask.astype(w.dtype) if weights is None else weights
    safe = safe_gather_index(idx, w.shape[0])
    wc = w[safe][:, safe] * fmask[None, :]
    s = jnp.sum(wc, axis=1, keepdims=True)
    eye = jnp.eye(wc.shape[0], dtype=wc.dtype)
    return jnp.where(s > 1e-12, wc / jnp.maximum(s, 1e-12), eye)


def masked_clustered_rows(w, labels, num_clusters, idx, mask, weights=None):
    """Fixed-shape :func:`clustered_cohort` as per-slot rows.

    Returns (c, c): slot i's row is its cluster's centroid rule rebuilt
    from the masked cohort (renormalized over real columns); a slot whose
    centroid rule has no mass on the cohort falls back to the identity
    row (keeps its own locally-updated model), and pad slots are
    don't-care.

    ``weights`` optionally replaces the binary mask as the per-slot
    column weight of the uploads being mixed (staleness discounts in the
    buffered-async engine). Cluster MEMBERSHIP stays mask-based — a
    stale member still belongs to its cluster; only its upload's
    contribution is discounted. ``weights=None`` is bit-identical to the
    mask path.
    """
    fmask = mask.astype(w.dtype)
    colw = fmask if weights is None else weights
    safe = safe_gather_index(idx, w.shape[0])
    lc = jnp.take(labels, safe)
    onehot = jax.nn.one_hot(lc, num_clusters, dtype=w.dtype) * fmask[:, None]
    raw = onehot.T @ (w[safe][:, safe] * colw[None, :])  # (mt, c)
    rules = renormalize_rows(raw)
    alive = (jnp.sum(raw, axis=1) > 1e-12)[lc]  # (c,)
    eye = jnp.eye(safe.shape[0], dtype=w.dtype)
    return jnp.where(alive[:, None], jnp.take(rules, lc, axis=0), eye)


def masked_group_rows(assignment_c, n_c, mask):
    """Fixed-shape per-group FedAvg rows (CFL/Oracle cohort variant).

    assignment_c/n_c are the (c,) cohort-slot cluster ids and dataset
    sizes (pad slots: clamped-gather values, zeroed by the mask).
    """
    fmask = mask.astype(jnp.float32)
    same = (assignment_c[:, None] == assignment_c[None, :]).astype(jnp.float32)
    w = same * n_c.astype(jnp.float32)[None, :] * fmask[None, :]
    s = jnp.sum(w, axis=1, keepdims=True)
    eye = jnp.eye(w.shape[0], dtype=w.dtype)
    return jnp.where(s > 1e-12, w / jnp.maximum(s, 1e-12), eye)


def masked_fedavg_weights(n_c, mask, weights=None):
    """Fixed-shape Eq. 1 weights over the cohort: (1, c), pad slots 0.

    An all-masked cohort yields all-zero weights (0/eps) rather than NaN;
    ``fedavg_masked_mix`` uses that to fall back to the previous model.
    ``weights`` optionally replaces the binary mask (staleness discounts
    in the buffered-async engine, zero on empty slots); ``None`` is
    bit-identical to the mask path.
    """
    wn = n_c.astype(jnp.float32) * (
        mask.astype(jnp.float32) if weights is None else weights)
    return (wn / jnp.maximum(jnp.sum(wn), 1e-12))[None, :]


def masked_column_mixing(w, idx, mask):
    """Fixed-shape :func:`cohort_column_mixing` for the §V-E upper bound:
    (m, c) row-renormalized over real cohort columns, plus the (m,) alive
    marker for degenerate rows."""
    fmask = mask.astype(w.dtype)
    safe = safe_gather_index(idx, w.shape[0])
    cols = w[:, safe] * fmask[None, :]
    s = jnp.sum(cols, axis=1, keepdims=True)
    return cols / jnp.maximum(s, 1e-12), s[:, 0] > 1e-12


def masked_ewma_rows(buf, obs, idx, mask, alpha):
    """EWMA-fold per-slot observations into rows of a running buffer.

    ``buf`` is (m, ...) and ``obs`` is (c, ...): real slot i rewrites row
    ``idx[i]`` as ``(1−α)·buf + α·obs``; pad slots (sentinel index m,
    dropped by the scatter; mask False, blend suppressed) leave the
    buffer untouched. Used by the streaming W refresh for the (m, d)
    gradient-proxy buffer and the (m,) σ² buffer.
    """
    safe = safe_gather_index(idx, buf.shape[0])
    prev = jnp.take(buf, safe, axis=0)
    fmask = mask.reshape((-1,) + (1,) * (obs.ndim - 1)).astype(buf.dtype)
    blended = prev + fmask * alpha * (obs.astype(buf.dtype) - prev)
    return buf.at[idx].set(blended, mode="drop")


def masked_unit_ewma_rows(buf, obs, idx, mask, alpha, eps=1e-12):
    """:func:`masked_ewma_rows` re-projected onto the unit sphere.

    The streaming refresh keeps its (m, d) gradient-DIRECTION buffer
    unit-norm (the scale-free statistic space, see
    :mod:`repro.core.similarity`); a plain EWMA of two unit vectors has
    norm < 1, which would shrink every subsequent distance against the
    blended row, so the blend is renormalized before the scatter.
    """
    safe = safe_gather_index(idx, buf.shape[0])
    prev = jnp.take(buf, safe, axis=0)
    blended = prev + alpha * (obs.astype(buf.dtype) - prev)
    blended = blended / jnp.maximum(
        jnp.linalg.norm(blended, axis=-1, keepdims=True), eps)
    rows = jnp.where(mask[:, None], blended, prev)
    return buf.at[idx].set(rows, mode="drop")


def masked_delta_rows(delta, grads, idx, mask):
    """Refresh the observed clients' rows AND columns of the Δ buffer.

    Recomputes ``Δ[idx_i, j] = ‖grads[idx_i] − grads[j]‖²`` for every
    real slot against the full (already refreshed) gradient buffer and
    scatters it into both the rows and the (symmetric) columns; entries
    between two absent clients keep their previous value. Both of a
    cohort pair's entries derive from the same matmul, so the refreshed
    Δ stays symmetric with a zero diagonal up to matmul round-off (the
    expansion is clamped at 0; Eq. 9 needs no exact symmetry). Pad slots
    are dropped by the sentinel-index scatter and masked out of the row
    values.
    """
    m = delta.shape[0]
    safe = safe_gather_index(idx, m)
    g = jnp.take(grads, safe, axis=0).astype(jnp.float32)  # (c, d)
    gm = grads.astype(jnp.float32)  # (m, d)
    sq = jnp.sum(g * g, axis=-1)[:, None] + \
        jnp.sum(gm * gm, axis=-1)[None, :] - 2.0 * (g @ gm.T)
    rows = jnp.maximum(sq, 0.0)  # (c, m); clamp matmul round-off
    prev = jnp.take(delta, safe, axis=0)
    rows = jnp.where(mask[:, None], rows, prev)
    out = delta.at[idx].set(rows, mode="drop")       # observed rows
    return out.at[:, idx].set(rows.T, mode="drop")   # symmetric columns


def staleness_update(stale, idx, mask):
    """Advance the per-client staleness counters by one cohort round.

    Every client's counter (rounds since its Δ/σ² stats were observed)
    increments; the real cohort slots then reset to 0. Pad slots are
    dropped by the sentinel scatter and masked out of the reset.
    """
    bumped = stale + 1
    safe = safe_gather_index(idx, stale.shape[0])
    reset = jnp.where(mask, 0, jnp.take(bumped, safe))
    return bumped.at[idx].set(reset, mode="drop")


def cohort_gather(full, safe, *, impl=None):
    """Round-start cohort gather ``full[safe]`` as ONE kernel launch.

    A single-leaf stacked tree gathers through the HBM-resident per-row
    DMA kernel (:func:`repro.kernels.ops.cohort_gather`) on the
    zero-copy (m, d) flat view — ``full`` never streams through VMEM, so
    traffic is O(c·d) at any m. Multi-leaf trees fall back to the
    per-leaf ``jnp.take`` (:func:`repro.core.pytree.gather_rows`) —
    XLA's gather is already O(c·d) there and raveling the full state
    would cost the copy this path avoids. ``safe`` must be pre-clamped
    (:func:`safe_gather_index`); semantics are bit-identical to
    ``gather_rows``.
    """
    leaves, treedef = jax.tree.flatten(full)
    if len(leaves) == 1:
        leaf = leaves[0]
        flat = leaf.reshape(leaf.shape[0], -1)  # zero-copy view
        out = ops.cohort_gather(flat, safe, impl=impl)
        return jax.tree.unflatten(
            treedef, [out.reshape((safe.shape[0],) + leaf.shape[1:])])
    return jax.tree.map(lambda x: jnp.take(x, safe, axis=0), full)


def mix_scatter(full, cohort_updated, rows, idx, mask, *, impl=None):
    """Apply per-slot mixing rows and scatter into the full stacked state.

    The cohort-stacked update tree is raveled ONCE to a (c, d) matrix so
    the whole PS mix is a single kernel launch (instead of one
    ``mix_aggregate`` per pytree leaf). A single-leaf (already-flat)
    state then takes the fully fused ``masked_mix_scatter`` path — mix +
    masked row scatter in one kernel pass over a zero-copy (m, d)
    reshape view, with the pallas path aliasing the state buffer. For a
    multi-leaf tree, raveling the *full* state would itself copy the
    (m, d) bytes the fusion exists to save, so the mixed (c, d) rows are
    instead split back per leaf (cheap: c ≪ m rows) and row-scattered in
    place — under ``donate_argnums`` absent clients' rows never move.

    Pad slots rely on the sentinel-index contract: the scatter drops
    out-of-range rows, so ``mask`` must be False exactly where ``idx``
    is the sentinel m (guaranteed by ``participation.as_cohort``).
    """
    leaves, treedef = jax.tree.flatten(full)
    flat_c = stacked_ravel(cohort_updated)
    if len(leaves) == 1:
        leaf = leaves[0]
        flat = leaf.reshape(leaf.shape[0], -1)  # zero-copy view
        out = ops.masked_mix_scatter(rows, flat_c, idx, mask, flat,
                                     impl=impl)
        return jax.tree.unflatten(treedef, [out.reshape(leaf.shape)])
    mixed = ops.mix_aggregate(rows, flat_c, impl=impl)  # one launch
    return scatter_rows(full, idx, stacked_unravel(cohort_updated, mixed))


def mix_scatter_flat(full, flat_c, rows, idx, mask, *, impl=None):
    """:func:`mix_scatter` for an ALREADY-raveled (c, d) update matrix.

    The buffered-async flush stores pending uploads as raveled rows, so
    there is no cohort-stacked tree to ravel: single-leaf states take the
    same fused ``masked_mix_scatter`` kernel pass, multi-leaf trees mix
    once on (c, d) and unravel/row-scatter per leaf against ``full``'s
    trailing shapes. ``flat_c`` wider than the state's flat dim (the
    async buffer allocates rows at the 128-aligned width,
    ``ops.aligned_dim``) is sliced back — the tail columns are the
    deposit-time zero padding. Sentinel/mask semantics are identical to
    :func:`mix_scatter`.
    """
    leaves, treedef = jax.tree.flatten(full)
    d = sum(l.size // l.shape[0] for l in leaves)
    if flat_c.shape[1] > d:
        flat_c = flat_c[:, :d]
    if len(leaves) == 1:
        leaf = leaves[0]
        flat = leaf.reshape(leaf.shape[0], -1)  # zero-copy view
        out = ops.masked_mix_scatter(rows, flat_c, idx, mask, flat,
                                     impl=impl)
        return jax.tree.unflatten(treedef, [out.reshape(leaf.shape)])
    mixed = ops.mix_aggregate(rows, flat_c, impl=impl)  # one launch
    return scatter_rows(full, idx, stacked_unravel(full, mixed))


def centroid_rules(w, labels, num_clusters):
    """The (m_t, m) centroid mixing rows (the downlink streams)."""
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)
    counts = jnp.maximum(onehot.sum(axis=0), 1.0)
    return (onehot.T @ w) / counts[:, None]
