"""PS-side aggregation rules (paper Eq. 1/2/8 and §IV-B clustered variant).

All rules operate on a *client-stacked* pytree (every leaf has leading axis
m) and return a stacked pytree of the same structure:

  * ``fedavg``        — Eq. 1: one convex combination, broadcast to all m.
  * ``user_centric``  — Eq. 8: θ_i ← Σ_j W[i,j] θ_j (full personalization,
                        m distinct downlink streams).
  * ``clustered``     — §IV-B: only m_t centroid rules are materialized;
                        every client in cluster C_n receives the centroid
                        mix (group-cast, m_t streams).

The heavy lifting per leaf is a (rules, m) × (m, d) matmul executed by the
``mix_aggregate`` kernel (Pallas on TPU, jnp oracle on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _mix_tree(w, stacked, *, impl=None):
    """Apply mixing matrix w (k, m) to each leaf of a client-stacked tree."""

    def leaf(x):
        m = x.shape[0]
        flat = x.reshape(m, -1)
        out = ops.mix_aggregate(w, flat, impl=impl)
        return out.reshape((w.shape[0],) + x.shape[1:])

    return jax.tree.map(leaf, stacked)


def fedavg(stacked, n, *, impl=None):
    """Eq. 1 with w_i = n_i / Σ n_j, result broadcast back to all clients."""
    m = n.shape[0]
    w = (n / jnp.sum(n)).astype(jnp.float32)[None, :]  # (1, m)
    mixed = _mix_tree(w, stacked, impl=impl)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (m,) + x.shape[1:]), mixed)


def user_centric(stacked, w, *, impl=None):
    """Eq. 8 — full per-client personalization; w is the (m, m) matrix."""
    return _mix_tree(w, stacked, impl=impl)


def clustered(stacked, w, labels, num_clusters, *, impl=None):
    """§IV-B — m_t centroid aggregation rules, group-cast to members.

    Args:
      stacked: client-stacked pytree of locally-optimized models.
      w: (m, m) user-centric mixing matrix.
      labels: (m,) int cluster assignment from K-means over rows of w.
      num_clusters: static m_t.
    Returns:
      stacked tree where client i holds the mix of its cluster centroid.
    """
    m = w.shape[0]
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)  # (m, mt)
    counts = jnp.maximum(onehot.sum(axis=0), 1.0)  # (mt,)
    centroid_w = (onehot.T @ w) / counts[:, None]  # (mt, m) — centroid rules
    mixed = _mix_tree(centroid_w, stacked, impl=impl)  # (mt, ...)
    return jax.tree.map(lambda x: jnp.take(x, labels, axis=0), mixed)


def centroid_rules(w, labels, num_clusters):
    """The (m_t, m) centroid mixing rows (the downlink streams)."""
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)
    counts = jnp.maximum(onehot.sum(axis=0), 1.0)
    return (onehot.T @ w) / counts[:, None]
