"""PS-side aggregation rules (paper Eq. 1/2/8 and §IV-B clustered variant).

All rules operate on a *client-stacked* pytree (every leaf has leading axis
m) and return a stacked pytree of the same structure:

  * ``fedavg``        — Eq. 1: one convex combination, broadcast to all m.
  * ``user_centric``  — Eq. 8: θ_i ← Σ_j W[i,j] θ_j (full personalization,
                        m distinct downlink streams).
  * ``clustered``     — §IV-B: only m_t centroid rules are materialized;
                        every client in cluster C_n receives the centroid
                        mix (group-cast, m_t streams).

Partial participation (cohort) variants operate on a *cohort-stacked*
pytree (leading axis = cohort size c ≤ m) plus the sorted cohort index
array. The (m, m) mixing matrix W is sliced to the cohort's rows/columns
and **row-renormalized** so each participating client still applies a
convex combination over the uploads that actually arrived; absent clients
keep their last personalized model (the caller scatters the cohort result
back into the full stacked state):

  * ``fedavg_cohort``       — Eq. 1 restricted to the cohort, broadcast
                              back to all m (global-model semantics).
  * ``user_centric_cohort`` — Eq. 8 with W[cohort, cohort] renormalized.
  * ``clustered_cohort``    — §IV-B with centroid rules rebuilt from the
                              cohort members of each cluster.

The heavy lifting per leaf is a (rules, m) × (m, d) matmul executed by the
``mix_aggregate`` kernel (Pallas on TPU, jnp oracle on CPU).

The fixed-shape round engine uses the ``masked_*`` variants further down:
cohorts are padded to a static slot count with zero-weight masked slots,
every rule is expressed as per-slot (c, c) rows, and the mix + scatter
into the full stacked state runs as ONE fused ``masked_mix_scatter``
kernel pass over the ravel-once (c, d) update matrix.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.pytree import stacked_ravel
from repro.kernels import ops


def _mix_tree(w, stacked, *, impl=None):
    """Apply mixing matrix w (k, m) to each leaf of a client-stacked tree."""

    def leaf(x):
        m = x.shape[0]
        flat = x.reshape(m, -1)
        out = ops.mix_aggregate(w, flat, impl=impl)
        return out.reshape((w.shape[0],) + x.shape[1:])

    return jax.tree.map(leaf, stacked)


def fedavg(stacked, n, *, impl=None):
    """Eq. 1 with w_i = n_i / Σ n_j, result broadcast back to all clients."""
    m = n.shape[0]
    w = (n / jnp.sum(n)).astype(jnp.float32)[None, :]  # (1, m)
    mixed = _mix_tree(w, stacked, impl=impl)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (m,) + x.shape[1:]), mixed)


def user_centric(stacked, w, *, impl=None):
    """Eq. 8 — full per-client personalization; w is the (m, m) matrix."""
    return _mix_tree(w, stacked, impl=impl)


def clustered(stacked, w, labels, num_clusters, *, impl=None):
    """§IV-B — m_t centroid aggregation rules, group-cast to members.

    Args:
      stacked: client-stacked pytree of locally-optimized models.
      w: (m, m) user-centric mixing matrix.
      labels: (m,) int cluster assignment from K-means over rows of w.
      num_clusters: static m_t.
    Returns:
      stacked tree where client i holds the mix of its cluster centroid.
    """
    m = w.shape[0]
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)  # (m, mt)
    counts = jnp.maximum(onehot.sum(axis=0), 1.0)  # (mt,)
    centroid_w = (onehot.T @ w) / counts[:, None]  # (mt, m) — centroid rules
    mixed = _mix_tree(centroid_w, stacked, impl=impl)  # (mt, ...)
    return jax.tree.map(lambda x: jnp.take(x, labels, axis=0), mixed)


def renormalize_rows(w, eps: float = 1e-12):
    """Rescale rows to sum to 1; all-zero rows stay zero (0/eps)."""
    return w / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), eps)


def cohort_mixing_matrix(w, cohort):
    """Slice W to the cohort's rows/columns and renormalize rows.

    The result is (c, c) row-stochastic (up to float error): participant i
    redistributes the mass of the absent columns proportionally across the
    uploads it did receive. A degenerate row — a participant whose W mass
    lies entirely on absent clients (possible when Eq. 9 underflows the
    off-diagonals) — falls back to the identity row, i.e. that client
    keeps its own locally-updated model instead of a zeroed mix.
    """
    wc = w[cohort][:, cohort]
    s = jnp.sum(wc, axis=1, keepdims=True)
    eye = jnp.eye(wc.shape[0], dtype=wc.dtype)
    return jnp.where(s > 1e-12, wc / jnp.maximum(s, 1e-12), eye)


def cohort_column_mixing(w, cohort):
    """Column-slice W to the cohort and renormalize every row.

    Returns ``(wc, alive)``: wc is (m, c) with rows rescaled to sum to 1,
    and alive is an (m,) bool marking rows that had any mass on the cohort
    — degenerate rows (no mass) are the caller's cue to keep the previous
    model rather than apply the (meaningless) zero mix. Shares the same
    threshold/fallback semantics as :func:`cohort_mixing_matrix`.
    """
    cols = w[:, cohort]
    s = jnp.sum(cols, axis=1, keepdims=True)
    return cols / jnp.maximum(s, 1e-12), s[:, 0] > 1e-12


def fedavg_cohort(stacked_cohort, n_cohort, m, *, impl=None):
    """Eq. 1 over the cohort's uploads; new global broadcast to all m."""
    w = (n_cohort / jnp.sum(n_cohort)).astype(jnp.float32)[None, :]  # (1, c)
    mixed = _mix_tree(w, stacked_cohort, impl=impl)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (m,) + x.shape[1:]),
                        mixed)


def user_centric_cohort(stacked_cohort, w, cohort, *, impl=None):
    """Eq. 8 restricted to the cohort; returns the cohort-stacked mix."""
    return _mix_tree(cohort_mixing_matrix(w, cohort), stacked_cohort,
                     impl=impl)


def clustered_cohort(stacked_cohort, w, labels, num_clusters, cohort, *,
                     impl=None):
    """§IV-B with centroid rules rebuilt from the cohort.

    Each centroid rule sums the W rows of its *participating* members and
    is renormalized over the cohort columns (the per-cluster member-count
    divide of :func:`clustered` would cancel against the renormalization,
    so it is omitted); clusters with no sampled member produce a zero rule
    that nobody receives. A participant whose centroid rule has no mass on
    the cohort (Eq. 9 underflow onto absent clients) keeps its own
    locally-updated model, mirroring ``cohort_mixing_matrix``'s fallback.
    """
    lc = jnp.take(labels, cohort)
    onehot = jax.nn.one_hot(lc, num_clusters, dtype=jnp.float32)  # (c, mt)
    raw = onehot.T @ w[cohort][:, cohort]  # (mt, c)
    mixed = _mix_tree(renormalize_rows(raw), stacked_cohort, impl=impl)
    alive = (jnp.sum(raw, axis=1) > 1e-12)[lc]  # (c,)
    return jax.tree.map(
        lambda x, own: jnp.where(
            alive.reshape((-1,) + (1,) * (own.ndim - 1)),
            jnp.take(x, lc, axis=0), own),
        mixed, stacked_cohort)


# --------------------------------------------------------------------------
# Padded/masked fixed-shape cohort variants.
#
# The fixed-shape engine pads every cohort to a static slot count: pad
# slots carry the sentinel index m (clamped for gathers, dropped by
# scatters) and mask == False. The rules below reproduce the cohort_*
# semantics above bit-for-bit on the real slots — the pad columns are
# zeroed before the row renormalization, so the row sums (and hence every
# mixed value) match the unpadded slicing exactly — while pad rows
# produce don't-care values that the scatter never writes. Each rule is
# expressed as a per-slot (c, c) row matrix so the whole PS step runs as
# ONE fused ``masked_mix_scatter`` kernel pass over the raveled (c, d)
# updates (see :mod:`repro.kernels.masked_mix_scatter`).
# --------------------------------------------------------------------------


def safe_gather_index(idx, m):
    """Clamp padded sentinel indices for gathers (pads read row m-1)."""
    return jnp.minimum(idx, m - 1)


def masked_cohort_matrix(w, idx, mask, weights=None):
    """Fixed-shape :func:`cohort_mixing_matrix`: (c, c) with zeroed pad
    columns, row-renormalized; degenerate rows fall back to identity.

    ``weights`` optionally replaces the binary mask as the per-slot
    COLUMN weight (the buffered-async engine passes staleness discounts
    ``(1+τ)^{-α}``, zero on empty slots); the row renormalization keeps
    every row a convex combination either way, and ``weights=None`` is
    bit-identical to the mask path.
    """
    fmask = mask.astype(w.dtype) if weights is None else weights
    safe = safe_gather_index(idx, w.shape[0])
    wc = w[safe][:, safe] * fmask[None, :]
    s = jnp.sum(wc, axis=1, keepdims=True)
    eye = jnp.eye(wc.shape[0], dtype=wc.dtype)
    return jnp.where(s > 1e-12, wc / jnp.maximum(s, 1e-12), eye)


def masked_clustered_rows(w, labels, num_clusters, idx, mask, weights=None):
    """Fixed-shape :func:`clustered_cohort` as per-slot rows.

    Returns (c, c): slot i's row is its cluster's centroid rule rebuilt
    from the masked cohort (renormalized over real columns); a slot whose
    centroid rule has no mass on the cohort falls back to the identity
    row (keeps its own locally-updated model), and pad slots are
    don't-care.

    ``weights`` optionally replaces the binary mask as the per-slot
    column weight of the uploads being mixed (staleness discounts in the
    buffered-async engine). Cluster MEMBERSHIP stays mask-based — a
    stale member still belongs to its cluster; only its upload's
    contribution is discounted. ``weights=None`` is bit-identical to the
    mask path.
    """
    fmask = mask.astype(w.dtype)
    colw = fmask if weights is None else weights
    safe = safe_gather_index(idx, w.shape[0])
    lc = jnp.take(labels, safe)
    onehot = jax.nn.one_hot(lc, num_clusters, dtype=w.dtype) * fmask[:, None]
    raw = onehot.T @ (w[safe][:, safe] * colw[None, :])  # (mt, c)
    rules = renormalize_rows(raw)
    alive = (jnp.sum(raw, axis=1) > 1e-12)[lc]  # (c,)
    eye = jnp.eye(safe.shape[0], dtype=w.dtype)
    return jnp.where(alive[:, None], jnp.take(rules, lc, axis=0), eye)


def masked_group_rows(assignment_c, n_c, mask):
    """Fixed-shape per-group FedAvg rows (CFL/Oracle cohort variant).

    assignment_c/n_c are the (c,) cohort-slot cluster ids and dataset
    sizes (pad slots: clamped-gather values, zeroed by the mask).
    """
    fmask = mask.astype(jnp.float32)
    same = (assignment_c[:, None] == assignment_c[None, :]).astype(jnp.float32)
    w = same * n_c.astype(jnp.float32)[None, :] * fmask[None, :]
    s = jnp.sum(w, axis=1, keepdims=True)
    eye = jnp.eye(w.shape[0], dtype=w.dtype)
    return jnp.where(s > 1e-12, w / jnp.maximum(s, 1e-12), eye)


def masked_fedavg_weights(n_c, mask, weights=None):
    """Fixed-shape Eq. 1 weights over the cohort: (1, c), pad slots 0.

    An all-masked cohort yields all-zero weights (0/eps) rather than NaN;
    ``fedavg_masked_mix`` uses that to fall back to the previous model.
    ``weights`` optionally replaces the binary mask (staleness discounts
    in the buffered-async engine, zero on empty slots); ``None`` is
    bit-identical to the mask path.
    """
    wn = n_c.astype(jnp.float32) * (
        mask.astype(jnp.float32) if weights is None else weights)
    return (wn / jnp.maximum(jnp.sum(wn), 1e-12))[None, :]


def masked_column_mixing(w, idx, mask):
    """Fixed-shape :func:`cohort_column_mixing` for the §V-E upper bound:
    (m, c) row-renormalized over real cohort columns, plus the (m,) alive
    marker for degenerate rows."""
    fmask = mask.astype(w.dtype)
    safe = safe_gather_index(idx, w.shape[0])
    cols = w[:, safe] * fmask[None, :]
    s = jnp.sum(cols, axis=1, keepdims=True)
    return cols / jnp.maximum(s, 1e-12), s[:, 0] > 1e-12


def masked_ewma_rows(buf, obs, idx, mask, alpha):
    """EWMA-fold per-slot observations into rows of a running buffer.

    ``buf`` is (m, ...) and ``obs`` is (c, ...): real slot i rewrites row
    ``idx[i]`` as ``(1−α)·buf + α·obs``; pad slots (sentinel index m,
    dropped by the scatter; mask False, blend suppressed) leave the
    buffer untouched. Used by the streaming W refresh for the (m, d)
    gradient-proxy buffer and the (m,) σ² buffer.
    """
    safe = safe_gather_index(idx, buf.shape[0])
    prev = jnp.take(buf, safe, axis=0)
    fmask = mask.reshape((-1,) + (1,) * (obs.ndim - 1)).astype(buf.dtype)
    blended = prev + fmask * alpha * (obs.astype(buf.dtype) - prev)
    return buf.at[idx].set(blended, mode="drop")


def masked_unit_ewma_rows(buf, obs, idx, mask, alpha, eps=1e-12):
    """:func:`masked_ewma_rows` re-projected onto the unit sphere.

    The streaming refresh keeps its (m, d) gradient-DIRECTION buffer
    unit-norm (the scale-free statistic space, see
    :mod:`repro.core.similarity`); a plain EWMA of two unit vectors has
    norm < 1, which would shrink every subsequent distance against the
    blended row, so the blend is renormalized before the scatter.
    """
    safe = safe_gather_index(idx, buf.shape[0])
    prev = jnp.take(buf, safe, axis=0)
    blended = prev + alpha * (obs.astype(buf.dtype) - prev)
    blended = blended / jnp.maximum(
        jnp.linalg.norm(blended, axis=-1, keepdims=True), eps)
    rows = jnp.where(mask[:, None], blended, prev)
    return buf.at[idx].set(rows, mode="drop")


def masked_delta_rows(delta, grads, idx, mask):
    """Refresh the observed clients' rows AND columns of the Δ buffer.

    Recomputes ``Δ[idx_i, j] = ‖grads[idx_i] − grads[j]‖²`` for every
    real slot against the full (already refreshed) gradient buffer and
    scatters it into both the rows and the (symmetric) columns; entries
    between two absent clients keep their previous value. Both of a
    cohort pair's entries derive from the same matmul, so the refreshed
    Δ stays symmetric with a zero diagonal up to matmul round-off (the
    expansion is clamped at 0; Eq. 9 needs no exact symmetry). Pad slots
    are dropped by the sentinel-index scatter and masked out of the row
    values.
    """
    m = delta.shape[0]
    safe = safe_gather_index(idx, m)
    g = jnp.take(grads, safe, axis=0).astype(jnp.float32)  # (c, d)
    gm = grads.astype(jnp.float32)  # (m, d)
    sq = jnp.sum(g * g, axis=-1)[:, None] + \
        jnp.sum(gm * gm, axis=-1)[None, :] - 2.0 * (g @ gm.T)
    rows = jnp.maximum(sq, 0.0)  # (c, m); clamp matmul round-off
    prev = jnp.take(delta, safe, axis=0)
    rows = jnp.where(mask[:, None], rows, prev)
    out = delta.at[idx].set(rows, mode="drop")       # observed rows
    return out.at[:, idx].set(rows.T, mode="drop")   # symmetric columns


def staleness_update(stale, idx, mask):
    """Advance the per-client staleness counters by one cohort round.

    Every client's counter (rounds since its Δ/σ² stats were observed)
    increments; the real cohort slots then reset to 0. Pad slots are
    dropped by the sentinel scatter and masked out of the reset.
    """
    bumped = stale + 1
    safe = safe_gather_index(idx, stale.shape[0])
    reset = jnp.where(mask, 0, jnp.take(bumped, safe))
    return bumped.at[idx].set(reset, mode="drop")


def cohort_gather(full, safe, *, impl=None):
    """Round-start cohort gather ``full[safe]`` as ONE kernel launch.

    A single-leaf stacked tree gathers through the HBM-resident per-row
    DMA kernel (:func:`repro.kernels.ops.cohort_gather`) on the
    zero-copy (m, d) flat view — ``full`` never streams through VMEM, so
    traffic is O(c·d) at any m. Multi-leaf trees fall back to the
    per-leaf ``jnp.take`` (:func:`repro.core.pytree.gather_rows`) —
    XLA's gather is already O(c·d) there and raveling the full state
    would cost the copy this path avoids. ``safe`` must be pre-clamped
    (:func:`safe_gather_index`); semantics are bit-identical to
    ``gather_rows``.
    """
    leaves, treedef = jax.tree.flatten(full)
    if len(leaves) == 1:
        leaf = leaves[0]
        flat = leaf.reshape(leaf.shape[0], -1)  # zero-copy view
        out = ops.cohort_gather(flat, safe, impl=impl)
        return jax.tree.unflatten(
            treedef, [out.reshape((safe.shape[0],) + leaf.shape[1:])])
    return jax.tree.map(lambda x: jnp.take(x, safe, axis=0), full)


def mix_scatter(full, cohort_updated, rows, idx, mask, *, impl=None):
    """Apply per-slot mixing rows and scatter into the full stacked state.

    The cohort-stacked update tree is raveled ONCE to a (c, d) matrix
    and the whole PS mix runs as the fully fused ``masked_mix_scatter``
    kernel pass — mix + masked row scatter over a zero-copy (m, d)
    reshape view, with the pallas path aliasing the state buffer.

    The stacked state must be a single leaf: the slab engine
    (:class:`repro.core.flat.LayoutTable`) is the state contract, and
    every strategy ravels multi-leaf models into one (m, d_aligned)
    matrix at construction. The old per-leaf scatter fallback is gone —
    a multi-leaf ``full`` here means a caller bypassed the layout table,
    which is an error, not a slow path.

    Pad slots rely on the sentinel-index contract: the scatter drops
    out-of-range rows, so ``mask`` must be False exactly where ``idx``
    is the sentinel m (guaranteed by ``participation.as_cohort``).
    """
    return mix_scatter_flat(full, stacked_ravel(cohort_updated), rows,
                            idx, mask, impl=impl)


def mix_scatter_flat(full, flat_c, rows, idx, mask, *, impl=None):
    """:func:`mix_scatter` for an ALREADY-raveled (c, d) update matrix.

    The buffered-async flush stores pending uploads as raveled rows, so
    there is no cohort-stacked tree to ravel: the single-leaf state takes
    the same fused ``masked_mix_scatter`` kernel pass. ``flat_c`` wider
    than the state's flat dim (a true-dim cohort ravel against a
    128-aligned slab never happens, but the async buffer may allocate
    beyond ``aligned_dim``) is sliced back — the tail columns are the
    deposit-time zero padding. Sentinel/mask semantics are identical to
    :func:`mix_scatter`; multi-leaf stacked state raises (see there).
    """
    leaves, treedef = jax.tree.flatten(full)
    if len(leaves) != 1:
        raise ValueError(
            "mix_scatter: multi-leaf stacked state is no longer supported "
            "on the mix path — the slab engine (repro.core.flat."
            "LayoutTable) is the state contract; ravel the state to one "
            f"(m, dim_aligned) matrix (got {len(leaves)} leaves)")
    leaf = leaves[0]
    d = leaf.size // leaf.shape[0]
    if flat_c.shape[1] > d:
        flat_c = flat_c[:, :d]
    flat = leaf.reshape(leaf.shape[0], -1)  # zero-copy view
    out = ops.masked_mix_scatter(rows, flat_c, idx, mask, flat, impl=impl)
    return jax.tree.unflatten(treedef, [out.reshape(leaf.shape)])


def centroid_rules(w, labels, num_clusters):
    """The (m_t, m) centroid mixing rows (the downlink streams)."""
    onehot = jax.nn.one_hot(labels, num_clusters, dtype=jnp.float32)
    counts = jnp.maximum(onehot.sum(axis=0), 1.0)
    return (onehot.T @ w) / counts[:, None]


# --------------------------------------------------------------------------
# Byzantine-robust masked rules.
#
# Each rule is a fixed-shape rewrite of the masked upload stage
# ``(flat_c, idx, mask) -> (flat_c', idx', mask')`` applied BEFORE the
# (c, c)-row mix: value rules (trimmed mean / median / norm clip)
# sanitize the (c, d) upload slab in place, selection rules (Krum /
# multi-Krum) demote deselected slots to masked pad slots (mask False,
# sentinel index — the exact contract the finite guard and the
# sentinel-drop scatter already obey); trimmed mean does both — it
# winsorizes surviving values AND demotes rows that are coordinate
# outliers in a supermajority of coordinates (a clamped attacker row
# would otherwise keep its full mixing mass). Because the rewrite
# happens on
# the replicated cohort slab and the downstream rules are the existing
# masked (c, c) rows, the whole PS step keeps its single fused
# ``masked_mix_scatter`` launch, composes with staleness weights /
# ``w_refresh`` unchanged, and works under ``shard_state`` at O(c·d)
# server cost. A rule at its neutral parameter (``trim_k=0``,
# ``clip=inf``, ``multi_krum`` selecting every real slot) is a bit-exact
# pass-through. All rules assume a FINITE slab — run
# :func:`repro.federated.faults.finite_guard` first.
# --------------------------------------------------------------------------

_BIG = 1e30  # finite stand-in for +inf (inf * 0 = NaN would poison sorts)


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Byzantine-robust aggregation policy (``FedConfig.robust``).

    Attributes:
      rule: ``trimmed_mean`` | ``median`` | ``norm_clip`` | ``krum`` |
        ``multi_krum``.
      trim_k: coordinates trimmed (winsorized) from EACH tail
        (trimmed_mean); rows outside the inlier range in ≥ 75% of
        coordinates are demoted to masked pad slots. 0 is a bit-exact
        no-op.
      clip: deviation-norm ceiling (norm_clip); rows are shrunk toward
        the masked cohort mean. ``inf`` is a bit-exact no-op.
      f: assumed Byzantine count entering the Krum score (sum over the
        ``c_real − f − 2`` nearest neighbors).
      q: slots multi_krum keeps (``krum`` forces 1; ``None`` under
        multi_krum keeps ``c_real − f``). ``q >= c_real`` keeps every
        real slot — a bit-exact no-op.
    """

    rule: str = "trimmed_mean"
    trim_k: int = 1
    clip: float = math.inf
    f: int = 1
    q: int | None = None

    _RULES = ("trimmed_mean", "median", "norm_clip", "krum", "multi_krum")

    def __post_init__(self):
        if self.rule not in self._RULES:
            raise ValueError(f"unknown robust rule {self.rule!r} "
                             f"(expected one of {self._RULES})")
        if self.trim_k < 0:
            raise ValueError(f"trim_k must be >= 0, got {self.trim_k}")
        if self.clip <= 0:
            raise ValueError(f"clip must be > 0, got {self.clip}")


def masked_trimmed_mean(flat_c, mask, trim_k: int):
    """Coordinate-wise winsorized trimmed mean over the real cohort rows.

    For every coordinate, the ``trim_eff = min(trim_k, (c_real−1)//2)``
    smallest and largest real values are treated as outliers and CLAMPED
    to the surviving inlier range ``[lo, hi]`` (winsorization) instead
    of being replaced by a cross-client mean: a clamped attacker value
    cannot leave the honest coordinate range, while an honest extreme
    keeps (a clipped version of) its own signal rather than being
    averaged away — which matters under user-centric W mixing, where
    every trimmed honest value would otherwise dilute that client's
    personalization. In-range values pass through untouched (``clip`` is
    the identity for them), so ``trim_k=0`` is bit-exact. Masked rows
    are left as-is (their mix weight is already zero).
    Permutation-equivariant over rows by construction (order statistics).
    """
    if trim_k == 0:
        return flat_c
    lo, hi, fmask = _winsor_bounds(flat_c, mask, trim_k)
    return jnp.where(fmask, jnp.clip(flat_c, lo, hi), flat_c)


def _winsor_bounds(flat_c, mask, trim_k: int):
    """Per-coordinate inlier range after trimming ``trim_eff`` per tail.

    Returns ``(lo, hi, fmask)`` with lo/hi of shape (1, d) — the
    ``trim_eff``-th and ``(n_real−1−trim_eff)``-th order statistics of
    the real rows — and the (c, 1) bool row mask.
    """
    c = flat_c.shape[0]
    fmask = mask[:, None]
    n_real = jnp.sum(mask.astype(jnp.int32))
    trim_eff = jnp.minimum(trim_k, jnp.maximum(n_real - 1, 0) // 2)
    # ascending sort with masked rows pushed past every real value
    vals = jnp.where(fmask, flat_c, _BIG)
    svals = jnp.sort(vals, axis=0)
    lo_i = jnp.clip(trim_eff, 0, c - 1)
    hi_i = jnp.clip(n_real - 1 - trim_eff, 0, c - 1)
    take = lambda i: jnp.take_along_axis(  # noqa: E731 — tiny local helper
        svals, jnp.full((1, flat_c.shape[1]), i, jnp.int32), axis=0)
    return take(lo_i), take(hi_i), fmask


def trimmed_outlier_rows(flat_c, mask, trim_k: int, frac: float = 0.75):
    """Real rows that sit OUTSIDE the winsorization inlier range in at
    least ``frac`` of coordinates — i.e. rows that are coordinate-wise
    outliers almost everywhere, which no honest update is (honest rows
    land in the trimmed tails of scattered coordinates, a Byzantine
    sign-flip/scaled-noise row lands there in essentially all of them).

    Winsorization alone cannot defend a W-weighted mix: the clamped
    attacker row still carries its full mixing mass, now pointed at the
    boundary of the honest range — a systematic per-coordinate bias.
    Demoting supermajority-outlier rows (the caller flips them to masked
    pad slots, the same sentinel contract as drops/finite-guard) removes
    that mass entirely; the W renormalization over survivors does the
    rest. Returns a (c,) bool demote mask (False for masked rows).
    """
    lo, hi, fmask = _winsor_bounds(flat_c, mask, trim_k)
    out = fmask & ((flat_c < lo) | (flat_c > hi))
    d = max(flat_c.shape[1], 1)
    out_frac = jnp.sum(out.astype(jnp.float32), axis=1) / d
    return mask & (out_frac >= frac)


def masked_median_rows(flat_c, mask):
    """Replace every real row with the coordinate-wise masked median.

    The median of ``c_real`` values averages the two central order
    statistics for even counts. Any convex (c, c)-row mix of identical
    rows returns the median itself, so the downstream rule — FedAvg
    weights, user-centric W, clustered centroids — degenerates to the
    coordinate-median aggregate, which ≤ ⌊(c_real−1)/2⌋ arbitrary rows
    cannot move outside the honest rows' coordinate ranges (the
    breakdown property the tests pin).
    """
    c = flat_c.shape[0]
    n_real = jnp.sum(mask.astype(jnp.int32))
    svals = jnp.sort(jnp.where(mask[:, None], flat_c, _BIG), axis=0)
    k_lo = jnp.clip((n_real - 1) // 2, 0, c - 1)
    k_hi = jnp.clip(n_real // 2, 0, c - 1)
    take = lambda i: jnp.take_along_axis(  # noqa: E731
        svals, jnp.full((1, flat_c.shape[1]), i, jnp.int32), axis=0)
    med = 0.5 * (take(k_lo) + take(k_hi))
    return jnp.where(mask[:, None], med, flat_c)


def masked_norm_clip(flat_c, mask, clip: float):
    """Clip each real row's deviation from the masked cohort mean.

    Rows whose deviation norm already fits under ``clip`` pass through
    via ``jnp.where`` (bit-exact — the clip idempotence/no-op property),
    outliers are shrunk radially onto the ``clip`` sphere around the
    mean. ``clip=inf`` never fires (`norm <= inf` is always true).
    """
    fmask = mask[:, None].astype(flat_c.dtype)
    cnt = jnp.maximum(jnp.sum(fmask), 1.0)
    mu = jnp.sum(flat_c * fmask, axis=0, keepdims=True) / cnt
    dev = flat_c - mu
    norm = jnp.sqrt(jnp.sum(dev * dev, axis=1, keepdims=True))
    scaled = mu + dev * (clip / jnp.maximum(norm, 1e-12))
    keep = (norm <= clip) | ~mask[:, None]
    return jnp.where(keep, flat_c, scaled)


def krum_scores(flat_c, mask, f: int):
    """Krum scores over the masked cohort (lower = more central).

    score_i = sum of slot i's ``max(c_real − f − 2, 1)`` smallest
    squared distances to the OTHER real slots; masked slots (and pairs
    touching them) score ``_BIG`` so they never outrank a real slot.
    """
    c = flat_c.shape[0]
    x = jnp.where(mask[:, None], flat_c, 0.0).astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
    pair_ok = mask[:, None] & mask[None, :] & ~jnp.eye(c, dtype=bool)
    d2 = jnp.where(pair_ok, d2, _BIG)
    sd = jnp.sort(d2, axis=1)  # ascending; invalid pairs at the top
    n_real = jnp.sum(mask.astype(jnp.int32))
    k = jnp.clip(n_real - f - 2, 1, c - 1)
    csum = jnp.cumsum(sd, axis=1)
    score = jnp.take_along_axis(
        csum, jnp.full((c, 1), 0, jnp.int32) + (k - 1), axis=1)[:, 0]
    return jnp.where(mask, score, _BIG)


def masked_krum_select(flat_c, idx, mask, m: int, f: int,
                       q: int | None = None):
    """(multi-)Krum selection as a cohort-slot rewrite.

    Keeps the ``q`` lowest-scoring real slots (``q=None`` keeps
    ``c_real − f``; ``q=1`` is classic Krum) and demotes the rest to
    masked pad slots — mask False, sentinel index ``m`` — exactly like
    the finite guard, so deselected clients keep their previous model
    and every downstream rule/scatter composes unchanged. When the keep
    count covers every real slot the rewrite is bit-exact (``mask`` and
    ``idx`` come back unchanged). Returns ``(idx', mask')``.
    """
    score = krum_scores(flat_c, mask, f)
    n_real = jnp.sum(mask.astype(jnp.int32))
    keep_n = (jnp.maximum(n_real - f, 1) if q is None
              else jnp.clip(q, 1, flat_c.shape[0]))
    # rank via double argsort: deterministic under ties
    rank = jnp.argsort(jnp.argsort(score))
    selected = mask & (rank < keep_n)
    return jnp.where(selected, idx, m), selected


def robust_stage(cfg: RobustConfig | None):
    """Build the robust upload rewrite, or ``None`` when the knob is off.

    Returns a traceable ``stage(flat_c, idx, mask, m) ->
    (flat_c', idx', mask')`` over the replicated (c, d) upload slab.
    """
    if cfg is None:
        return None

    if cfg.rule == "trimmed_mean":
        def stage(flat_c, idx, mask, m):
            out = masked_trimmed_mean(flat_c, mask, cfg.trim_k)
            if cfg.trim_k == 0:  # neutral knob: bit-exact pass-through
                return out, idx, mask
            keep = mask & ~trimmed_outlier_rows(flat_c, mask, cfg.trim_k)
            return out, jnp.where(keep, idx, m), keep
    elif cfg.rule == "median":
        def stage(flat_c, idx, mask, m):
            return masked_median_rows(flat_c, mask), idx, mask
    elif cfg.rule == "norm_clip":
        def stage(flat_c, idx, mask, m):
            return masked_norm_clip(flat_c, mask, cfg.clip), idx, mask
    else:  # krum / multi_krum
        q = 1 if cfg.rule == "krum" else cfg.q

        def stage(flat_c, idx, mask, m):
            idx, mask = masked_krum_select(flat_c, idx, mask, m, cfg.f, q)
            return flat_c, idx, mask

    return stage
