"""The paper's proposed method: User-Centric Federated Learning.

Algorithm 1 end-to-end:
  1. special round — broadcast θ⁰; clients upload full gradients + σ_k²
     (Eq. 10) on a fixed minibatch partition of size ``var_batch_size``
     (a hyperparameter, §V-F);
  2. PS computes Δ and the mixing matrix W (Eq. 9);
  3. optionally K-means over rows of W to m_t centroid rules (§IV-B),
     picked by silhouette (Alg. 2) when ``num_streams="auto"``;
  4. every round: clients run ClientUpdate from their personalized model;
     PS applies the user-centric (or clustered) aggregation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, clustering, similarity
from repro.core.pytree import gather_rows, scatter_rows, stacked_ravel
from repro.core.strategy import FedConfig, Strategy, register
from repro.data.loader import fixed_partition
from repro.federated import client as fedclient


def compute_collaboration(apply_fn, params0, data, *, var_batch_size=100,
                          impl=None):
    """Run the special pre-training round; returns the dict of §IV-A."""
    m = data.num_clients
    stacked0 = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (m,) + x.shape), params0
    )
    xb, yb = jax.vmap(lambda x, y: fixed_partition(x, y, var_batch_size))(
        data.x, data.y
    )
    mb_grads = fedclient.minibatch_gradients(apply_fn, stacked0, xb, yb)
    gmat = stacked_ravel(mb_grads, lead=2)  # (m, K, d)
    return similarity.collaboration_round(gmat, data.n.astype(jnp.float32),
                                          impl=impl)


@register("ucfl")
def make_ucfl(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
              num_streams=None, var_batch_size=100, silhouette_key=None,
              kernel_impl=None):
    """The proposed strategy.

    num_streams: None -> full personalization (m streams, Eq. 8);
                 int k -> clustered with k streams (§IV-B);
                 "auto" -> Alg. 2 silhouette selection.
    """
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size,
    )

    def init(key, data):
        m = data.num_clients
        collab = compute_collaboration(
            apply_fn, params0, data, var_batch_size=var_batch_size,
            impl=kernel_impl,
        )
        w = collab["W"]
        labels = None
        k = num_streams
        if k == "auto":
            kkey = silhouette_key if silhouette_key is not None else key
            k, _ = clustering.choose_num_streams(kkey, w, impl=kernel_impl)
        if k is not None:
            res = clustering.kmeans(key, w, int(k), impl=kernel_impl)
            labels = res.labels
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (m,) + x.shape) + 0.0, params0
        )
        return {"params": stacked, "W": w, "labels": labels,
                "streams": k, "collab": collab}

    @functools.partial(jax.jit, static_argnames=("streams",))
    def _round(params, w, labels, x, y, key, streams):
        updated, _ = local(params, x, y, key)
        if streams is None:
            mixed = aggregation.user_centric(updated, w, impl=kernel_impl)
        else:
            mixed = aggregation.clustered(updated, w, labels, streams,
                                          impl=kernel_impl)
        return mixed

    @functools.partial(jax.jit, static_argnames=("streams",))
    def _round_cohort(params, w, labels, cohort, x, y, key, streams):
        # gather -> cohort local SGD -> cohort-sliced mix -> scatter back
        pc = gather_rows(params, cohort)
        updated, _ = local(pc, x[cohort], y[cohort], key)
        if streams is None:
            mixed = aggregation.user_centric_cohort(updated, w, cohort,
                                                    impl=kernel_impl)
        else:
            mixed = aggregation.clustered_cohort(updated, w, labels, streams,
                                                 cohort, impl=kernel_impl)
        return scatter_rows(params, cohort, mixed)

    def round(state, data, key, cohort=None):
        if cohort is None:
            new = _round(state["params"], state["W"], state["labels"],
                         data.x, data.y, key, state["streams"])
            active = data.num_clients
            streams = state["streams"] or active
        else:
            cohort = jnp.asarray(cohort)
            new = _round_cohort(state["params"], state["W"], state["labels"],
                                cohort, data.x, data.y, key, state["streams"])
            active = int(cohort.shape[0])
            if state["streams"]:
                # only the clusters actually represented in the cohort put
                # a centroid model on the downlink
                streams = int(np.unique(
                    np.asarray(state["labels"])[np.asarray(cohort)]).size)
            else:
                streams = active
        state = dict(state, params=new)
        return state, {"streams": streams, "cohort_size": active}

    scheme = "unicast" if num_streams is None else "groupcast"
    return Strategy(
        name="ucfl" if num_streams is None else f"ucfl_k{num_streams}",
        init=init, round=round, eval_params=lambda s: s["params"],
        comm_scheme=scheme,
        num_streams=None if num_streams in (None, "auto") else num_streams,
    )


@register("ucfl_parallel")
def make_ucfl_parallel(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                       var_batch_size=100, kernel_impl=None):
    """§V-E upper bound: m parallel FL instances solving Eq. 4 exactly.

    Every client locally optimizes ALL m personalized models each round
    (m× compute and uplink); the PS applies Eq. 12. Serves as the
    fully-collaborative upper bound in Fig. 6.
    """
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size,
    )

    def init(key, data):
        m = data.num_clients
        collab = compute_collaboration(
            apply_fn, params0, data, var_batch_size=var_batch_size,
            impl=kernel_impl,
        )
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (m,) + x.shape) + 0.0, params0
        )
        return {"params": stacked, "W": collab["W"]}

    @jax.jit
    def _round(params, w, x, y, key):
        m = x.shape[0]

        # θ_{i,j}: client j optimizes stream i's model on its local data.
        def per_stream(stream_params, skey):
            return local(
                jax.tree.map(
                    lambda p: jnp.broadcast_to(p, (m,) + p.shape), stream_params
                ),
                x, y, skey,
            )[0]

        keys = jax.random.split(key, m)
        all_updates = jax.vmap(per_stream)(params, keys)  # leaves (i=m, j=m, ...)
        # Eq. 12: θ_i ← Σ_j w_{i,j} θ_{i,j}
        return jax.tree.map(
            lambda u: jnp.einsum("ij,ij...->i...", w, u), all_updates
        )

    @jax.jit
    def _round_cohort(params, w, cohort, x, y, key):
        # Only cohort clients compute, but they still optimize ALL m stream
        # models (the defining m× cost of this upper bound); every stream
        # mixes over the cohort's uploads with renormalized weights.
        m = jax.tree.leaves(params)[0].shape[0]
        c = cohort.shape[0]
        xc, yc = x[cohort], y[cohort]

        def per_stream(stream_params, skey):
            return local(
                jax.tree.map(
                    lambda p: jnp.broadcast_to(p, (c,) + p.shape), stream_params
                ),
                xc, yc, skey,
            )[0]

        keys = jax.random.split(key, m)
        all_updates = jax.vmap(per_stream)(params, keys)  # leaves (i=m, j=c, ...)
        wc, alive = aggregation.cohort_column_mixing(w, cohort)  # (m, c), (m,)
        mixed = jax.tree.map(
            lambda u: jnp.einsum("ij,ij...->i...", wc, u), all_updates
        )
        # a stream whose W row has no mass on the cohort keeps its last
        # model instead of collapsing to the zero mix
        return jax.tree.map(
            lambda mix, old: jnp.where(
                alive.reshape((m,) + (1,) * (mix.ndim - 1)), mix, old
            ),
            mixed, params,
        )

    def round(state, data, key, cohort=None):
        if cohort is None:
            new = _round(state["params"], state["W"], data.x, data.y, key)
            active = data.num_clients
        else:
            cohort = jnp.asarray(cohort)
            new = _round_cohort(state["params"], state["W"], cohort,
                                data.x, data.y, key)
            active = int(cohort.shape[0])
        # streams stays m even under a cohort: every participant downloads
        # ALL m stream models to optimize them (the m x cost that makes
        # this the upper bound), so m distinct models hit the downlink.
        return dict(state, params=new), {"streams": data.num_clients,
                                         "cohort_size": active}

    return Strategy(
        name="ucfl_parallel", init=init, round=round,
        eval_params=lambda s: s["params"], comm_scheme="unicast",
    )
