"""The paper's proposed method: User-Centric Federated Learning.

Algorithm 1 end-to-end:
  1. special round — broadcast θ⁰; clients upload full gradients + σ_k²
     (Eq. 10) on a fixed minibatch partition of size ``var_batch_size``
     (a hyperparameter, §V-F);
  2. PS computes Δ and the mixing matrix W (Eq. 9);
  3. optionally K-means over rows of W to m_t centroid rules (§IV-B),
     picked by silhouette (Alg. 2) when ``num_streams="auto"``;
  4. every round: clients run ClientUpdate from their personalized model;
     PS applies the user-centric (or clustered) aggregation.

Cohort rounds use the fixed-shape masked engine (see
:mod:`repro.core.baselines.common`): the padded ``(indices, mask)`` slots
compile one round shape, the stacked-params buffer is donated, the PS mix
runs as one fused ``masked_mix_scatter`` kernel pass, and the downlink
stream count is computed on device from cluster-membership one-hots
precomputed at init (no per-round ``np.unique`` host sync).

State layout
------------
``init`` returns a dict of stacked device state plus host bookkeeping:

  * ``params`` — (m, ...) client-stacked personalized models;
  * ``W`` — the (m, m) mixing matrix (static without refresh, replaced
    every cohort round with refresh on);
  * ``labels`` / ``cluster_onehot`` / ``streams`` — clustered-variant
    bookkeeping (labels are fixed at init even under refresh: the
    downlink group structure stays static so one compiled round and a
    stable stream count survive — re-clustering is a host-side concern a
    caller can layer on top);
  * ``collab`` — the special round's raw statistics (kept for
    diagnostics/benchmarks; never donated);
  * ``refresh`` — only with ``FedConfig.w_refresh`` on: the streaming
    Δ/σ²/gradient-proxy/staleness buffers
    (:func:`repro.core.similarity.init_refresh_state`);
  * ``abuf`` — only with ``FedConfig.async_buffer`` on: the fixed-shape
    pending-upload buffer of the buffered-async server
    (:mod:`repro.federated.async_buffer`), created lazily on the first
    cohort round (its slot count is a participation-policy property).

Donation caveat: the jitted masked round donates BOTH the stacked
``params`` tree and (when present) the ``refresh`` or ``abuf`` buffers —
they are rewritten every cohort round. Callers that keep a pre-round state alive
must copy it (:func:`repro.federated.simulation.donation_safe_copy`
copies every ``jax.Array`` leaf, refresh buffers included); ``W`` and
``collab`` are not donated, so the init-time collaboration statistics
stay readable for the whole run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aggregation, clustering, flat, similarity
from repro.core.baselines import common
from repro.core.pytree import gather_rows, stacked_ravel, tree_count_params
from repro.core.strategy import FedConfig, Strategy, register
from repro.data.loader import fixed_partition
from repro.federated import async_buffer
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib
from repro.kernels import ops


def compute_collaboration(apply_fn, params0, data, *, var_batch_size=100,
                          impl=None, chunk_size=None, mesh=None):
    """Run the special pre-training round; returns the dict of §IV-A.

    ``chunk_size`` bounds the client axis with the same ``lax.map``
    machinery as local training: each chunk materializes only its own
    (chunk, K, d) minibatch-gradient stack and immediately reduces it to
    the (chunk, d) full gradients + (chunk,) variance estimates, so init
    memory is O(chunk·K·d) instead of O(m·K·d). ``mesh`` shards the
    client axis across devices (chunking within each shard) when the
    shard count divides m.
    """
    loss = fedclient.make_loss(apply_fn)
    grad_fn = jax.grad(loss)

    def one_client(x, y):
        xb, yb = fixed_partition(x, y, var_batch_size)
        g = jax.vmap(grad_fn, in_axes=(None, 0, 0))(params0, xb, yb)
        gmat = stacked_ravel(g, lead=1)  # (K, d)
        full = jnp.mean(gmat, axis=0)
        return full, similarity.sigma_sq(gmat, full)

    run = fedclient.client_vmap(one_client, chunk_size=chunk_size, mesh=mesh)
    full, sig = run(data.x, data.y)
    delta = similarity.pairwise_delta(full, impl=impl)
    w = similarity.mixing_weights(delta, sig, data.n.astype(jnp.float32))
    return {"full_grads": full, "sigma_sq": sig, "delta": delta, "W": w}


@register("ucfl")
def make_ucfl(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
              num_streams=None, var_batch_size=100, silhouette_key=None,
              kernel_impl=None):
    """The proposed strategy.

    num_streams: None -> full personalization (m streams, Eq. 8);
                 int k -> clustered with k streams (§IV-B);
                 "auto" -> Alg. 2 silhouette selection.

    ``cfg.w_refresh`` opts the cohort rounds into the streaming W refresh
    (see :mod:`repro.core.similarity`): the cohort's uploads re-estimate
    its Δ/σ² statistics and W is recomputed on device before the mix.

    ``cfg.async_buffer`` opts the cohort rounds into the buffered-async
    server (see :mod:`repro.federated.async_buffer`): uploads land in a
    fixed-shape pending buffer and the Eq. 8 / §IV-B mix is applied —
    staleness-discounted — only when ``flush_k`` have accumulated.
    Mutually exclusive with ``w_refresh`` for now (the refresh folds the
    barrier round's uploads; buffering them too would need a second
    (B, d) pre-params slab — recorded in ROADMAP).

    ``cfg.topology`` opts the CLUSTERED variant's cohort rounds into the
    two-tier engine (see :mod:`repro.federated.topology`): edges ship
    per-cluster partial sums, the PS normalizes once, and the centroids
    match the flat mix up to float association. Full personalization
    rejects the knob at construction (its Eq. 8 unicast mix does not
    factorize over edge partials); ``w_refresh`` composes — the fresh
    rules feed the same tiered serve.
    """
    if cfg.async_buffer is not None and cfg.w_refresh is not None:
        raise ValueError(
            "FedConfig.async_buffer and FedConfig.w_refresh cannot be "
            "combined yet: the streaming refresh consumes each barrier "
            "round's (pre, post) upload pair, which the async buffer "
            "does not retain (see ROADMAP)")
    if num_streams is None:
        topology_lib.unsupported(
            cfg.topology, "ucfl",
            "full personalization's Eq. 8 mix is per-client unicast — "
            "every receiver's row reads every cohort column, so the PS "
            "rule has no per-edge partial-sum factorization (use the "
            "clustered variant)")
    topo = topology_lib.check_composition(
        cfg.topology, f"ucfl_k{num_streams}", shard_state=cfg.shard_state,
        async_buffer=cfg.async_buffer)
    edge_arr = topo.edge_array() if topo is not None else None
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )
    refresh_hook = common.w_refresh_hook(cfg.w_refresh)
    acfg = cfg.async_buffer
    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    layout = flat.LayoutTable.build(params0)
    # wire schema: one delta upload either way; full personalization also
    # delta-codes its per-client downlink (each receiver's reference is
    # its own round-start row), while the clustered variant's centroid
    # groupcast stays raw — a centroid is not any receiver's old model
    if num_streams is None:
        schema = transport_lib.single_delta_schema(
            "ucfl", layout.dim,
            downlink=(transport_lib.Stream("personalized", layout.dim),))
    else:
        schema = transport_lib.single_delta_schema(
            f"ucfl_k{num_streams}", layout.dim,
            downlink=(transport_lib.Stream("centroids", layout.dim,
                                           coding="raw"),))
    # fault injection / finite guard / robust rewrite of the upload slab
    # (None when both knobs are off — the bodies keep their exact trace)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)
    # quantized uplink (None when off — exact stage-free trace); the EF
    # accumulator slab rides the params layout, shard_state included
    tstage = transport_lib.make_wire_stage(schema, cfg.transport, "uplink")
    # per-client downlink stage (full personalization only): the served
    # row is delta-coded against the receiver's round-start model with a
    # server-side per-client EF slab
    dstage = transport_lib.make_wire_stage(schema, cfg.transport,
                                           "downlink")

    def init(key, data):
        m = data.num_clients
        if topo is not None:
            topo.check_clients(m, "ucfl")
        collab = compute_collaboration(
            apply_fn, params0, data, var_batch_size=var_batch_size,
            impl=kernel_impl, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
        )
        w = collab["W"]
        labels = None
        onehot = None
        k = num_streams
        if k == "auto":
            kkey = silhouette_key if silhouette_key is not None else key
            k, _ = clustering.choose_num_streams(kkey, w, impl=kernel_impl)
        if k is not None:
            res = clustering.kmeans(key, w, int(k), impl=kernel_impl)
            labels = res.labels
            # cluster-membership one-hots: lets the cohort round count the
            # represented clusters (downlink streams) on device instead of
            # a per-round np.unique host round-trip
            onehot = jax.nn.one_hot(labels, int(k), dtype=jnp.float32)
        stacked = layout.slab(params0, m)
        state = {"params": stacked, "W": w, "labels": labels,
                 "cluster_onehot": onehot, "streams": k, "collab": collab}
        if refresh_hook is not None:
            state["refresh"] = similarity.init_refresh_state(collab, m)
        if tstage is not None:
            state["ef"] = jnp.zeros_like(stacked)
        if dstage is not None:
            state["ef_dl"] = jnp.zeros_like(stacked)
        return state

    @functools.partial(jax.jit, static_argnames=("streams",))
    def _round(params, w, labels, x, y, key, streams):
        updated, _ = local(layout.unravel(params), x, y, key)
        if streams is None:
            mixed = aggregation.user_centric(updated, w, impl=kernel_impl)
        else:
            mixed = aggregation.clustered(updated, w, labels, streams,
                                          impl=kernel_impl)
        return layout.ravel(mixed)

    def _mix_rows(w, labels, onehot, idx, mask, safe, streams,
                  weights=None):
        # ``weights`` (buffered-async staleness discounts) replaces the
        # binary mask as the upload-column weight; None = the barrier mix
        if streams is None:
            rows = aggregation.masked_cohort_matrix(w, idx, mask, weights)
            n_streams = jnp.sum(mask)
        else:
            rows = aggregation.masked_clustered_rows(w, labels, streams,
                                                     idx, mask, weights)
            # only the clusters actually represented in the cohort put a
            # centroid model on the downlink
            oc = jnp.take(onehot, safe, axis=0) * mask[:, None]
            n_streams = jnp.sum(jnp.max(oc, axis=0) > 0)
        return rows, n_streams

    def _serve(params, pc, post, rows, idx, mask, ef_dl):
        # PS mix + downlink. dstage None (transport off, or the clustered
        # raw groupcast) keeps the fused masked mix + scatter — the exact
        # pre-schema trace. With the full variant's delta downlink the
        # mix is materialized per cohort row (same O(c·d) math, unfused),
        # delta-coded against each receiver's round-start row pc with the
        # per-client server-side EF, and scattered at the ORIGINAL slots
        # (sentinel-demoted slots drop — their receiver gets nothing, and
        # keeps both its model and its EF row).
        if dstage is None:
            return (sops.mix_scatter_flat(params, post, rows, idx, mask,
                                          impl=kernel_impl), ef_dl)
        safe = aggregation.safe_gather_index(idx, params.shape[0])
        mixed = ops.mix_aggregate(rows, post, impl=kernel_impl)
        served, efdc = dstage(pc, mixed, sops.gather(ef_dl, safe))
        ef_dl = sops.scatter(ef_dl, idx, efdc)
        return sops.scatter(params, idx, served), ef_dl

    def _tiered_serve(params, w, labels, onehot, post, idx, mask, safe):
        # Two-tier §IV-B mix. Tier 1: each edge accumulates per-cluster
        # PARTIAL sums of its own members' uploads plus the matching
        # rule-mass partials — the raw centroid rules of
        # ``masked_clustered_rows`` split by edge membership. Tier 2: the
        # PS sums the E partials and normalizes ONCE, so the centroids
        # equal the flat renormalized mix up to float association while
        # only E·k (partial, mass) aggregates transit the backhaul
        # instead of c client uploads. The alive fallback (a slot whose
        # centroid rule has no cohort mass keeps its own model) and the
        # represented-cluster stream count match the flat path exactly.
        fmask = mask.astype(w.dtype)
        lc = jnp.take(labels, safe)
        oc = jnp.take(onehot, safe, axis=0) * fmask[:, None]  # (c, k)
        cw = oc.T @ (w[safe][:, safe] * fmask[None, :])  # (k, c) raw rules
        eoh = topology_lib.edge_onehot(edge_arr, topo.num_edges, idx, mask)
        part = jnp.einsum("kc,ce,cd->ekd", cw, eoh, post)  # (E, k, d)
        pmass = jnp.einsum("kc,ce->ek", cw, eoh)  # (E, k)
        massk = jnp.sum(pmass, axis=0)  # (k,)
        cent = jnp.sum(part, axis=0) / jnp.maximum(massk, 1e-12)[:, None]
        served = jnp.where((massk > 1e-12)[lc][:, None],
                           jnp.take(cent, lc, axis=0), post)
        n_streams = jnp.sum(jnp.max(oc, axis=0) > 0)
        return sops.scatter(params, idx, served), n_streams

    @functools.partial(jax.jit, static_argnames=("streams",),
                       donate_argnums=(0, 1, 2))
    def _masked(params, ef, ef_dl, w, labels, onehot, idx, mask, x, y, key,
                streams):
        # masked gather -> cohort local SGD -> (quantized transport) ->
        # (fault/robust upload rewrite) -> masked mix + downlink serve.
        # ``ef``/``ef_dl`` are None when the owning stage is off (empty
        # pytrees — inert donation slots, exactly the stage-free trace).
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        keys = common.cohort_keys(key, x.shape[0], safe)
        pc = sops.gather(params, safe)
        updated, _ = local(layout.unravel(pc), x[safe], y[safe], None,
                           keys=keys)
        post = layout.ravel(updated)
        if tstage is not None:
            # EF rows ride the cohort: gathered at the clamped indices,
            # scattered back at the ORIGINAL slots (a later fault/robust
            # demotion loses the upload, not the client's residual)
            post, efc = tstage(pc, post, sops.gather(ef, safe))
            ef = sops.scatter(ef, idx, efc)
        if ustage is not None:
            post, idx, mask = ustage(pc, post, idx, mask, key, x.shape[0])
            safe = aggregation.safe_gather_index(idx, x.shape[0])
        if topo is not None:
            new, n_streams = _tiered_serve(params, w, labels, onehot,
                                           post, idx, mask, safe)
            return new, ef, ef_dl, n_streams
        rows, n_streams = _mix_rows(w, labels, onehot, idx, mask, safe,
                                    streams)
        new, ef_dl = _serve(params, pc, post, rows, idx, mask, ef_dl)
        return new, ef, ef_dl, n_streams

    @functools.partial(jax.jit, static_argnames=("streams",),
                       donate_argnums=(0, 1, 2, 3))
    def _masked_refresh(params, ef, ef_dl, refresh, w, labels, onehot, idx,
                        mask, n, x, y, key, streams):
        # masked gather -> cohort local SGD -> (quantized transport) ->
        # (fault/robust upload rewrite) -> streaming W refresh from the
        # uploads -> fused masked mix + scatter with the FRESH rows. The
        # stages run FIRST so the refresh only ever folds the upload the
        # server actually decoded, with the FINAL slot arrays: the
        # dequantized post (EF keeps its drift from the raw delta
        # bounded, so quantization noise stays out of the Δ/σ²
        # statistics) and none of the demoted/Byzantine-trimmed rows (W
        # quarantines what the guard caught).
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        keys = common.cohort_keys(key, x.shape[0], safe)
        pc = sops.gather(params, safe)
        updated, _ = local(layout.unravel(pc), x[safe], y[safe], None,
                           keys=keys)
        post = layout.ravel(updated)
        if tstage is not None:
            post, efc = tstage(pc, post, sops.gather(ef, safe))
            ef = sops.scatter(ef, idx, efc)
        if ustage is not None:
            post, idx, mask = ustage(pc, post, idx, mask, key, x.shape[0])
            safe = aggregation.safe_gather_index(idx, x.shape[0])
        # the refresh buffers are true-dim wide (they come from the
        # special round's raveled gradients); the slab's aligned tail is
        # zero on both sides, so slicing it off is value-free
        refresh, w = refresh_hook(pc[..., :layout.dim],
                                  post[..., :layout.dim], refresh, idx,
                                  mask, n)
        if topo is not None:
            # the FRESH rules feed the same tiered serve — w_refresh and
            # the two-tier engine compose without a second code path
            new, n_streams = _tiered_serve(params, w, labels, onehot,
                                           post, idx, mask, safe)
            return new, ef, ef_dl, refresh, w, n_streams
        rows, n_streams = _mix_rows(w, labels, onehot, idx, mask, safe,
                                    streams)
        new, ef_dl = _serve(params, pc, post, rows, idx, mask, ef_dl)
        return new, ef, ef_dl, refresh, w, n_streams

    amasked = _amasked_jit = None
    if acfg is not None:
        flush_k = int(acfg.flush_k)
        dim = tree_count_params(params0)
        ascatter = sops.buffer_scatter()

        @functools.partial(jax.jit, static_argnames=("streams",),
                           donate_argnums=(0, 1, 2))
        def _amasked(params, ef, abuf, w, labels, onehot, idx, mask, x, y,
                     key, streams):
            # masked gather -> cohort local SGD -> buffer deposit ->
            # staleness-weighted flush (fused mix + scatter) when >= K
            # uploads are pending. ONE compiled shape covers deposit-only
            # and flush rounds (lax.cond), so the one-compilation
            # guarantee of the barrier engine carries over.
            m = x.shape[0]
            safe = aggregation.safe_gather_index(idx, m)
            keys = common.cohort_keys(key, m, safe)
            pc = sops.gather(params, safe)
            updated, _ = local(layout.unravel(pc), x[safe], y[safe], None,
                               keys=keys)
            post_flat = layout.ravel(updated)
            if tstage is not None:
                # the user-centric buffer holds MODELS, so the deposit is
                # the reconstructed post' = pre + dequant — exactly what
                # the wire carried plus the base the server already has
                post_flat, efc = tstage(pc, post_flat,
                                        sops.gather(ef, safe))
                ef = sops.scatter(ef, idx, efc)
            if ustage is not None:
                # rewrite the upload BEFORE it is deposited: demoted
                # slots carry the sentinel/False mask, so their junk
                # rows never enter the pending buffer
                post_flat, idx, mask = ustage(pc, post_flat, idx, mask,
                                              key, m)
                safe = aggregation.safe_gather_index(idx, m)
            # a client trains from its OWN row, untouched since the flush
            # that last wrote it — that version is the upload's base
            base_ver = jnp.take(abuf["last_sync"], safe)
            abuf = async_buffer.deposit(abuf, post_flat, idx,
                                        mask, base_ver, m,
                                        scatter=ascatter)
            flush = abuf["count"] >= flush_k
            weights = async_buffer.staleness_weights(abuf, m, acfg.alpha)
            tau = async_buffer.staleness(abuf)
            applied = abuf["count"]
            bidx = abuf["idx"]
            bvalid = async_buffer.valid_mask(abuf, m)
            bsafe = aggregation.safe_gather_index(bidx, m)

            def do_flush(params, abuf):
                rows, n_streams = _mix_rows(w, labels, onehot, bidx, bvalid,
                                            bsafe, streams, weights)
                new = sops.mix_scatter_flat(params, abuf["upd"],
                                            rows, bidx, bvalid,
                                            impl=kernel_impl,
                                            flat_sharded=sops.sharded)
                return new, async_buffer.flush_reset(abuf, m), n_streams

            def no_flush(params, abuf):
                return params, abuf, jnp.zeros((), jnp.int32)

            params, abuf, n_streams = jax.lax.cond(
                flush, do_flush, no_flush, params, abuf)
            metrics = {**async_buffer.flush_metrics(
                flush, applied, tau, weights, abuf["count"]),
                "streams": n_streams}
            return params, ef, abuf, metrics

        _amasked_jit = _amasked

        def amasked(state, data, key, idx, mask):
            abuf = common.state_async_buffer(state, acfg, data.num_clients,
                                             idx.shape[0], dim, sops,
                                             schema)
            new, ef, abuf, am = _amasked(
                state["params"], state.get("ef"), abuf, state["W"],
                state["labels"], state["cluster_onehot"], idx, mask,
                data.x, data.y, key, state["streams"])
            out = dict(state, params=new, abuf=abuf)
            if ef is not None:
                out["ef"] = ef
            return out, am

    def dense(state, data, key):
        # the dense path never refreshes: cohort=None must stay bit-exact
        # with the paper's compute-W-once engine (and has no staleness)
        new = _round(state["params"], state["W"], state["labels"],
                     data.x, data.y, key, state["streams"])
        return dict(state, params=new), {
            "streams": state["streams"] or data.num_clients}

    def masked(state, data, key, idx, mask):
        if refresh_hook is None:
            new, ef, ef_dl, n_streams = _masked(
                state["params"], state.get("ef"), state.get("ef_dl"),
                state["W"], state["labels"], state["cluster_onehot"],
                idx, mask, data.x, data.y, key, state["streams"])
            out = dict(state, params=new)
            if ef is not None:
                out["ef"] = ef
            if ef_dl is not None:
                out["ef_dl"] = ef_dl
            return out, {"streams": n_streams}
        new, ef, ef_dl, refresh, w, n_streams = _masked_refresh(
            state["params"], state.get("ef"), state.get("ef_dl"),
            state["refresh"], state["W"], state["labels"],
            state["cluster_onehot"], idx, mask, data.n, data.x, data.y,
            key, state["streams"])
        out = dict(state, params=new, refresh=refresh, W=w)
        if ef is not None:
            out["ef"] = ef
        if ef_dl is not None:
            out["ef_dl"] = ef_dl
        return (out,
                {"streams": n_streams, **common.staleness_metrics(refresh)})

    scheme = "unicast" if num_streams is None else "groupcast"
    if acfg is not None:
        masked_jit = _amasked_jit
    elif refresh_hook is not None:
        masked_jit = _masked_refresh
    else:
        masked_jit = _masked
    shard_keys = ("params",)
    if tstage is not None:
        shard_keys += ("ef",)
    if dstage is not None:
        shard_keys += ("ef_dl",)
    return Strategy(
        name="ucfl" if num_streams is None else f"ucfl_k{num_streams}",
        init=init, round=common.cohort_round(
            dense, masked, masked_jit=masked_jit, mesh=cfg.mesh,
            async_fn=amasked, async_cfg=acfg, sops=sops,
            shard_keys=shard_keys, upload_stage=ustage,
            transport=cfg.transport, topology=topo),
        eval_params=lambda s: layout.unravel(s["params"]),
        comm_scheme=scheme,
        num_streams=None if num_streams in (None, "auto") else num_streams,
        skip_round=common.refresh_skip_round if refresh_hook is not None
        else None,
        injects_faults=cfg.faults is not None,
        wire_schema=schema,
    )


@register("ucfl_parallel")
def make_ucfl_parallel(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                       var_batch_size=100, kernel_impl=None):
    """§V-E upper bound: m parallel FL instances solving Eq. 4 exactly.

    Every client locally optimizes ALL m personalized models each round
    (m× compute and uplink); the PS applies Eq. 12. Serves as the
    fully-collaborative upper bound in Fig. 6.
    """
    if cfg.shard_state:
        raise NotImplementedError(
            "FedConfig.shard_state is not supported by ucfl_parallel: its "
            "(m, c) column mix reads every stream's row each round, so "
            "there is no O(c·d) row-routing to exploit (the m× cost is "
            "the point of this upper bound)")
    if cfg.faults is not None or cfg.robust is not None:
        raise NotImplementedError(
            "FedConfig.faults/robust are not supported by ucfl_parallel: "
            "the m× per-stream update stack has no single (c, d) upload "
            "slab for the fault/robust stage to rewrite — this idealized "
            "§V-E upper bound assumes honest clients by construction")
    transport_lib.unsupported(
        cfg.transport, "ucfl_parallel",
        "the m× per-stream update stack has no single (c, d) upload "
        "slab to quantize — the m× uplink cost is the point of this "
        "upper bound")
    topology_lib.unsupported(
        cfg.topology, "ucfl_parallel",
        "the §V-E upper bound mixes EVERY stream over every cohort "
        "column with the (m, c) column-sliced W — there are no per-edge "
        "partial aggregates for an edge tier to ship")
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )
    refresh_hook = common.w_refresh_hook(cfg.w_refresh)
    layout = flat.LayoutTable.build(params0)

    def init(key, data):
        m = data.num_clients
        collab = compute_collaboration(
            apply_fn, params0, data, var_batch_size=var_batch_size,
            impl=kernel_impl, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
        )
        state = {"params": layout.slab(params0, m), "W": collab["W"]}
        if refresh_hook is not None:
            state["refresh"] = similarity.init_refresh_state(collab, m)
        return state

    @jax.jit
    def _round(params, w, x, y, key):
        m = x.shape[0]
        tree = layout.unravel(params)

        # θ_{i,j}: client j optimizes stream i's model on its local data.
        def per_stream(stream_params, skey):
            return local(
                jax.tree.map(
                    lambda p: jnp.broadcast_to(p, (m,) + p.shape), stream_params
                ),
                x, y, skey,
            )[0]

        keys = jax.random.split(key, m)
        all_updates = jax.vmap(per_stream)(tree, keys)  # leaves (i=m, j=m, ...)
        # Eq. 12: θ_i ← Σ_j w_{i,j} θ_{i,j}
        return layout.ravel(jax.tree.map(
            lambda u: jnp.einsum("ij,ij...->i...", w, u), all_updates
        ))

    def _all_updates(params, idx, mask, x, y, key):
        # Only cohort clients compute, but they still optimize ALL m stream
        # models (the defining m× cost of this upper bound).
        m = params.shape[0]
        c = idx.shape[0]
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        xc, yc = x[safe], y[safe]

        def per_stream(stream_params, skey):
            return local(
                jax.tree.map(
                    lambda p: jnp.broadcast_to(p, (c,) + p.shape), stream_params
                ),
                xc, yc, None, keys=common.cohort_keys(skey, m, safe),
            )[0]

        keys = jax.random.split(key, m)
        # leaves (i=m, j=c, ...)
        return jax.vmap(per_stream)(layout.unravel(params), keys), safe

    def _masked_mix(params, w, all_updates, idx, mask):
        # every stream mixes over the cohort's uploads with masked
        # renormalized weights (pad slots carry zero weight).
        wc, alive = aggregation.masked_column_mixing(w, idx, mask)  # (m, c)
        mixed = jax.tree.map(
            lambda u: jnp.einsum("ij,ij...->i...", wc, u), all_updates
        )
        # a stream whose W row has no mass on the cohort keeps its last
        # model instead of collapsing to the zero mix
        return jnp.where(alive[:, None], layout.ravel(mixed), params)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def _masked(params, w, idx, mask, x, y, key):
        all_updates, _ = _all_updates(params, idx, mask, x, y, key)
        return _masked_mix(params, w, all_updates, idx, mask)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def _masked_refresh(params, refresh, w, idx, mask, n, x, y, key):
        all_updates, safe = _all_updates(params, idx, mask, x, y, key)
        # client j's own personalized trajectory is stream idx_j: use its
        # update of its OWN stream model as the gradient-proxy upload
        c = idx.shape[0]
        own = jax.tree.map(lambda u: u[safe, jnp.arange(c)], all_updates)
        pre = gather_rows(params, safe)
        refresh, w = refresh_hook(pre[..., :layout.dim],
                                  layout.ravel(own)[..., :layout.dim],
                                  refresh, idx, mask, n)
        return _masked_mix(params, w, all_updates, idx, mask), refresh, w

    def dense(state, data, key):
        new = _round(state["params"], state["W"], data.x, data.y, key)
        return dict(state, params=new), {"streams": data.num_clients}

    def masked(state, data, key, idx, mask):
        # streams stays m even under a cohort: every participant downloads
        # ALL m stream models to optimize them (the m x cost that makes
        # this the upper bound), so m distinct models hit the downlink.
        if refresh_hook is None:
            new = _masked(state["params"], state["W"], idx, mask,
                          data.x, data.y, key)
            return dict(state, params=new), {"streams": data.num_clients}
        new, refresh, w = _masked_refresh(
            state["params"], state["refresh"], state["W"], idx, mask,
            data.n, data.x, data.y, key)
        return (dict(state, params=new, refresh=refresh, W=w),
                {"streams": data.num_clients,
                 **common.staleness_metrics(refresh)})

    return Strategy(
        name="ucfl_parallel", init=init,
        round=common.cohort_round(
            dense, masked,
            masked_jit=_masked if refresh_hook is None else _masked_refresh,
            mesh=cfg.mesh, async_cfg=cfg.async_buffer),
        eval_params=lambda s: layout.unravel(s["params"]),
        comm_scheme="unicast",
        skip_round=common.refresh_skip_round if refresh_hook is not None
        else None,
    )
