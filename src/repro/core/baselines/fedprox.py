"""FedProx (Li et al., 2018) — FedAvg + proximal term μ/2·||θ − θ_global||²."""
from __future__ import annotations

import jax

from repro.core import aggregation
from repro.core.baselines.common import broadcast_params, gather_rows
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient


@register("fedprox")
def make_fedprox(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                 mu: float = 0.1, kernel_impl=None):
    def prox_hook(grads, params, center):
        g = jax.tree.map(lambda gg, p, c: gg + mu * (p - c), grads, params,
                         center)
        return g, center

    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, grad_hook=prox_hook,
        chunk_size=cfg.chunk_size,
    )

    def init(key, data):
        return {"params": broadcast_params(params0, data.num_clients)}

    @jax.jit
    def _round(params, n, x, y, key):
        updated, _ = local(params, x, y, key, params)  # center = round start
        return aggregation.fedavg(updated, n, impl=kernel_impl)

    @jax.jit
    def _round_cohort(params, cohort, n, x, y, key):
        pc = gather_rows(params, cohort)
        updated, _ = local(pc, x[cohort], y[cohort], key, pc)
        return aggregation.fedavg_cohort(updated, n[cohort], x.shape[0],
                                         impl=kernel_impl)

    def round(state, data, key, cohort=None):
        if cohort is None:
            new = _round(state["params"], data.n, data.x, data.y, key)
        else:
            new = _round_cohort(state["params"], jax.numpy.asarray(cohort),
                                data.n, data.x, data.y, key)
        return {"params": new}, {"streams": 1}

    return Strategy(f"fedprox_mu{mu}", init, round, lambda s: s["params"],
                    comm_scheme="broadcast", num_streams=1)
