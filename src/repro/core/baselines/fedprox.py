"""FedProx (Li et al., 2018) — FedAvg + proximal term μ/2·||θ − θ_global||²."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation, flat
from repro.core.baselines import common
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib


@register("fedprox")
def make_fedprox(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                 mu: float = 0.1, kernel_impl=None):
    def prox_hook(grads, params, center):
        g = jax.tree.map(lambda gg, p, c: gg + mu * (p - c), grads, params,
                         center)
        return g, center

    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, grad_hook=prox_hook,
        chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )

    layout = flat.LayoutTable.build(params0)
    schema = transport_lib.single_delta_schema(
        "fedprox", layout.dim,
        downlink=(transport_lib.Stream("model", layout.dim),))

    def init(key, data):
        if cfg.topology is not None:
            cfg.topology.check_clients(data.num_clients, "fedprox")
        state = {"params": layout.slab(params0, data.num_clients)}
        if cfg.transport is not None:
            state["ef"] = jnp.zeros(
                (data.num_clients, schema.width_aligned("uplink")),
                jnp.float32)
            state["ef_dl"] = jnp.zeros(
                (1, schema.width_aligned("downlink")), jnp.float32)
        return state

    @jax.jit
    def _round(params, n, x, y, key):
        tree = layout.unravel(params)
        updated, _ = local(tree, x, y, key, tree)  # center = round start
        return layout.ravel(aggregation.fedavg(updated, n,
                                               impl=kernel_impl))

    def _train(pc, xc, yc, keys, n, *_):
        updated, _ = local(pc, xc, yc, None, pc, keys=keys)  # center = start
        return updated

    topo = topology_lib.check_composition(
        cfg.topology, "fedprox", shard_state=cfg.shard_state,
        async_buffer=cfg.async_buffer)
    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)
    _masked = common.make_fedavg_masked_round(
        local, train=_train, impl=kernel_impl, sops=sops,
        upload_stage=ustage, layout=layout, transport=cfg.transport,
        schema=schema, topology=topo)

    def dense(state, data, key):
        new = _round(state["params"], data.n, data.x, data.y, key)
        return {"params": new}, {"streams": 1}

    def masked(state, data, key, idx, mask):
        if cfg.transport is None:
            new = _masked(state["params"], idx, mask, data.x, data.y, key,
                          data.n)
            return dict(state, params=new), {"streams": 1}
        (new, ef_dl), ef = _masked(state["params"], state["ef"], idx, mask,
                                   data.x, data.y, key, data.n,
                                   state["ef_dl"])
        return dict(state, params=new, ef=ef, ef_dl=ef_dl), {"streams": 1}

    amasked, masked_jit = common.fedavg_async_wrapper(
        _train, params0, cfg.async_buffer, impl=kernel_impl, sops=sops,
        upload_stage=ustage, layout=layout, transport=cfg.transport,
        schema=schema)

    shard_keys = (("params", "ef") if cfg.transport is not None
                  else ("params",))
    return Strategy(f"fedprox_mu{mu}", init,
                    common.cohort_round(dense, masked,
                                        masked_jit=masked_jit or _masked,
                                        mesh=cfg.mesh, async_fn=amasked,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops, shard_keys=shard_keys,
                                        upload_stage=ustage,
                                        transport=cfg.transport,
                                        topology=topo),
                    lambda s: layout.unravel(s["params"]),
                    comm_scheme="broadcast", num_streams=1,
                    injects_faults=cfg.faults is not None,
                    wire_schema=schema)
