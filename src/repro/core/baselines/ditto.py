"""Ditto (Li et al., 2021) — global FedAvg + per-client personal model
trained with a proximal pull λ·(v_i − θ_global) toward the global model.
Evaluation uses the personal models v_i.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aggregation, flat
from repro.core.baselines import common
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib


@register("ditto")
def make_ditto(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
               lam: float = 0.5, kernel_impl=None):
    # global-model update: plain FedAvg local training
    local_global = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )

    def ditto_hook(grads, params, center):
        g = jax.tree.map(lambda gg, p, c: gg + lam * (p - c), grads, params,
                         center)
        return g, center

    local_personal = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, grad_hook=ditto_hook,
        chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )

    layout = flat.LayoutTable.build(params0)
    # only the GLOBAL model crosses the wire: the personal model (and its
    # proximal pull toward the received global) is client-side state
    schema = transport_lib.single_delta_schema(
        "ditto", layout.dim,
        downlink=(transport_lib.Stream("model", layout.dim),))

    def init(key, data):
        m = data.num_clients
        state = {
            "params": layout.slab(params0, m),  # global (stacked)
            "personal": layout.slab(params0, m),
        }
        if cfg.transport is not None:
            state["ef"] = jnp.zeros(
                (m, schema.width_aligned("uplink")), jnp.float32)
            state["ef_dl"] = jnp.zeros(
                (1, schema.width_aligned("downlink")), jnp.float32)
        return state

    @jax.jit
    def _round(params, personal, n, x, y, key):
        k1, k2 = jax.random.split(key)
        tree = layout.unravel(params)
        updated, _ = local_global(tree, x, y, k1)
        new_global = layout.ravel(
            aggregation.fedavg(updated, n, impl=kernel_impl))
        # personal solver runs against the *received* global model
        new_personal, _ = local_personal(layout.unravel(personal), x, y,
                                         k2, tree)
        return new_global, layout.ravel(new_personal)

    topology_lib.unsupported(
        cfg.topology, "ditto",
        "the round interleaves the global FedAvg leg with a client-side "
        "personal solver keyed to the same cohort gather — threading the "
        "two-tier mix through both legs is future work")
    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)
    tstage = transport_lib.make_wire_stage(schema, cfg.transport, "uplink")
    dstage = transport_lib.make_wire_stage(schema, cfg.transport,
                                           "downlink")
    # the broadcast-family mix: plain masked Eq. 1 when the downlink is
    # raw, delta-coded against the old global with server-side EF when
    # the schema compresses the broadcast
    dl_mix = common.fedavg_mix_closure(sops=sops, impl=kernel_impl,
                                       dstage=dstage)

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
    def _masked(params, personal, ef, ef_dl, idx, mask, n, x, y, key):
        k1, k2 = jax.random.split(key)
        m = x.shape[0]
        safe = aggregation.safe_gather_index(idx, m)
        pc = sops.gather(params, safe)
        pct = layout.unravel(pc)
        xc, yc = x[safe], y[safe]
        updated, _ = local_global(pct, xc, yc, None,
                                  keys=common.cohort_keys(k1, m, safe))
        post = layout.ravel(updated)
        if tstage is not None:
            post, efc = tstage(pc, post, sops.gather(ef, safe))
            ef = sops.scatter(ef, idx, efc)
        # the fault/robust stage rewrites the UPLINK (the global-model
        # upload) only: personal models are client-side state that never
        # leaves the device, so their scatter keeps the ORIGINAL slots
        gidx, gmask = idx, mask
        if ustage is not None:
            post, gidx, gmask = ustage(pc, post, idx, mask, key, m)
        if dstage is None:
            new_global = dl_mix(params, post, gidx, gmask, n)
        else:
            new_global, ef_dl = dl_mix(params, post, gidx, gmask, n, ef_dl)
        # only participants advance their personal solver (against the
        # global they hold — pct, the round-start row)
        new_pc, _ = local_personal(
            layout.unravel(sops.gather(personal, safe)), xc, yc, None,
            pct, keys=common.cohort_keys(k2, m, safe))
        return (new_global, sops.scatter(personal, idx,
                                         layout.ravel(new_pc)), ef, ef_dl)

    def dense(state, data, key):
        g, p = _round(state["params"], state["personal"], data.n, data.x,
                      data.y, key)
        return {"params": g, "personal": p}, {"streams": 1}

    def masked(state, data, key, idx, mask):
        g, p, ef, ef_dl = _masked(state["params"], state["personal"],
                                  state.get("ef"), state.get("ef_dl"),
                                  idx, mask, data.n, data.x, data.y, key)
        out = {"params": g, "personal": p}
        if ef is not None:
            out["ef"] = ef
        if ef_dl is not None:
            out["ef_dl"] = ef_dl
        return out, {"streams": 1}

    shard_keys = ("params", "personal")
    if cfg.transport is not None:
        shard_keys += ("ef",)
    return Strategy(f"ditto_lam{lam}", init,
                    common.cohort_round(dense, masked, masked_jit=_masked,
                                        mesh=cfg.mesh,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops,
                                        shard_keys=shard_keys,
                                        upload_stage=ustage,
                                        transport=cfg.transport),
                    lambda s: layout.unravel(s["personal"]),
                    comm_scheme="broadcast",
                    num_streams=1,
                    injects_faults=cfg.faults is not None,
                    wire_schema=schema)
