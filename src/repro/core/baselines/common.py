"""Helpers shared by the baseline strategies — including the single
cohort-dispatch engine every strategy's ``round`` is built from.

PR 1 gave each of the eleven strategies its own hand-written
``round(state, data, key, cohort)`` wrapper repeating the same
``if cohort is None: dense else gather/train/mix/scatter`` shape, and the
availability sampler re-jitted the cohort path on every distinct
eligible-set size. The engine here replaces all of that:

  * :func:`cohort_round` — the ONE dispatch point. Normalizes the cohort
    argument to the padded ``(indices, mask)`` contract
    (:func:`repro.federated.participation.as_cohort`), routes to the
    dense or masked jitted path, and attaches the host-side
    ``cohort_size`` metric. Because every padded cohort of a policy has
    the same slot count, the masked path compiles exactly once.
  * :func:`make_masked_round` — the standard masked round body
    (masked gather -> chunked local SGD -> masked mix -> fused scatter)
    jitted with ``donate_argnums=(0,)`` so the (m, d) stacked-params
    buffer is updated in place instead of paying a full HBM copy per
    round. Strategies with extra stacked state (SCAFFOLD controls, Ditto
    / pFedMe personal models) keep custom jitted bodies but reuse the
    same pieces.

Slab state layout
-----------------
Every strategy's stacked device state — ``params``, SCAFFOLD controls,
Ditto/pFedMe personal models, transport error-feedback accumulators — is
a single float32 ``(m, dim_aligned)`` *slab* per entry, laid out by a
static :class:`repro.core.flat.LayoutTable` built once from ``params0``
at strategy construction. Pytree structure reappears ONLY at ``apply_fn``
boundaries: ``layout.unravel`` before local SGD / evaluation,
``layout.ravel`` on the way back. Because a bare matrix is a single-leaf
pytree, every tree-generic helper here (:class:`StateOps`, the mesh
row-sharding, :func:`fedavg_masked_mix`, the sentinel scatters) operates
on slabs unchanged — and always hits the fused single-leaf
``masked_mix_scatter`` / HBM gather-mix-scatter kernel path, multi-leaf
model or not. The ``dim_aligned - dim`` tail columns are zero by
construction (``LayoutTable.ravel`` zero-fills them); all mixes are
column-independent, so the tail never contaminates values, norms or the
streaming Δ/σ² statistics.

Donation rules: jax actually honors ``donate_argnums`` on CPU and TPU —
after a masked round the *input* state buffers are dead. Donated per
body: the ``params`` slab always; the ``ef`` transport accumulator,
``refresh`` buffers and the async ``abuf`` whenever the owning knob is
on (they are rewritten every cohort round). ``W`` and ``collab`` are
never donated. The simulation loop always rebinds the state, and its
warm-up call runs on a copy; any direct caller that wants to keep the
pre-round state alive must copy it first (see
tests/test_masked_cohort.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aggregation, similarity
from repro.core.pytree import (  # noqa: F401  (re-export)
    gather_rows, scatter_rows, stacked_ravel, stacked_unravel,
    tree_count_params,
)
from repro.federated import async_buffer
from repro.federated import mesh as mesh_lib
from repro.federated import participation
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib
from repro.kernels import ops


def broadcast_params(params0, m):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (m,) + x.shape) + 0.0, params0
    )


def group_mixing_matrix(assignment, n):
    """Row-stochastic W implementing per-group FedAvg (CFL/Oracle).

    W[i, j] = n_j · 1[a_i == a_j] / Σ_{a_k == a_i} n_k.
    """
    same = (assignment[:, None] == assignment[None, :]).astype(jnp.float32)
    w = same * n.astype(jnp.float32)[None, :]
    return w / jnp.sum(w, axis=1, keepdims=True)


def group_average(stacked, assignment, n, *, impl=None):
    w = group_mixing_matrix(assignment, n)
    return aggregation.user_centric(stacked, w, impl=impl)


# ------------------------------------------------------------ W refresh hook

def w_refresh_hook(refresh_cfg):
    """Build the in-jit streaming W-refresh step for W-owning strategies.

    Returns ``None`` when the knob is off, else a traceable
    ``hook(pre_flat, post_flat, refresh, idx, mask, n) -> (refresh', W')``
    where ``pre_flat``/``post_flat`` are the (c, d) raveled cohort params
    before/after local SGD (the upload the round already has — refreshing
    W adds NO uplink bytes). The hook introduces no new shapes, so one
    compiled round per policy still holds (recompile-guard tested in
    tests/test_w_refresh.py).
    """
    if refresh_cfg is None:
        return None

    def hook(pre_flat, post_flat, refresh, idx, mask, n):
        obs = similarity.grad_proxy(pre_flat, post_flat)
        return similarity.streaming_refresh(refresh, obs, idx, mask, n,
                                            cfg=refresh_cfg)

    return hook


def staleness_metrics(refresh):
    """Round metrics for the refresh buffers: the per-client staleness
    counters plus their max/mean (device scalars, like ``streams`` — no
    host sync in-round)."""
    stale = refresh["staleness"]
    return {"staleness": stale, "staleness_max": jnp.max(stale),
            "staleness_mean": jnp.mean(stale.astype(jnp.float32))}


def refresh_skip_round(state):
    """``Strategy.skip_round`` hook for W-refresh strategies.

    A round nobody attends still ages every client's Δ/σ² statistics:
    the per-client staleness counters advance exactly as
    :func:`repro.core.aggregation.staleness_update` would with an
    all-masked cohort (bump everyone, reset nobody). Without this, an
    all-offline round between two refresh rounds under-reported
    staleness by one — the simulation loop used to skip strategy state
    entirely.
    """
    refresh = state["refresh"]
    return dict(state,
                refresh=dict(refresh, staleness=refresh["staleness"] + 1))


# ----------------------------------------------------------- state layout

class StateOps:
    """Layout-aware primitives over the (m, ·) stacked server state.

    One object per strategy, built from the ``FedConfig`` knobs
    (``StateOps(cfg.mesh, cfg.shard_state)``) and closed over by the
    jitted round bodies, so every gather/scatter/mix against the stacked
    state goes through ONE dispatch point:

      * replicated (``shard_state=False``, the default): every method is
        exactly the pre-existing helper (``gather_rows``,
        ``aggregation.mix_scatter``, :func:`fedavg_masked_mix`, ...) —
        bit-exact with the unsharded engine, mesh or not.
      * row-sharded (``shard_state=True``): the state's leading axis
        lives partitioned across the ``clients`` mesh (see the
        row-sharded section of :mod:`repro.federated.mesh`); gathers
        assemble the cohort with a (c, d) psum, scatters/mixes rewrite
        only the owner shard's block, and the buffered-async flush's
        tiled all-gather of its (B, d) buffer is the only model-sized
        collective. Requires a mesh and ``m % num_shards == 0``.

    Cohort-shaped intermediates (the (c, ·) gathered trees, (c, c) mix
    rows, per-slot arrays) are always replicated — only (m, ·) /(B, ·)
    stacked state changes layout.
    """

    def __init__(self, mesh=None, shard_state: bool = False):
        mesh = mesh_lib.resolve(mesh)
        if shard_state and mesh is None:
            raise ValueError(
                "FedConfig.shard_state requires a mesh (FedConfig.mesh): "
                "row-sharding partitions the state across the clients "
                "mesh's devices")
        self.mesh = mesh
        self.sharded = bool(shard_state)

    # ---- cohort row movement

    def gather(self, tree, safe):
        """Cohort gather ``tree[safe]`` (``safe`` pre-clamped).

        Replicated single-leaf states route through the HBM-resident
        per-row DMA gather kernel (:func:`aggregation.cohort_gather` —
        bit-identical to ``gather_rows``, O(c·d) traffic at any m)."""
        if self.sharded:
            return mesh_lib.shard_gather_rows(tree, safe, self.mesh)
        return aggregation.cohort_gather(tree, safe)

    def scatter(self, tree, idx, updates):
        """Sentinel-drop cohort scatter (pads never write)."""
        if self.sharded:
            return mesh_lib.shard_scatter_rows(tree, idx, updates,
                                               self.mesh)
        return scatter_rows(tree, idx, updates)

    # ---- fused PS mixes

    def mix_scatter(self, full, cohort_updated, rows, idx, mask, *,
                    impl=None):
        """:func:`repro.core.aggregation.mix_scatter` in either layout."""
        if not self.sharded:
            return aggregation.mix_scatter(full, cohort_updated, rows,
                                           idx, mask, impl=impl)
        return self.mix_scatter_flat(full, stacked_ravel(cohort_updated),
                                     rows, idx, mask, impl=impl)

    def mix_scatter_flat(self, full, flat_c, rows, idx, mask, *,
                         impl=None, flat_sharded=False):
        """:func:`repro.core.aggregation.mix_scatter_flat` in either
        layout. Sharded: the (c, c) × (c, d) mix is computed redundantly
        per device (c ≪ m — cheaper than a collective) and each device's
        fused kernel rewrites only the rows its block owns (localized
        indices; non-owned slots become per-block sentinels).
        ``flat_sharded=True`` marks ``flat_c`` as itself row-sharded
        (the async buffer's pending ``upd`` rows): it is all-gathered
        INSIDE the same shard_map — the flush's one model-sized
        collective."""
        if not self.sharded:
            return aggregation.mix_scatter_flat(full, flat_c, rows, idx,
                                                mask, impl=impl)
        update = mesh_lib.shard_block_update(
            lambda block, loc, lm, fc, w: aggregation.mix_scatter_flat(
                block, fc, w, loc, lm, impl=impl),
            self.mesh, gather_args=1 if flat_sharded else 0)
        return update(full, idx, mask, flat_c, rows)

    def fedavg_mix(self, params, updated, idx, mask, n, *, impl=None):
        """:func:`fedavg_masked_mix` in either layout (the (1, c) mix is
        replicated; sharded states broadcast it block-wise)."""
        if not self.sharded:
            return fedavg_masked_mix(params, updated, idx, mask, n,
                                     impl=impl)
        safe = aggregation.safe_gather_index(idx, n.shape[0])
        w = aggregation.masked_fedavg_weights(jnp.take(n, safe), mask)
        mixed = aggregation.user_centric(updated, w, impl=impl)
        return mesh_lib.shard_broadcast_rows(params, mixed,
                                             jnp.any(mask), self.mesh)

    def constrain(self, tree):
        """Pin a traced (m, ·) output to the state's layout (no-op when
        replicated) — used where plain jnp ops produce the new state."""
        if self.sharded:
            return mesh_lib.constrain_rows(tree, self.mesh)
        return tree

    # ---- host-side commits (outside jit)

    def commit_state(self, state, shard_keys=("params",)):
        """Commit a strategy-state dict to its steady-state shardings.

        ``shard_keys`` names the row-sharded (m, ·) entries; everything
        else (W, cohort bookkeeping, refresh buffers, host leaves) is
        replicate-committed. No-op without a mesh, copy-free once
        committed — the dispatcher calls this every round to keep the
        one-compilation guarantee (see :func:`mesh.commit_replicated`).
        """
        if self.mesh is None:
            return state
        if not self.sharded:
            return mesh_lib.commit_replicated(state, self.mesh)
        out = dict(state)
        for k, v in state.items():
            if k in shard_keys:
                out[k] = mesh_lib.commit_rows(v, self.mesh)
            elif k == "abuf" and v is not None:
                out[k] = self.commit_buffer(v)
            else:
                out[k] = mesh_lib.commit_replicated(v, self.mesh)
        return out

    # ---- buffered-async buffer layout

    @property
    def buffer_shards(self) -> int:
        """Shard count the async buffer's B axis must divide by."""
        return mesh_lib.num_shards(self.mesh) if self.sharded else 1

    def buffer_scatter(self):
        """Deposit hook for :func:`repro.federated.async_buffer.deposit`:
        routes each upload row to its owner shard of the row-sharded
        (B, d) ``upd`` array. None (plain ``.at[].set``) when replicated.
        """
        if not self.sharded:
            return None
        mesh = self.mesh
        return lambda upd, dest, rows: mesh_lib.shard_scatter_rows(
            upd, dest, rows, mesh)

    def buffer_gather(self, upd):
        """Replicate the pending-upload rows for a flush — the ONLY
        model-sized collective of the sharded async engine."""
        if self.sharded:
            return mesh_lib.all_gather_rows(upd, self.mesh)
        return upd

    def commit_buffer(self, buf):
        """Commit an async buffer: ``upd`` row-sharded, metadata
        (idx/ver/count/version/last_sync) replicated."""
        if self.mesh is None:
            return buf
        if not self.sharded:
            return mesh_lib.commit_replicated(buf, self.mesh)
        meta = {k: v for k, v in buf.items() if k != "upd"}
        out = mesh_lib.commit_replicated(meta, self.mesh)
        out["upd"] = mesh_lib.commit_rows(buf["upd"], self.mesh)
        return out


# ------------------------------------------------------------------ engine

def cohort_round(dense_fn, masked_fn, *, masked_jit=None, mesh=None,
                 async_fn=None, async_cfg=None, sops=None,
                 shard_keys=("params",), upload_stage=None,
                 transport=None, topology=None):
    """Build ``round(state, data, key, cohort=None)`` from the two paths.

    Args:
      dense_fn: ``(state, data, key) -> (state, metrics)`` — the legacy
        full-participation path (must stay bit-exact with the pre-cohort
        engine).
      masked_fn: ``(state, data, key, idx, mask) -> (state, metrics)`` —
        the fixed-shape padded-cohort path; ``idx``/``mask`` are the
        device-side (c,) slot arrays.
      masked_jit: optional handle on the underlying jitted masked body,
        attached to the returned function as ``round.masked_jit`` so
        tests can assert the one-compilation guarantee via
        ``_cache_size()``.
      mesh: optional client-axis mesh knob (``FedConfig.mesh``; see
        :mod:`repro.federated.mesh`). Every cohort is padded to a slot
        count divisible by the shard count before dispatch, so the
        shard_mapped local-SGD stage inside ``masked_fn`` always sees an
        evenly partitionable slot axis; the extra sentinel slots are
        bit-invisible and the padded count is the same every round, so
        the one-compilation guarantee holds under a fixed mesh.
      async_fn: the strategy's buffered-async cohort body (same
        signature as ``masked_fn``), used in place of it when
        ``async_cfg`` is set — the one dispatch point is how ALL
        strategies share the ``FedConfig.async_buffer`` knob.
      async_cfg: the ``FedConfig.async_buffer`` value. Setting it
        without an ``async_fn`` raises ``NotImplementedError`` here, at
        construction time: the strategy's PS step has no buffered form
        (SCAFFOLD controls, Ditto/pFedMe personal models, FedFomo
        client-side mixing, ucfl_parallel's m× streams).
      sops: the strategy's :class:`StateOps` (built from
        ``FedConfig.mesh`` / ``FedConfig.shard_state``). When it is
        row-sharded, the state is committed per ``shard_keys`` (the
        names of the (m, ·) stacked entries) and ``cohort=None`` raises
        — the dense path trains every client and broadcasts the whole
        state, which is exactly the O(m·d) traffic shard_state removes.
      upload_stage: the strategy's fault/robust upload rewrite
        (:func:`repro.federated.faults.upload_stage`), passed here ONLY
        so the dispatcher can reject ``cohort=None``: faults and robust
        rules are masked-slot transforms with no dense counterpart, so
        the dense path raises at call time (the masked bodies already
        closed over the stage themselves).
      transport: the ``FedConfig.transport`` value, passed here ONLY so
        the dispatcher can reject ``cohort=None`` — quantization rewrites
        the masked upload stage, and the dense path has no upload.
      topology: the ``FedConfig.topology`` value, passed here ONLY so
        the dispatcher can reject ``cohort=None`` — the two-tier engine
        partitions the COHORT's upload slots over edges, and the dense
        path has no per-edge upload stage (the masked bodies already
        closed over the tiered mix themselves).

    The returned ``round`` accepts ``cohort=None`` (dense), a
    :class:`~repro.federated.participation.Cohort`, or a plain index
    array (normalized to an unpadded all-real cohort).
    """
    if async_cfg is not None and async_fn is None:
        raise NotImplementedError(
            "FedConfig.async_buffer is set but this strategy has no "
            "buffered-async aggregation rule (supported: ucfl "
            "full/clustered and the FedAvg family — strategies whose PS "
            "step is the masked row aggregation)")
    use_async = async_cfg is not None
    mesh = mesh_lib.resolve(mesh)
    sharded = sops is not None and sops.sharded

    def round(state, data, key, cohort=None):
        if sops is not None and sops.mesh is not None:
            # commit the state so round 1 already enters with the
            # steady-state input shardings (replicated, or row-sharded
            # per shard_keys) — otherwise jit would compile a second,
            # post-warm-up entry when round 2 first sees a committed
            # state. No-op after the first round.
            state = sops.commit_state(state, shard_keys)
        elif mesh is not None:
            state = mesh_lib.commit_replicated(state, mesh)
        cohort = participation.as_cohort(cohort, data.num_clients)
        if cohort is None:
            if sharded:
                raise ValueError(
                    "FedConfig.shard_state requires cohort rounds: "
                    "cohort=None is the dense full-participation path, "
                    "whose broadcast is the O(m·d) traffic row-sharding "
                    "removes — pass a participation config (or drop "
                    "shard_state)")
            if use_async:
                raise ValueError(
                    "the buffered-async engine processes arrival cohorts; "
                    "cohort=None is the bulk-synchronous dense path — pass "
                    "a participation config (or drop FedConfig.async_buffer)")
            if upload_stage is not None:
                raise ValueError(
                    "FedConfig.faults/robust require cohort rounds: the "
                    "injection and robust rewrites are fixed-shape masked "
                    "slot transforms with no dense counterpart — pass a "
                    "participation config (or drop faults/robust)")
            if transport is not None:
                raise ValueError(
                    "FedConfig.transport requires cohort rounds: "
                    "quantization compresses the masked upload stage, and "
                    "the dense full-participation path has no upload — "
                    "pass a participation config (or drop transport)")
            if topology is not None:
                raise ValueError(
                    "FedConfig.topology requires cohort rounds: the "
                    "two-tier engine partitions the cohort's upload slots "
                    "over edge aggregators, and the dense "
                    "full-participation path has no per-edge upload stage "
                    "— pass a participation config (or drop topology)")
            state, metrics = dense_fn(state, data, key)
            size = data.num_clients
        else:
            if mesh is not None:
                cohort = mesh_lib.pad_cohort(cohort, mesh, data.num_clients)
            # idx/mask stay host numpy here (jit converts at dispatch), so
            # wrappers can derive host-side metrics without a device sync
            fn = async_fn if use_async else masked_fn
            state, metrics = fn(state, data, key, cohort.indices,
                                cohort.mask)
            size = len(cohort)
        return state, {**metrics, "cohort_size": size}

    round.masked_jit = masked_jit
    return round


def cohort_keys(key, m, safe_idx):
    """Client-indexed per-slot PRNG keys for the masked cohort round.

    Splits the round key by the STATIC client count m and gathers the
    rows at the cohort's (clamped) indices, so a slot's key depends only
    on its client id — not on the slot count or cohort composition. This
    makes padded cohorts reproduce unpadded ones bit-for-bit, and a full
    cohort reproduce the dense path's ``split(key, m)`` exactly.
    """
    return jnp.take(jax.random.split(key, m), safe_idx, axis=0)


def make_masked_round(train, mix, *, donate=True, sops=None,
                      upload_stage=None, layout=None, transport=None,
                      schema=None):
    """Jit the standard masked round body with a donated params buffer.

    With ``layout`` (a :class:`repro.core.flat.LayoutTable` — the slab
    engine, used by every strategy):

    train(pc_tree, xc, yc, keys, *args) -> cohort-stacked updated tree
      (the body unravels the gathered (c, d_al) slab rows for it and
      ravels its result back — the ONLY tree boundary in the round)
    mix(params_slab, post_flat, idx, mask, *args) -> new (m, d_al) slab

    Without ``layout`` the legacy tree contract holds (``mix`` receives
    the cohort-stacked updated TREE) — kept for direct callers/tests.

    ``*args`` is an arbitrary tuple of device arrays (W, labels, n, ...)
    threaded to both closures. ``donate=True`` passes
    ``donate_argnums=(0,)`` so the stacked state is consumed in place.

    ``transport`` (``FedConfig.transport``; requires ``layout``) inserts
    the quantize→dequantize delta stage with error feedback between
    local SGD and the upload stage: the returned body then takes AND
    returns the (m, W_ul) ``ef`` accumulator slab as its second donated
    argument — ``body(params, ef, idx, mask, x, y, key, *args) ->
    (mix(...), ef')``. ``schema`` (the strategy's
    :class:`~repro.federated.transport.WireSchema`) keys the stage: the
    per-stream :func:`~repro.federated.transport.make_wire_stage` over
    the schema's concatenated uplink slab (W_ul = its aligned width;
    a single-delta schema is bit-identical to the legacy single-slab
    stage, which ``schema=None`` keeps for direct callers/tests).
    ``transport=None`` keeps the stage (and the extra argument) out of
    the trace entirely — bit-exact with the transport-free engine.
    Downlink compression is a MIX concern (the served payload is mix
    output): see :func:`fedavg_mix_closure` for the broadcast family's
    compressed-downlink mix; ``mix`` results are opaque to this body, so
    a downlink-compressing mix simply returns ``(new_state, ef_dl')``
    with the server-side EF threaded through ``*args``.

    ``upload_stage`` (:func:`repro.federated.faults.upload_stage`) is the
    fault-injection / finite-guard / robust rewrite applied between
    local SGD (and the transport stage — faults corrupt what the wire
    carried) and ``mix``: it sees the (c, d) pre/post upload slab plus
    the slot arrays and hands ``mix`` the rewritten upload and
    ``idx``/``mask`` (demoted slots carry the sentinel, so the fused
    scatter drops them). ``None`` (the default) keeps the exact
    pre-existing trace — bit-exact with the stage-free engine.

    Sharding: when the strategy's ``local`` was built with a mesh
    (``FedConfig.mesh``), ``train`` runs under shard_map with the cohort
    slots partitioned across devices and its per-slot results
    all-gathered (see :func:`repro.federated.client.client_vmap`), so
    ``mix`` — the tiny (c, c) rules and the fused scatter over the
    (m, d) state — always operates on replicated cohort arrays. The
    state itself is replicated unless ``sops`` is row-sharded
    (``FedConfig.shard_state``), in which case the round-start gather
    routes through the owner shards (``mix`` closures must use the same
    ``sops`` for their scatters; the ``ef`` slab rides the same layout).
    The dispatcher pads slot counts to a shard multiple
    (:func:`cohort_round`'s ``mesh`` arg).
    """
    gather = sops.gather if sops is not None else (
        lambda tree, safe: gather_rows(tree, safe))
    scatter = sops.scatter if sops is not None else scatter_rows
    if schema is not None:
        tstage = transport_lib.make_wire_stage(schema, transport, "uplink")
    else:
        tstage = transport_lib.make_stage(transport)
    if tstage is not None and layout is None:
        raise ValueError("transport requires the slab layout table")

    def core(params, ef, idx, mask, x, y, key, *args):
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        keys = cohort_keys(key, x.shape[0], safe)
        pc = gather(params, safe)
        if layout is not None:
            updated = train(layout.unravel(pc), x[safe], y[safe], keys,
                            *args)
            post = layout.ravel(updated)
            if tstage is not None:
                # the EF rows ride the cohort: gathered at the clamped
                # indices, scattered back at the ORIGINAL slots (clients
                # keep their residual even if a later stage demotes
                # their upload — the loss happened on the wire)
                post, efc = tstage(pc, post, gather(ef, safe))
                ef = scatter(ef, idx, efc)
            if upload_stage is not None:
                post, idx, mask = upload_stage(pc, post, idx, mask, key,
                                               x.shape[0])
            return mix(params, post, idx, mask, *args), ef
        updated = train(pc, x[safe], y[safe], keys, *args)
        if upload_stage is not None:
            flat, idx, mask = upload_stage(
                stacked_ravel(pc), stacked_ravel(updated), idx, mask,
                key, x.shape[0])
            updated = stacked_unravel(updated, flat)
        return mix(params, updated, idx, mask, *args), ef

    if tstage is None:
        def body(params, idx, mask, x, y, key, *args):
            out, _ = core(params, None, idx, mask, x, y, key, *args)
            return out

        return jax.jit(body, donate_argnums=(0,) if donate else ())

    def body_t(params, ef, idx, mask, x, y, key, *args):
        return core(params, ef, idx, mask, x, y, key, *args)

    return jax.jit(body_t, donate_argnums=(0, 1) if donate else ())


def fedavg_masked_mix(params, updated, idx, mask, n, *, impl=None):
    """Masked Eq. 1: n-weighted cohort mean, broadcast to every row of
    ``params``.

    ``n`` must be the full (m,) dataset sizes — the sentinel pad indices
    are clamped against it, NOT against ``params`` (pFedMe passes the
    cohort-stacked local copies as ``params`` to get a cohort-shaped
    broadcast).
    """
    rows = jax.tree.leaves(params)[0].shape[0]
    safe = aggregation.safe_gather_index(idx, n.shape[0])
    w = aggregation.masked_fedavg_weights(jnp.take(n, safe), mask)
    mixed = aggregation.user_centric(updated, w, impl=impl)
    # an all-masked cohort has zero weight mass: keep the previous model
    # instead of broadcasting the degenerate zero mix (the engine skips
    # such rounds, but direct callers get safe semantics too)
    alive = jnp.any(mask)
    return jax.tree.map(
        lambda x, p: jnp.where(alive,
                               jnp.broadcast_to(x, (rows,) + x.shape[1:]), p),
        mixed, params)


def tiered_fedavg_weights(edge_arr, num_edges, slots, idx, mask, n):
    """Two-tier FedAvg weights over a padded cohort.

    Tier 1 applies the existing masked rule PER EDGE: the cohort's slot
    arrays are partitioned into fixed-shape ``(E, s)`` per-edge cohorts
    (:func:`repro.federated.topology.edge_partition`) and
    ``masked_fedavg_weights`` vmaps over them — each edge normalizes its
    own members' ``n`` mass, an empty edge gets all-zero weights. Tier 2
    is the same rule over the per-edge masses. Returns

      wpe (E, c) — tier-1 weights mapped back to cohort columns, so
                   ``wpe @ upload_slab`` is the (E, d) edge-aggregate
                   slab that crosses the edge↔PS backhaul;
      w2  (E,)   — tier-2 inter-edge weights (mass-proportional).

    Because ``w2[e]·wpe[e, j] = n_j / Σn`` wherever edge e has mass, the
    composition reproduces the flat n-weighted mean EXACTLY up to float
    association — matched accuracy is by construction, the PS-side
    saving is that only E aggregates transit the backhaul.
    """
    c = idx.shape[0]
    eidx, emask, eslot = topology_lib.edge_partition(
        edge_arr, num_edges, slots, idx, mask)
    esafe = aggregation.safe_gather_index(eidx, n.shape[0])
    ne = (jnp.take(n, esafe) * emask).astype(jnp.float32)  # (E, s)
    w1 = jax.vmap(aggregation.masked_fedavg_weights)(ne, emask)[:, 0, :]
    wpe = (jnp.zeros((num_edges, c), jnp.float32)
           .at[jnp.arange(num_edges)[:, None], eslot]
           .set(w1 * emask, mode="drop"))
    mass = jnp.sum(ne, axis=1)  # (E,)
    w2 = aggregation.masked_fedavg_weights(mass, mass > 0)[0]
    return wpe, w2


def fedavg_mix_closure(*, sops=None, impl=None, dstage=None, topology=None):
    """Build the FedAvg-family mix (masked Eq. 1, broadcast back).

    ``dstage=None`` returns the plain broadcast mix
    (:func:`fedavg_masked_mix` / ``sops.fedavg_mix``) — the exact
    pre-schema trace. With ``dstage`` (the schema's downlink
    :func:`~repro.federated.transport.make_wire_stage`) the served
    global is delta-coded against the receivers' shared reference — the
    OLD global, row 0 of the broadcast-uniform stacked state — with the
    server-side (1, W_dl) EF accumulator threaded as a trailing mix arg:
    ``mix(params, updated, idx, mask, n, ef_dl) -> (new, ef_dl')``. An
    all-masked cohort keeps params AND ef_dl unchanged (no wire
    activity — skip-round semantics, like the plain mix).

    ``topology`` (a :class:`repro.federated.topology.Topology`) swaps
    the single global mean for the two-tier factorization
    (:func:`tiered_fedavg_weights`): tier-1 edge aggregates, tier-2
    mass-weighted combine, then the identical broadcast/EF tail — so
    the tiered mix composes with the compressed downlink unchanged.
    ``None`` keeps the flat mix bit-exact.
    """
    if topology is not None:
        return _tiered_fedavg_mix_closure(topology, sops=sops,
                                          dstage=dstage)
    if dstage is None:
        if sops is None:
            return functools.partial(fedavg_masked_mix, impl=impl)

        def plain_mix(params, updated, idx, mask, n):
            return sops.fedavg_mix(params, updated, idx, mask, n,
                                   impl=impl)

        return plain_mix

    gather = sops.gather if sops is not None else (
        lambda tree, safe: gather_rows(tree, safe))

    def mix(params, updated, idx, mask, n, ef_dl):
        rows = jax.tree.leaves(params)[0].shape[0]
        safe = aggregation.safe_gather_index(idx, n.shape[0])
        w = aggregation.masked_fedavg_weights(jnp.take(n, safe), mask)
        mixed = aggregation.user_centric(updated, w, impl=impl)  # (1, W)
        ref = gather(params, jnp.zeros((1,), jnp.int32))
        served, new_ef = dstage(ref, mixed, ef_dl)
        alive = jnp.any(mask)
        ef_dl = jnp.where(alive, new_ef, ef_dl)
        if sops is not None and sops.sharded:
            new = mesh_lib.shard_broadcast_rows(params, served, alive,
                                                sops.mesh)
        else:
            new = jnp.where(
                alive,
                jnp.broadcast_to(served, (rows,) + served.shape[1:]),
                params)
        return new, ef_dl

    return mix


def _tiered_fedavg_mix_closure(topology, *, sops=None, dstage=None):
    """The two-tier FedAvg mix (see :func:`fedavg_mix_closure`).

    Tier-1 edge aggregates materialize as one ``(E, d)`` matmul over the
    upload slab, tier-2 as a length-E weighted sum — both inside the
    same jitted round body, so the tiered path keeps the one-compilation
    guarantee and O(c·d + E·d) cost. Construction-time guards upstream
    ensure ``sops`` is never row-sharded here.
    """
    edge_arr = topology.edge_array()
    num_edges = topology.num_edges

    def tiered_global(updated, idx, mask, n):
        c = idx.shape[0]
        slots = topology.slots_per_edge(c)
        wpe, w2 = tiered_fedavg_weights(edge_arr, num_edges, slots,
                                        idx, mask, n)

        def leaf(u):
            agg = wpe @ u.reshape(c, -1)  # (E, d) edge-aggregate slab
            return (w2 @ agg).reshape((1,) + u.shape[1:])

        return jax.tree.map(leaf, updated)

    def broadcast(params, mixed, mask):
        rows = jax.tree.leaves(params)[0].shape[0]
        alive = jnp.any(mask)
        return jax.tree.map(
            lambda x, p: jnp.where(
                alive, jnp.broadcast_to(x, (rows,) + x.shape[1:]), p),
            mixed, params)

    if dstage is None:
        def tmix(params, updated, idx, mask, n):
            return broadcast(params, tiered_global(updated, idx, mask, n),
                             mask)

        return tmix

    gather = sops.gather if sops is not None else (
        lambda tree, safe: gather_rows(tree, safe))

    def tmix_dl(params, updated, idx, mask, n, ef_dl):
        mixed = tiered_global(updated, idx, mask, n)  # (1, W)
        ref = gather(params, jnp.zeros((1,), jnp.int32))
        served, new_ef = dstage(ref, mixed, ef_dl)
        alive = jnp.any(mask)
        ef_dl = jnp.where(alive, new_ef, ef_dl)
        return broadcast(params, served, mask), ef_dl

    return tmix_dl


def make_fedavg_masked_round(local, *, train=None, impl=None, donate=True,
                             sops=None, upload_stage=None, layout=None,
                             transport=None, schema=None, topology=None):
    """The FedAvg-family masked round (FedAvg/FedProx reuse it).

    ``fedavg_masked_mix`` is tree-generic, so the same mix serves the
    legacy tree contract and the slab engine (where ``updated`` is the
    (c, d_al) upload matrix) unchanged. ``train`` overrides the default
    plain-local-SGD train closure (FedProx passes its proximal-centered
    one); it must accept ``(pc, xc, yc, keys, n, *extra)`` — the extra
    args carry the downlink EF when the schema compresses the broadcast.
    ``topology`` routes the mix through the two-tier engine (see
    :func:`fedavg_mix_closure`); ``None`` keeps the flat mix bit-exact.
    """

    if train is None:
        def train(pc, xc, yc, keys, n, *_):
            updated, _ = local(pc, xc, yc, None, keys=keys)
            return updated

    dstage = (transport_lib.make_wire_stage(schema, transport, "downlink")
              if schema is not None else None)
    mix = fedavg_mix_closure(sops=sops, impl=impl, dstage=dstage,
                             topology=topology)
    return make_masked_round(train, mix, donate=donate, sops=sops,
                             upload_stage=upload_stage, layout=layout,
                             transport=transport, schema=schema)


# ------------------------------------------------------- buffered-async path

def state_async_buffer(state, acfg, m, slots, dim, sops=None, schema=None):
    """Fetch — or lazily create — the strategy state's upload buffer.

    The buffer's slot count depends on the participation policy's cohort
    slot count, which the strategy cannot know at ``init`` time, so the
    first cohort round creates it here (host-side, outside jit; the
    shapes are the same every round, so the one-compilation guarantee is
    unaffected — a warm-up that discards its state merely re-creates the
    same-shaped zeros on round 1).

    ``sops`` commits the fresh buffer to its steady-state layout —
    replicated over the mesh, or (``shard_state``) ``upd`` row-sharded
    with B padded to a shard multiple — exactly like the dispatcher
    commits the rest of the state: a buffer born uncommitted on round 1
    would re-enter round 2 with the round's output sharding and trigger
    a second compile.
    """
    buf = state.get("abuf")
    if buf is None:
        shards = sops.buffer_shards if sops is not None else 1
        buf = async_buffer.init_buffer(acfg, m, slots, dim, shards=shards,
                                       schema=schema)
        if sops is not None:
            buf = sops.commit_buffer(buf)
    return buf


def make_fedavg_async_round(train, acfg, *, impl=None, sops=None,
                            upload_stage=None, layout=None,
                            transport=None, schema=None):
    """The FedAvg-family buffered-async round (FedAvg/FedProx reuse it).

    FedBuff's server rule in delta form: the buffer holds the cohort's
    model DELTAS ``θ_upload − θ_base`` (each computed against the global
    model current at its upload round), and a flush adds the n-weighted
    mean delta to the current global — with an all-fresh buffer this
    reproduces the barrier :func:`fedavg_masked_mix` exactly
    (θ + Σ w̃(u − θ) = Σ w̃ u). Mixing raw stale MODELS instead would
    drag the global back toward old versions, which is why the delta
    form is load-bearing here.

    Honest staleness note: under the flush-the-whole-buffer rule the
    FedAvg-family τ is STRUCTURALLY ZERO — the server version only moves
    at a flush, a flush clears every pending slot, and a client samples
    the current global at deposit, so no upload can ever outlive a
    version bump. The ``(1+τ)^{-α}`` machinery is kept in the shared
    body (a partial-flush rule would make it live, and the user-centric
    rules — whose base is the client's own last-rewritten row — exercise
    it for real), but for this family the discount never engages and
    ``tau_max``/``tau_mean`` report 0.

    ``train(pc, xc, yc, keys, n) -> updated`` as in
    :func:`make_fedavg_masked_round` (``layout`` unravels/ravels around
    it on the slab engine). Returns a jitted
    ``body(params, abuf, idx, mask, x, y, key, n) ->
    (params', abuf', metrics)`` with ``params`` AND the buffer donated —
    or, with ``transport`` on, ``body(params, ef, abuf, ...) ->
    (params', ef', abuf', metrics)`` with all three donated: the delta
    is quantized (error-feedback carried in ``ef``) BEFORE it is
    deposited, so the pending buffer holds exactly what the wire
    carried. ``sops`` picks the state/buffer layout (row-sharded
    deposits route each upload to its owner shard; the flush all-gathers
    the (B, d) rows — the engine's only model-sized collective).
    """
    flush_k = int(acfg.flush_k)
    gather = sops.gather if sops is not None else (
        lambda tree, safe: gather_rows(tree, safe))
    scatter = sops.buffer_scatter() if sops is not None else None
    efscatter = sops.scatter if sops is not None else scatter_rows
    if schema is not None:
        tstage = transport_lib.make_wire_stage(schema, transport, "uplink")
    else:
        tstage = transport_lib.make_stage(transport)
    if tstage is not None and layout is None:
        raise ValueError("transport requires the slab layout table")

    def core(params, ef, abuf, idx, mask, x, y, key, n):
        m = x.shape[0]
        safe = aggregation.safe_gather_index(idx, m)
        keys = cohort_keys(key, m, safe)
        pc = gather(params, safe)
        if layout is not None:
            updated = train(layout.unravel(pc), x[safe], y[safe], keys, n)
            pre_flat, post_flat = pc, layout.ravel(updated)
        else:
            updated = train(pc, x[safe], y[safe], keys, n)
            pre_flat = stacked_ravel(pc)
            post_flat = stacked_ravel(updated)
        if tstage is not None:
            post_flat, efc = tstage(pre_flat, post_flat, gather(ef, safe))
            ef = efscatter(ef, idx, efc)
        if upload_stage is not None:
            # faults/guard/robust rewrite the upload BEFORE it is
            # deposited: demoted slots carry the sentinel, so their junk
            # delta rows never enter the pending buffer
            post_flat, idx, mask = upload_stage(pre_flat, post_flat, idx,
                                                mask, key, m)
        delta = post_flat - pre_flat
        # FedAvg clients download the CURRENT global when sampled, so the
        # upload's base version is the version at deposit time
        base_ver = jnp.broadcast_to(abuf["version"], idx.shape)
        abuf = async_buffer.deposit(abuf, delta, idx, mask, base_ver, m,
                                    scatter=scatter)
        flush = abuf["count"] >= flush_k
        weights = async_buffer.staleness_weights(abuf, m, acfg.alpha)
        tau = async_buffer.staleness(abuf)
        applied = abuf["count"]
        bvalid = async_buffer.valid_mask(abuf, m)
        bsafe = aggregation.safe_gather_index(abuf["idx"], m)

        def do_flush(params, abuf):
            w = aggregation.masked_fedavg_weights(jnp.take(n, bsafe),
                                                  bvalid, weights)
            upd = (sops.buffer_gather(abuf["upd"]) if sops is not None
                   else abuf["upd"])
            # (1, d_al); stacked_unravel ignores the aligned-width tail
            step = ops.mix_aggregate(w, upd, impl=impl)
            new = jax.tree.map(jnp.add, params,
                               stacked_unravel(params, step))
            return new, async_buffer.flush_reset(abuf, m)

        params, abuf = jax.lax.cond(flush, do_flush,
                                    lambda p, b: (p, b), params, abuf)
        if sops is not None:
            # the flush's broadcast add is plain jnp — pin the output to
            # the committed layout so round 2 doesn't recompile
            params = sops.constrain(params)
        metrics = async_buffer.flush_metrics(flush, applied, tau, weights,
                                             abuf["count"])
        # one broadcast stream hits the downlink only when a flush ships
        # a new global
        metrics["streams"] = flush.astype(jnp.int32)
        return params, ef, abuf, metrics

    if tstage is None:
        def body(params, abuf, idx, mask, x, y, key, n):
            params, _, abuf, metrics = core(params, None, abuf, idx, mask,
                                            x, y, key, n)
            return params, abuf, metrics

        return jax.jit(body, donate_argnums=(0, 1))

    def body_t(params, ef, abuf, idx, mask, x, y, key, n):
        return core(params, ef, abuf, idx, mask, x, y, key, n)

    return jax.jit(body_t, donate_argnums=(0, 1, 2))


def fedavg_async_wrapper(train, params0, acfg, *, impl=None, sops=None,
                         upload_stage=None, layout=None, transport=None,
                         schema=None):
    """Build the FedAvg-family buffered-async cohort body + jit handle.

    Returns ``(amasked, jitted_body)`` for ``cohort_round(async_fn=...,
    masked_jit=...)``, or ``(None, None)`` when the knob is off.
    ``train`` as in :func:`make_fedavg_async_round`; the body manages the
    lazily-created buffer in ``state["abuf"]`` (and, with ``transport``
    on, the error-feedback slab in ``state["ef"]``), committed to the
    layout ``sops`` (the strategy's :class:`StateOps`) picks. ``schema``
    sizes the buffer rows at the uplink wire-slab width and keys the
    per-stream transport stage (the async downlink stays raw f32 — see
    the transport capability matrix).
    """
    if acfg is None:
        return None, None
    body = make_fedavg_async_round(train, acfg, impl=impl, sops=sops,
                                   upload_stage=upload_stage,
                                   layout=layout, transport=transport,
                                   schema=schema)
    dim = tree_count_params(params0)

    def amasked(state, data, key, idx, mask):
        abuf = state_async_buffer(state, acfg, data.num_clients,
                                  idx.shape[0], dim, sops, schema)
        if transport is None:
            new, abuf, metrics = body(state["params"], abuf, idx, mask,
                                      data.x, data.y, key, data.n)
            return dict(state, params=new, abuf=abuf), metrics
        new, ef, abuf, metrics = body(state["params"], state["ef"], abuf,
                                      idx, mask, data.x, data.y, key,
                                      data.n)
        return dict(state, params=new, ef=ef, abuf=abuf), metrics

    return amasked, body
