"""Helpers shared by the baseline strategies."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.core.pytree import gather_rows, scatter_rows  # noqa: F401  (re-export)


def broadcast_params(params0, m):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (m,) + x.shape) + 0.0, params0
    )


def group_mixing_matrix(assignment, n):
    """Row-stochastic W implementing per-group FedAvg (CFL/Oracle).

    W[i, j] = n_j · 1[a_i == a_j] / Σ_{a_k == a_i} n_k.
    """
    same = (assignment[:, None] == assignment[None, :]).astype(jnp.float32)
    w = same * n.astype(jnp.float32)[None, :]
    return w / jnp.sum(w, axis=1, keepdims=True)


def group_average(stacked, assignment, n, *, impl=None):
    w = group_mixing_matrix(assignment, n)
    return aggregation.user_centric(stacked, w, impl=impl)
