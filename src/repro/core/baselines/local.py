"""Local training — no collaboration (reference lower/upper bound)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import flat
from repro.core.baselines import common
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib


@register("local")
def make_local(apply_fn, params0, cfg: FedConfig = FedConfig()):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )

    layout = flat.LayoutTable.build(params0)
    # no downlink: each participant keeps its own update on the server
    schema = transport_lib.single_delta_schema("local", layout.dim)

    def init(key, data):
        state = {"params": layout.slab(params0, data.num_clients)}
        if cfg.transport is not None:
            state["ef"] = jnp.zeros_like(state["params"])
        return state

    @jax.jit
    def _round(params, x, y, key):
        updated, _ = local(layout.unravel(params), x, y, key)
        return layout.ravel(updated)

    def _train(pc, xc, yc, keys):
        updated, _ = local(pc, xc, yc, None, keys=keys)
        return updated

    topology_lib.unsupported(
        cfg.topology, "local",
        "no collaboration — each participant's upload scatters back to "
        "its own row, so there is no aggregate for an edge tier to form")
    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)
    # no mixing: each participant keeps its own update (pad slots are
    # dropped by the sentinel-index scatter)
    _masked = common.make_masked_round(
        _train, lambda params, updated, idx, mask: sops.scatter(
            params, idx, updated), sops=sops, upload_stage=ustage,
        layout=layout, transport=cfg.transport, schema=schema)

    def dense(state, data, key):
        return {"params": _round(state["params"], data.x, data.y, key)}, \
            {"streams": 0}

    def masked(state, data, key, idx, mask):
        if cfg.transport is None:
            new = _masked(state["params"], idx, mask, data.x, data.y, key)
            return dict(state, params=new), {"streams": 0}
        new, ef = _masked(state["params"], state["ef"], idx, mask, data.x,
                          data.y, key)
        return dict(state, params=new, ef=ef), {"streams": 0}

    shard_keys = (("params", "ef") if cfg.transport is not None
                  else ("params",))
    return Strategy("local", init,
                    common.cohort_round(dense, masked, masked_jit=_masked,
                                        mesh=cfg.mesh,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops, shard_keys=shard_keys,
                                        upload_stage=ustage,
                                        transport=cfg.transport),
                    lambda s: layout.unravel(s["params"]),
                    comm_scheme="broadcast", num_streams=0,
                    injects_faults=cfg.faults is not None,
                    wire_schema=schema)
