"""Local training — no collaboration (reference lower/upper bound)."""
from __future__ import annotations

import jax

from repro.core.baselines.common import broadcast_params
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient


@register("local")
def make_local(apply_fn, params0, cfg: FedConfig = FedConfig()):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size,
    )

    def init(key, data):
        return {"params": broadcast_params(params0, data.num_clients)}

    @jax.jit
    def _round(params, x, y, key):
        updated, _ = local(params, x, y, key)
        return updated

    def round(state, data, key):
        return ({"params": _round(state["params"], data.x, data.y, key)},
                {"streams": 0})

    return Strategy("local", init, round, lambda s: s["params"],
                    comm_scheme="broadcast", num_streams=0)
