"""Local training — no collaboration (reference lower/upper bound)."""
from __future__ import annotations

import jax

from repro.core.baselines.common import (broadcast_params, gather_rows,
                                         scatter_rows)
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient


@register("local")
def make_local(apply_fn, params0, cfg: FedConfig = FedConfig()):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size,
    )

    def init(key, data):
        return {"params": broadcast_params(params0, data.num_clients)}

    @jax.jit
    def _round(params, x, y, key):
        updated, _ = local(params, x, y, key)
        return updated

    @jax.jit
    def _round_cohort(params, cohort, x, y, key):
        updated, _ = local(gather_rows(params, cohort), x[cohort], y[cohort],
                           key)
        return scatter_rows(params, cohort, updated)

    def round(state, data, key, cohort=None):
        if cohort is None:
            new = _round(state["params"], data.x, data.y, key)
        else:
            new = _round_cohort(state["params"], jax.numpy.asarray(cohort),
                                data.x, data.y, key)
        return {"params": new}, {"streams": 0}

    return Strategy("local", init, round, lambda s: s["params"],
                    comm_scheme="broadcast", num_streams=0)
