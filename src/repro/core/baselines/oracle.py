"""Oracle — per-ground-truth-cluster FedAvg (the paper's upper bound)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.baselines.common import (broadcast_params, gather_rows,
                                         group_average, scatter_rows)
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient


@register("oracle")
def make_oracle(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                kernel_impl=None):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size,
    )

    def init(key, data):
        return {"params": broadcast_params(params0, data.num_clients)}

    @jax.jit
    def _round(params, group, n, x, y, key):
        updated, _ = local(params, x, y, key)
        return group_average(updated, group, n, impl=kernel_impl)

    @jax.jit
    def _round_cohort(params, cohort, group, n, x, y, key):
        # per-group FedAvg over the cohort members of each ground-truth
        # group; absent clients keep their last model.
        updated, _ = local(gather_rows(params, cohort), x[cohort], y[cohort],
                           key)
        mixed = group_average(updated, group[cohort], n[cohort],
                              impl=kernel_impl)
        return scatter_rows(params, cohort, mixed)

    def round(state, data, key, cohort=None):
        if cohort is None:
            new = _round(state["params"], data.group, data.n, data.x, data.y,
                         key)
            num_groups = int(jax.numpy.max(data.group)) + 1
        else:
            cohort = jax.numpy.asarray(cohort)
            new = _round_cohort(state["params"], cohort, data.group, data.n,
                                data.x, data.y, key)
            num_groups = int(
                np.unique(np.asarray(data.group)[np.asarray(cohort)]).size)
        return {"params": new}, {"streams": num_groups}

    return Strategy("oracle", init, round, lambda s: s["params"],
                    comm_scheme="groupcast")
