"""Oracle — per-ground-truth-cluster FedAvg (the paper's upper bound)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation, flat
from repro.core.baselines import common
from repro.core.baselines.common import group_average
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib


@register("oracle")
def make_oracle(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                kernel_impl=None):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )

    layout = flat.LayoutTable.build(params0)
    # groupcast downlink stays raw: group means are weight-scale values
    # with no per-receiver reference to delta-code against
    schema = transport_lib.single_delta_schema(
        "oracle", layout.dim,
        downlink=(transport_lib.Stream("group_models", layout.dim,
                                       coding="raw"),))

    def init(key, data):
        num_groups = int(jnp.max(data.group)) + 1
        # group one-hots let the cohort round count the represented groups
        # (downlink streams) on device — no per-round np.unique host sync
        state = {"params": layout.slab(params0, data.num_clients),
                 "group_onehot": jax.nn.one_hot(data.group, num_groups,
                                                dtype=jnp.float32),
                 "num_groups": num_groups}
        if cfg.transport is not None:
            state["ef"] = jnp.zeros_like(state["params"])
        return state

    @jax.jit
    def _round(params, group, n, x, y, key):
        updated, _ = local(layout.unravel(params), x, y, key)
        return layout.ravel(group_average(updated, group, n,
                                          impl=kernel_impl))

    def _train(pc, xc, yc, keys, group, n, onehot):
        updated, _ = local(pc, xc, yc, None, keys=keys)
        return updated

    topology_lib.unsupported(
        cfg.topology, "oracle",
        "per-group FedAvg factorizes over groups, but ground-truth "
        "group membership crosscuts the static edge assignment — a "
        "(group × edge) partial-sum layout is future work")
    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)

    def _mix(params, updated, idx, mask, group, n, onehot):
        # per-group FedAvg over the cohort members of each ground-truth
        # group; absent clients keep their last model. ``updated`` is the
        # (c, d_al) upload slab — straight into the fused flat mix.
        safe = aggregation.safe_gather_index(idx, onehot.shape[0])
        rows = aggregation.masked_group_rows(jnp.take(group, safe),
                                             jnp.take(n, safe), mask)
        new = sops.mix_scatter_flat(params, updated, rows, idx, mask,
                                    impl=kernel_impl)
        oc = jnp.take(onehot, safe, axis=0) * mask[:, None]
        return new, jnp.sum(jnp.max(oc, axis=0) > 0)

    _masked = common.make_masked_round(_train, _mix, sops=sops,
                                       upload_stage=ustage, layout=layout,
                                       transport=cfg.transport,
                                       schema=schema)

    def dense(state, data, key):
        new = _round(state["params"], data.group, data.n, data.x, data.y,
                     key)
        return dict(state, params=new), {"streams": state["num_groups"]}

    def masked(state, data, key, idx, mask):
        if cfg.transport is None:
            new, streams = _masked(state["params"], idx, mask, data.x,
                                   data.y, key, data.group, data.n,
                                   state["group_onehot"])
            return dict(state, params=new), {"streams": streams}
        (new, streams), ef = _masked(state["params"], state["ef"], idx,
                                     mask, data.x, data.y, key,
                                     data.group, data.n,
                                     state["group_onehot"])
        return dict(state, params=new, ef=ef), {"streams": streams}

    shard_keys = (("params", "ef") if cfg.transport is not None
                  else ("params",))
    return Strategy("oracle", init,
                    common.cohort_round(dense, masked, masked_jit=_masked,
                                        mesh=cfg.mesh,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops, shard_keys=shard_keys,
                                        upload_stage=ustage,
                                        transport=cfg.transport),
                    lambda s: layout.unravel(s["params"]),
                    comm_scheme="groupcast",
                    injects_faults=cfg.faults is not None,
                    wire_schema=schema)
