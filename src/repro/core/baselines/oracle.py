"""Oracle — per-ground-truth-cluster FedAvg (the paper's upper bound)."""
from __future__ import annotations

import jax

from repro.core.baselines.common import broadcast_params, group_average
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient


@register("oracle")
def make_oracle(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                kernel_impl=None):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size,
    )

    def init(key, data):
        return {"params": broadcast_params(params0, data.num_clients)}

    @jax.jit
    def _round(params, group, n, x, y, key):
        updated, _ = local(params, x, y, key)
        return group_average(updated, group, n, impl=kernel_impl)

    def round(state, data, key):
        new = _round(state["params"], data.group, data.n, data.x, data.y, key)
        num_groups = int(jax.numpy.max(data.group)) + 1
        return {"params": new}, {"streams": num_groups}

    return Strategy("oracle", init, round, lambda s: s["params"],
                    comm_scheme="groupcast")
