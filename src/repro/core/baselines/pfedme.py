"""pFedMe (Dinh et al., 2020) — Moreau-envelope personalization.

Per batch, the client approximately solves the proximal inner problem
  φ ≈ argmin_φ f̃_i(φ; batch) + (λ/2)||φ − w_i||²
with S gradient steps, then moves its local copy w_i ← w_i − η·λ·(w_i − φ).
The server averages the w_i. Evaluation uses the personalized φ_i.
Paper footnote 2: η_global = η_local = 0.01, S = 15, E = 1, batch 20.
"""
from __future__ import annotations

import jax

from repro.core import aggregation
from repro.core.baselines.common import (broadcast_params, gather_rows,
                                         scatter_rows)
from repro.core.strategy import FedConfig, Strategy, register
from repro.data.loader import epoch_batches
from repro.federated.client import client_vmap, make_loss


@register("pfedme")
def make_pfedme(apply_fn, params0,
                cfg: FedConfig = FedConfig(lr=0.01, momentum=0.0, epochs=1,
                                           batch_size=20), *,
                lam: float = 15.0, inner_steps: int = 15,
                inner_lr: float = 0.01, beta: float = 1.0, kernel_impl=None):
    loss = make_loss(apply_fn)
    grad_fn = jax.grad(loss)

    def client_update(w, x, y, key):
        def one_epoch(carry, ekey):
            w, phi = carry
            xb, yb = epoch_batches(ekey, x, y, cfg.batch_size)

            def step(c, batch):
                w, _ = c
                bx, by = batch

                def inner(_, phi):
                    g = grad_fn(phi, bx, by)
                    return jax.tree.map(
                        lambda p, gg, ww: p - inner_lr * (gg + lam * (p - ww)),
                        phi, g, w,
                    )

                phi = jax.lax.fori_loop(0, inner_steps, inner, w)
                w = jax.tree.map(lambda ww, p: ww - cfg.lr * lam * (ww - p),
                                 w, phi)
                return (w, phi), None

            (w, phi), _ = jax.lax.scan(step, (w, w), (xb, yb))
            return (w, phi), None

        (w, phi), _ = jax.lax.scan(one_epoch, (w, w),
                                   jax.random.split(key, cfg.epochs))
        return w, phi

    run_clients = client_vmap(client_update, chunk_size=cfg.chunk_size)

    def init(key, data):
        m = data.num_clients
        return {
            "params": broadcast_params(params0, m),  # local copies w_i
            "personal": broadcast_params(params0, m),  # φ_i
        }

    @jax.jit
    def _round(w, n, x, y, key):
        m = x.shape[0]
        keys = jax.random.split(key, m)
        new_w, phi = run_clients(w, x, y, keys)
        avg = aggregation.fedavg(new_w, n, impl=kernel_impl)
        mixed = jax.tree.map(lambda a, b: (1 - beta) * a + beta * b, new_w, avg)
        return mixed, phi

    @jax.jit
    def _round_cohort(w, personal, cohort, n, x, y, key):
        # cohort-only Moreau steps; the β-mix pulls participants toward a
        # cohort average, absent clients keep their last w_i / φ_i.
        c = cohort.shape[0]
        keys = jax.random.split(key, c)
        wc = gather_rows(w, cohort)
        new_wc, phic = run_clients(wc, x[cohort], y[cohort], keys)
        avg = aggregation.fedavg(new_wc, n[cohort], impl=kernel_impl)
        mixed = jax.tree.map(lambda a, b: (1 - beta) * a + beta * b, new_wc,
                             avg)
        return (scatter_rows(w, cohort, mixed),
                scatter_rows(personal, cohort, phic))

    def round(state, data, key, cohort=None):
        if cohort is None:
            w, phi = _round(state["params"], data.n, data.x, data.y, key)
        else:
            w, phi = _round_cohort(state["params"], state["personal"],
                                   jax.numpy.asarray(cohort), data.n, data.x,
                                   data.y, key)
        return {"params": w, "personal": phi}, {"streams": 1}

    return Strategy("pfedme", init, round, lambda s: s["personal"],
                    comm_scheme="broadcast", num_streams=1)
