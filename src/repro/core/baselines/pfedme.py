"""pFedMe (Dinh et al., 2020) — Moreau-envelope personalization.

Per batch, the client approximately solves the proximal inner problem
  φ ≈ argmin_φ f̃_i(φ; batch) + (λ/2)||φ − w_i||²
with S gradient steps, then moves its local copy w_i ← w_i − η·λ·(w_i − φ).
The server averages the w_i. Evaluation uses the personalized φ_i.
Paper footnote 2: η_global = η_local = 0.01, S = 15, E = 1, batch 20.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aggregation, flat
from repro.core.baselines import common
from repro.core.strategy import FedConfig, Strategy, register
from repro.data.loader import epoch_batches
from repro.federated import faults as faults_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib
from repro.federated.client import client_vmap, make_loss


@register("pfedme")
def make_pfedme(apply_fn, params0,
                cfg: FedConfig = FedConfig(lr=0.01, momentum=0.0, epochs=1,
                                           batch_size=20), *,
                lam: float = 15.0, inner_steps: int = 15,
                inner_lr: float = 0.01, beta: float = 1.0, kernel_impl=None):
    loss = make_loss(apply_fn)
    grad_fn = jax.grad(loss)

    def client_update(w, x, y, key):
        def one_epoch(carry, ekey):
            w, phi = carry
            xb, yb = epoch_batches(ekey, x, y, cfg.batch_size)

            def step(c, batch):
                w, _ = c
                bx, by = batch

                def inner(_, phi):
                    g = grad_fn(phi, bx, by)
                    return jax.tree.map(
                        lambda p, gg, ww: p - inner_lr * (gg + lam * (p - ww)),
                        phi, g, w,
                    )

                phi = jax.lax.fori_loop(0, inner_steps, inner, w)
                w = jax.tree.map(lambda ww, p: ww - cfg.lr * lam * (ww - p),
                                 w, phi)
                return (w, phi), None

            (w, phi), _ = jax.lax.scan(step, (w, w), (xb, yb))
            return (w, phi), None

        (w, phi), _ = jax.lax.scan(one_epoch, (w, w),
                                   jax.random.split(key, cfg.epochs))
        return w, phi

    run_clients = client_vmap(client_update, chunk_size=cfg.chunk_size,
                              mesh=cfg.mesh)

    layout = flat.LayoutTable.build(params0)
    # uplink: the w_i delta, quantized with client-side EF; the (1-β)
    # retention term is client-side physical state and keeps the RAW w_i
    # (no second EF stream needed). Downlink: the β-mix average is a
    # weight-scale value with no shared receiver reference — raw.
    schema = transport_lib.single_delta_schema(
        "pfedme", layout.dim,
        downlink=(transport_lib.Stream("average", layout.dim,
                                       coding="raw"),))

    def init(key, data):
        m = data.num_clients
        state = {
            "params": layout.slab(params0, m),  # local copies w_i
            "personal": layout.slab(params0, m),  # φ_i
        }
        if cfg.transport is not None:
            state["ef"] = jnp.zeros(
                (m, schema.width_aligned("uplink")), jnp.float32)
        return state

    @jax.jit
    def _round(w, n, x, y, key):
        m = x.shape[0]
        keys = jax.random.split(key, m)
        new_w, phi = run_clients(layout.unravel(w), x, y, keys)
        avg = aggregation.fedavg(new_w, n, impl=kernel_impl)
        mixed = jax.tree.map(lambda a, b: (1 - beta) * a + beta * b, new_w, avg)
        return layout.ravel(mixed), layout.ravel(phi)

    topology_lib.unsupported(
        cfg.topology, "pfedme",
        "the β-mix blends each participant's RAW w_i with the cohort "
        "average CLIENT-side — the served value is per-client, not a "
        "broadcast aggregate an edge tier could relay")
    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)
    tstage = transport_lib.make_wire_stage(schema, cfg.transport, "uplink")

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
    def _masked(w, personal, ef, idx, mask, n, x, y, key):
        # masked cohort-only Moreau steps; the β-mix pulls participants
        # toward the zero-weight-padded cohort average, absent clients and
        # pad slots keep their last w_i / φ_i. The FedAvg broadcast here
        # is COHORT-shaped (wc is the gathered, replicated cohort), so it
        # stays the plain masked mix in either state layout.
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        keys = common.cohort_keys(key, x.shape[0], safe)
        wc = sops.gather(w, safe)
        new_wc_t, phic_t = run_clients(layout.unravel(wc), x[safe],
                                       y[safe], keys)
        raw_wc = layout.ravel(new_wc_t)
        phic = layout.ravel(phic_t)
        # the server's avg consumes the DEQUANTIZED wire upload; the
        # (1-β) retention keeps the client's raw w_i (client-side state
        # the wire never touched)
        if tstage is not None:
            wire, efc = tstage(wc, raw_wc, sops.gather(ef, safe))
            ef = sops.scatter(ef, idx, efc)
        else:
            wire = raw_wc
        # the fault/robust stage rewrites the w_i UPLOAD; φ_i is
        # client-side and keeps the original slots (like Ditto's
        # personal models). Demoted w slots drop out of the scatter.
        widx, wmask = idx, mask
        if ustage is not None:
            wire, widx, wmask = ustage(wc, wire, idx, mask, key,
                                       x.shape[0])
            if tstage is None:
                raw_wc = wire  # pre-schema faults-only trace, bit-exact
        avg = common.fedavg_masked_mix(wc, wire, widx, wmask, n,
                                       impl=kernel_impl)
        mixed = (1 - beta) * raw_wc + beta * avg
        return (sops.scatter(w, widx, mixed),
                sops.scatter(personal, idx, phic), ef)

    def dense(state, data, key):
        w, phi = _round(state["params"], data.n, data.x, data.y, key)
        return {"params": w, "personal": phi}, {"streams": 1}

    def masked(state, data, key, idx, mask):
        w, phi, ef = _masked(state["params"], state["personal"],
                             state.get("ef"), idx, mask, data.n, data.x,
                             data.y, key)
        out = {"params": w, "personal": phi}
        if ef is not None:
            out["ef"] = ef
        return out, {"streams": 1}

    shard_keys = ("params", "personal")
    if cfg.transport is not None:
        shard_keys += ("ef",)
    return Strategy("pfedme", init,
                    common.cohort_round(dense, masked, masked_jit=_masked,
                                        mesh=cfg.mesh,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops,
                                        shard_keys=shard_keys,
                                        upload_stage=ustage,
                                        transport=cfg.transport),
                    lambda s: layout.unravel(s["personal"]),
                    comm_scheme="broadcast",
                    num_streams=1,
                    injects_faults=cfg.faults is not None,
                    wire_schema=schema)
