"""FedAvg (McMahan et al., 2017) — Eq. 1.

Partial participation: the cohort trains from the current global model and
the new global (an n-weighted mean of the cohort's uploads) is broadcast
back to every row of the stacked state — one downlink stream either way.
"""
from __future__ import annotations

import jax

from repro.core import aggregation
from repro.core.baselines.common import broadcast_params, gather_rows
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient


@register("fedavg")
def make_fedavg(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                kernel_impl=None):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size,
    )

    def init(key, data):
        return {"params": broadcast_params(params0, data.num_clients)}

    @jax.jit
    def _round(params, n, x, y, key):
        updated, _ = local(params, x, y, key)
        return aggregation.fedavg(updated, n, impl=kernel_impl)

    @jax.jit
    def _round_cohort(params, cohort, n, x, y, key):
        updated, _ = local(gather_rows(params, cohort), x[cohort], y[cohort],
                           key)
        return aggregation.fedavg_cohort(updated, n[cohort], x.shape[0],
                                         impl=kernel_impl)

    def round(state, data, key, cohort=None):
        if cohort is None:
            new = _round(state["params"], data.n, data.x, data.y, key)
        else:
            new = _round_cohort(state["params"], jax.numpy.asarray(cohort),
                                data.n, data.x, data.y, key)
        return {"params": new}, {"streams": 1}

    return Strategy("fedavg", init, round, lambda s: s["params"],
                    comm_scheme="broadcast", num_streams=1)
