"""FedAvg (McMahan et al., 2017) — Eq. 1.

Partial participation: the cohort trains from the current global model and
the new global (an n-weighted mean of the cohort's uploads, pad slots
zero-weight) is broadcast back to every row of the stacked state — one
downlink stream either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation, flat
from repro.core.baselines import common
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib


@register("fedavg")
def make_fedavg(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                kernel_impl=None):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )
    topo = topology_lib.check_composition(
        cfg.topology, "fedavg", shard_state=cfg.shard_state,
        async_buffer=cfg.async_buffer)
    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    layout = flat.LayoutTable.build(params0)
    schema = transport_lib.single_delta_schema(
        "fedavg", layout.dim,
        downlink=(transport_lib.Stream("model", layout.dim),))
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)

    def init(key, data):
        if topo is not None:
            topo.check_clients(data.num_clients, "fedavg")
        state = {"params": layout.slab(params0, data.num_clients)}
        if cfg.transport is not None:
            state["ef"] = jnp.zeros(
                (data.num_clients, schema.width_aligned("uplink")),
                jnp.float32)
            state["ef_dl"] = jnp.zeros(
                (1, schema.width_aligned("downlink")), jnp.float32)
        return state

    @jax.jit
    def _round(params, n, x, y, key):
        updated, _ = local(layout.unravel(params), x, y, key)
        return layout.ravel(aggregation.fedavg(updated, n,
                                               impl=kernel_impl))

    _masked = common.make_fedavg_masked_round(local, impl=kernel_impl,
                                              sops=sops,
                                              upload_stage=ustage,
                                              layout=layout,
                                              transport=cfg.transport,
                                              schema=schema,
                                              topology=topo)

    def dense(state, data, key):
        new = _round(state["params"], data.n, data.x, data.y, key)
        return {"params": new}, {"streams": 1}

    def masked(state, data, key, idx, mask):
        if cfg.transport is None:
            new = _masked(state["params"], idx, mask, data.x, data.y, key,
                          data.n)
            return dict(state, params=new), {"streams": 1}
        (new, ef_dl), ef = _masked(state["params"], state["ef"], idx, mask,
                                   data.x, data.y, key, data.n,
                                   state["ef_dl"])
        return dict(state, params=new, ef=ef, ef_dl=ef_dl), {"streams": 1}

    amasked, masked_jit = common.fedavg_async_wrapper(
        lambda pc, xc, yc, keys, n: local(pc, xc, yc, None, keys=keys)[0],
        params0, cfg.async_buffer, impl=kernel_impl, sops=sops,
        upload_stage=ustage, layout=layout, transport=cfg.transport,
        schema=schema)

    shard_keys = (("params", "ef") if cfg.transport is not None
                  else ("params",))
    return Strategy("fedavg", init,
                    common.cohort_round(dense, masked,
                                        masked_jit=masked_jit or _masked,
                                        mesh=cfg.mesh, async_fn=amasked,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops, shard_keys=shard_keys,
                                        upload_stage=ustage,
                                        transport=cfg.transport,
                                        topology=topo),
                    lambda s: layout.unravel(s["params"]),
                    comm_scheme="broadcast", num_streams=1,
                    injects_faults=cfg.faults is not None,
                    wire_schema=schema)
