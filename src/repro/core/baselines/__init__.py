"""All nine baselines the paper compares against (Tables I/II, Figs 2/5/6).

Import side effects register each into ``repro.core.strategy.REGISTRY``.
"""
from repro.core.baselines import (  # noqa: F401
    cfl,
    ditto,
    fedavg,
    fedfomo,
    fedprox,
    local,
    oracle,
    pfedme,
    scaffold,
)
