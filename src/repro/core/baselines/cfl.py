"""Clustered Federated Learning (Sattler et al., 2020) — hard clustering.

Recursive bi-partitioning: train FedAvg within each cluster; when a
cluster's mean update norm is small while individual update norms stay
large (conflicting objectives), split it in two by the sign of the leading
eigenvector of the pairwise cosine-similarity matrix of client updates
(the spectral relaxation of Sattler's min-max-similarity bipartition).

Deviation from the original: the split thresholds are *relative*
(‖mean Δ‖ < eps1_rel·mean‖Δ_i‖) since absolute ε₁/ε₂ don't transfer
across datasets; recorded in DESIGN.md. Cluster bookkeeping is host-side
(numpy); the per-round training/aggregation is jitted. Cohort rounds use
the fixed-shape masked engine: the update-delta rows of pad slots are
sliced off host-side before the split check (real members occupy the
sorted slot prefix).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, flat
from repro.core.baselines import common
from repro.core.baselines.common import group_average
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib


def _spectral_bipartition(sim: np.ndarray) -> np.ndarray:
    """Sign split on the leading eigenvector of the centered similarity."""
    s = sim - sim.mean()
    v = np.random.default_rng(0).normal(size=s.shape[0])
    for _ in range(50):
        v = s @ v
        nrm = np.linalg.norm(v)
        if nrm < 1e-12:
            break
        v = v / nrm
    side = v >= 0
    if side.all() or (~side).all():  # degenerate: split by median
        side = v >= np.median(v)
    return side


@register("cfl")
def make_cfl(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
             eps1_rel: float = 0.4, warmup_rounds: int = 3,
             min_cluster: int = 4, kernel_impl=None):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )

    layout = flat.LayoutTable.build(params0)
    # the split statistics consume the DEQUANTIZED wire deltas — the
    # server can only cluster on what it received; the cluster-model
    # groupcast stays raw (a cluster mean is not any receiver's old model)
    schema = transport_lib.single_delta_schema(
        "cfl", layout.dim,
        downlink=(transport_lib.Stream("cluster_models", layout.dim,
                                       coding="raw"),))

    def init(key, data):
        m = data.num_clients
        state = {
            "params": layout.slab(params0, m),
            "assignment": np.zeros(m, dtype=np.int32),
            "round": 0,
        }
        if cfg.transport is not None:
            state["ef"] = jnp.zeros(
                (m, schema.width_aligned("uplink")), jnp.float32)
        return state

    @jax.jit
    def _train_agg(params, assignment, n, x, y, key):
        updated, _ = local(layout.unravel(params), x, y, key)
        post = layout.ravel(updated)
        new_params = layout.ravel(
            group_average(updated, assignment, n, impl=kernel_impl))
        return new_params, post - params

    topology_lib.unsupported(
        cfg.topology, "cfl",
        "the split check consumes every surviving member's PER-CLIENT "
        "update-delta row at the host each round — per-edge partial "
        "means would erase the rows the spectral bipartition needs")
    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)
    tstage = transport_lib.make_wire_stage(schema, cfg.transport, "uplink")

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def _masked(params, ef, idx, mask, assignment_c, n, x, y, key):
        # within-cluster FedAvg over the masked cohort members of each
        # cluster; absent clients keep their last model.
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        pc = sops.gather(params, safe)
        keys = common.cohort_keys(key, x.shape[0], safe)
        updated, _ = local(layout.unravel(pc), x[safe], y[safe], None,
                           keys=keys)
        post = layout.ravel(updated)
        if tstage is not None:
            # quantize the upload FIRST: the split statistics (and the
            # mix) consume the dequantized wire delta post' − pc — the
            # server clusters on what it received
            post, efc = tstage(pc, post, sops.gather(ef, safe))
            ef = sops.scatter(ef, idx, efc)
        if ustage is not None:
            # sanitize the upload BEFORE the split statistics: the
            # returned deltas (and the split bookkeeping fed from them)
            # see only surviving rows, and the FINAL mask travels back
            # to the host so demoted slots leave the member pool too
            post, idx, mask = ustage(pc, post, idx, mask, key, x.shape[0])
        delta = post - pc
        rows = aggregation.masked_group_rows(assignment_c,
                                             jnp.take(n, safe), mask)
        new_params = sops.mix_scatter_flat(params, post, rows, idx, mask,
                                           impl=kernel_impl)
        if ustage is not None:
            return new_params, delta, mask, ef
        return new_params, delta, ef

    def _maybe_split(assignment, members_pool, dmat_rows):
        """Recursive bipartition check over the clients in members_pool.

        dmat_rows maps *global* client id -> update-delta row (only ids in
        members_pool are present).
        """
        assignment = assignment.copy()
        next_id = assignment.max() + 1
        for c in np.unique(assignment[members_pool]):
            members = members_pool[assignment[members_pool] == c]
            if len(members) < min_cluster:
                continue
            d = np.stack([dmat_rows[i] for i in members])
            norms = np.linalg.norm(d, axis=1)
            mean_norm = np.linalg.norm(d.mean(axis=0))
            if mean_norm < eps1_rel * norms.mean():
                nd = d / np.maximum(norms[:, None], 1e-12)
                side = _spectral_bipartition(nd @ nd.T)
                if side.any() and (~side).any():
                    assignment[members[side]] = next_id
                    next_id += 1
        return assignment

    def _bookkeep(state, pool, rows):
        assignment = state["assignment"]
        rnd = state["round"] + 1
        if rnd > warmup_rounds:
            assignment = _maybe_split(assignment, pool, rows)
        return assignment, rnd

    def dense(state, data, key):
        assignment = state["assignment"]
        new_params, dmat = _train_agg(
            state["params"], jnp.asarray(assignment), data.n,
            data.x, data.y, key,
        )
        pool = np.arange(len(assignment))
        dmat = np.asarray(dmat)
        assignment, rnd = _bookkeep(state, pool,
                                    {int(i): dmat[i] for i in pool})
        return ({"params": new_params, "assignment": assignment,
                 "round": rnd},
                {"streams": len(np.unique(assignment))})

    def masked(state, data, key, idx, mask):
        assignment = state["assignment"]
        safe = np.minimum(np.asarray(idx), data.num_clients - 1)
        out = _masked(
            state["params"], state.get("ef"), idx, mask,
            jnp.asarray(assignment[safe]), data.n, data.x, data.y, key,
        )
        if ustage is None:
            new_params, dmat, ef = out
            members = np.asarray(idx)[np.asarray(mask)]  # sorted real prefix
            slots = np.arange(len(members))
        else:
            # the stage may demote slots mid-cohort, so the survivors are
            # no longer a slot prefix — index dmat by surviving slot
            new_params, dmat, fmask, ef = out
            slots = np.nonzero(np.asarray(fmask))[0]
            members = np.asarray(idx)[slots]
        dmat = np.asarray(dmat)
        assignment, rnd = _bookkeep(
            state, members,
            {int(g): dmat[j] for j, g in zip(slots, members)})
        new_state = {"params": new_params, "assignment": assignment,
                     "round": rnd}
        if ef is not None:
            new_state["ef"] = ef
        return (new_state,
                {"streams": len(np.unique(assignment[members]))
                 if len(members) else 0})

    shard_keys = (("params", "ef") if cfg.transport is not None
                  else ("params",))
    return Strategy("cfl", init,
                    common.cohort_round(dense, masked, masked_jit=_masked,
                                        mesh=cfg.mesh,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops, shard_keys=shard_keys,
                                        upload_stage=ustage,
                                        transport=cfg.transport),
                    lambda s: layout.unravel(s["params"]),
                    comm_scheme="groupcast",
                    injects_faults=cfg.faults is not None,
                    wire_schema=schema)
