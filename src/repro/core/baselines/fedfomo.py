"""FedFomo (Zhang et al., 2020) — first-order client-side mixing.

Every round each client downloads ALL other clients' models (the m× DL
cost the paper criticizes, priced as "client_mixing" in the comm model),
evaluates them on a held-out local validation split and mixes:

  w_{i,j} = max(0, (L_i(θ_i) − L_i(θ_j)) / ||θ_j − θ_i||),  normalized,
  θ_i ← θ_i + Σ_j ŵ_{i,j} (θ_j − θ_i).

The weighting is *refined every round* (unlike the paper's one-shot W).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.baselines.common import (broadcast_params, gather_rows,
                                         scatter_rows)
from repro.core.pytree import stacked_ravel
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated.client import make_loss
from repro.kernels import ops


@register("fedfomo")
def make_fedfomo(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                 val_frac: float = 0.2, kernel_impl=None):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size,
    )
    loss = make_loss(apply_fn)

    def init(key, data):
        return {"params": broadcast_params(params0, data.num_clients)}

    @jax.jit
    def _round(params, x, y, key):
        m, n = x.shape[0], x.shape[1]
        n_val = max(int(n * val_frac), 1)
        x_val, y_val = x[:, :n_val], y[:, :n_val]
        x_tr, y_tr = x[:, n_val:], y[:, n_val:]

        updated, _ = local(params, x_tr, y_tr, key)

        # L[i, j]: client i's val loss under client j's updated model.
        def losses_for_client(xv, yv):
            return jax.vmap(lambda p: loss(p, xv, yv))(updated)

        lmat = jax.vmap(losses_for_client)(x_val, y_val)  # (m, m)
        flat = stacked_ravel(updated)  # (m, d)
        dist = jnp.sqrt(ops.pairwise_delta(flat, impl=kernel_impl) + 1e-12)
        base = jnp.diag(lmat)  # own updated model as baseline
        raw = jnp.maximum(base[:, None] - lmat, 0.0) / dist
        raw = raw * (1.0 - jnp.eye(m))  # exclude self
        norm = jnp.sum(raw, axis=1, keepdims=True)
        w = jnp.where(norm > 0, raw / jnp.maximum(norm, 1e-12), 0.0)
        # θ_i ← θ_i + Σ_j ŵ_ij (θ_j − θ_i)
        mixed_delta = ops.mix_aggregate(w, flat, impl=kernel_impl)
        self_w = jnp.sum(w, axis=1, keepdims=True)
        new_flat = flat + mixed_delta - self_w * flat

        # unflatten back into the stacked tree
        def unflatten(tree, mat):
            out, off = [], 0
            leaves, treedef = jax.tree.flatten(tree)
            for l in leaves:
                size = math.prod(l.shape[1:])
                out.append(mat[:, off: off + size].reshape(l.shape))
                off += size
            return jax.tree.unflatten(treedef, out)

        return unflatten(updated, new_flat)

    @jax.jit
    def _round_cohort(params, cohort, x, y, key):
        # client-side mixing restricted to the cohort: each participant
        # downloads only the cohort's models (c, not m, DL streams per
        # client); absent clients keep their last model.
        mixed = _round(gather_rows(params, cohort), x[cohort], y[cohort], key)
        return scatter_rows(params, cohort, mixed)

    def round(state, data, key, cohort=None):
        if cohort is None:
            new = _round(state["params"], data.x, data.y, key)
            streams = data.num_clients
        else:
            cohort = jax.numpy.asarray(cohort)
            new = _round_cohort(state["params"], cohort, data.x, data.y, key)
            streams = int(cohort.shape[0])
        return {"params": new}, {"streams": streams}

    return Strategy("fedfomo", init, round, lambda s: s["params"],
                    comm_scheme="client_mixing")
