"""FedFomo (Zhang et al., 2020) — first-order client-side mixing.

Every round each client downloads ALL other clients' models (the m× DL
cost the paper criticizes, priced as "client_mixing" in the comm model),
evaluates them on a held-out local validation split and mixes:

  w_{i,j} = max(0, (L_i(θ_i) − L_i(θ_j)) / ||θ_j − θ_i||),  normalized,
  θ_i ← θ_i + Σ_j ŵ_{i,j} (θ_j − θ_i).

The weighting is *refined every round* (unlike the paper's one-shot W).
Cohort rounds restrict the mixing to the masked cohort slots (pad slots
get zero weight and are dropped by the scatter).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aggregation, flat
from repro.core.baselines import common
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib
from repro.federated.client import make_loss
from repro.kernels import ops


@register("fedfomo")
def make_fedfomo(apply_fn, params0, cfg: FedConfig = FedConfig(), *,
                 val_frac: float = 0.2, kernel_impl=None):
    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )
    loss = make_loss(apply_fn)
    layout = flat.LayoutTable.build(params0)
    # uplink: each participant ships its model delta to the PS (EF
    # client-side); downlink: peers RELAY the already-quantized uploads
    # (priced compressed, no second stage — re-quantizing a dequantized
    # payload would double the noise), so the loss matrix scores exactly
    # the models the wire carried
    schema = transport_lib.single_delta_schema(
        "fedfomo", layout.dim,
        downlink=(transport_lib.Stream("peer_models", layout.dim,
                                       coding="relay"),))

    def init(key, data):
        state = {"params": layout.slab(params0, data.num_clients)}
        if cfg.transport is not None:
            state["ef"] = jnp.zeros(
                (data.num_clients, schema.width_aligned("uplink")),
                jnp.float32)
        return state

    def _train_val(params_c, x, y, key, keys=None):
        """Local SGD on the train split; returns the updated models plus
        the held-out validation split the mixing weights are scored on."""
        n = x.shape[1]
        n_val = max(int(n * val_frac), 1)
        x_val, y_val = x[:, :n_val], y[:, :n_val]
        x_tr, y_tr = x[:, n_val:], y[:, n_val:]
        updated, _ = local(params_c, x_tr, y_tr, key, keys=keys)
        return updated, x_val, y_val

    def _fomo_mix(updated, flat, x_val, y_val, col_mask=None):
        """First-order mix over the slots.

        ``updated`` is the cohort-stacked tree (scored by the loss
        matrix), ``flat`` its (c, d_al) slab rows (mixed directly).
        col_mask: optional (c,) 0/1 weights zeroing the pad columns so a
        real participant never mixes in a pad slot's duplicate model.
        Returns the mixed (c, d_al) slab.
        """
        c = flat.shape[0]

        # L[i, j]: client i's val loss under client j's updated model.
        def losses_for_client(xv, yv):
            return jax.vmap(lambda p: loss(p, xv, yv))(updated)

        lmat = jax.vmap(losses_for_client)(x_val, y_val)  # (c, c)
        dist = jnp.sqrt(ops.pairwise_delta(flat, impl=kernel_impl) + 1e-12)
        base = jnp.diag(lmat)  # own updated model as baseline
        raw = jnp.maximum(base[:, None] - lmat, 0.0) / dist
        raw = raw * (1.0 - jnp.eye(c))  # exclude self
        if col_mask is not None:
            raw = raw * col_mask[None, :]
        norm = jnp.sum(raw, axis=1, keepdims=True)
        w = jnp.where(norm > 0, raw / jnp.maximum(norm, 1e-12), 0.0)
        # θ_i ← θ_i + Σ_j ŵ_ij (θ_j − θ_i)
        mixed_delta = ops.mix_aggregate(w, flat, impl=kernel_impl)
        self_w = jnp.sum(w, axis=1, keepdims=True)
        return flat + mixed_delta - self_w * flat

    @jax.jit
    def _round(params, x, y, key):
        updated, x_val, y_val = _train_val(layout.unravel(params), x, y,
                                           key)
        return _fomo_mix(updated, layout.ravel(updated), x_val, y_val)

    topology_lib.unsupported(
        cfg.topology, "fedfomo",
        "client-side first-order mixing downloads every cohort peer's "
        "model per receiver (the m× downlink the paper prices) — there "
        "is no PS aggregate for an edge tier to ship")
    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)
    tstage = transport_lib.make_wire_stage(schema, cfg.transport, "uplink")

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def _masked(params, ef, idx, mask, x, y, key):
        # client-side mixing restricted to the masked cohort: each
        # participant downloads only the real cohort models (len(cohort),
        # not m, DL streams per client); absent clients keep their last
        # model and pad slots are dropped by the scatter. The transport
        # stage quantizes the PS uploads FIRST (peers relay what the
        # wire carried — the loss matrix scores dequantized models), the
        # fault stage rewrites them BEFORE the matrix is scored, and the
        # FINAL mask zeroes demoted columns — a guarded/trimmed model is
        # never downloaded by peers.
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        pc = sops.gather(params, safe)
        updated, x_val, y_val = _train_val(
            layout.unravel(pc), x[safe], y[safe], None,
            keys=common.cohort_keys(key, x.shape[0], safe))
        flat = layout.ravel(updated)
        if tstage is not None:
            flat, efc = tstage(pc, flat, sops.gather(ef, safe))
            ef = sops.scatter(ef, idx, efc)
            updated = layout.unravel(flat)
        if ustage is not None:
            flat, idx, mask = ustage(pc, flat, idx, mask, key, x.shape[0])
            updated = layout.unravel(flat)  # the scored models = the wire
        mixed = _fomo_mix(updated, flat, x_val, y_val,
                          mask.astype(jnp.float32))
        return sops.scatter(params, idx, mixed), ef

    def dense(state, data, key):
        new = _round(state["params"], data.x, data.y, key)
        return {"params": new}, {"streams": data.num_clients}

    def masked(state, data, key, idx, mask):
        new, ef = _masked(state["params"], state.get("ef"), idx, mask,
                          data.x, data.y, key)
        out = dict(state, params=new)
        if ef is not None:
            out["ef"] = ef
        return out, {"streams": int(mask.sum())}  # host mask

    shard_keys = (("params", "ef") if cfg.transport is not None
                  else ("params",))
    return Strategy("fedfomo", init,
                    common.cohort_round(dense, masked, masked_jit=_masked,
                                        mesh=cfg.mesh,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops, shard_keys=shard_keys,
                                        upload_stage=ustage,
                                        transport=cfg.transport),
                    lambda s: layout.unravel(s["params"]),
                    comm_scheme="client_mixing",
                    injects_faults=cfg.faults is not None,
                    wire_schema=schema)
