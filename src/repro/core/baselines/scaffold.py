"""SCAFFOLD (Karimireddy et al., 2019) — stochastic controlled averaging.

Local step: θ ← θ − η(∇f_i(θ) − c_i + c). Control update (option II):
c_i⁺ = c_i − c + (θ_global − θ_i⁺)/(K·η); with full participation the
server sets c ← mean_i c_i⁺ and θ ← mean_i θ_i⁺. Paper footnote 2 uses
η=0.01, E=5, no momentum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aggregation, flat
from repro.core.baselines import common
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib


@register("scaffold")
def make_scaffold(apply_fn, params0, cfg: FedConfig = FedConfig(lr=0.01, momentum=0.0, epochs=5), *,
                  kernel_impl=None):
    def control_hook(grads, params, ctrl):
        # ctrl = (c_i, c): correction −c_i + c
        c_i, c = ctrl
        g = jax.tree.map(lambda gg, ci, cg: gg - ci + cg, grads, c_i, c)
        return g, ctrl

    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, grad_hook=control_hook,
        chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )

    common.reject_transport(
        cfg.transport, "scaffold",
        "the uplink carries the control variate alongside the model "
        "delta; quantizing only the model half would bias the c_i "
        "update the server derives from it")
    layout = flat.LayoutTable.build(params0)

    def init(key, data):
        m = data.num_clients
        stacked = layout.slab(params0, m)
        return {
            "params": stacked,
            "c_i": jnp.zeros_like(stacked),
            "c": jnp.zeros_like(stacked),  # stacked copy of the global c
        }

    @jax.jit
    def _round(params, c_i, c, n, x, y, key):
        steps = (x.shape[1] // cfg.batch_size) * cfg.epochs
        tree, cit, ct = (layout.unravel(params), layout.unravel(c_i),
                         layout.unravel(c))
        updated, _ = local(tree, x, y, key, (cit, ct))
        post = layout.ravel(updated)
        inv = 1.0 / (steps * cfg.lr)
        new_c_i = c_i - c + inv * (params - post)
        new_params = layout.ravel(
            aggregation.fedavg(updated, n, impl=kernel_impl))
        new_c = jnp.broadcast_to(jnp.mean(new_c_i, axis=0),
                                 new_c_i.shape) + 0.0
        return new_params, new_c_i, new_c

    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust)

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
    def _masked(params, c_i, c, idx, mask, n, x, y, key):
        # Option II with partial participation: only the cohort refreshes
        # its c_i (pad slots are dropped by the sentinel-index scatter);
        # the server control c re-averages ALL stored c_i (stale ones
        # included) and the new global mixes the cohort's masked uploads.
        steps = (x.shape[1] // cfg.batch_size) * cfg.epochs
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        pc = sops.gather(params, safe)
        cic, cc = sops.gather(c_i, safe), sops.gather(c, safe)
        keys = common.cohort_keys(key, x.shape[0], safe)
        updated, _ = local(layout.unravel(pc), x[safe], y[safe], None,
                           (layout.unravel(cic), layout.unravel(cc)),
                           keys=keys)
        post = layout.ravel(updated)
        if ustage is not None:
            # the fault/robust stage rewrites the MODEL upload; the
            # control update below then derives from the sanitized
            # upload, and demoted slots (sentinel idx) drop out of BOTH
            # scatters — a faulty client's stale c_i survives untouched
            post, idx, mask = ustage(pc, post, idx, mask, key, x.shape[0])
        inv = 1.0 / (steps * cfg.lr)
        new_cic = cic - cc + inv * (pc - post)
        c_i_full = sops.scatter(c_i, idx, new_cic)
        new_params = sops.fedavg_mix(params, post, idx, mask, n,
                                     impl=kernel_impl)
        # cross-row mean all-reduces under a sharded layout; re-pin the
        # broadcast result to the committed row sharding
        new_c = sops.constrain(
            jnp.broadcast_to(jnp.mean(c_i_full, axis=0),
                             c_i_full.shape) + 0.0)
        return new_params, c_i_full, new_c

    def dense(state, data, key):
        p, ci, c = _round(state["params"], state["c_i"], state["c"],
                          data.n, data.x, data.y, key)
        return {"params": p, "c_i": ci, "c": c}, {"streams": 1}

    def masked(state, data, key, idx, mask):
        p, ci, c = _masked(state["params"], state["c_i"], state["c"],
                           idx, mask, data.n, data.x, data.y, key)
        return {"params": p, "c_i": ci, "c": c}, {"streams": 1}

    return Strategy("scaffold", init,
                    common.cohort_round(dense, masked, masked_jit=_masked,
                                        mesh=cfg.mesh,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops,
                                        shard_keys=("params", "c_i", "c"),
                                        upload_stage=ustage),
                    lambda s: layout.unravel(s["params"]),
                    comm_scheme="broadcast",
                    num_streams=1,
                    injects_faults=cfg.faults is not None)
