"""SCAFFOLD (Karimireddy et al., 2019) — stochastic controlled averaging.

Local step: θ ← θ − η(∇f_i(θ) − c_i + c). Control update (option II):
c_i⁺ = c_i − c + (θ_global − θ_i⁺)/(K·η); with full participation the
server sets c ← mean_i c_i⁺ and θ ← mean_i θ_i⁺. Paper footnote 2 uses
η=0.01, E=5, no momentum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.core.baselines import common
from repro.core.baselines.common import broadcast_params
from repro.core.pytree import stacked_ravel, stacked_unravel, tree_zeros_like
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib


@register("scaffold")
def make_scaffold(apply_fn, params0, cfg: FedConfig = FedConfig(lr=0.01, momentum=0.0, epochs=5), *,
                  kernel_impl=None):
    def control_hook(grads, params, ctrl):
        # ctrl = (c_i, c): correction −c_i + c
        c_i, c = ctrl
        g = jax.tree.map(lambda gg, ci, cg: gg - ci + cg, grads, c_i, c)
        return g, ctrl

    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, grad_hook=control_hook,
        chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )

    def init(key, data):
        m = data.num_clients
        stacked = broadcast_params(params0, m)
        return {
            "params": stacked,
            "c_i": tree_zeros_like(stacked),
            "c": tree_zeros_like(stacked),  # stacked copy of the global c
        }

    @jax.jit
    def _round(params, c_i, c, n, x, y, key):
        steps = (x.shape[1] // cfg.batch_size) * cfg.epochs
        updated, _ = local(params, x, y, key, (c_i, c))
        inv = 1.0 / (steps * cfg.lr)
        new_c_i = jax.tree.map(
            lambda ci, cg, start, end: ci - cg + inv * (start - end),
            c_i, c, params, updated,
        )
        new_params = aggregation.fedavg(updated, n, impl=kernel_impl)
        new_c = jax.tree.map(
            lambda ci: jnp.broadcast_to(jnp.mean(ci, axis=0),
                                        ci.shape) + 0.0,
            new_c_i,
        )
        return new_params, new_c_i, new_c

    sops = common.StateOps(cfg.mesh, cfg.shard_state)
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust)

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
    def _masked(params, c_i, c, idx, mask, n, x, y, key):
        # Option II with partial participation: only the cohort refreshes
        # its c_i (pad slots are dropped by the sentinel-index scatter);
        # the server control c re-averages ALL stored c_i (stale ones
        # included) and the new global mixes the cohort's masked uploads.
        steps = (x.shape[1] // cfg.batch_size) * cfg.epochs
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        pc = sops.gather(params, safe)
        cic, cc = sops.gather(c_i, safe), sops.gather(c, safe)
        keys = common.cohort_keys(key, x.shape[0], safe)
        updated, _ = local(pc, x[safe], y[safe], None, (cic, cc), keys=keys)
        if ustage is not None:
            # the fault/robust stage rewrites the MODEL upload; the
            # control update below then derives from the sanitized
            # upload, and demoted slots (sentinel idx) drop out of BOTH
            # scatters — a faulty client's stale c_i survives untouched
            flat, idx, mask = ustage(stacked_ravel(pc),
                                     stacked_ravel(updated), idx, mask,
                                     key, x.shape[0])
            updated = stacked_unravel(updated, flat)
        inv = 1.0 / (steps * cfg.lr)
        new_cic = jax.tree.map(
            lambda ci, cg, start, end: ci - cg + inv * (start - end),
            cic, cc, pc, updated,
        )
        c_i_full = sops.scatter(c_i, idx, new_cic)
        new_params = sops.fedavg_mix(params, updated, idx, mask, n,
                                     impl=kernel_impl)
        # cross-row mean all-reduces under a sharded layout; re-pin the
        # broadcast result to the committed row sharding
        new_c = sops.constrain(jax.tree.map(
            lambda ci: jnp.broadcast_to(jnp.mean(ci, axis=0),
                                        ci.shape) + 0.0,
            c_i_full,
        ))
        return new_params, c_i_full, new_c

    def dense(state, data, key):
        p, ci, c = _round(state["params"], state["c_i"], state["c"],
                          data.n, data.x, data.y, key)
        return {"params": p, "c_i": ci, "c": c}, {"streams": 1}

    def masked(state, data, key, idx, mask):
        p, ci, c = _masked(state["params"], state["c_i"], state["c"],
                           idx, mask, data.n, data.x, data.y, key)
        return {"params": p, "c_i": ci, "c": c}, {"streams": 1}

    return Strategy("scaffold", init,
                    common.cohort_round(dense, masked, masked_jit=_masked,
                                        mesh=cfg.mesh,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops,
                                        shard_keys=("params", "c_i", "c"),
                                        upload_stage=ustage),
                    lambda s: s["params"], comm_scheme="broadcast",
                    num_streams=1,
                    injects_faults=cfg.faults is not None)
