"""SCAFFOLD (Karimireddy et al., 2019) — stochastic controlled averaging.

Local step: θ ← θ − η(∇f_i(θ) − c_i + c). Control update (option II):
c_i⁺ = c_i − c + (θ_global − θ_i⁺)/(K·η); with full participation the
server sets c ← mean_i c_i⁺ and θ ← mean_i θ_i⁺. Paper footnote 2 uses
η=0.01, E=5, no momentum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.core.baselines.common import (broadcast_params, gather_rows,
                                         scatter_rows)
from repro.core.strategy import FedConfig, Strategy, register
from repro.core.pytree import tree_zeros_like
from repro.federated import client as fedclient


@register("scaffold")
def make_scaffold(apply_fn, params0, cfg: FedConfig = FedConfig(lr=0.01, momentum=0.0, epochs=5), *,
                  kernel_impl=None):
    def control_hook(grads, params, ctrl):
        # ctrl = (c_i, c): correction −c_i + c
        c_i, c = ctrl
        g = jax.tree.map(lambda gg, ci, cg: gg - ci + cg, grads, c_i, c)
        return g, ctrl

    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, grad_hook=control_hook,
        chunk_size=cfg.chunk_size,
    )

    def init(key, data):
        m = data.num_clients
        stacked = broadcast_params(params0, m)
        return {
            "params": stacked,
            "c_i": tree_zeros_like(stacked),
            "c": tree_zeros_like(stacked),  # stacked copy of the global c
        }

    @jax.jit
    def _round(params, c_i, c, n, x, y, key):
        steps = (x.shape[1] // cfg.batch_size) * cfg.epochs
        updated, _ = local(params, x, y, key, (c_i, c))
        inv = 1.0 / (steps * cfg.lr)
        new_c_i = jax.tree.map(
            lambda ci, cg, start, end: ci - cg + inv * (start - end),
            c_i, c, params, updated,
        )
        new_params = aggregation.fedavg(updated, n, impl=kernel_impl)
        new_c = jax.tree.map(
            lambda ci: jnp.broadcast_to(jnp.mean(ci, axis=0),
                                        ci.shape) + 0.0,
            new_c_i,
        )
        return new_params, new_c_i, new_c

    @jax.jit
    def _round_cohort(params, c_i, c, cohort, n, x, y, key):
        # Option II with partial participation: only the cohort refreshes
        # its c_i; the server control c re-averages ALL stored c_i (stale
        # ones included) and the new global mixes the cohort's uploads.
        steps = (x.shape[1] // cfg.batch_size) * cfg.epochs
        pc = gather_rows(params, cohort)
        cic, cc = gather_rows(c_i, cohort), gather_rows(c, cohort)
        updated, _ = local(pc, x[cohort], y[cohort], key, (cic, cc))
        inv = 1.0 / (steps * cfg.lr)
        new_cic = jax.tree.map(
            lambda ci, cg, start, end: ci - cg + inv * (start - end),
            cic, cc, pc, updated,
        )
        c_i_full = scatter_rows(c_i, cohort, new_cic)
        new_params = aggregation.fedavg_cohort(updated, n[cohort], x.shape[0],
                                               impl=kernel_impl)
        new_c = jax.tree.map(
            lambda ci: jnp.broadcast_to(jnp.mean(ci, axis=0),
                                        ci.shape) + 0.0,
            c_i_full,
        )
        return new_params, c_i_full, new_c

    def round(state, data, key, cohort=None):
        if cohort is None:
            p, ci, c = _round(state["params"], state["c_i"], state["c"],
                              data.n, data.x, data.y, key)
        else:
            p, ci, c = _round_cohort(state["params"], state["c_i"],
                                     state["c"], jnp.asarray(cohort),
                                     data.n, data.x, data.y, key)
        return {"params": p, "c_i": ci, "c": c}, {"streams": 1}

    return Strategy("scaffold", init, round, lambda s: s["params"],
                    comm_scheme="broadcast", num_streams=1)
