"""SCAFFOLD (Karimireddy et al., 2019) — stochastic controlled averaging.

Local step: θ ← θ − η(∇f_i(θ) − c_i + c). Control update (option II):
c_i⁺ = c_i − c + (θ_global − θ_i⁺)/(K·η); with full participation the
server sets c ← mean_i c_i⁺ and θ ← mean_i θ_i⁺. Paper footnote 2 uses
η=0.01, E=5, no momentum.

Wire schema: the SCAFFOLD upload is genuinely TWO streams — the model
delta and the control-variate delta — so its wire slab is the (c, 2·W)
concatenation ``[post | c_i⁺]`` against ``[pre | c_i]``, each half
quantized with its own error-feedback slice (quantizing only the model
half would bias the c_i update the server derives from it, which is why
the pre-schema engine rejected transport here). The downlink mirrors it:
``[new global | new c]`` delta-coded against the broadcast-uniform
``[old global | old c]`` with one shared server-side EF row. The fault
stage operates on the same concatenated wire, and the per-stream finite
guard demotes a slot when EITHER half goes non-finite.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aggregation, flat
from repro.core.baselines import common
from repro.core.strategy import FedConfig, Strategy, register
from repro.federated import client as fedclient
from repro.federated import faults as faults_lib
from repro.federated import mesh as mesh_lib
from repro.federated import topology as topology_lib
from repro.federated import transport as transport_lib


@register("scaffold")
def make_scaffold(apply_fn, params0, cfg: FedConfig = FedConfig(lr=0.01, momentum=0.0, epochs=5), *,
                  kernel_impl=None):
    def control_hook(grads, params, ctrl):
        # ctrl = (c_i, c): correction −c_i + c
        c_i, c = ctrl
        g = jax.tree.map(lambda gg, ci, cg: gg - ci + cg, grads, c_i, c)
        return g, ctrl

    local = fedclient.make_federated_local_sgd(
        apply_fn, lr=cfg.lr, momentum=cfg.momentum, epochs=cfg.epochs,
        batch_size=cfg.batch_size, grad_hook=control_hook,
        chunk_size=cfg.chunk_size, mesh=cfg.mesh,
    )

    layout = flat.LayoutTable.build(params0)
    schema = transport_lib.WireSchema(
        "scaffold",
        uplink=(transport_lib.Stream("delta", layout.dim),
                transport_lib.Stream("control_delta", layout.dim)),
        downlink=(transport_lib.Stream("model", layout.dim),
                  transport_lib.Stream("control", layout.dim)),
    )
    width = layout.dim_aligned  # one stream's slab slice
    topology_lib.unsupported(
        cfg.topology, "scaffold",
        "option II couples every client's control variate to ONE global "
        "c re-averaged over all m stored c_i rows each round — per-edge "
        "partial means of the cohort's c_i⁺ are not that update")
    ustage = faults_lib.upload_stage(cfg.faults, cfg.robust, schema)
    tstage = transport_lib.make_wire_stage(schema, cfg.transport, "uplink")
    dstage = transport_lib.make_wire_stage(schema, cfg.transport,
                                           "downlink")

    def init(key, data):
        m = data.num_clients
        stacked = layout.slab(params0, m)
        state = {
            "params": stacked,
            "c_i": jnp.zeros_like(stacked),
            "c": jnp.zeros_like(stacked),  # stacked copy of the global c
        }
        if tstage is not None:
            state["ef"] = jnp.zeros(
                (m, schema.width_aligned("uplink")), jnp.float32)
            state["ef_dl"] = jnp.zeros(
                (1, schema.width_aligned("downlink")), jnp.float32)
        return state

    @jax.jit
    def _round(params, c_i, c, n, x, y, key):
        steps = (x.shape[1] // cfg.batch_size) * cfg.epochs
        tree, cit, ct = (layout.unravel(params), layout.unravel(c_i),
                         layout.unravel(c))
        updated, _ = local(tree, x, y, key, (cit, ct))
        post = layout.ravel(updated)
        inv = 1.0 / (steps * cfg.lr)
        new_c_i = c_i - c + inv * (params - post)
        new_params = layout.ravel(
            aggregation.fedavg(updated, n, impl=kernel_impl))
        new_c = jnp.broadcast_to(jnp.mean(new_c_i, axis=0),
                                 new_c_i.shape) + 0.0
        return new_params, new_c_i, new_c

    sops = common.StateOps(cfg.mesh, cfg.shard_state)

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
    def _masked(params, c_i, c, ef, ef_dl, idx, mask, n, x, y, key):
        # Option II with partial participation: only the cohort refreshes
        # its c_i (pad slots are dropped by the sentinel-index scatter);
        # the server control c re-averages ALL stored c_i (stale ones
        # included) and the new global mixes the cohort's masked uploads.
        # ``ef``/``ef_dl`` are None when transport is off (inert donation
        # slots — the trace is exactly stage-free).
        steps = (x.shape[1] // cfg.batch_size) * cfg.epochs
        safe = aggregation.safe_gather_index(idx, x.shape[0])
        pc = sops.gather(params, safe)
        cic, cc = sops.gather(c_i, safe), sops.gather(c, safe)
        keys = common.cohort_keys(key, x.shape[0], safe)
        updated, _ = local(layout.unravel(pc), x[safe], y[safe], None,
                           (layout.unravel(cic), layout.unravel(cc)),
                           keys=keys)
        post = layout.ravel(updated)
        inv = 1.0 / (steps * cfg.lr)
        if tstage is not None or ustage is not None:
            # the wire carries BOTH halves: the client derives its new
            # control from its RAW local model (a client-side physical
            # quantity — the wire never saw it), then the transport stage
            # quantizes each stream slice of [model | control] with its
            # own EF slice, and the fault/robust stage corrupts/sanitizes
            # exactly what the wire carried
            new_cic = cic - cc + inv * (pc - post)
            wire_pre = jnp.concatenate([pc, cic], axis=-1)
            wire_post = jnp.concatenate([post, new_cic], axis=-1)
            if tstage is not None:
                wire_post, efc = tstage(wire_pre, wire_post,
                                        sops.gather(ef, safe))
                ef = sops.scatter(ef, idx, efc)
            if ustage is not None:
                wire_post, idx, mask = ustage(wire_pre, wire_post, idx,
                                              mask, key, x.shape[0])
            post = wire_post[..., :width]
            new_cic = wire_post[..., width:]
        else:
            new_cic = cic - cc + inv * (pc - post)
        c_i_full = sops.scatter(c_i, idx, new_cic)
        if dstage is None:
            new_params = sops.fedavg_mix(params, post, idx, mask, n,
                                         impl=kernel_impl)
            # cross-row mean all-reduces under a sharded layout; re-pin
            # the broadcast result to the committed row sharding
            new_c = sops.constrain(
                jnp.broadcast_to(jnp.mean(c_i_full, axis=0),
                                 c_i_full.shape) + 0.0)
            return new_params, c_i_full, new_c, ef, ef_dl
        # compressed downlink: both broadcast rows delta-coded against
        # the receivers' shared reference — row 0 of the broadcast-
        # uniform [params | c] state — with one server-side EF row; an
        # all-masked cohort keeps everything unchanged (no wire activity)
        safe = aggregation.safe_gather_index(idx, n.shape[0])
        w = aggregation.masked_fedavg_weights(jnp.take(n, safe), mask)
        mixed = aggregation.user_centric(post, w, impl=kernel_impl)
        mean_c = jnp.mean(c_i_full, axis=0, keepdims=True)
        dl_pre = jnp.concatenate([params[0:1], c[0:1]], axis=-1)
        dl_post = jnp.concatenate([mixed, mean_c], axis=-1)
        served, new_efdl = dstage(dl_pre, dl_post, ef_dl)
        alive = jnp.any(mask)
        ef_dl = jnp.where(alive, new_efdl, ef_dl)
        sm, sc = served[..., :width], served[..., width:]
        if sops.sharded:
            new_params = mesh_lib.shard_broadcast_rows(params, sm, alive,
                                                       sops.mesh)
            new_c = mesh_lib.shard_broadcast_rows(c, sc, alive, sops.mesh)
        else:
            new_params = jnp.where(
                alive, jnp.broadcast_to(sm, params.shape), params)
            new_c = jnp.where(alive, jnp.broadcast_to(sc, c.shape), c)
        return new_params, c_i_full, new_c, ef, ef_dl

    def dense(state, data, key):
        p, ci, c = _round(state["params"], state["c_i"], state["c"],
                          data.n, data.x, data.y, key)
        return {"params": p, "c_i": ci, "c": c}, {"streams": 1}

    def masked(state, data, key, idx, mask):
        p, ci, c, ef, ef_dl = _masked(
            state["params"], state["c_i"], state["c"], state.get("ef"),
            state.get("ef_dl"), idx, mask, data.n, data.x, data.y, key)
        out = {"params": p, "c_i": ci, "c": c}
        if ef is not None:
            out["ef"] = ef
        if ef_dl is not None:
            out["ef_dl"] = ef_dl
        return out, {"streams": 1}

    # ef_dl is a (1, ·) broadcast row — replicate-committed, not sharded
    shard_keys = ("params", "c_i", "c")
    if tstage is not None:
        shard_keys += ("ef",)
    return Strategy("scaffold", init,
                    common.cohort_round(dense, masked, masked_jit=_masked,
                                        mesh=cfg.mesh,
                                        async_cfg=cfg.async_buffer,
                                        sops=sops,
                                        shard_keys=shard_keys,
                                        upload_stage=ustage,
                                        transport=cfg.transport),
                    lambda s: layout.unravel(s["params"]),
                    comm_scheme="broadcast",
                    num_streams=1,
                    injects_faults=cfg.faults is not None,
                    wire_schema=schema)
