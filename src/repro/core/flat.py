"""Static layout table for the flat-slab client state.

Every strategy stores its (m, ·) stacked state as ONE float32
``(m, dim_aligned)`` matrix — the *slab* — instead of a stacked pytree.
The :class:`LayoutTable` is built once at strategy construction from the
``params0`` template and records, per leaf, the trailing shape, dtype,
flat size and column offset into the slab; ``dim_aligned`` rounds the
concatenated width up to the 128-lane multiple (:func:`ops.aligned_dim`)
so the slab always takes the aliased zero-copy
``masked_mix_scatter`` / HBM gather-mix-scatter kernel path and the
row-sharded ``shard_state`` layout with no per-leaf scatter loop.

Contract (the "layout-table contract" in ROADMAP.md):

  * the table is static — offsets/shapes/dtypes are host Python computed
    once; ``ravel``/``unravel`` trace to pure reshape/concat/slice ops
    (exact for float32 leaves, no arithmetic), so slab round-trips are
    bit-exact;
  * ``ravel`` accepts ANY leading shape — ``()`` for a bare params tree,
    ``(c,)`` cohort stacks, ``(m, c)`` per-stream stacks — and zero-fills
    the ``dim_aligned - dim`` tail columns. All mixing rules are
    column-independent linear ops, so the zero tail contributes nothing
    to mixes, norms or pairwise distances;
  * ``unravel`` ignores the tail columns and casts each leaf back to its
    template dtype — it is the ONLY place tree structure reappears, at
    ``apply_fn`` boundaries (local SGD, evaluation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class LayoutTable:
    """Per-leaf slab layout of a params pytree (see module docstring)."""

    treedef: Any
    shapes: tuple  # trailing (per-client) shape of each leaf
    dtypes: tuple
    sizes: tuple  # flat column count of each leaf
    offsets: tuple  # column offset of each leaf in the slab
    dim: int  # true concatenated width
    dim_aligned: int  # slab width: dim rounded up to the 128 multiple

    @classmethod
    def build(cls, template) -> "LayoutTable":
        leaves, treedef = jax.tree.flatten(template)
        if not leaves:
            raise ValueError("LayoutTable.build: empty params tree")
        shapes = tuple(tuple(leaf.shape) for leaf in leaves)
        dtypes = tuple(jnp.asarray(leaf).dtype for leaf in leaves)
        sizes = tuple(int(math.prod(s)) for s in shapes)
        offsets, off = [], 0
        for s in sizes:
            offsets.append(off)
            off += s
        return cls(
            treedef=treedef,
            shapes=shapes,
            dtypes=dtypes,
            sizes=sizes,
            offsets=tuple(offsets),
            dim=off,
            dim_aligned=ops.aligned_dim(off),
        )

    def ravel(self, tree):
        """Tree with any leading shape -> ``(*lead, dim_aligned)`` f32
        matrix, tail columns zero."""
        leaves = self.treedef.flatten_up_to(tree)
        lead = leaves[0].ndim - len(self.shapes[0])
        head = tuple(leaves[0].shape[:lead])
        parts = [
            jnp.asarray(leaf).astype(jnp.float32).reshape(head + (s,))
            for leaf, s in zip(leaves, self.sizes)
        ]
        pad = self.dim_aligned - self.dim
        if pad:
            parts.append(jnp.zeros(head + (pad,), jnp.float32))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)

    def unravel(self, mat):
        """``(*lead, >= dim)`` matrix -> tree with that leading shape."""
        if mat.shape[-1] < self.dim:
            msg = f"LayoutTable.unravel: matrix width {mat.shape[-1]} < layout dim {self.dim}"
            raise ValueError(msg + " — slab built from a different template")
        head = tuple(mat.shape[:-1])
        leaves = [
            mat[..., off : off + size].reshape(head + shape).astype(dt)
            for off, size, shape, dt in zip(self.offsets, self.sizes, self.shapes, self.dtypes)
        ]
        return jax.tree.unflatten(self.treedef, leaves)

    def slab(self, template, m: int):
        """Broadcast a params tree to the (m, dim_aligned) initial slab."""
        vec = self.ravel(template)
        return jnp.broadcast_to(vec, (m,) + vec.shape) + 0.0
