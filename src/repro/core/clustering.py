"""JAX K-means + silhouette scoring over collaboration vectors (Alg. 2).

Everything is jit-able: K-means++ seeding with a fixed PRNG key, Lloyd
iterations under ``lax.fori_loop``, assignment via the ``kmeans_assign``
kernel, and the exact (O(m²)) silhouette score of the paper's §IV-C.
``choose_num_streams`` implements Algorithm 2: sweep k, score each
clustering with a communication/personalization trade-off function
c(k, s_k), return the argmax.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (k, f)
    labels: jax.Array  # (m,) int32
    inertia: jax.Array  # scalar — Eq. 11 objective


def _plusplus_init(key, points, k):
    """K-means++ seeding (greedy D² sampling)."""
    m = points.shape[0]
    first = jax.random.randint(key, (), 0, m)
    centroids = jnp.zeros((k, points.shape[1]), points.dtype)
    centroids = centroids.at[0].set(points[first])

    def body(i, carry):
        centroids, key = carry
        key, sub = jax.random.split(key)
        # distance to nearest of the first i centroids; mask the rest.
        d = (
            jnp.sum((points[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
        )  # (m, k)
        d = jnp.where(jnp.arange(k)[None, :] < i, d, jnp.inf)
        dmin = jnp.min(d, axis=1)
        probs = dmin / jnp.maximum(jnp.sum(dmin), 1e-12)
        idx = jax.random.choice(sub, m, p=probs)
        return centroids.at[i].set(points[idx]), key

    centroids, _ = jax.lax.fori_loop(1, k, body, (centroids, key))
    return centroids


@functools.partial(jax.jit, static_argnames=("k", "iters", "impl"))
def kmeans(key, points, k: int, *, iters: int = 50, impl=None) -> KMeansResult:
    """Lloyd's algorithm on (m, f) points with K-means++ init."""
    points = points.astype(jnp.float32)
    centroids = _plusplus_init(key, points, k)

    def step(_, centroids):
        labels, _ = ops.kmeans_assign(points, centroids, impl=impl)
        onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32)  # (m, k)
        counts = onehot.sum(axis=0)  # (k,)
        sums = onehot.T @ points  # (k, f)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # keep empty clusters where they were
        return jnp.where(counts[:, None] > 0, new, centroids)

    centroids = jax.lax.fori_loop(0, iters, step, centroids)
    labels, sqd = ops.kmeans_assign(points, centroids, impl=impl)
    # Paper's Eq. 11 uses the (non-squared) distance sum; report that.
    inertia = jnp.sum(jnp.sqrt(jnp.maximum(sqd, 0.0)))
    return KMeansResult(centroids, labels, inertia)


@jax.jit
def silhouette_score(points, labels):
    """Exact mean silhouette over (m, f) points with int labels.

    s(i) = (b_i − a_i) / max(a_i, b_i); a = mean intra-cluster distance
    (excluding self), b = smallest mean distance to another cluster.
    Singleton clusters get s(i) = 0 (sklearn convention).
    """
    points = points.astype(jnp.float32)
    m = points.shape[0]
    d = jnp.sqrt(
        jnp.maximum(
            jnp.sum(points**2, 1)[:, None]
            + jnp.sum(points**2, 1)[None, :]
            - 2 * points @ points.T,
            0.0,
        )
    )  # (m, m) euclidean
    same = labels[:, None] == labels[None, :]  # (m, m)
    not_self = ~jnp.eye(m, dtype=bool)
    intra_cnt = jnp.sum(same & not_self, axis=1)
    a = jnp.where(
        intra_cnt > 0,
        jnp.sum(jnp.where(same & not_self, d, 0.0), axis=1)
        / jnp.maximum(intra_cnt, 1),
        0.0,
    )
    # mean distance to each other cluster: use segment trick over labels
    k = m  # labels < m always
    onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32)  # (m, k)
    cnt = onehot.sum(0)  # (k,)
    sums = d @ onehot  # (m, k) — Σ_{j in cluster c} d(i, j)
    mean_to = sums / jnp.maximum(cnt[None, :], 1.0)
    own = jax.nn.one_hot(labels, k, dtype=bool)
    mean_to = jnp.where(own | (cnt[None, :] == 0), jnp.inf, mean_to)
    b = jnp.min(mean_to, axis=1)
    s = jnp.where(
        (intra_cnt > 0) & jnp.isfinite(b),
        (b - a) / jnp.maximum(jnp.maximum(a, b), 1e-12),
        0.0,
    )
    return jnp.mean(s)


def default_tradeoff(k: int, s: float, *, comm_penalty: float = 0.02) -> float:
    """A typical c(k, s): increasing in silhouette, decreasing in #streams."""
    return float(s) - comm_penalty * k


def choose_num_streams(
    key,
    w_vectors,
    *,
    k_max: int | None = None,
    tradeoff: Callable[[int, float], float] = default_tradeoff,
    iters: int = 50,
    impl=None,
):
    """Algorithm 2 — silhouette-based selection of m_t.

    Sweeps k = 2..k_max, computes the silhouette of each K-means clustering
    of the collaboration vectors, scores with ``tradeoff`` and returns
    (best_k, {k: (silhouette, score, KMeansResult)}).
    """
    m = w_vectors.shape[0]
    k_max = k_max or m - 1
    results = {}
    best_k, best_score = 1, -jnp.inf
    for k in range(2, k_max + 1):
        key, sub = jax.random.split(key)
        res = kmeans(sub, w_vectors, k, iters=iters, impl=impl)
        s = float(silhouette_score(w_vectors, res.labels))
        score = tradeoff(k, s)
        results[k] = (s, score, res)
        if score > best_score:
            best_k, best_score = k, score
    return best_k, results
