from repro.core import (  # noqa: F401
    aggregation,
    clustering,
    comm_model,
    pytree,
    similarity,
    strategy,
    ucfl,
)
from repro.core import baselines  # noqa: F401  (registers all baselines)
from repro.core.strategy import REGISTRY, FedConfig, Strategy  # noqa: F401
