"""Strategy protocol shared by the proposed method and all baselines.

A strategy owns three callables:

  * ``init(key, data) -> state`` — build the initial server/client state
    (including any pre-training round, e.g. the paper's collaboration
    round or nothing for FedAvg);
  * ``round(state, data, key) -> (state, metrics)`` — one communication
    round (local training + PS aggregation); jitted internally;
  * ``eval_params(state) -> stacked params`` — the per-client models that
    should be evaluated (personalized where the method has them).

``metrics`` may include per-round diagnostics (e.g. downlink stream
count, which feeds the §V-D comm model in the Fig. 5 benchmark).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

REGISTRY: Dict[str, Callable[..., "Strategy"]] = {}


@dataclasses.dataclass
class Strategy:
    name: str
    init: Callable[..., Any]
    round: Callable[..., Any]
    eval_params: Callable[[Any], Any]
    # downlink streams per round, for the comm model ("broadcast",
    # "groupcast", "unicast", "client_mixing") and the stream count.
    comm_scheme: str = "broadcast"
    num_streams: int | None = None


def register(name):
    def deco(fn):
        REGISTRY[name] = fn
        return fn
    return deco


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Paper §V-A hyperparameters."""
    lr: float = 0.1
    momentum: float = 0.9
    epochs: int = 1
    batch_size: int = 50
