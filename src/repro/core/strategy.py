"""Strategy protocol shared by the proposed method and all baselines.

A strategy owns three callables:

  * ``init(key, data) -> state`` — build the initial server/client state
    (including any pre-training round, e.g. the paper's collaboration
    round or nothing for FedAvg);
  * ``round(state, data, key, cohort=None) -> (state, metrics)`` — one
    communication round (local training + PS aggregation); jitted
    internally. ``cohort`` is a fixed-shape padded
    :class:`~repro.federated.participation.Cohort` (``(indices, mask)``
    with sentinel-index zero-weight pad slots), a plain sorted index
    array (normalized to an unpadded all-real cohort), or ``None`` for
    full participation. With a cohort, only the masked slots are
    gathered/trained/uploaded; the aggregation mixes with the masked
    row-renormalized W and absent clients keep their last personalized
    model (the fused ``masked_mix_scatter`` kernel writes only the real
    cohort rows of the stacked state, whose buffer the jitted round
    *donates* — callers must not reuse the pre-round state).
    ``cohort=None`` must follow the exact dense full-participation path
    so that fraction=1.0 stays bit-exact with the pre-cohort engine.
  * ``eval_params(state) -> stacked params`` — the per-client models that
    should be evaluated (personalized where the method has them).

All eleven strategies build ``round`` from the single dispatch helper
:func:`repro.core.baselines.common.cohort_round`, so the padded-cohort
contract lives in one place. Cohorts are drawn by
:mod:`repro.federated.participation` and threaded by the simulation loop;
the static slot count means one policy compiles ONE round shape — the
availability sampler included (its short rounds are masked, not
truncated).

``metrics`` may include per-round diagnostics (e.g. downlink stream
count, which feeds the §V-D comm model in the Fig. 5 benchmark).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

REGISTRY: Dict[str, Callable[..., "Strategy"]] = {}


@dataclasses.dataclass
class Strategy:
    name: str
    init: Callable[..., Any]
    round: Callable[..., Any]
    eval_params: Callable[[Any], Any]
    # downlink streams per round, for the comm model ("broadcast",
    # "groupcast", "unicast", "client_mixing") and the stream count.
    comm_scheme: str = "broadcast"
    num_streams: int | None = None
    # optional ``skip_round(state) -> state`` hook the simulation loop
    # calls on rounds nobody attends (an all-offline availability
    # cohort): time still passes for per-client bookkeeping — e.g. the
    # streaming W refresh's staleness counters advance — even though no
    # training/aggregation runs. None = skipped rounds don't touch state.
    skip_round: Callable[[Any], Any] | None = None
    # True when the strategy was built with ``FedConfig.faults`` — the
    # simulation loop's fail-fast non-finite guard stands down (injected
    # NaN/Inf uploads are expected and absorbed by the finite guard;
    # raising on them would defeat the graceful-degradation test).
    injects_faults: bool = False
    # the strategy's declared wire layout (a
    # :class:`repro.federated.transport.WireSchema`): named uplink and
    # downlink streams with per-stream widths and codings, consumed by
    # the transport stages and the §V-D byte pricing
    # (``comm_model.wire_bytes``). None only for strategies that reject
    # ``FedConfig.transport`` (ucfl_parallel).
    wire_schema: Any = None


def register(name):
    def deco(fn):
        REGISTRY[name] = fn
        return fn
    return deco


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Paper §V-A hyperparameters.

    ``chunk_size`` bounds peak client-axis memory: local SGD runs as a
    sequential ``lax.map`` over chunks of that many vmapped clients (see
    :func:`repro.federated.client.make_federated_local_sgd`); ``None``
    keeps the single monolithic vmap.

    ``mesh`` shards the cohort/client axis across devices (see
    :mod:`repro.federated.mesh`): a 1-D ``jax.sharding.Mesh`` over a
    ``clients`` axis, an int shard count, or ``"auto"`` for all local
    devices. Local SGD runs shard_mapped with the cohort slots
    partitioned across the mesh (``chunk_size`` then chunks *within*
    each shard) and the cohort dispatcher pads slot counts to a shard
    multiple; the (c, c) mix and the fused scatter stay replicated.
    ``None`` keeps the single-device path bit-exact.

    ``async_buffer`` (a :class:`repro.federated.async_buffer.AsyncConfig`,
    or ``None`` = off) opts cohort rounds into the buffered-async
    FedBuff-style server: uploads land in a fixed-shape pending buffer
    and the PS applies them — staleness-discounted by
    ``(1+τ)^{-α}`` — once ``flush_k`` have accumulated, instead of
    barrier-mixing every round. Supported by the strategies whose PS
    step is the masked row aggregation (ucfl full/clustered and the
    FedAvg family); the rest raise at construction. Requires cohort
    rounds (a participation config) — the dense ``cohort=None`` path is
    the bulk-synchronous barrier by definition. ``None`` (the default)
    keeps every existing trajectory bit-identical.

    ``shard_state`` row-shards the (m, ·) stacked server state across the
    ``mesh`` (see the row-sharded section of :mod:`repro.federated.mesh`):
    device k owns rows ``[k·m/s, (k+1)·m/s)`` of every state leaf, the
    round-start gather and round-end scatter route each cohort row to its
    owner shard inside the jitted round, and the only model-sized
    collectives are O(c·d). Requires a mesh with ``m % num_shards == 0``
    and cohort rounds (the dense path raises); ``False`` (the default)
    keeps the replicated layout bit-exact. Results match the replicated
    layout within float32 round-off (the cohort psum can associate
    additions differently).

    ``w_refresh`` (a :class:`repro.core.similarity.RefreshConfig`, or
    ``None`` = off) opts the W-owning strategies (ucfl, clustered ucfl,
    ucfl_parallel) into the streaming W refresh: every masked cohort
    round folds the cohort's gradient proxies into running Δ/σ² buffers
    and recomputes W on device, with per-client staleness counters in
    the round metrics. Off (the default, the paper's compute-W-once
    rule) keeps every existing trajectory bit-identical; the dense
    ``cohort=None`` path never refreshes either way. Strategies without
    a W ignore the knob.

    ``faults`` (a :class:`repro.federated.faults.FaultConfig`, or
    ``None`` = off) opts cohort rounds into deterministic fault
    injection — Byzantine uploads from a static seed-drawn attacker
    set, NaN/Inf corruption, mid-round upload drops — applied as
    fixed-shape masked transforms inside the jitted round (see
    :mod:`repro.federated.faults`). ``robust`` (a
    :class:`repro.core.aggregation.RobustConfig`, or ``None`` = off)
    turns on the Byzantine-robust upload rewrite — coordinate
    trimmed-mean/median, norm clipping, (multi-)Krum selection — ahead
    of the strategy's masked mix. Either knob also arms the finite
    guard that demotes non-finite upload rows to masked pad slots, so a
    poisoned round degrades gracefully instead of NaN-ing the state.
    Both require cohort rounds (the dense ``cohort=None`` path raises);
    ``None``/``None`` (the defaults) keep every existing trajectory
    bit-identical.

    ``transport`` (a :class:`repro.federated.transport.TransportConfig`,
    or ``None`` = off) opts cohort rounds into quantized wire transport.
    Each strategy declares a :class:`repro.federated.transport.WireSchema`
    — named uplink and downlink streams, each a slab-width slice with
    its own coding and its own error-feedback accumulator — and the
    transport stages run per stream inside the same jitted round (one
    compiled shape): ``delta`` streams travel int8/fp8 per-chunk-scaled
    with EF (client-side on the uplink, server-side on the downlink),
    ``raw`` streams stay float32, ``relay`` streams forward payloads
    another hop already quantized. Compression noise stays unbiased —
    including under ``w_refresh``, whose Δ/σ² estimation observes the
    dequantized uploads. Every strategy supports the knob except
    ``ucfl_parallel`` (no PS wire to compress — it raises at
    construction; see the capability matrix in
    :mod:`repro.federated.transport`). Requires cohort rounds (the dense
    path has no upload stage). ``None`` (the default) keeps every
    existing trajectory bit-identical.

    ``topology`` (a :class:`repro.federated.topology.Topology`, or
    ``None`` = off) opts cohort rounds into the two-tier hierarchical
    engine: clients are statically assigned to edge aggregators, the
    tier-1 masked mix runs per edge over fixed-shape padded per-edge
    slots (the Cohort/sentinel trick one level up), and only the
    ``(E, ·)`` edge-aggregate slab crosses the edge↔PS backhaul for the
    mass-weighted tier-2 combine — an exact factorization of the flat
    linear rules, so accuracy matches while PS-side traffic shrinks
    from ``c`` uploads to ``E·k`` aggregates (priced by
    ``comm_model.SystemParams.tiers``). Supported where the PS rule is
    linear in the uploads (the FedAvg family and clustered ucfl,
    composing with ``transport``, ``faults``/``robust``, ``w_refresh``
    and replicated ``mesh``); per-client unicast mixes (ucfl full,
    fedfomo, ...), ``shard_state`` and ``async_buffer`` raise
    NotImplementedError at construction with a capability note.
    Requires cohort rounds (the dense path has no per-edge upload
    stage). ``None`` (the default) keeps every existing trajectory
    bit-identical.

    ``selection`` (a :class:`repro.federated.participation.SelectionConfig`,
    or ``None`` = off) declares Pareto-biased cohort selection: per-round
    sampling mass biased by compute speed, link quality, a
    battery/diurnal availability trace, and data value, with a
    deterministic round-robin fairness lane bounding every
    positive-mass client's selection window. Drivers thread it into the
    sampler via :func:`repro.federated.participation.with_selection`
    (the strategy itself never draws cohorts). ``None`` keeps the
    configured sampler untouched.
    """
    lr: float = 0.1
    momentum: float = 0.9
    epochs: int = 1
    batch_size: int = 50
    chunk_size: int | None = None
    mesh: Any = None
    shard_state: bool = False
    w_refresh: Any = None
    async_buffer: Any = None
    faults: Any = None
    robust: Any = None
    transport: Any = None
    topology: Any = None
    selection: Any = None
