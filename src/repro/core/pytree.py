"""Pytree utilities used across the federated runtime.

Stacked-client convention: a "client-stacked" pytree has every leaf with a
leading axis of size ``m`` (number of clients). The PS-side aggregation
rules in :mod:`repro.core.aggregation` operate on stacked trees.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def tree_stack(trees):
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, m: int):
    """Inverse of :func:`tree_stack`."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(m)]


def tree_ravel(tree):
    """Flatten a pytree to a 1-D vector; returns (vector, unravel_fn)."""
    return ravel_pytree(tree)


def tree_stacked_ravel(stacked):
    """Ravel a client-stacked tree to an (m, d) matrix.

    Returns (matrix, unravel_fn) where unravel_fn maps an (m, d) matrix back
    to the stacked tree.
    """
    leaves = jax.tree.leaves(stacked)
    m = leaves[0].shape[0]
    one = jax.tree.map(lambda x: x[0], stacked)
    _, unravel_one = ravel_pytree(one)
    mat = jax.vmap(lambda i: ravel_pytree(jax.tree.map(lambda x: x[i], stacked))[0])(
        jnp.arange(m)
    )

    def unravel(matrix):
        return jax.vmap(unravel_one)(matrix)

    return mat, unravel


def stacked_ravel(tree, lead: int = 1):
    """Ravel a tree whose leaves share ``lead`` leading axes into a matrix.

    Leaves (L0,..,L_{lead-1}, ...) are flattened and concatenated on the
    last axis -> (L0,..,L_{lead-1}, d). No unravel is provided; use this
    for similarity/distance computations only.
    """
    leaves = jax.tree.leaves(tree)
    head = leaves[0].shape[:lead]
    return jnp.concatenate(
        [l.reshape(head + (-1,)) for l in leaves], axis=-1
    )


def stacked_unravel(template, mat):
    """Inverse of :func:`stacked_ravel` (lead=1) against a template tree.

    ``mat`` is (r, d); the result has ``template``'s structure with every
    leaf's trailing shape and a leading axis of r (r need not match the
    template's leading axis — e.g. unraveling a cohort matrix against the
    full stacked state).
    """
    leaves, treedef = jax.tree.flatten(template)
    r = mat.shape[0]
    out, off = [], 0
    for leaf in leaves:
        size = math.prod(leaf.shape[1:])
        out.append(mat[:, off:off + size].reshape((r,) + leaf.shape[1:]))
        off += size
    return jax.tree.unflatten(treedef, out)


def gather_rows(tree, idx):
    """Select cohort rows from a client-stacked tree (leading axis m)."""
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), tree)


def scatter_rows(full, idx, updates):
    """Write cohort rows back; absent clients keep their previous rows.

    Out-of-range indices (the padded-cohort sentinel ``m``) are dropped,
    so pad slots never write.
    """
    return jax.tree.map(lambda f, u: f.at[idx].set(u, mode="drop"),
                        full, updates)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    """Inner product between two pytrees."""
    parts = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, parts)


def tree_sq_norm(a):
    return tree_dot(a, a)


def tree_weighted_sum(trees_stacked, w):
    """``out = sum_j w[j] * stacked[j]`` for a client-stacked tree."""
    return jax.tree.map(
        lambda x: jnp.tensordot(w, x, axes=([0], [0])), trees_stacked
    )


def tree_cast(a, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, a
    )


def tree_count_params(a) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(a))
