"""Communication/straggler timing model of §V-D.

Round time of a federated system with m clients served by m_t downlink
streams, parametrized by

  * ρ = T_ul / T_dl — UL/DL model-transmission-time asymmetry (base station
    transmits faster than edge devices; typical wireless ρ ∈ [2, 4]);
  * shifted-exponential per-client compute time
      P[T_i > t] = 1 − 1(t ≥ T_min)(1 − e^{−μ(t−T_min)}),
    whose m-way max has mean  T_comp = T_min + H_m / μ;
  * scheme — who transmits what:
      - "broadcast"      (FedAvg):        1 DL stream, m UL uploads
                                          (UL is parallel on orthogonal
                                          resources, so counted once);
      - "groupcast"      (clustered UCFL): m_t DL streams;
      - "unicast"        (full UCFL):      m DL streams;
      - "client_mixing"  (FedFomo):        every client downloads all m
                                           models ⇒ m DL streams *per
                                           client*; we charge m·T_dl like
                                           the paper's Fig. 5 does.

Partial participation: every cost function takes ``cohort_size`` (None =
full participation, the paper's regime). With a cohort of c clients the
straggler max runs over c compute times (H_c, not H_m), unicast needs c
streams, client mixing charges c downloads, and groupcast needs at most
min(m_t, c) distinct streams. This is what makes round cost O(cohort)
instead of O(m) on the wireless side.

Buffered-async rounds (``FedConfig.async_buffer``): the server applies
the pending uploads as soon as the K-th lands, so the wait term is the
K-th ORDER STATISTIC of the c shifted-exponential completion times —
``T_min + (H_c − H_{c−K})/μ`` in expectation — instead of the c-way max
``T_min + H_c/μ`` (:func:`expected_kth_compute_time`,
:func:`async_round_time`), and the downlink serves only the applied
batch. :func:`sample_arrival_times` draws per-client completion times
from the same shifted-exponential compute + ρ-asymmetric link model for
trace replays that want realized (not expected) arrivals.

Quantized wire transport (``FedConfig.transport``): a quantized stream
carries 1 B/param plus one float32 scale per chunk instead of 4 B/param.
Pricing is per STREAM via the strategy's declared wire schema
(:func:`wire_bytes` — duck-typed on ``.width``/``.coding`` so this
module stays numpy-only): ``delta`` and ``relay`` streams compress,
``raw`` streams ship 4 B/coordinate regardless of transport. Every
round-time/bytes function takes an optional ``schema``; the uplink AND
the downlink terms scale by the schema's compressed/raw byte ratio, so
a compressed broadcast (server-side EF) shrinks Tdl exactly like the
quantized upload shrinks Tul. ``schema=None`` prices the payload as one
single-delta model stream (``transport_ul_scale`` on the uplink, raw
downlink) — exactly what the deleted scalar ``transport_payload_bytes``
charged.

Per-tier link budgets (``SystemParams.tiers``, a :class:`TierParams`):
the two-tier topology (``FedConfig.topology``) splits every link price
into a client↔edge tier and an edge↔PS backhaul tier. The client↔edge
terms keep the flat ``t_dl``/``ρ·t_dl`` rates (edges are near the
clients); the backhaul adds ``backhaul_dl·t_dl`` per model transmission
(UL asymmetry ``backhaul_rho``), multiplied by a LOAD-DEPENDENT
congestion factor ``1 + congestion·(e_active − 1)`` on the PS links —
the more edges talk to the PS at once, the slower each PS link runs.
Only ``broadcast``/``groupcast`` schemes tier (per-client ``unicast`` /
``client_mixing`` mixes read every cohort column at the PS and do not
factorize over edge aggregates — they raise, matching the engine's
capability guard). The flat-equivalence contract, pinned by tests:
``tiers=None`` leaves every price byte-identical to the single-link
model, and so does the degenerate ``TierParams(backhaul_dl=0,
congestion=0)`` (a free backhaul collapses the two tiers into one).
What the topology buys is counted by :func:`ps_uplink_bytes_per_round` /
:func:`ps_downlink_bytes_per_round`: the PS-side backhaul carries
``e_active·k`` edge aggregates per round instead of ``c`` client
uploads.

TPU-adaptation note (DESIGN.md §2): on a pod these DL streams become ICI
collective volume; this module keeps the paper's analytic wireless model so
the Fig. 5 benchmark can be reproduced, while the measured ICI counterpart
lives in launch/roofline.py.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


def harmonic(m: int) -> float:
    return sum(1.0 / i for i in range(1, m + 1))


@dataclasses.dataclass(frozen=True)
class _FallbackStream:
    """Duck-typed single-delta stream for schema-less byte pricing.

    ``width`` may be fractional (``model_bytes / 4`` for a payload that
    is not 4-byte aligned) so the raw price round-trips to exactly
    ``model_bytes``; declared :class:`~repro.federated.transport.Stream`
    widths are always integers.
    """

    width: float
    coding: str = "delta"


@dataclasses.dataclass(frozen=True)
class _FallbackSchema:
    uplink: tuple
    downlink: tuple = ()


def _model_schema(model_bytes: int) -> _FallbackSchema:
    """Price a bare ``model_bytes`` payload as one delta model stream.

    Strategies without a declared wire schema upload exactly one model
    delta and download raw models, so the schema-less fallback is the
    single-stream schema with ``width = model_bytes/4`` float32
    coordinates (delta up, raw down) — :func:`wire_bytes` then
    reproduces the pre-schema scalar pricing exactly, including for
    payloads that are not 4-byte aligned (the width stays fractional and
    only the final byte total is ceiled).
    """
    w = int(model_bytes) / 4.0
    return _FallbackSchema(uplink=(_FallbackStream(w),),
                           downlink=(_FallbackStream(w, "raw"),))


def wire_bytes(schema, transport=None, direction: str = "uplink") -> int:
    """Bytes ONE transmission of a direction's declared streams costs.

    The ONE byte-pricing primitive (schema-less payloads route through
    it too, via :func:`_model_schema`): each stream of
    ``schema.uplink``/``schema.downlink`` is priced by its TRUE
    coordinate count and coding — ``raw`` streams (and every stream when
    ``transport`` is None) cost ``4·width`` (float32); quantized
    ``delta`` streams, and ``relay`` streams (whose payload some other
    hop already quantized), cost ``width + 4·ceil(width/chunk)``
    (1 B/coordinate + one f32 scale per chunk). Duck-typed on the
    stream's ``width``/``coding`` and the transport's ``chunk`` so this
    module stays numpy-only.

    A transmission is one emission of the direction's streams: per
    uploading client on the uplink; per downlink stream-slot (broadcast
    = 1, groupcast = m_t, unicast/client_mixing = per receiver) on the
    downlink — the scheme multiplicity lives in
    :func:`uplink_bytes_per_round` / :func:`downlink_bytes_per_round`.
    """
    streams = schema.uplink if direction == "uplink" else schema.downlink
    total = 0.0
    for s in streams:
        # declared Stream widths are ints; the schema-less fallback may
        # carry a fractional float32 width (unaligned model_bytes)
        w = s.width
        if transport is None or s.coding == "raw":
            total += 4 * w
        else:
            chunk = int(transport.chunk)
            if chunk <= 0:
                raise ValueError(
                    f"transport.chunk must be positive, got {chunk}")
            total += w + 4 * math.ceil(w / chunk)
    return int(math.ceil(total))


def _wire_scale(schema, transport, direction: str) -> float:
    """Compressed/raw byte ratio of a direction (1.0 when inapplicable)."""
    if schema is None:
        return transport_ul_scale(transport) if direction == "uplink" else 1.0
    raw = wire_bytes(schema, None, direction)
    if raw == 0:
        return 1.0
    return wire_bytes(schema, transport, direction) / raw


def transport_ul_scale(transport=None) -> float:
    """Multiplier on UL transmission time/bytes under ``transport``.

    ``(1 + 4/chunk) / 4`` — the asymptotic compressed/raw ratio of a
    quantized delta stream (exact when ``chunk`` divides the parameter
    count, which the slab layout's 128-lane alignment guarantees for
    the default chunk). ``None`` = 1.
    """
    if transport is None:
        return 1.0
    chunk = int(transport.chunk)
    if chunk <= 0:
        raise ValueError(f"transport.chunk must be positive, got {chunk}")
    return (1.0 + 4.0 / chunk) / 4.0


@dataclasses.dataclass(frozen=True)
class TierParams:
    """Edge↔PS backhaul budget for the two-tier topology.

    ``backhaul_dl`` is the PS→edge transmission time of one model in
    units of the client-tier ``t_dl`` (0 = free backhaul — the
    flat-equivalence degenerate); ``backhaul_rho`` the backhaul's UL/DL
    asymmetry (wired backhauls are usually symmetric, hence 1.0, unlike
    the wireless client tier's ρ≈4); ``congestion`` the load penalty γ —
    every PS link runs ``1 + γ·(e_active − 1)`` slower when ``e_active``
    edges transact simultaneously.
    """

    num_edges: int
    backhaul_dl: float = 0.25
    backhaul_rho: float = 1.0
    congestion: float = 0.0

    def __post_init__(self):
        if self.num_edges < 1:
            raise ValueError(f"num_edges must be >= 1, got {self.num_edges}")
        if self.backhaul_dl < 0 or self.backhaul_rho <= 0 or \
                self.congestion < 0:
            raise ValueError(
                "need backhaul_dl >= 0, backhaul_rho > 0, congestion >= 0; "
                f"got {self.backhaul_dl}, {self.backhaul_rho}, "
                f"{self.congestion}")


@dataclasses.dataclass(frozen=True)
class SystemParams:
    m: int  # number of clients
    rho: float = 4.0  # T_ul / T_dl
    t_dl: float = 1.0  # downlink transmission time of one model
    t_min: float = 1.0  # minimum compute time (in units of t_dl)
    inv_mu: float = 1.0  # mean extra straggler delay 1/μ (0 ⇒ reliable)
    tiers: TierParams | None = None  # edge↔PS budget; None = flat single-link


def _active(m: int, cohort_size: int | None) -> int:
    return m if cohort_size is None else max(1, min(cohort_size, m))


def _require_streams(num_streams, scheme: str) -> int:
    """Groupcast pricing is undefined without a stream count.

    A bare ``assert`` here would be stripped under ``python -O`` and the
    groupcast costs would silently misprice (``min(None, c)`` raising a
    TypeError at best) — this must stay a real runtime check.
    """
    if num_streams is None:
        raise ValueError(
            f"{scheme!r} pricing needs num_streams (the m_t downlink "
            "stream count); got None")
    return int(num_streams)


def _tier_streams(scheme: str, num_streams, served: int) -> int:
    """Downlink stream count k of a tiered round (broadcast/groupcast)."""
    if scheme == "broadcast":
        return 1
    if scheme == "groupcast":
        return min(_require_streams(num_streams, scheme), max(served, 1))
    raise ValueError(
        f"{scheme!r} does not tier: per-client unicast/client-mixing "
        "downlinks read every cohort column at the PS and cannot "
        "factorize over edge aggregates (SystemParams.tiers supports "
        "broadcast and groupcast schemes only — the same capability "
        "boundary as FedConfig.topology)")


def _tier_terms(p: SystemParams, scheme: str, num_streams, c: int,
                served: int, dl_scale: float, ul_scale: float):
    """(downlink, extra backhaul-uplink) time of a tiered round.

    The downlink is the PS→edge backhaul (k model streams, congested by
    the active-edge load) plus the edge→client last hop at the flat
    ``t_dl`` rate; the returned uplink term is the NEW edge→PS leg (k
    aggregates per edge link, congested) that rides on top of the flat
    client→edge upload. With ``backhaul_dl = 0`` both backhaul legs
    vanish and the round prices exactly like the flat single-link model
    — the flat-equivalence contract.
    """
    tiers = p.tiers
    e = min(tiers.num_edges, c)
    cf = 1.0 + tiers.congestion * max(e - 1, 0)
    t_bh = tiers.backhaul_dl * p.t_dl
    k = _tier_streams(scheme, num_streams, served)
    dl = k * (t_bh * cf + p.t_dl) * dl_scale
    ul_bh = k * tiers.backhaul_rho * t_bh * cf * ul_scale
    return dl, ul_bh


def expected_compute_time(p: SystemParams,
                          cohort_size: int | None = None) -> float:
    """E[max over the active clients] = T_min + H_c/μ for shifted exps."""
    if p.inv_mu == 0.0:
        return p.t_min
    return p.t_min + harmonic(_active(p.m, cohort_size)) * p.inv_mu


def round_time(p: SystemParams, scheme: str, num_streams: int | None = None,
               cohort_size: int | None = None, *,
               transport=None, schema=None) -> float:
    """Wall-clock time of one communication round under §V-D.

    ``cohort_size`` prices a partial-participation round: only the cohort
    computes (straggler max over c), and only the cohort is served on the
    downlink. ``transport`` (a quantized-wire config, None = raw f32)
    shrinks the UL transmission term — and, with ``schema`` (the
    strategy's wire schema), BOTH link terms by the per-direction
    compressed/raw byte ratio of :func:`wire_bytes`; ``schema=None``
    keeps the pre-schema pricing (UL by :func:`transport_ul_scale`,
    downlink full-precision). With ``p.tiers`` the link terms split into
    client↔edge + congested edge↔PS backhaul legs (see
    :func:`_tier_terms`); ``tiers=None`` is byte-identical to the flat
    single-link price.
    """
    c = _active(p.m, cohort_size)
    ul_scale = _wire_scale(schema, transport, "uplink")
    dl_scale = _wire_scale(schema, transport, "downlink")
    t_ul = p.rho * p.t_dl * ul_scale
    t_dl = p.t_dl * dl_scale
    t_comp = expected_compute_time(p, cohort_size)
    if p.tiers is not None:
        dl, ul_bh = _tier_terms(p, scheme, num_streams, c, c,
                                dl_scale, ul_scale)
        return dl + t_comp + t_ul + ul_bh
    if scheme == "broadcast":
        dl = t_dl
    elif scheme == "groupcast":
        dl = min(_require_streams(num_streams, scheme), c) * t_dl
    elif scheme == "unicast":
        dl = c * t_dl
    elif scheme == "client_mixing":  # FedFomo-style client-side aggregation
        dl = c * t_dl
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return dl + t_comp + t_ul


def deadline_round_time(p: SystemParams, scheme: str,
                        num_streams: int | None = None,
                        cohort_size: int | None = None, *,
                        deadline: float = math.inf, compute=None,
                        transport=None, schema=None):
    """:func:`round_time` with a straggler deadline; returns the price
    AND who got cut.

    The fault model's timeout (``FaultConfig.deadline``) is a PRICING
    fault: a client whose compute time exceeds ``deadline`` is dropped
    from the round (its upload never lands — the device round sees it as
    a mid-round drop), and the server stops waiting at the deadline
    instead of the straggler max.

    Args:
      p / scheme / num_streams / cohort_size: as :func:`round_time`.
      deadline: compute-time ceiling, in the same units as ``t_min``
        (``inf`` = no timeouts — bit-identical to :func:`round_time`).
      compute: optional (c,) realized per-client compute times (e.g.
        from :func:`sample_arrival_times`'s compute term). ``None`` uses
        the deterministic expected order-statistic profile — client k's
        time is the expected k-th smallest of c shifted exponentials
        (``expected_kth_compute_time``), whose max (k = c) is EXACTLY
        the ``H_c`` straggler mean :func:`round_time` charges, giving
        the deadline=inf bit-identity the regression test pins.

    Returns:
      ``(time, dropped)`` — the §V-D round price and the (c,) bool mask
      of clients cut by the deadline (ordered by the order-statistic
      profile when ``compute`` is None). With every client cut, no
      upload lands and no downlink is served (the round degrades to
      skip-round semantics: deadline wait + nothing).
    """
    c = _active(p.m, cohort_size)
    if compute is None:
        compute = np.array([expected_kth_compute_time(p, k, cohort_size)
                            for k in range(1, c + 1)])
    else:
        compute = np.asarray(compute, float)
        c = compute.shape[0]
    dropped = compute > deadline
    survivors = int((~dropped).sum())
    ul_scale = _wire_scale(schema, transport, "uplink")
    dl_scale = _wire_scale(schema, transport, "downlink")
    t_ul = p.rho * p.t_dl * ul_scale
    t_dl = p.t_dl * dl_scale
    if survivors == 0:
        # everyone timed out: the server waits out the deadline (or the
        # fastest client under an infinite one) and serves nobody
        return float(min(deadline, compute.min())), dropped
    t_comp = float(deadline) if dropped.any() else float(compute.max())
    if p.tiers is not None:
        dl, ul_bh = _tier_terms(p, scheme, num_streams, c, survivors,
                                dl_scale, ul_scale)
        return dl + t_comp + t_ul + ul_bh, dropped
    if scheme == "broadcast":
        dl = t_dl
    elif scheme == "groupcast":
        dl = min(_require_streams(num_streams, scheme), survivors) * t_dl
    elif scheme in ("unicast", "client_mixing"):
        dl = survivors * t_dl
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return dl + t_comp + t_ul, dropped


def sample_arrival_times(p: SystemParams, rng, cohort_size: int | None = None):
    """Draw per-client upload completion times for one round.

    Each active client downloads (``t_dl``), computes for a
    shifted-exponential ``T_min + Exp(1/μ)``, and uploads over the
    ρ-asymmetric link (``ρ·t_dl``); the returned (c,) array is when each
    upload lands at the PS. A buffered-async server flushes at the K-th
    smallest of these; the bulk-synchronous barrier waits for the max.

    Args:
      p: §V-D system parameters.
      rng: ``numpy.random.Generator``.
      cohort_size: active clients this round (None = all m).
    """
    c = _active(p.m, cohort_size)
    compute = np.full(c, p.t_min, float)
    if p.inv_mu > 0.0:
        compute = compute + rng.exponential(p.inv_mu, size=c)
    return p.t_dl + compute + p.rho * p.t_dl


def expected_kth_compute_time(p: SystemParams, k: int,
                              cohort_size: int | None = None) -> float:
    """E[k-th order statistic of the active clients' compute times].

    For c iid shifted exponentials the k-th smallest has mean
    ``T_min + (H_c − H_{c−k})/μ`` (partial sums of the exponential
    spacings); ``k = c`` recovers :func:`expected_compute_time`'s
    straggler max ``T_min + H_c/μ``.
    """
    c = _active(p.m, cohort_size)
    k = max(1, min(int(k), c))
    if p.inv_mu == 0.0:
        return p.t_min
    tail = harmonic(c - k) if k < c else 0.0
    return p.t_min + (harmonic(c) - tail) * p.inv_mu


def async_round_time(p: SystemParams, scheme: str,
                     num_streams: int | None = None,
                     cohort_size: int | None = None, *, flush_k: int,
                     applied: int | None = None,
                     transport=None, schema=None) -> float:
    """Wall-clock §V-D price of one buffered-async round.

    Same ``dl + compute + ul`` structure as :func:`round_time`, with two
    substitutions: the server stops waiting at the ``flush_k``-th
    arrival (the K-th order statistic of the c active compute times, not
    the straggler max), and the downlink serves only the APPLIED batch:

      * ``applied`` is how many uploads the flush shipped back (the
        buffer may hold more than K when earlier rounds deposited
        without flushing); ``None`` means exactly the flush threshold.
      * ``applied=0`` prices a deposit-only round: nothing is served
        (dl = 0) but the round still spans the arrivals it banked — the
        full c-way max, like a barrier round without its downlink.
      * ``flush_k >= c`` with ``applied = c`` degrades to
        :func:`round_time` exactly, so async pricing is never optimistic
        on availability-starved rounds.

    Strictly below :func:`round_time` whenever ``flush_k < c`` and
    stragglers exist (``inv_mu > 0``) — the trade the paper's Fig. 5
    studies, bought at the accuracy cost of staleness-discounted
    aggregation.
    """
    c = _active(p.m, cohort_size)
    # the async UPLINK compresses per schema like the barrier round; the
    # async DOWNLINK stays raw f32 (a flush rewrites arbitrary row
    # subsets — no per-receiver reference to delta-code against), so the
    # dl terms below deliberately keep the raw t_dl
    ul_scale = _wire_scale(schema, transport, "uplink")
    t_ul = p.rho * p.t_dl * ul_scale
    if applied is not None and applied <= 0:
        return expected_compute_time(p, cohort_size) + t_ul
    b = min(min(int(flush_k), c) if applied is None else int(applied), p.m)
    t_comp = expected_kth_compute_time(p, min(int(flush_k), c), cohort_size)
    if p.tiers is not None:
        # the raw async downlink tiers too (dl_scale 1.0); the flush's
        # applied batch sets the served stream count on both backhaul legs
        dl, ul_bh = _tier_terms(p, scheme, num_streams, c, b, 1.0, ul_scale)
        return dl + t_comp + t_ul + ul_bh
    if scheme == "broadcast":
        dl = p.t_dl
    elif scheme == "groupcast":
        dl = min(_require_streams(num_streams, scheme), b) * p.t_dl
    elif scheme in ("unicast", "client_mixing"):
        dl = b * p.t_dl
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return dl + t_comp + t_ul


def rounds_to_time(p: SystemParams, scheme: str, num_rounds: int,
                   num_streams: int | None = None,
                   cohort_size: int | None = None, *, transport=None,
                   schema=None):
    """Cumulative time axis (length num_rounds) for accuracy-vs-time plots."""
    rt = round_time(p, scheme, num_streams, cohort_size, transport=transport,
                    schema=schema)
    return [rt * (t + 1) for t in range(num_rounds)]


def downlink_bytes_per_round(model_bytes: int, scheme: str, m: int,
                             num_streams: int | None = None,
                             cohort_size: int | None = None, *,
                             transport=None, schema=None) -> int:
    """DL payload per round — the wireless quantity the paper trades.

    One downlink transmission costs ``model_bytes`` raw, or the schema's
    per-stream :func:`wire_bytes` when the strategy declares one (a
    compressed ``delta`` broadcast with server-side EF is cheaper than
    raw; a ``raw``-coded downlink like the clustered centroids is not);
    the scheme then sets how many transmissions a round needs.
    """
    c = _active(m, cohort_size)
    unit = (wire_bytes(schema, transport, "downlink")
            if schema is not None else int(model_bytes))
    if scheme == "broadcast":
        return unit
    if scheme == "groupcast":
        return min(_require_streams(num_streams, scheme), c) * unit
    if scheme in ("unicast", "client_mixing"):
        return c * unit
    raise ValueError(f"unknown scheme {scheme!r}")


def uplink_bytes_per_round(model_bytes: int, scheme: str, m: int,
                           cohort_size: int | None = None, *,
                           transport=None, schema=None) -> int:
    """UL payload per round: every active client uploads ONE model.

    This holds for every scheme — broadcast/groupcast/unicast servers and
    FedFomo-style client mixing all consume exactly one locally-updated
    model per participant (``ucfl_parallel`` is the deliberate exception,
    the §V-E upper bound, and is priced by its own m× factor elsewhere).
    The streaming W refresh (``FedConfig.w_refresh``) re-estimates Δ/σ²
    from these same c uploads, so refreshed and stale-W runs have
    IDENTICAL per-round uplink bytes — pinned by a regression test.

    ``transport`` prices the quantized wire per client (1 B/param + one
    f32 scale per chunk); ``None`` is the raw float32 payload,
    unchanged. With a ``schema`` the per-client unit is the schema's
    per-stream :func:`wire_bytes` — SCAFFOLD's two-stream upload
    honestly costs twice a model, quantized or not; without one the
    payload prices as a single delta model stream (the same
    :func:`wire_bytes` path, see :func:`_model_schema`).
    """
    if scheme not in ("broadcast", "groupcast", "unicast", "client_mixing"):
        raise ValueError(f"unknown scheme {scheme!r}")
    unit = wire_bytes(schema if schema is not None
                      else _model_schema(model_bytes), transport, "uplink")
    return _active(m, cohort_size) * unit


def ps_uplink_bytes_per_round(model_bytes: int, scheme: str, m: int,
                              num_streams: int | None = None,
                              cohort_size: int | None = None, *,
                              num_edges: int | None = None,
                              transport=None, schema=None) -> int:
    """Edge↔PS uplink bytes — the backhaul the two-tier engine relieves.

    Flat (``num_edges=None``): every client upload transits the PS link,
    so this equals :func:`uplink_bytes_per_round`. Tiered: each of the
    ``e = min(num_edges, c)`` active edges ships its tier-1 aggregates
    once — ``k`` model-sized streams for a k-stream groupcast policy,
    one for broadcast — so the PS ingests ``e·k`` units instead of
    ``c``. That ``c / (e·k)`` ratio is the hierarchical replay's
    headline metric.
    """
    unit = wire_bytes(schema if schema is not None
                      else _model_schema(model_bytes), transport, "uplink")
    c = _active(m, cohort_size)
    if num_edges is None:
        if scheme not in ("broadcast", "groupcast", "unicast",
                          "client_mixing"):
            raise ValueError(f"unknown scheme {scheme!r}")
        return c * unit
    e = min(int(num_edges), c)
    return e * _tier_streams(scheme, num_streams, c) * unit


def ps_downlink_bytes_per_round(model_bytes: int, scheme: str, m: int,
                                num_streams: int | None = None,
                                cohort_size: int | None = None, *,
                                num_edges: int | None = None,
                                transport=None, schema=None) -> int:
    """Edge↔PS downlink bytes (PS egress over the backhaul links).

    Flat: equals :func:`downlink_bytes_per_round`. Tiered: the PS sends
    each active edge the round's ``k`` downlink streams once
    (``e·k`` units) and the edges fan out to their clients over the
    client tier — broadcast replication across e backhaul links can make
    this LARGER than the flat single broadcast; the topology's win is
    the uplink counter above, and reporting both keeps the replay
    honest.
    """
    unit = wire_bytes(schema if schema is not None
                      else _model_schema(model_bytes), transport, "downlink")
    c = _active(m, cohort_size)
    if num_edges is None:
        return downlink_bytes_per_round(
            model_bytes, scheme, m, num_streams, cohort_size,
            transport=transport, schema=schema)
    e = min(int(num_edges), c)
    return e * _tier_streams(scheme, num_streams, c) * unit


def ici_collective_bytes(model_bytes: int, scheme: str, m: int,
                         num_streams: int | None = None,
                         cohort_size: int | None = None) -> int:
    """TPU counterpart: mixing-collective volume over the client axis.

    FedAvg  = all-reduce           ≈ 2·model_bytes (ring),
    UCFL    = all-gather + local mix ≈ (m−1)/m·m·model_bytes ≈ m·model_bytes,
    cluster = m_t weighted reduce+bcast ≈ 2·m_t·model_bytes.
    These closed forms are sanity checks for the HLO-parsed numbers in
    launch/roofline.py.
    """
    c = _active(m, cohort_size)
    if scheme == "broadcast":
        return 2 * model_bytes
    if scheme == "groupcast":
        return 2 * min(_require_streams(num_streams, scheme), c) * model_bytes
    if scheme in ("unicast", "client_mixing"):
        return c * model_bytes
    raise ValueError(f"unknown scheme {scheme!r}")
