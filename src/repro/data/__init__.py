from repro.data import loader, synthetic  # noqa: F401
from repro.data.synthetic import SCENARIOS, FederatedData  # noqa: F401
