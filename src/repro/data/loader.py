"""Jit-friendly federated batching.

``epoch_batches`` reshapes each client's (n, ...) arrays into
(steps, B, ...) after a per-epoch shuffle, so the local-update scan can
iterate over the leading axis. Stacked over clients it becomes
(m, steps, B, ...), consumed by the vmapped client update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def epoch_batches(key, x, y, batch_size):
    """Shuffle one client's data and split into full batches."""
    n = x.shape[0]
    steps = n // batch_size
    perm = jax.random.permutation(key, n)[: steps * batch_size]
    xb = x[perm].reshape((steps, batch_size) + x.shape[1:])
    yb = y[perm].reshape((steps, batch_size) + y.shape[1:])
    return xb, yb


def federated_epoch_batches(key, x, y, batch_size):
    """Stacked version: x (m, n, ...), y (m, n) -> (m, steps, B, ...)."""
    m = x.shape[0]
    keys = jax.random.split(key, m)
    return jax.vmap(lambda k, xc, yc: epoch_batches(k, xc, yc, batch_size))(
        keys, x, y
    )


def fixed_partition(x, y, batch_size):
    """Deterministic split into minibatches (Eq. 10 variance estimation)."""
    n = x.shape[0]
    steps = n // batch_size
    xb = x[: steps * batch_size].reshape((steps, batch_size) + x.shape[1:])
    yb = y[: steps * batch_size].reshape((steps, batch_size) + y.shape[1:])
    return xb, yb
