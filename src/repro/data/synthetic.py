"""Procedural federated datasets replicating the paper's heterogeneity.

The container is offline (no EMNIST/CIFAR), so we synthesize learnable
image-classification tasks with the SAME heterogeneity mechanisms as §V-A:

  * label shift      — per-client class proportions ~ Dirichlet(α);
  * covariate shift  — client groups see inputs rotated by {0,90,180,270}°
                       (exact jnp.rot90, like the paper's EMNIST rotation);
  * concept shift    — client groups use different label permutations
                       (CIFAR scenario).

Samples are class-prototype images (smooth low-frequency patterns,
upsampled) plus Gaussian pixel noise, so LeNet-5 can separate classes but
noise/rotation/permutation create exactly the transfer structure the paper
studies. A "rotation-invariant subset" of prototypes (symmetric patterns)
reproduces the paper's observation that some characters are invariant to
180° rotation, enabling inter-cluster collaboration (Fig. 3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FederatedData(NamedTuple):
    x: jax.Array  # (m, n, H, W, C)
    y: jax.Array  # (m, n) int32
    x_test: jax.Array  # (m, n_test, H, W, C)
    y_test: jax.Array  # (m, n_test) int32
    group: jax.Array  # (m,) int32 — ground-truth heterogeneity group
    n: jax.Array  # (m,) int32 — local dataset sizes (all equal here)

    @property
    def num_clients(self):
        return self.x.shape[0]


def make_prototypes(key, num_classes, hw=(28, 28), channels=1, *,
                    symmetric_frac=0.3):
    """Smooth class prototypes; a fraction are made 180°-symmetric."""
    h, w = hw
    k1, k2 = jax.random.split(key)
    low = jax.random.normal(k1, (num_classes, 7, 7, channels))
    proto = jax.image.resize(low, (num_classes, h, w, channels), "bicubic")
    proto = proto / (jnp.std(proto, axis=(1, 2, 3), keepdims=True) + 1e-6)
    n_sym = int(num_classes * symmetric_frac)
    if n_sym:
        sym = 0.5 * (proto[:n_sym] + jnp.rot90(jnp.rot90(proto[:n_sym], axes=(1, 2)), axes=(1, 2)))
        proto = proto.at[:n_sym].set(sym)
    return proto


def _dirichlet_labels(key, m, n, num_classes, alpha):
    """Per-client labels with Dirichlet(α) class proportions."""
    k1, k2 = jax.random.split(key)
    props = jax.random.dirichlet(k1, alpha * jnp.ones((num_classes,)), (m,))
    keys = jax.random.split(k2, m)
    sample = lambda k, p: jax.random.choice(k, num_classes, (n,), p=p)
    return jax.vmap(sample)(keys, props)


def _render(key, proto, labels, noise=0.8):
    """x = prototype[y] + noise; labels (..., n)."""
    eps = jax.random.normal(key, labels.shape + proto.shape[1:])
    return proto[labels] + noise * eps


def _rotate_groups(x, group):
    """Rotate each client's images by 90°·group (exact)."""
    def rot_client(xc, g):
        r0 = xc
        r1 = jnp.rot90(xc, 1, axes=(1, 2))
        r2 = jnp.rot90(xc, 2, axes=(1, 2))
        r3 = jnp.rot90(xc, 3, axes=(1, 2))
        return jnp.select(
            [g == 0, g == 1, g == 2, g == 3], [r0, r1, r2, r3], r0
        )
    return jax.vmap(rot_client)(x, group)


def label_shift(key, *, m=20, n=500, n_test=100, num_classes=47,
                alpha=0.4, hw=(28, 28), channels=1, noise=0.8):
    """Scenario 1 — EMNIST-like user-dependent label shift (α=0.4)."""
    kp, kl, kx, klt, kxt = jax.random.split(key, 5)
    proto = make_prototypes(kp, num_classes, hw, channels)
    y = _dirichlet_labels(kl, m, n, num_classes, alpha)
    y_test = _dirichlet_labels(klt, m, n_test, num_classes, alpha)
    x = _render(kx, proto, y, noise)
    x_test = _render(kxt, proto, y_test, noise)
    group = jnp.zeros((m,), jnp.int32)
    nvec = jnp.full((m,), n, jnp.int32)
    return FederatedData(x, y, x_test, y_test, group, nvec)


def covariate_label_shift(key, *, m=100, n=1000, n_test=100, num_classes=47,
                          alpha=8.0, groups=4, hw=(28, 28), channels=1,
                          noise=0.8):
    """Scenario 2 — label shift (α=8) + group rotations {0,90,180,270}°."""
    base = label_shift(key, m=m, n=n, n_test=n_test, num_classes=num_classes,
                       alpha=alpha, hw=hw, channels=channels, noise=noise)
    group = jnp.arange(m, dtype=jnp.int32) % groups
    x = _rotate_groups(base.x, group)
    x_test = _rotate_groups(base.x_test, group)
    return base._replace(x=x, x_test=x_test, group=group)


def concept_shift(key, *, m=20, n=500, n_test=100, num_classes=10,
                  groups=4, hw=(32, 32), channels=3, noise=0.6):
    """Scenario 3 — CIFAR-like group-dependent label permutation."""
    kperm, kbase = jax.random.split(key)
    base = label_shift(kbase, m=m, n=n, n_test=n_test,
                       num_classes=num_classes, alpha=100.0, hw=hw,
                       channels=channels, noise=noise)
    group = jnp.arange(m, dtype=jnp.int32) % groups
    perms = jnp.stack([
        jax.random.permutation(k, num_classes)
        for k in jax.random.split(kperm, groups)
    ])  # (groups, C)
    y = jax.vmap(lambda yc, g: perms[g][yc])(base.y, group).astype(jnp.int32)
    y_test = jax.vmap(lambda yc, g: perms[g][yc])(base.y_test, group).astype(jnp.int32)
    return base._replace(y=y, y_test=y_test, group=group)


SCENARIOS = {
    "label_shift": label_shift,
    "covariate_label_shift": covariate_label_shift,
    "concept_shift": concept_shift,
}
