"""Synthetic heterogeneous LM data for the end-to-end training driver.

Each heterogeneity group g owns a hidden permutation π_g over the vocab;
sequences follow x_{t+1} = π_g(x_t) with probability (1−ε), else uniform
noise. A model can reach low loss only by learning its group's chain —
giving the transformer-zoo trainer the same conflicting-task structure as
the paper's concept-shift scenario (per-group label permutation), so the
user-centric weights have real signal to find.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_group_chains(key, groups: int, vocab: int):
    return jnp.stack([
        jax.random.permutation(k, vocab)
        for k in jax.random.split(key, groups)
    ])  # (groups, vocab)


def sample_sequences(key, chain, batch: int, seq: int, *, noise: float = 0.05):
    """Markov-chain sequences under one permutation chain (vocab,)."""
    vocab = chain.shape[0]
    k0, kn, kr = jax.random.split(key, 3)
    x0 = jax.random.randint(k0, (batch,), 0, vocab)

    def step(x, ks):
        knoise, krand = jax.random.split(ks)
        nxt = chain[x]
        rand = jax.random.randint(krand, x.shape, 0, vocab)
        use_noise = jax.random.uniform(knoise, x.shape) < noise
        nxt = jnp.where(use_noise, rand, nxt)
        return nxt, nxt

    _, seqs = jax.lax.scan(step, x0, jax.random.split(kn, seq))
    return jnp.moveaxis(seqs, 0, 1)  # (batch, seq)


def federated_lm_batch(key, chains, m: int, batch: int, seq: int, *,
                       noise: float = 0.05):
    """(m, batch, seq+1) tokens; client i uses chain i % groups."""
    groups = chains.shape[0]
    keys = jax.random.split(key, m)
    seqs = jnp.stack([
        sample_sequences(keys[i], chains[i % groups], batch, seq + 1,
                         noise=noise)
        for i in range(m)
    ])
    return {"tokens": seqs[:, :, :-1], "labels": seqs[:, :, 1:]}
