"""Msgpack checkpointing for pytrees of jax/numpy arrays.

Crash safety: :func:`save` is atomic — the payload is written to a
uniquely-named temp file in the target directory, flushed AND fsynced to
disk, then ``os.replace``d over the destination (POSIX rename atomicity),
and finally the directory entry itself is fsynced. A run killed at ANY
point therefore leaves either the previous complete checkpoint or the
new complete checkpoint, never a truncated hybrid; at worst an orphaned
``.tmp.*`` file remains, which :func:`restore` never looks at.
"""
from __future__ import annotations

import os
import uuid

import jax
import msgpack
import numpy as np


def _encode(obj):
    if isinstance(obj, (np.ndarray, np.generic)):
        return {
            b"__nd__": True,
            b"dtype": str(obj.dtype),
            b"shape": list(np.shape(obj)),
            b"data": np.ascontiguousarray(obj).tobytes(),
        }
    return obj


def _decode(obj):
    if isinstance(obj, dict) and obj.get(b"__nd__"):
        arr = np.frombuffer(obj[b"data"], dtype=np.dtype(obj[b"dtype"].decode()
                            if isinstance(obj[b"dtype"], bytes) else obj[b"dtype"]))
        return arr.reshape(obj[b"shape"])
    return obj


def save(path: str, tree) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [np.asarray(l) for l in leaves],
    }
    # unique temp name: two concurrent savers (or a crashed one's
    # leftover) can never clobber each other's half-written payload
    tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    try:
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(payload, default=_encode))
            f.flush()
            os.fsync(f.fileno())  # data durable BEFORE the rename
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    # fsync the directory so the rename itself survives a power cut
    dirfd = os.open(os.path.dirname(os.path.abspath(path)), os.O_RDONLY)
    try:
        os.fsync(dirfd)
    finally:
        os.close(dirfd)


def restore(path: str, like):
    """Restore into the structure of ``like`` (shapes/dtypes verified)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_decode,
                                  strict_map_key=False)
    leaves, treedef = jax.tree.flatten(like)
    saved = payload["leaves"]
    if len(saved) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(saved)} leaves, expected {len(leaves)}"
        )
    out = []
    for l, s in zip(leaves, saved):
        if tuple(np.shape(s)) != tuple(np.shape(l)):
            raise ValueError(f"shape mismatch {np.shape(s)} vs {np.shape(l)}")
        out.append(np.asarray(s).astype(l.dtype))
    return jax.tree.unflatten(treedef, out)
