"""Device mesh for sharding the cohort/client axis across devices.

``chunk_size`` bounds single-host memory by running local SGD as a
*sequential* ``lax.map`` over chunks — cohort wall-time grows linearly
with cohort size even when devices sit idle. This module adds the
*parallel* scale axis: a 1-D :class:`jax.sharding.Mesh` over a
``clients`` axis partitions the padded cohort slots across devices, so
a cohort of c slots runs local SGD as ``num_shards`` concurrent blocks
of ``c / num_shards`` slots (each block still chunked by ``chunk_size``
*within* its shard — the two knobs compose).

Three arrays ride the cohort axis and share one sharding
(:func:`slot_sharding`): the padded ``Cohort(indices, mask)`` slot
arrays, the per-slot client-indexed PRNG key batch, and the raveled
(c, d) update slab. The (m, d) stacked state and the (c, c) per-slot
mix rules stay replicated: the per-slot updates are all-gathered right
after local SGD (inside :func:`shard_clients`, used by
``repro.federated.client.client_vmap``) and the mix + fused
``masked_mix_scatter`` then run identically on every device's
host-local copy of the (m, d) state — the mix is tiny next to local
SGD, and keeping it replicated preserves the donation/aliasing story of
the unsharded engine unchanged.

Shape contract: ``shard_map`` requires the slot count to divide evenly
across shards, so :func:`pad_cohort` rounds every cohort up to the next
multiple of ``num_shards(mesh)`` with sentinel slots (index m, mask
False) — the exact padding the fixed-shape engine already treats as
bit-invisible (zero weight in every masked rule, dropped by the
scatter, client-indexed PRNG keys). A policy's slot count is static, so
the padded count is static too and the one-compilation guarantee
survives under a fixed mesh.

Running multi-device on CPU (no accelerator required)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    JAX_PLATFORMS=cpu python ...

forces 8 host devices (set *before* the first jax import);
``FedConfig(mesh=8)`` — or ``mesh="auto"`` for all local devices — then
shards every cohort round 8 ways. This is how CI exercises the mesh
code path on every PR (the ``multi-device`` job).
"""
from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.federated import participation

AXIS = "clients"

if hasattr(jax, "shard_map"):  # jax >= 0.6 top-level API
    _shard_map = jax.shard_map
else:  # jax 0.4/0.5: experimental API
    from jax.experimental.shard_map import shard_map as _shard_map
# The all-gathered outputs are replicated, but the static replication
# checker cannot infer that through lax.all_gather — disable it. The
# kwarg was renamed check_rep -> check_vma independently of the API's
# promotion to jax.shard_map, so pick the spelling off the actual
# signature rather than the module location.
_RELAX = {("check_vma" if "check_vma"
           in inspect.signature(_shard_map).parameters
           else "check_rep"): False}


def client_mesh(num_shards: int | None = None, *, devices=None):
    """Build the 1-D ``clients`` mesh over the first ``num_shards`` devices."""
    if devices is None:
        devices = jax.devices()
    if num_shards is None:
        num_shards = len(devices)
    if not 1 <= int(num_shards) <= len(devices):
        raise ValueError(
            f"need 1 <= num_shards <= {len(devices)} local devices, "
            f"got {num_shards}")
    return jax.sharding.Mesh(np.asarray(devices[:int(num_shards)]), (AXIS,))


def resolve(mesh):
    """Normalize the ``FedConfig.mesh`` knob to a Mesh (or None).

    Accepts ``None`` (sharding off), a 1-D :class:`jax.sharding.Mesh`
    whose single axis enumerates clients, an int shard count, or
    ``"auto"`` (all local devices).
    """
    if mesh is None:
        return None
    if isinstance(mesh, jax.sharding.Mesh):
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"client mesh must be 1-D, got axes {mesh.axis_names}")
        return mesh
    if mesh == "auto":
        return client_mesh()
    return client_mesh(int(mesh))


def num_shards(mesh) -> int:
    return int(mesh.devices.size)


def _axis(mesh) -> str:
    return mesh.axis_names[0]


def slot_sharding(mesh) -> NamedSharding:
    """Sharding of every per-slot array: cohort ``indices``/``mask``, the
    per-slot key batch, and the raveled (c, d) update slab — axis 0
    partitioned across the mesh."""
    return NamedSharding(mesh, P(_axis(mesh)))


def replicated_sharding(mesh) -> NamedSharding:
    """Sharding of the (m, d) stacked state and the (c, c) mix rules."""
    return NamedSharding(mesh, P())


def pad_to_shards(slots: int, shards: int) -> int:
    """Round a slot count up to the next multiple of the shard count."""
    return -(-int(slots) // int(shards)) * int(shards)


def pad_cohort(cohort: participation.Cohort, mesh,
               m: int) -> participation.Cohort:
    """Pad a cohort's slot count to a multiple of the mesh's shard count.

    The extra slots are sentinel pads (index ``m``, mask False) — bit-
    invisible to the masked engine. No-op when already divisible (in
    particular for a 1-device mesh).
    """
    return participation.pad_slots(
        cohort, pad_to_shards(cohort.num_slots, num_shards(mesh)), m)


def commit_replicated(tree, mesh):
    """Commit every ``jax.Array`` leaf of ``tree`` to the replicated
    sharding of ``mesh``.

    The sharded round's outputs are replicated over the mesh, so from
    round 2 on the state enters the jitted round replicated-committed.
    Committing the *initial* state the same way keeps every call's input
    shardings identical — without this, the steady-state input sharding
    first appears on round 2 and jit compiles the round a second time
    inside the timed region (the cohort dispatcher calls this; it is a
    copy-free no-op once the state is already committed). Host (numpy)
    leaves — e.g. CFL's cluster bookkeeping — are untouched.
    """
    sh = replicated_sharding(mesh)
    return jax.tree.map(
        lambda x: jax.device_put(x, sh) if isinstance(x, jax.Array) else x,
        tree)


# ------------------------------------------------------- row-sharded state
#
# The replicated engine keeps the whole (m, d) stacked state on every
# device. The row-sharded layout partitions the LEADING (client) axis of
# every state leaf across the ``clients`` mesh instead — device k owns
# rows [k·m/s, (k+1)·m/s) — so server memory AND per-round bandwidth
# scale down with the device count. Every cohort row is routed to its
# owner shard inside a shard_map body: ownership of slot i on device k
# is ``lo <= idx[i] < lo + m/s`` (lo = k·m/s); non-owned slots are
# localized to the per-block sentinel m/s, which the sentinel-drop
# scatter contract already treats as a pad. The cohort gather is a
# (c, d) psum of one-hot-owned rows and the scatter/mix write only the
# owner block, so the only model-sized collectives are O(c·d) — never
# O(m·d). Opt in via ``FedConfig.shard_state`` (requires a mesh);
# ``mesh=None`` and the replicated layout stay bit-exact.


def row_sharding(mesh) -> NamedSharding:
    """Sharding of a row-sharded (m, ·) state leaf: leading axis
    partitioned across the ``clients`` mesh."""
    return NamedSharding(mesh, P(_axis(mesh)))


def commit_rows(tree, mesh):
    """Commit every ``jax.Array`` leaf of ``tree`` to the row sharding.

    The row-sharded round's state outputs carry this sharding already
    (shard_map out_specs), so — exactly like :func:`commit_replicated` —
    this is a copy-free no-op from round 2 on; committing the initial
    state keeps every call's input shardings identical and preserves the
    one-compilation guarantee. Host (numpy) leaves are untouched.
    """
    s = num_shards(mesh)
    sh = row_sharding(mesh)

    def put(x):
        if not isinstance(x, jax.Array):
            return x
        if x.shape[0] % s:
            raise ValueError(
                f"row-sharded state needs a leading axis divisible by the "
                f"{s}-device mesh, got shape {x.shape} (pad m to a shard "
                f"multiple or drop FedConfig.shard_state)")
        return jax.device_put(x, sh)

    return jax.tree.map(put, tree)


def constrain_rows(tree, mesh):
    """Pin a traced (m, ·) tree to the row sharding inside jit.

    Used where a strategy's state output is produced by plain jnp ops
    (e.g. SCAFFOLD's broadcast server control) rather than a shard_map —
    without the constraint the round's output sharding could differ from
    the committed input sharding and trigger a recompile on round 2.
    """
    sh = row_sharding(mesh)
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, sh), tree)


def _localize(idx, mb: int, axis: str):
    """Map global row ids to block-local ids on the current shard.

    Returns ``(loc, own)``: ``own`` marks the slots this shard owns and
    ``loc`` is their block-local row (non-owned slots — including the
    global sentinel m — get the local sentinel mb, dropped by every
    ``mode="drop"`` scatter).
    """
    lo = jax.lax.axis_index(axis) * mb
    own = (idx >= lo) & (idx < lo + mb)
    return jnp.where(own, idx - lo, mb).astype(idx.dtype), own


def shard_gather_rows(tree, safe, mesh):
    """Cohort gather from a row-sharded state: each device contributes
    the rows it owns (zeros elsewhere) and a (c, d)-sized psum assembles
    the replicated cohort — O(c·d) traffic, never O(m·d). ``safe`` must
    be pre-clamped (``aggregation.safe_gather_index``), matching the
    replicated ``jnp.take`` semantics exactly."""
    axis = _axis(mesh)

    def body(block, safe):
        mb = jax.tree.leaves(block)[0].shape[0]
        lo = jax.lax.axis_index(axis) * mb
        own = (safe >= lo) & (safe < lo + mb)
        loc = jnp.clip(safe - lo, 0, mb - 1)
        part = jax.tree.map(
            lambda b: jnp.where(
                own.reshape((-1,) + (1,) * (b.ndim - 1)),
                jnp.take(b, loc, axis=0), 0), block)
        return jax.tree.map(lambda x: jax.lax.psum(x, axis), part)

    return _shard_map(body, mesh=mesh, in_specs=(P(axis), P()),
                      out_specs=P(), **_RELAX)(tree, safe)


def shard_scatter_rows(tree, idx, updates, mesh):
    """Cohort scatter into a row-sharded state: each device writes only
    the rows it owns (localized indices; non-owned and pad slots drop on
    the local sentinel). No collective at all — the (c, d) updates are
    already replicated."""
    axis = _axis(mesh)

    def body(block, idx, updates):
        mb = jax.tree.leaves(block)[0].shape[0]
        loc, _ = _localize(idx, mb, axis)
        return jax.tree.map(
            lambda b, u: b.at[loc].set(u.astype(b.dtype), mode="drop"),
            block, updates)

    return _shard_map(body, mesh=mesh, in_specs=(P(axis), P(), P()),
                      out_specs=P(axis), **_RELAX)(tree, idx, updates)


def shard_block_update(fn, mesh, *, gather_args=0):
    """Run a masked row-rewrite on each shard's block of the state.

    Builds ``update(tree, idx, mask, *args) -> tree'`` where ``fn(block,
    loc_idx, loc_mask, *args)`` rewrites one device's (m/s, ·) row block;
    ``idx``/``mask`` are localized per shard (non-owned slots get the
    local sentinel / a False mask, so the fused masked kernels and
    ``mode="drop"`` scatters apply unchanged per block) and ``*args``
    stay replicated — except the first ``gather_args`` of them, which
    enter ROW-SHARDED and are all-gathered (tiled) inside the body
    before ``fn`` sees them (the buffered-async flush passes its sharded
    (B, d) pending-upload shard this way: that gather is the flush's one
    model-sized collective).
    """
    axis = _axis(mesh)

    def update(tree, idx, mask, *args):
        def body(block, idx, mask, *args):
            mb = jax.tree.leaves(block)[0].shape[0]
            loc, own = _localize(idx, mb, axis)
            args = tuple(
                jax.lax.all_gather(a, axis, axis=0, tiled=True)
                if i < gather_args else a
                for i, a in enumerate(args))
            return fn(block, loc, mask & own, *args)

        specs = tuple(P(axis) if i < gather_args else P()
                      for i in range(len(args)))
        return _shard_map(body, mesh=mesh,
                          in_specs=(P(axis), P(), P()) + specs,
                          out_specs=P(axis), **_RELAX)(tree, idx, mask,
                                                       *args)

    return update


def shard_broadcast_rows(full, mixed, alive, mesh):
    """FedAvg-family broadcast into a row-sharded state: every device
    rewrites its block with the replicated (1, ·) mix; ``alive`` False
    (an all-masked cohort) keeps the previous block instead."""
    axis = _axis(mesh)

    def body(block, mixed, alive):
        return jax.tree.map(
            lambda x, p: jnp.where(
                alive, jnp.broadcast_to(x, (p.shape[0],) + x.shape[1:]), p),
            mixed, block)

    return _shard_map(body, mesh=mesh, in_specs=(P(axis), P(), P()),
                      out_specs=P(axis), **_RELAX)(full, mixed, alive)


def all_gather_rows(x, mesh):
    """Replicate a row-sharded array: tiled all_gather over the leading
    axis (the buffered-async flush's one model-sized collective)."""
    axis = _axis(mesh)
    return _shard_map(
        lambda b: jax.lax.all_gather(b, axis, axis=0, tiled=True),
        mesh=mesh, in_specs=P(axis), out_specs=P(), **_RELAX)(x)


def shard_clients(fn, mesh):
    """shard_map ``fn`` over the leading client/slot axis of every arg.

    Each device receives its contiguous block of rows and runs ``fn`` on
    it; the per-row outputs are all-gathered (tiled) back to full
    arrays, so callers downstream — the (c, c) mix, the fused scatter —
    see replicated values and need no sharding awareness. This is the
    "mix after an all-gather of the (c, d) updates" step of the sharded
    round. Row order is preserved and per-row computation is
    semantically identical to the unsharded vmap; numerically, results
    match ``mesh=None`` within float32 round-off (XLA picks reduction
    tilings per *local* batch shape, so convolution/matmul reductions
    inside a row can associate differently — observed ulp-level only;
    sentinel-slot padding itself is bit-exact).
    """
    axis = _axis(mesh)

    def body(*args):
        out = fn(*args)
        return jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis, axis=0, tiled=True), out)

    return _shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(),
                      **_RELAX)
