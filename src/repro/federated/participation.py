"""Cohort sampling for partial-participation rounds.

The paper's aggregation rules (Eq. 8/9) assume every client uploads every
round; real communication-constrained deployments sample a small *cohort*
per round. This module owns that policy: a :class:`ParticipationConfig`
describes how many clients participate and how they are drawn, and
:func:`sample_cohort` turns it into a :class:`Cohort` — a **fixed-shape**
``(indices, mask)`` pair the round engine threads through every layer
(masked gather -> chunked local SGD -> masked mix -> fused scatter).

Fixed-shape contract
--------------------
Every cohort has exactly ``resolve_size(m)`` slots, so jit compiles the
round ONCE for a participation policy — including the ``availability``
sampler, whose eligible set varies per round. Slots beyond the real
members are *pad slots*: ``indices`` holds the out-of-range sentinel
``m`` there and ``mask`` is False, so pad slots are gathered safely
(index clamped), carry zero weight in every masked aggregation rule, and
are dropped by the scatter. Real members occupy a sorted prefix of
``indices`` with ``mask`` True, which keeps the per-slot PRNG keys of a
padded cohort identical to the unpadded cohort's (bit-exactness).

Samplers
--------
``uniform``
    Cohort drawn uniformly without replacement (the FedAvg-paper policy).
``weighted``
    Without-replacement sampling with inclusion probability proportional
    to the local dataset size ``n`` (biased selection; cf. the
    Pareto-optimal client-selection line of work). Zero-size clients are
    never drawn; when fewer than ``cohort_size`` clients carry positive
    mass the whole positive-mass set participates and the remaining
    slots are masked pads (all-zero sizes raise a ``ValueError``).
``round_robin``
    Deterministic cyclic schedule: round t takes clients
    ``[t*c, ..., (t+1)*c) mod m``. Every client is visited once every
    ``ceil(m/c)`` rounds — useful to bound staleness of personalized
    models.
``availability``
    Clients are only eligible when their availability trace says so; the
    cohort is drawn uniformly from the eligible set and padded with
    masked slots when fewer than ``cohort_size`` clients are up (an
    all-masked cohort — nobody online — makes the engine skip the round
    entirely). The trace is an (m, period) boolean array, cycled over
    rounds — e.g. diurnal device availability. :func:`diurnal_trace`
    (time-of-day cosine with per-client offsets) and
    :func:`battery_trace` (charge-limited duty cycles) generate
    realistic such traces.
``pareto``
    Pareto-biased selection (the Jung et al. 2024 line): per-round
    sampling mass is the product of the :class:`SelectionConfig` biases
    — compute speed, link quality, data value — sharpened by the
    ``bias`` exponent and gated by a battery/diurnal availability trace
    (phases reuse the generators above). Zero-mass clients are never
    drawn; when fewer than ``cohort_size`` clients carry mass the whole
    positive-mass set participates, availability-style. A deterministic
    round-robin *fairness lane* reserves one slot per round for the
    statically-positive clients in turn, so every client with positive
    static mass is selected at least once every ``n_pos`` rounds it is
    up — biased throughput without starvation.

Full participation (``fraction=1.0``, the default) is represented by a
``None`` cohort so the engine can keep the legacy dense path bit-exact.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np

SAMPLERS = ("uniform", "weighted", "round_robin", "availability", "pareto")


@dataclasses.dataclass(frozen=True)
class Cohort:
    """A fixed-shape padded cohort.

    Attributes:
      indices: (cohort_size,) int32; real members form a sorted prefix,
        pad slots hold the out-of-range sentinel ``m``.
      mask: (cohort_size,) bool; True exactly on the real-member prefix.

    Construction validates the engine invariants the masked rules and the
    client-indexed PRNG keys rely on: ``indices``/``mask`` are 1-D and the
    same length, the mask is a *prefix* (no real slot after a pad slot),
    and the real members are strictly increasing (sorted, no duplicates).
    """

    indices: np.ndarray
    mask: np.ndarray

    def __post_init__(self):
        idx = np.asarray(self.indices, np.int32)
        mask = np.asarray(self.mask, bool)
        if idx.ndim != 1 or mask.shape != idx.shape:
            raise ValueError(
                f"indices/mask must be 1-D and the same length, got shapes "
                f"{idx.shape} and {mask.shape}")
        if mask.size and np.any(mask[1:] & ~mask[:-1]):
            raise ValueError(
                "mask must be a sorted prefix: every real slot (mask True) "
                "must precede every pad slot (mask False)")
        members = idx[mask]
        if members.size > 1 and not np.all(np.diff(members) > 0):
            raise ValueError(
                "real member indices must be strictly increasing "
                f"(sorted, unique), got {members.tolist()}")
        object.__setattr__(self, "indices", idx)
        object.__setattr__(self, "mask", mask)

    def __len__(self) -> int:
        """Number of REAL members (pad slots excluded)."""
        return int(self.mask.sum())

    @property
    def num_slots(self) -> int:
        return int(self.indices.shape[0])

    @property
    def members(self) -> np.ndarray:
        """The real member indices (sorted, unpadded)."""
        return self.indices[self.mask]


def as_cohort(cohort, m: int) -> Cohort | None:
    """Normalize a round's cohort argument to the padded contract.

    ``None`` stays None (dense path); a :class:`Cohort` passes through; a
    plain index array becomes an unpadded all-real Cohort (the PR 1
    calling convention, kept for tests and direct callers).
    """
    if cohort is None or isinstance(cohort, Cohort):
        return cohort
    idx = np.asarray(cohort, np.int32)
    return Cohort(indices=idx, mask=np.ones(idx.shape[0], bool))


def pad_slots(cohort: Cohort, slots: int, m: int) -> Cohort:
    """Extend a cohort with extra sentinel pad slots (index ``m``, mask
    False) up to ``slots`` total; no-op when already exactly that size.

    Pad slots are bit-invisible to the masked engine (zero weight in
    every masked rule, dropped by the scatter, client-indexed PRNG
    keys), so the result is equivalent to the input cohort. The mesh
    layer uses this to make the slot count divisible by the shard count
    (:func:`repro.federated.mesh.pad_cohort`).

    Raises:
      ValueError: if ``slots < cohort.num_slots``. Padding can only ever
        *extend*; silently returning the larger cohort used to let a
        mis-sized mesh pad through, surfacing much later as a slot axis
        the shard count doesn't divide.
    """
    extra = slots - cohort.num_slots
    if extra < 0:
        raise ValueError(
            f"cannot pad a {cohort.num_slots}-slot cohort down to {slots} "
            "slots; pad_slots only extends (check the mesh shard count / "
            "slot-count computation)")
    if extra == 0:
        return cohort
    return Cohort(
        indices=np.concatenate(
            [cohort.indices, np.full(extra, m, np.int32)]),
        mask=np.concatenate([cohort.mask, np.zeros(extra, bool)]))


def _pad(members: np.ndarray, slots: int, m: int) -> Cohort:
    members = np.sort(np.asarray(members, np.int32))
    take = members.shape[0]
    idx = np.full(slots, m, np.int32)
    idx[:take] = members
    mask = np.zeros(slots, bool)
    mask[:take] = True
    return Cohort(indices=idx, mask=mask)


@dataclasses.dataclass(frozen=True)
class SelectionConfig:
    """Pareto-biased cohort selection mass for the ``pareto`` sampler.

    Each knob weights one per-client utility; the per-round sampling
    mass is their product, sharpened by ``bias`` and gated by the
    battery trace::

        mass_i(t) = (compute_i · link_i · n_i^[data_value])^bias
                    · battery[i, t mod period]

    Attributes:
      compute: optional (m,) nonnegative relative compute speeds — bias
        toward clients that finish local SGD fast (shrinks the
        max-of-cohort compute term in ``comm_model.round_time``).
      link: optional (m,) nonnegative relative link qualities — bias
        toward clients with cheap uplinks.
      battery: optional (m, period) boolean availability trace (see
        :func:`battery_trace` / :func:`diurnal_trace`); a client in a
        down phase has zero mass that round.
      data_value: when True, multiply by the local dataset size ``n``
        (the classic importance-sampling bias).
      bias: exponent > 0 sharpening (>1) or flattening (<1) the static
        mass; battery gating is applied after the exponent.
      fairness_lane: when True (default), one cohort slot per round is
        reserved for the statically-positive clients in deterministic
        round-robin turn (skipped if that client is battery-gated), so
        every positive-static-mass client is selected within a bounded
        window instead of starving under sharp bias.
    """

    compute: np.ndarray | None = None
    link: np.ndarray | None = None
    battery: np.ndarray | None = None
    data_value: bool = False
    bias: float = 1.0
    fairness_lane: bool = True

    def __post_init__(self):
        if not self.bias > 0.0:
            raise ValueError(f"bias must be > 0, got {self.bias}")
        for name in ("compute", "link"):
            v = getattr(self, name)
            if v is None:
                continue
            v = np.asarray(v, np.float64)
            if v.ndim != 1:
                raise ValueError(f"{name} must be 1-D (m,), got {v.shape}")
            if np.any(v < 0) or not np.all(np.isfinite(v)):
                raise ValueError(f"{name} must be finite and nonnegative")
            object.__setattr__(self, name, v)
        if self.battery is not None:
            b = np.asarray(self.battery, bool)
            if b.ndim != 2:
                raise ValueError(
                    f"battery must be an (m, period) trace, got {b.shape}")
            object.__setattr__(self, "battery", b)

    def static_mass(self, m: int, n=None) -> np.ndarray:
        """The round-independent mass (before battery gating)."""
        mass = np.ones(m, np.float64)
        for name in ("compute", "link"):
            v = getattr(self, name)
            if v is not None:
                if v.shape[0] != m:
                    raise ValueError(
                        f"{name} has {v.shape[0]} entries for m={m} clients")
                mass = mass * v
        if self.data_value:
            if n is None:
                raise ValueError(
                    "SelectionConfig.data_value needs per-client sizes n")
            nn = np.clip(np.asarray(jax.device_get(n), np.float64), 0.0, None)
            if nn.shape[0] != m:
                raise ValueError(
                    f"n has {nn.shape[0]} entries for m={m} clients")
            mass = mass * nn
        return mass ** self.bias

    def mass(self, rnd: int, m: int, n=None) -> np.ndarray:
        """Round ``rnd``'s sampling mass (static mass, battery-gated)."""
        mass = self.static_mass(m, n)
        if self.battery is not None:
            if self.battery.shape[0] != m:
                raise ValueError(
                    f"battery trace has {self.battery.shape[0]} rows for "
                    f"m={m} clients")
            mass = mass * self.battery[:, (rnd - 1) % self.battery.shape[1]]
        return mass


def _pareto_members(sel: SelectionConfig, rng, rnd: int, c: int, m: int,
                    n=None) -> np.ndarray:
    """Draw the ``pareto`` sampler's members for one round."""
    mass = sel.mass(rnd, m, n)
    pos = np.flatnonzero(mass > 0)
    if pos.size == 0:
        # every client gated off this phase: an all-masked cohort the
        # engine skips, same contract as the availability sampler
        return np.empty(0, np.int64)
    if pos.size <= c:
        return pos
    picks = []
    p = mass.copy()
    if sel.fairness_lane:
        static_pos = np.flatnonzero(sel.static_mass(m, n) > 0)
        lane = int(static_pos[(rnd - 1) % static_pos.size])
        if p[lane] > 0:  # the lane client may be battery-gated this round
            picks.append(lane)
            p[lane] = 0.0
    rest = rng.choice(m, size=c - len(picks), replace=False, p=p / p.sum())
    return np.concatenate([np.asarray(picks, np.int64), rest])


def with_selection(pcfg: "ParticipationConfig | None",
                   selection: SelectionConfig | None):
    """Thread a ``FedConfig.selection`` into a participation policy.

    ``None`` selection returns ``pcfg`` untouched; otherwise the policy
    (or a fresh full-participation one) is switched to the ``pareto``
    sampler carrying the selection config. This is the seam drivers use
    — the strategy never draws cohorts itself.
    """
    if selection is None:
        return pcfg
    base = pcfg if pcfg is not None else ParticipationConfig()
    return dataclasses.replace(base, sampler="pareto", selection=selection)


@dataclasses.dataclass(frozen=True)
class ParticipationConfig:
    """Who participates each round.

    Attributes:
      fraction: target cohort fraction of m; 1.0 means full participation.
      cohort_size: explicit cohort size; overrides ``fraction`` when set.
      sampler: one of :data:`SAMPLERS`.
      availability: optional (m, period) boolean array for the
        ``availability`` sampler; column ``t % period`` gates round t.
      selection: a :class:`SelectionConfig`, required by (and only used
        by) the ``pareto`` sampler.
      seed: extra salt folded into the sampling key stream so the cohort
        sequence is independent of the training randomness.
    """

    fraction: float = 1.0
    cohort_size: int | None = None
    sampler: str = "uniform"
    availability: np.ndarray | None = None
    selection: SelectionConfig | None = None
    seed: int = 0

    def __post_init__(self):
        if self.sampler not in SAMPLERS:
            raise ValueError(
                f"unknown sampler {self.sampler!r}; expected one of {SAMPLERS}")
        if self.cohort_size is None and not (0.0 < self.fraction <= 1.0):
            raise ValueError(f"fraction must be in (0, 1], got {self.fraction}")
        if self.sampler == "availability" and self.availability is None:
            raise ValueError("availability sampler needs an availability trace")
        if self.sampler == "pareto" and self.selection is None:
            raise ValueError("pareto sampler needs a SelectionConfig "
                             "(ParticipationConfig.selection)")

    def resolve_size(self, m: int) -> int:
        """Number of cohort slots for ``m`` clients.

        Fractional targets use an explicit CEIL rule:
        ``ceil(fraction * m)``, clamped to [1, m]. ``int(round(...))``
        banker's-rounds half-way fractions down (fraction=0.25, m=10 ->
        2, not 3), silently under-provisioning the cohort; ceil
        guarantees at least the requested participation fraction. The
        product is snapped to 9 decimals first so binary float fuzz
        (0.1 * 130 == 13.000000000000002) cannot bump an exact target up
        a slot.
        """
        if self.cohort_size is not None:
            return max(1, min(int(self.cohort_size), m))
        return max(1, min(m, math.ceil(round(self.fraction * m, 9))))

    def is_full(self, m: int) -> bool:
        # availability/pareto can mask slots (gated clients) even at
        # fraction 1.0, so they never take the dense full-participation
        # fast path
        return (self.sampler not in ("availability", "pareto")
                and self.resolve_size(m) == m)


def _rng(cfg: ParticipationConfig, rnd: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, rnd, 0x5EED]))


# ---------------------------------------------------------- trace generators
#
# Deterministic (m, period) boolean availability traces for the
# ``availability`` sampler, modeling the two dominant edge-device effects:
# time-of-day usage cycles (diurnal) and charge-limited duty cycles
# (battery). Both guarantee every client is up in at least one phase —
# a never-up client can never train, which makes worst-node metrics
# vacuous — but make NO per-phase guarantee: a phase where nobody is up
# is a legitimate all-offline round the engine skips.


def diurnal_trace(m: int, period: int = 24, *, peak: float = 0.9,
                  trough: float = 0.1, spread: bool = True,
                  seed: int = 0) -> np.ndarray:
    """Sinusoidal time-of-day availability with per-client phase offsets.

    Client i is up in phase t with probability following a cosine
    between ``trough`` and ``peak`` over the ``period``-phase cycle,
    shifted by a per-client offset (time zones / usage habits) when
    ``spread`` is True — offsets are what keeps SOME clients up in the
    global trough, the regime where a buffered-async server banks
    deposits across skinny rounds.
    """
    if not 0.0 <= trough <= peak <= 1.0:
        raise ValueError(
            f"need 0 <= trough <= peak <= 1, got {trough}, {peak}")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xD1E1]))
    offsets = rng.integers(0, period, m) if spread else np.zeros(m, int)
    t = (np.arange(period)[None, :] + offsets[:, None]) % period
    up_p = trough + (peak - trough) * 0.5 * (
        1.0 + np.cos(2.0 * np.pi * t / period))
    trace = rng.random((m, period)) < up_p
    return _ensure_each_client_up(trace, rng)


def battery_trace(m: int, period: int = 24, *, duty: int = 3,
                  recharge: int = 2, seed: int = 0) -> np.ndarray:
    """Charge-limited duty cycles: up ``duty`` phases, down ``recharge``.

    Each device cycles through ``duty`` consecutive up phases (draining)
    followed by ``recharge`` down phases (charging), from a random
    initial charge state — the classic battery/plugged-in gating of
    cross-device FL. Different initial states de-synchronize the fleet,
    so the eligible set size varies per phase without ever collapsing
    the whole fleet at once (unless duty/recharge make it so).
    """
    if duty < 1 or recharge < 0:
        raise ValueError(
            f"need duty >= 1 and recharge >= 0, got {duty}, {recharge}")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xBA77]))
    cycle = duty + recharge
    phase0 = rng.integers(0, cycle, m)
    t = (np.arange(period)[None, :] + phase0[:, None]) % cycle
    trace = t < duty
    return _ensure_each_client_up(trace, rng)


def _ensure_each_client_up(trace: np.ndarray, rng) -> np.ndarray:
    """Force at least one up phase per client (see the section comment)."""
    trace = np.asarray(trace, bool)
    never = np.flatnonzero(~trace.any(axis=1))
    if never.size:
        trace[never, rng.integers(0, trace.shape[1], never.size)] = True
    return trace


def sample_cohort(cfg: ParticipationConfig | None, rnd: int, m: int,
                  n=None) -> Cohort | None:
    """Draw round ``rnd``'s cohort; ``None`` means everyone participates.

    Args:
      cfg: participation policy (None == full participation).
      rnd: 1-based round index (drives round_robin / availability phase).
      m: total number of clients.
      n: (m,) local dataset sizes, required by the ``weighted`` sampler.

    Returns:
      A :class:`Cohort` with exactly ``resolve_size(m)`` slots, or None
      for the full-participation fast path. Every sampler emits the same
      slot count each round, so jit sees ONE static round shape; the
      ``availability`` sampler masks the slots it cannot fill (an
      all-masked cohort means nobody was online and the engine skips the
      round).
    """
    if cfg is None or cfg.is_full(m):
        return None
    c = cfg.resolve_size(m)
    rng = _rng(cfg, rnd)
    if cfg.sampler == "uniform":
        members = rng.choice(m, size=c, replace=False)
    elif cfg.sampler == "weighted":
        if n is None:
            raise ValueError("weighted sampler needs per-client sizes n")
        p = np.clip(np.asarray(jax.device_get(n), np.float64), 0.0, None)
        pos = np.flatnonzero(p > 0)
        if pos.size == 0:
            raise ValueError(
                "weighted sampler: every client has zero dataset size, so "
                "no inclusion probability can be formed (n must have at "
                "least one positive entry)")
        if pos.size <= c:
            # fewer clients carry mass than the cohort has slots: take the
            # whole positive-mass set (weights are irrelevant then) and
            # pad the remaining slots masked, availability-style —
            # rng.choice would raise on size > nonzero(p) and zero-mass
            # clients must never be drawn
            members = pos
        else:
            members = rng.choice(m, size=c, replace=False, p=p / p.sum())
    elif cfg.sampler == "round_robin":
        start = ((rnd - 1) * c) % m
        members = (start + np.arange(c)) % m
    elif cfg.sampler == "pareto":
        members = _pareto_members(cfg.selection, rng, rnd, c, m, n)
    else:  # availability
        trace = np.asarray(cfg.availability, bool)
        up = np.flatnonzero(trace[:, (rnd - 1) % trace.shape[1]])
        members = rng.choice(up, size=min(c, up.size), replace=False)
    return _pad(members, c, m)


def cohort_schedule(cfg: ParticipationConfig | None, rounds: int, m: int,
                    n=None):
    """Materialize the full cohort sequence (diagnostics / tests)."""
    return [sample_cohort(cfg, r, m, n) for r in range(1, rounds + 1)]
