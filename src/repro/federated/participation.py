"""Cohort sampling for partial-participation rounds.

The paper's aggregation rules (Eq. 8/9) assume every client uploads every
round; real communication-constrained deployments sample a small *cohort*
per round. This module owns that policy: a :class:`ParticipationConfig`
describes how many clients participate and how they are drawn, and
:func:`sample_cohort` turns it into a sorted index array the round engine
threads through every layer (client gather -> local SGD -> cohort-sliced
aggregation -> scatter back into the stacked state).

Samplers
--------
``uniform``
    Cohort drawn uniformly without replacement (the FedAvg-paper policy).
``weighted``
    Without-replacement sampling with inclusion probability proportional
    to the local dataset size ``n`` (biased selection; cf. the
    Pareto-optimal client-selection line of work).
``round_robin``
    Deterministic cyclic schedule: round t takes clients
    ``[t*c, ..., (t+1)*c) mod m``. Every client is visited once every
    ``ceil(m/c)`` rounds — useful to bound staleness of personalized
    models.
``availability``
    Clients are only eligible when their availability trace says so; the
    cohort is drawn uniformly from the eligible set (truncated when fewer
    than ``cohort_size`` clients are up; an empty cohort — nobody online —
    makes the engine skip the round entirely). The trace is an
    (m, period) boolean array, cycled over rounds — e.g. diurnal device
    availability.

Full participation (``fraction=1.0``, the default) is represented by a
``None`` cohort so the engine can keep the legacy dense path bit-exact.

The cohort size is *fixed* across rounds (jit recompiles only once):
``cohort_size`` wins if given, else ``max(1, round(fraction*m))``. The
one exception is ``availability``, whose cohort shrinks to the eligible
set when fewer than ``cohort_size`` clients are up: each *distinct* size
triggers one extra jit compile of the round (inside the timed region —
the warm-up only covers round 1's shape). Trace realism is prioritized
over shape stability here; see ROADMAP for the padded/masked follow-up.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

SAMPLERS = ("uniform", "weighted", "round_robin", "availability")


@dataclasses.dataclass(frozen=True)
class ParticipationConfig:
    """Who participates each round.

    Attributes:
      fraction: target cohort fraction of m; 1.0 means full participation.
      cohort_size: explicit cohort size; overrides ``fraction`` when set.
      sampler: one of :data:`SAMPLERS`.
      availability: optional (m, period) boolean array for the
        ``availability`` sampler; column ``t % period`` gates round t.
      seed: extra salt folded into the sampling key stream so the cohort
        sequence is independent of the training randomness.
    """

    fraction: float = 1.0
    cohort_size: int | None = None
    sampler: str = "uniform"
    availability: np.ndarray | None = None
    seed: int = 0

    def __post_init__(self):
        if self.sampler not in SAMPLERS:
            raise ValueError(
                f"unknown sampler {self.sampler!r}; expected one of {SAMPLERS}")
        if self.cohort_size is None and not (0.0 < self.fraction <= 1.0):
            raise ValueError(f"fraction must be in (0, 1], got {self.fraction}")
        if self.sampler == "availability" and self.availability is None:
            raise ValueError("availability sampler needs an availability trace")

    def resolve_size(self, m: int) -> int:
        if self.cohort_size is not None:
            return max(1, min(int(self.cohort_size), m))
        return max(1, min(m, int(round(self.fraction * m))))

    def is_full(self, m: int) -> bool:
        return self.sampler != "availability" and self.resolve_size(m) == m


def _rng(cfg: ParticipationConfig, rnd: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, rnd, 0x5EED]))


def sample_cohort(cfg: ParticipationConfig | None, rnd: int, m: int,
                  n=None) -> np.ndarray | None:
    """Draw round ``rnd``'s cohort; ``None`` means everyone participates.

    Args:
      cfg: participation policy (None == full participation).
      rnd: 1-based round index (drives round_robin / availability phase).
      m: total number of clients.
      n: (m,) local dataset sizes, required by the ``weighted`` sampler.

    Returns:
      Sorted int32 index array of the participating clients, or None for
      the full-participation fast path. All samplers except
      ``availability`` return exactly ``resolve_size(m)`` indices, so jit
      sees one static cohort shape across rounds.
    """
    if cfg is None or cfg.is_full(m):
        return None
    c = cfg.resolve_size(m)
    rng = _rng(cfg, rnd)
    if cfg.sampler == "uniform":
        cohort = rng.choice(m, size=c, replace=False)
    elif cfg.sampler == "weighted":
        if n is None:
            raise ValueError("weighted sampler needs per-client sizes n")
        p = np.asarray(jax.device_get(n), np.float64)
        p = p / p.sum()
        cohort = rng.choice(m, size=c, replace=False, p=p)
    elif cfg.sampler == "round_robin":
        start = ((rnd - 1) * c) % m
        cohort = (start + np.arange(c)) % m
    else:  # availability
        trace = np.asarray(cfg.availability, bool)
        up = np.flatnonzero(trace[:, (rnd - 1) % trace.shape[1]])
        if up.size == 0:  # nobody online: the engine skips this round
            return np.empty(0, np.int32)
        take = min(c, up.size)
        cohort = rng.choice(up, size=take, replace=False)
    return np.sort(cohort.astype(np.int32))


def cohort_schedule(cfg: ParticipationConfig | None, rounds: int, m: int,
                    n=None):
    """Materialize the full cohort sequence (diagnostics / tests)."""
    return [sample_cohort(cfg, r, m, n) for r in range(1, rounds + 1)]
