"""Static client→edge topology for the two-tier hierarchical engine.

The flat round engine assumes every cohort upload lands on ONE parameter
server.  A :class:`Topology` declares the production alternative: clients
are statically assigned to edge aggregators (``edge_of[i]`` = the edge
serving client ``i``), each edge runs the tier-1 masked mix over its own
cohort members, and only the per-edge aggregates travel the edge↔PS
backhaul for the tier-2 combine.  ``FedConfig.topology = None`` keeps the
flat single-tier path bit-exact — the knob is strictly opt-in.

Fixed-shape discipline (the Cohort/sentinel trick one level up):

* every edge is padded to the same slot count ``s = slots_per_edge(c)``
  (the static min of the cohort size and the largest edge population),
  so the tiered round compiles exactly once per policy;
* :func:`edge_partition` splits a padded cohort's ``(c,)`` slot arrays
  into ``(E, s)`` per-edge slot arrays INSIDE the jitted round — a
  stable argsort by edge id, so each edge's real slots form a prefix and
  keep the cohort's strictly-increasing member order (the invariants the
  per-edge masked (c, c)-row rules require);
* pad slots carry the same sentinels as the flat engine (client index
  ``m``, cohort-slot index ``c``) and rely on the sentinel-drop scatter
  contract, so no gathered pad ever reaches a mix.

Tiered mixes factorize the flat LINEAR rules exactly: tier-1 aggregates
are normalized per edge together with their weight mass, tier-2
reweights by mass — identical to the flat mix up to float association,
which is why the hierarchical replay matches flat accuracy while the PS
uplink shrinks from ``c`` client uploads to ``E·k`` edge aggregates.

Strategies whose PS rule does NOT factorize over edge partial sums
(per-client unicast mixes reading every cohort column: ucfl full
personalization, fedfomo, pfedme's group payloads, ...) reject the knob
at construction via :func:`unsupported` — the same capability-note
discipline as ``transport.unsupported``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """Static client→edge assignment for two-tier rounds.

    Attributes:
      edge_of: length-m tuple; ``edge_of[i]`` is the edge aggregator
        serving client ``i`` (values in ``[0, num_edges)``).
      num_edges: number of edge aggregators E (every edge may be empty
        in a given cohort; a globally empty edge is allowed too).
    """

    edge_of: tuple
    num_edges: int

    def __post_init__(self):
        edge_of = tuple(int(e) for e in self.edge_of)
        object.__setattr__(self, "edge_of", edge_of)
        if self.num_edges < 1:
            raise ValueError(f"num_edges must be >= 1, got {self.num_edges}")
        if not edge_of:
            raise ValueError("edge_of must assign at least one client")
        bad = [e for e in edge_of if not 0 <= e < self.num_edges]
        if bad:
            raise ValueError(
                f"edge ids must lie in [0, {self.num_edges}), got {bad[:4]}")

    @property
    def num_clients(self) -> int:
        return len(self.edge_of)

    @classmethod
    def from_labels(cls, labels) -> "Topology":
        """Build from any per-client label array (e.g. cluster labels)."""
        lab = np.asarray(labels, dtype=np.int64).reshape(-1)
        return cls(tuple(lab.tolist()), int(lab.max()) + 1)

    @classmethod
    def contiguous(cls, m: int, num_edges: int) -> "Topology":
        """m clients in num_edges contiguous, near-equal blocks."""
        return cls(tuple(np.arange(m) * num_edges // max(m, 1)), num_edges)

    def slots_per_edge(self, cohort_slots: int) -> int:
        """Static per-edge slot count s for a c-slot cohort.

        A cohort draws distinct clients, so an edge can never hold more
        cohort members than min(its population, c) — padding every edge
        to that bound keeps the tiered round one compiled shape while
        guaranteeing :func:`edge_partition` never overflows a block.
        """
        pop = np.bincount(np.asarray(self.edge_of), minlength=self.num_edges)
        return int(min(cohort_slots, pop.max()))

    def edge_array(self):
        """The assignment as a device-ready (m,) int32 array."""
        return jnp.asarray(self.edge_of, jnp.int32)

    def check_clients(self, m: int, strategy: str) -> None:
        if self.num_clients != m:
            raise ValueError(
                f"{strategy}: topology assigns {self.num_clients} clients "
                f"but the dataset has {m}")


def edge_ids(edge_arr, num_edges: int, idx, mask):
    """Per-cohort-slot edge id; pads get the sentinel edge ``num_edges``."""
    m = edge_arr.shape[0]
    safe = jnp.minimum(idx, m - 1)
    return jnp.where(mask, jnp.take(edge_arr, safe), num_edges)


def edge_onehot(edge_arr, num_edges: int, idx, mask):
    """(c, E) float32 edge membership of each cohort slot (pads all-zero)."""
    g = edge_ids(edge_arr, num_edges, idx, mask)
    return (g[:, None] == jnp.arange(num_edges)[None, :]).astype(jnp.float32)


def edge_partition(edge_arr, num_edges: int, slots: int, idx, mask):
    """Split a padded cohort into fixed-shape per-edge slot arrays.

    Jit-safe: pure gather/argsort/scatter on static shapes.  Returns

      eidx  (E, s) int32 — client indices per edge, sentinel m on pads
      emask (E, s) bool  — True on real per-edge slots (prefix per edge)
      eslot (E, s) int32 — the cohort slot each per-edge slot came from
                           (sentinel c on pads; indexes the (c, ·) slab)

    The stable argsort by edge id preserves the cohort's within-edge
    slot order, so each edge's real members stay strictly increasing —
    a valid Cohort one level down.  Pads sort to the sentinel edge
    ``num_edges`` whose destinations fall past E·s and drop.
    """
    c = idx.shape[0]
    m = edge_arr.shape[0]
    g = edge_ids(edge_arr, num_edges, idx, mask)
    order = jnp.argsort(g, stable=True)
    gs = jnp.take(g, order)
    pos = jnp.arange(c) - jnp.searchsorted(gs, gs, side="left")
    dest = gs * slots + pos
    flat = num_edges * slots
    eidx = (jnp.full((flat,), m, jnp.int32)
            .at[dest].set(jnp.take(idx, order).astype(jnp.int32),
                          mode="drop"))
    emask = (jnp.zeros((flat,), bool)
             .at[dest].set(jnp.take(mask, order), mode="drop"))
    eslot = (jnp.full((flat,), c, jnp.int32)
             .at[dest].set(order.astype(jnp.int32), mode="drop"))
    return (eidx.reshape(num_edges, slots),
            emask.reshape(num_edges, slots),
            eslot.reshape(num_edges, slots))


def check_composition(topology, strategy: str, *, shard_state=False,
                      async_buffer=None):
    """Construction-time guards for the knob combos that cannot tier.

    Returns ``topology`` (possibly None) when the combo is legal; the
    supporting strategies call this once at build time so illegal combos
    fail loudly with a capability note instead of silently flattening.
    """
    if topology is None:
        return None
    if not isinstance(topology, Topology):
        raise TypeError(
            f"FedConfig.topology must be a federated.topology.Topology, "
            f"got {type(topology).__name__}")
    if shard_state:
        raise NotImplementedError(
            f"FedConfig.topology does not compose with shard_state in "
            f"{strategy}: the row-sharded gather/scatter owns the client "
            "axis per device while the edge partition owns it per edge — "
            "a joint edge×shard layout is future work (drop one knob)")
    if async_buffer is not None:
        raise NotImplementedError(
            f"FedConfig.topology does not compose with async_buffer in "
            f"{strategy}: a flush applies arrivals banked across rounds, "
            "so no single round's edge partition covers the flushed "
            "batch — tiering the pending buffer is future work (drop "
            "one knob)")
    return topology


def unsupported(topology, strategy: str, why: str) -> None:
    """Raise at construction when a strategy cannot tier its PS mix.

    Mirrors ``transport.unsupported``: unsupported combos fail loudly
    when the strategy is built, with a capability note, never silently
    fall back to the flat path.
    """
    if topology is not None:
        raise NotImplementedError(
            f"FedConfig.topology is not supported by {strategy}: {why} "
            "(supported: the fedavg family and clustered ucfl — "
            "strategies whose PS mix factorizes over per-edge partial "
            "aggregates)")
