from repro.federated import client, mesh, participation, simulation  # noqa: F401
from repro.federated.participation import ParticipationConfig  # noqa: F401
