from repro.federated import client, simulation  # noqa: F401
