"""Quantized wire transport (``FedConfig.transport``) over declared streams.

PR 8 compressed one hard-coded ``(c, d)`` uplink delta slab. The wire
layer is now schema-driven: every strategy declares a
:class:`WireSchema` — named uplink and downlink :class:`Stream` slices of
the 128-aligned slab, each with its own quantization eligibility
(``coding``) and its own error-feedback accumulator slice — and
:func:`make_wire_stage` builds the per-stream quantize→dequantize stage
for either direction. ``transport=None`` keeps every strategy's exact
stage-free trace, bit-for-bit.

Stream codings
--------------
  * ``"delta"`` — a per-receiver model/state delta: quantized int8/fp8
    per chunk with error feedback (the only coding that owns EF state).
  * ``"raw"``   — never compressed; 4 B/coordinate on the wire and a
    pass-through in the stage (the receiver has no shared reference to
    delta-code against, and stateless absolute quantization of
    weight-scale values would inject ~``max|chunk|/254`` noise — outside
    the 2e-3 drift budget the transport tests pin).
  * ``"relay"`` — the receiver downloads a payload some OTHER hop
    already quantized (FedFomo peers fetch the cohort's quantized
    uploads): priced at the compressed width, but no second stage runs —
    re-quantizing an already-dequantized relay would double the noise.

Per-strategy stream/capability matrix
-------------------------------------
=============  ==============================  =============================
strategy       uplink streams                  downlink streams
=============  ==============================  =============================
fedavg         delta                           broadcast: delta (server EF)
fedprox        delta                           broadcast: delta (server EF)
local          delta                           — (no downlink)
oracle         delta                           groupcast: raw
ucfl (full)    delta                           personalized: delta (server
                                               EF rows per client)
ucfl (clust.)  delta                           centroids: raw
scaffold       delta + control_delta           model: delta, control: delta
                                               (one shared server EF row)
ditto          global_delta                    broadcast: delta (the
                                               personal model never leaves
                                               the client)
pfedme         w_delta                         broadcast: raw (the β-mix
                                               average has no shared
                                               receiver reference)
fedfomo        delta                           peer_models: relay
cfl            delta (split stats consume the  centroids: raw
               dequantized deltas)
ucfl_parallel  UNSUPPORTED — the m× per-stream update stack has no wire
               slab (:func:`unsupported` raises at construction)
=============  ==============================  =============================

Buffered-async composition: the uplink stage runs before the deposit
(the pending buffer holds what the wire carried); the async DOWNLINK
stays raw f32 — a flush rewrites arbitrary subsets of rows, so there is
no per-receiver reference to delta-code against.

Two-tier topology composition (``FedConfig.topology``): the transport
stage dequantizes the cohort's uploads BEFORE the tier-1 per-edge mix,
so the tiered engine consumes the same post-wire slab as the flat one
and every supported (strategy, transport) pair above composes with a
topology unchanged — the client→edge hop carries the quantized wire,
the edge→PS hop carries f32 partial aggregates (priced per tier by
``comm_model.SystemParams.tiers`` and the ``ps_*_bytes_per_round``
backhaul counters). Topology itself is supported only where the PS mix
factorizes over per-edge partial sums — fedavg, fedprox, and clustered
ucfl; the rest raise at construction
(:func:`repro.federated.topology.unsupported`), as do
topology×shard_state and topology×async_buffer.

Error feedback: each DIRECTION keeps one f32 accumulator slab spanning
the concatenated aligned stream widths — ``(m, Σ dim_aligned)`` per
client on the uplink, ``(1, Σ)`` (broadcast) or ``(m, Σ)`` (unicast) on
the server for the downlink. A round quantizes ``delta + ef`` per stream
and carries each stream's new residual forward, so the long-run applied
update is unbiased per stream — on a constant delta the applied values
telescope to the truth within one quantization step (pinned in
tests/test_transport.py and, per stream, tests/test_wire_schema.py).
This is what keeps compression noise out of the streaming Δ/σ²
estimation under ``FedConfig.w_refresh``: the refresh observes the
dequantized upload the server actually received.

Downlink wire format
--------------------
A compressed (``delta``) downlink stream ships, per receiver group
(1 broadcast row, or one row per unicast receiver): ``width`` payload
bytes (1 B/coordinate, int8 and fp8-e4m3 alike) plus one f32 scale per
``chunk`` coordinates — ``width + 4·ceil(width/chunk)`` vs ``4·width``
raw, the same ~3.9× reduction as the uplink at the default chunk=128.
The server-side EF accumulator makes the compressed broadcast unbiased
exactly like the client-side EF makes the upload unbiased. ``raw``
streams ship ``4·width``; ``relay`` streams are priced at the compressed
width of the payload their source hop shipped. Pricing lives in
:func:`repro.core.comm_model.wire_bytes`.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.kernels import ops

_QMAX = {"int8": 127.0, "fp8": 448.0}  # fp8 = e4m3 finite max

_CODINGS = ("delta", "raw", "relay")


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Wire compression knobs (both directions share one config).

    kind: ``"int8"`` (symmetric round-to-nearest) or ``"fp8"``
      (e4m3 cast, per-chunk rescaled to the e4m3 range).
    chunk: coordinates sharing one f32 scale. Must divide every
      ``delta`` stream's aligned slab width; the default 128 equals the
      kernel lane alignment (``ops.ALIGN``), so any ``dim_aligned``
      stream chunks evenly.
    """

    kind: str = "int8"
    chunk: int = 128

    def __post_init__(self):
        if self.kind not in _QMAX:
            raise ValueError(
                f"TransportConfig.kind must be one of {sorted(_QMAX)}, got {self.kind!r}",
            )
        if int(self.chunk) <= 0:
            raise ValueError("TransportConfig.chunk must be positive")


@dataclasses.dataclass(frozen=True)
class Stream:
    """One named slice of a direction's wire slab.

    width: TRUE coordinate count (what the wire prices); the slab slice
      is the 128-aligned ``width_aligned``, whose zero tail quantizes to
      exact zeros.
    coding: ``"delta"`` (quantized, owns an EF slice), ``"raw"``
      (pass-through, 4 B/coord), or ``"relay"`` (priced compressed, no
      stage — see the module docstring).
    """

    name: str
    width: int
    coding: str = "delta"

    def __post_init__(self):
        if self.coding not in _CODINGS:
            raise ValueError(
                f"Stream.coding must be one of {_CODINGS}, got {self.coding!r}",
            )
        if int(self.width) < 0:
            raise ValueError(f"Stream.width must be >= 0, got {self.width}")

    @property
    def width_aligned(self) -> int:
        return ops.aligned_dim(int(self.width)) if self.width else 0


@dataclasses.dataclass(frozen=True)
class WireSchema:
    """A strategy's declared wire layout (see the capability matrix)."""

    strategy: str
    uplink: tuple = ()
    downlink: tuple = ()

    def streams(self, direction: str) -> tuple:
        if direction not in ("uplink", "downlink"):
            raise ValueError(f"unknown wire direction {direction!r}")
        return self.uplink if direction == "uplink" else self.downlink

    def width(self, direction: str) -> int:
        """TRUE coordinate count of the direction's concatenated streams."""
        return sum(int(s.width) for s in self.streams(direction))

    def width_aligned(self, direction: str) -> int:
        """Slab width of the direction's concatenated aligned slices."""
        return sum(s.width_aligned for s in self.streams(direction))

    def slices(self, direction: str) -> tuple:
        """(lo, hi) aligned-slab slice per stream, in declaration order."""
        out, lo = [], 0
        for s in self.streams(direction):
            out.append((lo, lo + s.width_aligned))
            lo += s.width_aligned
        return tuple(out)


def single_delta_schema(strategy: str, dim: int, *, downlink=()) -> WireSchema:
    """The common one-uplink-delta schema (FedAvg family, ucfl, ...)."""
    return WireSchema(
        strategy,
        uplink=(Stream("delta", dim),),
        downlink=downlink,
    )


def unsupported(transport, strategy: str, why: str):
    """Uniform construction-time capability error for schema-less wires.

    Strategies that cannot declare a :class:`WireSchema` (only
    ucfl_parallel's m× column mix remains) call this instead of the old
    ad-hoc ``reject_transport``; the message points at the capability
    matrix in this module's docstring.
    """
    if transport is not None:
        raise NotImplementedError(
            f"FedConfig.transport is not supported by {strategy}: {why} — "
            "this strategy declares no WireSchema (see the per-strategy "
            "stream/capability matrix in repro/federated/transport.py)"
        )


def quantize(x, cfg: TransportConfig):
    """(…, d) f32 -> (q, scale): q (…, d/chunk, chunk) in the wire dtype,
    scale (…, d/chunk, 1) f32 per chunk."""
    d = x.shape[-1]
    chunk = int(cfg.chunk)
    if d % chunk:
        msg = f"transport chunk {chunk} does not divide the slab width {d}"
        raise ValueError(msg + " (the aligned slab always chunks evenly at chunk=128)")
    xs = x.reshape(x.shape[:-1] + (d // chunk, chunk))
    scale = jnp.max(jnp.abs(xs), axis=-1, keepdims=True) / _QMAX[cfg.kind]
    # all-zero chunks (e.g. the slab's aligned tail) quantize to exact 0
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    if cfg.kind == "int8":
        q = jnp.clip(jnp.round(xs / scale), -127.0, 127.0).astype(jnp.int8)
    else:  # fp8
        q = (xs / scale).astype(jnp.float8_e4m3fn)
    return q, scale


def dequantize(q, scale):
    """Inverse of :func:`quantize` up to the quantization error."""
    xs = q.astype(jnp.float32) * scale
    return xs.reshape(xs.shape[:-2] + (xs.shape[-2] * xs.shape[-1],))


def roundtrip(x, cfg: TransportConfig):
    """What the receiver decodes from payload ``x``."""
    return dequantize(*quantize(x, cfg))


def _check_transport(transport):
    if not isinstance(transport, TransportConfig):
        got = type(transport).__name__
        raise TypeError(f"FedConfig.transport must be a TransportConfig or None, got {got}")


def make_stage(transport):
    """Build the single-slab transport stage, or ``None`` when off.

    The pre-schema primitive (a :func:`make_wire_stage` over one
    full-width ``delta`` stream is bit-identical): ``stage(pre, post,
    ef) -> (post', ef')`` over (c, d) cohort slabs — quantize
    ``(post - pre) + ef`` as the wire delta, reconstruct
    ``post' = pre + dequant`` (the payload the receiver decodes), and
    carry the residual in ``ef'``. Runs BEFORE the fault/robust upload
    stage — faults corrupt, and robust rules sanitize, the payload the
    wire actually carried.
    """
    if transport is None:
        return None
    _check_transport(transport)

    def stage(pre, post, ef):
        carry = (post - pre) + ef
        deq = roundtrip(carry, transport)
        return pre + deq, carry - deq

    return stage


def make_wire_stage(schema: WireSchema, transport, direction: str = "uplink"):
    """Build one direction's per-stream transport stage, or ``None``.

    ``None`` when ``transport`` is off, or when the direction declares no
    ``delta`` stream (nothing to quantize — raw/relay directions keep
    the exact stage-free trace).

    The returned ``stage(pre, post, ef) -> (post', ef')`` operates on the
    direction's CONCATENATED wire slab — ``(rows,
    schema.width_aligned(direction))`` — and applies, per stream slice:
    ``delta`` → the quantize→dequantize→EF fold of :func:`make_stage`;
    ``raw``/``relay`` → pass-through (their EF slice stays zero). Chunk
    divisibility is validated HERE, at stage construction, with an error
    naming the strategy and widths — not as a cryptic reshape failure
    deep inside the jitted round.
    """
    if transport is None:
        return None
    _check_transport(transport)
    streams = schema.streams(direction)
    chunk = int(transport.chunk)
    for s in streams:
        if s.coding == "delta" and s.width_aligned % chunk:
            raise ValueError(
                f"TransportConfig.chunk={chunk} does not divide the "
                f"{schema.strategy!r} {direction} stream {s.name!r}: "
                f"width {s.width} aligns to a {s.width_aligned}-wide slab "
                f"slice ({schema.strategy} {direction} wire is "
                f"{schema.width_aligned(direction)} wide) — pick a chunk "
                "dividing the aligned stream width (128 always does)"
            )
    if not any(s.coding == "delta" for s in streams):
        return None
    slices = schema.slices(direction)
    if len(streams) == 1:
        # the single-stream stage IS make_stage (no concat in the trace):
        # every pre-schema single-delta trajectory stays bit-identical
        return make_stage(transport)

    def stage(pre, post, ef):
        outs, efs = [], []
        for s, (lo, hi) in zip(streams, slices):
            p, q, e = pre[..., lo:hi], post[..., lo:hi], ef[..., lo:hi]
            if s.coding == "delta" and hi > lo:
                carry = (q - p) + e
                deq = roundtrip(carry, transport)
                outs.append(p + deq)
                efs.append(carry - deq)
            else:
                outs.append(q)
                efs.append(jnp.zeros_like(e))
        return jnp.concatenate(outs, axis=-1), jnp.concatenate(efs, axis=-1)

    return stage
