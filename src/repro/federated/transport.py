"""Quantized uplink transport (``FedConfig.transport``).

Clients upload their model *delta* int8- or fp8-quantized with one f32
scale per ``chunk`` consecutive coordinates; the server dequantizes
before the masked mix, inside the same jitted round body (one compiled
shape either way — ``transport=None`` keeps the exact stage-free trace,
bit-for-bit).

Error feedback: each client keeps an ``(m, dim_aligned)`` accumulator
slab ``ef`` of the quantization residual. A round quantizes
``delta + ef`` and carries the new residual forward, so the *long-run*
applied update is unbiased — on a constant delta the per-round applied
values telescope to the truth within one quantization step (pinned in
tests/test_transport.py). This is what keeps compression noise out of
the streaming Δ/σ² estimation under ``FedConfig.w_refresh``: the W
refresh observes the dequantized upload the server actually received,
and EF guarantees its drift from the raw delta stays bounded instead of
accumulating round over round.

Wire format per client per round (priced by
:func:`repro.core.comm_model.uplink_bytes_per_round`): ``dim`` payload
bytes (1 byte/coordinate for both int8 and fp8-e4m3) plus one f32 scale
per chunk — ``dim + 4·ceil(dim/chunk)`` vs ``4·dim`` for raw f32, a
~3.9× uplink reduction at the default ``chunk=128``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_QMAX = {"int8": 127.0, "fp8": 448.0}  # fp8 = e4m3 finite max


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Uplink compression knobs.

    kind: ``"int8"`` (symmetric round-to-nearest) or ``"fp8"``
      (e4m3 cast, per-chunk rescaled to the e4m3 range).
    chunk: coordinates sharing one f32 scale. Must divide the slab
      width; the default 128 equals the kernel lane alignment
      (``ops.ALIGN``), so any ``dim_aligned`` slab chunks evenly.
    """

    kind: str = "int8"
    chunk: int = 128

    def __post_init__(self):
        if self.kind not in _QMAX:
            raise ValueError(
                f"TransportConfig.kind must be one of {sorted(_QMAX)}, got {self.kind!r}",
            )
        if int(self.chunk) <= 0:
            raise ValueError("TransportConfig.chunk must be positive")


def quantize(x, cfg: TransportConfig):
    """(…, d) f32 -> (q, scale): q (…, d/chunk, chunk) in the wire dtype,
    scale (…, d/chunk, 1) f32 per chunk."""
    d = x.shape[-1]
    chunk = int(cfg.chunk)
    if d % chunk:
        msg = f"transport chunk {chunk} does not divide the slab width {d}"
        raise ValueError(msg + " (the aligned slab always chunks evenly at chunk=128)")
    xs = x.reshape(x.shape[:-1] + (d // chunk, chunk))
    scale = jnp.max(jnp.abs(xs), axis=-1, keepdims=True) / _QMAX[cfg.kind]
    # all-zero chunks (e.g. the slab's aligned tail) quantize to exact 0
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    if cfg.kind == "int8":
        q = jnp.clip(jnp.round(xs / scale), -127.0, 127.0).astype(jnp.int8)
    else:  # fp8
        q = (xs / scale).astype(jnp.float8_e4m3fn)
    return q, scale


def dequantize(q, scale):
    """Inverse of :func:`quantize` up to the quantization error."""
    xs = q.astype(jnp.float32) * scale
    return xs.reshape(xs.shape[:-2] + (xs.shape[-2] * xs.shape[-1],))


def roundtrip(x, cfg: TransportConfig):
    """What the server decodes from client payload ``x``."""
    return dequantize(*quantize(x, cfg))


def make_stage(transport):
    """Build the in-round transport stage, or ``None`` when off.

    ``stage(pre, post, ef) -> (post', ef')`` over (c, d) cohort slabs:
    quantize ``(post - pre) + ef`` as the wire delta, reconstruct
    ``post' = pre + dequant`` (the model the server mixes), and carry the
    residual in ``ef'``. Runs BEFORE the fault/robust upload stage —
    faults corrupt, and robust rules sanitize, the payload the wire
    actually carried.
    """
    if transport is None:
        return None
    if not isinstance(transport, TransportConfig):
        got = type(transport).__name__
        raise TypeError(f"FedConfig.transport must be a TransportConfig or None, got {got}")

    def stage(pre, post, ef):
        carry = (post - pre) + ef
        deq = roundtrip(carry, transport)
        return pre + deq, carry - deq

    return stage
