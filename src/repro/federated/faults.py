"""Deterministic fault injection + graceful degradation for cohort rounds.

Fixed-shape injection contract
------------------------------
Every fault is expressed as a masked transform of the quantities the
jitted cohort round ALREADY carries — the replicated (c, d) upload slab
``post_flat`` (the cohort's raveled post-SGD models), its pre-SGD
counterpart ``pre_flat``, and the padded cohort's ``(idx, mask)`` slot
arrays. Nothing changes shape, no host sync happens in-round, and the
whole stage rides inside the ONE compiled round per policy:

  * Byzantine corruption (``attack`` ∈ ``sign_flip`` / ``scaled_noise``
    / ``nan`` / ``inf``) rewrites the attacker slots' rows of
    ``post_flat`` in place — a static attacker set drawn once from
    ``seed`` (:func:`attacker_mask`), so the same clients lie every
    round, like a real compromised population;
  * mid-round upload drops flip a slot to a masked PAD slot after local
    SGD: ``mask`` goes False and ``idx`` becomes the sentinel ``m``, so
    the drop exercises the exact sentinel-drop contract the scatter and
    every masked (c, c) rule were built on — the dropped client keeps
    its previous model and contributes zero mix weight;
  * straggler timeouts are a PRICING fault: ``deadline`` feeds
    :func:`repro.core.comm_model.deadline_round_time`, which censors
    compute times and returns the dropped-slot mask for replays
    (the device round sees them as drops via ``drop_rate``).

The finite guard (:func:`finite_guard`) is the graceful-degradation
half: non-finite upload rows are demoted to masked pad slots AND zeroed
in the slab (a zero-weight column of NaNs would still poison the fused
mix — ``0 · NaN = NaN``), so the round survives ANY number of poisoned
uploads; with every slot demoted the sentinel-index scatter writes
nothing and the round degrades to skip-round semantics (state
unchanged).

Wire-slab generality: the stage operates on whatever slab the strategy's
:class:`~repro.federated.transport.WireSchema` declares as its uplink —
the single ``(c, d_al)`` delta for most strategies, SCAFFOLD's
concatenated ``(c, 2·d_al)`` model+control wire, ... Every transform
here is shape-agnostic over the trailing axis, and the finite guard
checks finiteness PER STREAM (ANDed across the schema's slices — a NaN
in scaffold's control stream demotes the whole slot, exactly like a NaN
in its model stream: the slot's upload is one wire transmission).

Donation interaction: the stage runs between local SGD and the mix
inside the SAME jitted body, on cohort-shaped intermediates — the
donated (m, ·) state buffers are never touched by the rewrite, so the
engine's ``donate_argnums`` discipline (and
``simulation.donation_safe_copy`` for callers) is unchanged.

Determinism: the attacker set is a pure function of ``(seed, m)``; the
per-round drop/noise randomness derives from the round key via
``fold_in`` plus client-indexed per-slot keys
(:func:`repro.core.baselines.common.cohort_keys` discipline), so padded
cohorts reproduce unpadded ones bit-for-bit and a replay with the same
seeds injects the same faults.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import aggregation

_FOLD = 0xFA117  # fault key domain separator (never collides with training)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Opt-in fault model (``FedConfig.faults``).

    Attributes:
      seed: draws the static attacker set (host- and trace-reproducible).
      byzantine_frac: fraction of the m clients that are attackers
        (``round(frac · m)`` of them, fixed for the whole run).
      attack: what an attacker uploads — ``sign_flip`` (the inverted,
        ``attack_scale``-amplified update), ``scaled_noise`` (a random
        Gaussian model of scale ``attack_scale`` around the pre-SGD
        point), ``nan`` / ``inf`` (non-finite garbage; exercises the
        finite guard).
      attack_scale: magnitude knob of sign_flip / scaled_noise.
      drop_rate: per-slot probability a REAL upload is lost mid-round
        (applies to every client, honest or not).
      deadline: straggler compute-time ceiling for §V-D pricing
        (``comm_model.deadline_round_time``); ``inf`` = no timeouts.
    """

    seed: int = 0
    byzantine_frac: float = 0.0
    attack: str = "sign_flip"
    attack_scale: float = 10.0
    drop_rate: float = 0.0
    deadline: float = math.inf

    _ATTACKS = ("sign_flip", "scaled_noise", "nan", "inf")

    def __post_init__(self):
        if self.attack not in self._ATTACKS:
            raise ValueError(f"unknown attack {self.attack!r} (expected one of {self._ATTACKS})")
        if not 0.0 <= self.byzantine_frac <= 1.0:
            raise ValueError(f"byzantine_frac must be in [0, 1], got {self.byzantine_frac}")
        if not 0.0 <= self.drop_rate <= 1.0:
            raise ValueError(f"drop_rate must be in [0, 1], got {self.drop_rate}")


def num_attackers(cfg: FaultConfig, m: int) -> int:
    return int(round(cfg.byzantine_frac * m))


def attacker_mask(cfg: FaultConfig, m: int):
    """The static (m,) bool attacker set — a pure function of (seed, m).

    Usable both inside jit (m is a static shape) and host-side (the
    Byzantine replay needs the same set to score W quarantine mass).
    """
    k = num_attackers(cfg, m)
    out = jnp.zeros((m,), bool)
    if k == 0:
        return out
    perm = jax.random.permutation(jax.random.PRNGKey(cfg.seed), m)
    return out.at[perm[:k]].set(True)


def inject(cfg: FaultConfig, pre_flat, post_flat, idx, mask, key, m: int):
    """Apply the round's faults to the upload stage.

    Args:
      pre_flat / post_flat: (c, d) raveled cohort params before/after
        local SGD (the same pair the W refresh consumes).
      idx / mask: the padded cohort slot arrays.
      key: the ROUND key — folded into the fault domain here, so the
        training key stream is untouched (faults off stays bit-exact).
      m: static client count (sentinel value for drops).
    Returns:
      ``(post_flat', idx', mask')``.
    """
    safe = aggregation.safe_gather_index(idx, m)
    fkey = jax.random.fold_in(key, _FOLD)
    # client-indexed per-slot keys: a slot's faults depend only on its
    # client id and the round, not on cohort composition/padding
    slot_keys = jnp.take(jax.random.split(fkey, m), safe, axis=0)

    if cfg.byzantine_frac > 0.0:
        atk = jnp.take(attacker_mask(cfg, m), safe) & mask
        if cfg.attack == "sign_flip":
            bad = pre_flat - cfg.attack_scale * (post_flat - pre_flat)
        elif cfg.attack == "scaled_noise":

            def _noise(k, r):
                return cfg.attack_scale * jax.random.normal(jax.random.fold_in(k, 1), r.shape)

            bad = pre_flat + jax.vmap(_noise)(slot_keys, post_flat)
        elif cfg.attack == "nan":
            bad = jnp.full_like(post_flat, jnp.nan)
        else:  # inf
            bad = jnp.full_like(post_flat, jnp.inf)
        post_flat = jnp.where(atk[:, None], bad, post_flat)

    if cfg.drop_rate > 0.0:
        u = jax.vmap(lambda k: jax.random.uniform(jax.random.fold_in(k, 2)))(slot_keys)
        drop = (u < cfg.drop_rate) & mask
        mask = mask & ~drop
        idx = jnp.where(drop, m, idx)
    return post_flat, idx, mask


def finite_guard(flat_c, idx, mask, m: int, schema=None):
    """Demote non-finite upload rows to masked pad slots.

    A guarded row gets mask False, the sentinel index ``m`` (so the
    fused scatter drops it — the client keeps its previous model) and a
    ZEROED slab row: the masked rules only zero a bad column's WEIGHT,
    and ``0 · NaN = NaN`` would still poison the mix. With every row
    demoted the round degrades to skip-round semantics. Returns
    ``(flat_c', idx', mask')``.

    ``schema`` (the strategy's wire schema) checks finiteness per uplink
    STREAM slice and ANDs the flags — numerically identical to the
    whole-row check (booleans associate), but it states the contract the
    multi-stream wire needs: ANY stream of a slot's upload going
    non-finite demotes the whole slot.
    """
    if schema is None:
        finite = jnp.all(jnp.isfinite(flat_c), axis=-1)
    else:
        finite = jnp.ones(flat_c.shape[:-1], bool)
        for lo, hi in schema.slices("uplink"):
            finite &= jnp.all(jnp.isfinite(flat_c[..., lo:hi]), axis=-1)
    finite = finite & mask
    return (
        jnp.where(finite[:, None], flat_c, 0.0),
        jnp.where(finite, idx, m),
        finite,
    )


def upload_stage(faults_cfg: FaultConfig | None, robust_cfg=None, schema=None):
    """Compose inject → finite guard → robust rewrite into ONE stage.

    Returns ``None`` when both knobs are off (the round body keeps its
    exact pre-existing trace — bit-exact), else a traceable
    ``stage(pre_flat, post_flat, idx, mask, key, m) ->
    (post_flat', idx', mask')`` the round bodies thread between local
    SGD and the masked mix. ``pre_flat``/``post_flat`` are the
    strategy's concatenated uplink WIRE slab (``schema`` — the single
    delta for most strategies); injection, guard and robust rules are
    all shape-agnostic over its width, and the guard demotes per stream
    (see :func:`finite_guard`). The finite guard runs whenever the stage
    is active: robustness without graceful degradation would still die
    on the first NaN upload, and fault injection without it is the
    non-survival baseline the subsystem exists to remove.
    """
    rstage = aggregation.robust_stage(robust_cfg)
    if faults_cfg is None and rstage is None:
        return None

    def stage(pre_flat, post_flat, idx, mask, key, m):
        if faults_cfg is not None:
            post_flat, idx, mask = inject(faults_cfg, pre_flat, post_flat, idx, mask, key, m)
        post_flat, idx, mask = finite_guard(post_flat, idx, mask, m, schema)
        if rstage is not None:
            post_flat, idx, mask = rstage(post_flat, idx, mask, m)
        return post_flat, idx, mask

    return stage
