"""Buffered-async server aggregation (FedBuff-style) for cohort rounds.

The bulk-synchronous engine prices every round by the cohort's straggler
max: the PS waits for all c uploads before it mixes. The buffered-async
server instead applies uploads as they land — it keeps a small pending
buffer and *flushes* (applies a staleness-weighted aggregation and bumps
its model version) as soon as ``flush_k`` uploads have accumulated, so
the §V-D round time is set by the K-th arrival, not the c-th (see
:func:`repro.core.comm_model.async_round_time`). Rarely-available
clients stop gating the round clock AND stop keeping frozen models:
their uploads are applied whenever they land, merely discounted by how
stale they are.

Fixed-shape buffer contract
---------------------------
Everything lives in strategy state as fixed-shape device arrays so ONE
compiled round serves every dynamics (deposit-only rounds, flush rounds,
availability-starved rounds) — the recompile guard in
tests/test_async_buffer.py pins this:

  * ``upd``   — (B, d) float32 pending upload rows (raveled; model
    uploads for the user-centric rules, model *deltas* for the
    FedAvg-family rule). ``B = flush_k - 1 + slots`` where ``slots`` is
    the participation policy's static cohort slot count: a flush clears
    the buffer whenever it holds ≥ flush_k uploads at round end, so at
    most ``flush_k - 1`` pend across rounds and one round deposits at
    most ``slots`` more — B can never overflow.
  * ``idx``   — (B,) int32 uploading client per slot; the sentinel ``m``
    marks an empty slot (exactly the padded-cohort convention: sentinel
    rows are dropped by every scatter and carry zero weight). Slot
    VALIDITY is ``idx < m`` — a flush only resets ``idx``/``count``;
    the ``upd``/``ver`` payloads of cleared slots are stale garbage
    that nothing may read.
  * ``ver``   — (B,) int32 server version of the base model the slot's
    upload was computed against; at flush time the slot's staleness is
    ``tau = version - ver`` and its aggregation weight is discounted by
    ``(1 + tau) ** -alpha`` (FedBuff's polynomial discount).
  * ``count`` — () int32 number of pending uploads.
  * ``version`` — () int32 flush counter (the server's model version).
  * ``last_sync`` — (m,) int32 server version at which each client's
    model row was last rewritten by a flush; the user-centric rules use
    it as the base version of a client's next upload (the client trains
    from its own row, which has not moved since).

Dedupe rule: a client with an upload already pending overwrites it in
place (latest upload wins) instead of occupying a second slot, so buffer
indices stay unique and the masked (B, B)-row aggregation and sentinel
scatter apply unchanged.

Wiring: opt in via ``FedConfig.async_buffer`` (an :class:`AsyncConfig`).
The cohort dispatcher (:func:`repro.core.baselines.common.cohort_round`)
routes every cohort round to the strategy's buffered body; strategies
whose PS step is not expressible as the masked row aggregation
(SCAFFOLD's controls, Ditto/pFedMe's personal models, FedFomo's
client-side mixing, ucfl_parallel's m× streams) raise at construction
time. The buffer is created lazily on the first cohort round (its slot
count is a participation-policy property the strategy cannot know at
init) and is donated by the jitted round alongside the params — callers
keeping a pre-round state alive must
:func:`repro.federated.simulation.donation_safe_copy` it.

Under ``FedConfig.mesh`` the buffer is replicated like the rest of the
stacked state: local SGD runs shard_mapped and the deposit/flush operate
on the post-all-gather updates (the same place the sync mix runs).
Under ``FedConfig.shard_state`` the (B, d) ``upd`` rows are additionally
row-sharded across the mesh (``init_buffer(..., shards=s)`` pads B to a
shard multiple with extra sentinel slots — bit-invisible: deposits never
reach them and they carry zero weight), deposits route each row to its
owner shard via the ``scatter`` hook of :func:`deposit`, and a flush's
tiled all-gather of ``upd`` is the engine's only model-sized collective;
the (B,) metadata stays replicated.

Row width: ``upd`` rows are the strategy's uplink WIRE slab — the
concatenated aligned stream widths of its
:class:`~repro.federated.transport.WireSchema` (``init_buffer``'s
``schema``), or ``ops.aligned_dim(dim)`` when no schema is given; both
are 128-lane multiples, so the flush's fused ``masked_mix_scatter``
against a flat single-leaf state always takes the aliased zero-copy
kernel path (never a padding copy; see
``masked_mix_scatter.padding_copy_needed``). Every strategy with a
buffered-async body today has a single-delta uplink (the two widths
coincide), but the deposit/flush machinery is width-agnostic: it banks
whatever slab the wire carried. Deposits zero-pad narrower row batches
into the buffer width and flush consumers slice the mixed rows back to
the true dim. The async downlink stays raw f32 (see the transport
capability matrix): a flush rewrites arbitrary row subsets, so there is
no per-receiver reference to delta-code the broadcast against.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.kernels import ops


def _pad_rows(rows, width: int):
    """Zero-pad a (c, d) row batch to the buffer's aligned row width."""
    if rows.shape[1] == width:
        return rows
    return jnp.zeros((rows.shape[0], width), rows.dtype).at[:, : rows.shape[1]].set(rows)


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Buffered-async server policy.

    Attributes:
      flush_k: the server applies the buffered uploads as soon as at
        least ``flush_k`` are pending at the end of a round (the flush
        applies the WHOLE buffer — uploads beyond the K-th landed in the
        same round and waiting for a later flush would only age them).
      alpha: staleness-discount exponent; an upload computed against a
        base model ``tau`` versions old is weighted by
        ``(1 + tau) ** -alpha`` before the usual row renormalization.
        0 disables the discount (pure FIFO buffering).
    """

    flush_k: int = 2
    alpha: float = 0.5

    def __post_init__(self):
        if int(self.flush_k) < 1:
            raise ValueError(f"flush_k must be >= 1, got {self.flush_k}")
        if not 0.0 <= float(self.alpha):
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")

    def capacity(self, slots: int) -> int:
        """Buffer slot count for a policy with ``slots`` cohort slots."""
        return int(self.flush_k) - 1 + int(slots)


def init_buffer(
    cfg: AsyncConfig, m: int, slots: int, dim: int, *, shards: int = 1, schema=None
) -> dict:
    """Fresh (empty) fixed-shape buffer state (see the module docstring).

    ``dim`` is the flat model size; rows are allocated at the 128-aligned
    width (:func:`repro.kernels.ops.aligned_dim`), or — with ``schema``,
    the strategy's wire schema — at its uplink wire-slab width, so the
    buffer banks exactly what the wire carried. ``shards`` pads the
    slot count B up to a multiple so a row-sharded ``upd`` partitions
    evenly — the extra slots are permanently-empty sentinels.
    """
    b = cfg.capacity(slots)
    b = -(-b // int(shards)) * int(shards)
    width = schema.width_aligned("uplink") if schema is not None else ops.aligned_dim(dim)
    return {
        "upd": jnp.zeros((b, width), jnp.float32),
        "idx": jnp.full((b,), m, jnp.int32),
        "ver": jnp.zeros((b,), jnp.int32),
        "count": jnp.zeros((), jnp.int32),
        "version": jnp.zeros((), jnp.int32),
        "last_sync": jnp.zeros((m,), jnp.int32),
    }


def valid_mask(buf, m: int):
    """(B,) bool — slots holding a pending upload (sentinel ``m`` = empty)."""
    return buf["idx"] < m


def deposit(buf, rows, idx, mask, base_ver, m: int, *, scatter=None):
    """Land one cohort's uploads in the buffer (fixed-shape, traceable).

    Args:
      buf: buffer state (:func:`init_buffer`).
      rows: (c, d) raveled upload rows (pad-slot rows are ignored);
        zero-padded here to the buffer's aligned row width.
      idx / mask: the padded cohort's slot arrays (sentinel index ``m``,
        mask False on pad slots).
      base_ver: (c,) int32 server version of the base model each upload
        was computed against (becomes the slot's ``ver``).
      m: client count (the sentinel).
      scatter: optional ``scatter(upd, dest, rows) -> upd`` hook for a
        row-sharded ``upd`` (``StateOps.buffer_scatter``) — it must keep
        the sentinel-drop semantics of the default ``.at[dest].set(...,
        mode="drop")``.

    Real slots whose client already has a pending upload overwrite that
    slot in place (latest wins); the rest append at ``count``-onward
    positions. Pad slots deposit nothing — a padded cohort deposits
    bit-identically to the unpadded one.
    """
    bcap = buf["idx"].shape[0]
    pending = valid_mask(buf, m)  # (B,)
    # (c, B) membership of each incoming client among the pending slots;
    # buffer indices are unique, so each row has at most one hit
    dup = (idx[:, None] == buf["idx"][None, :]) & mask[:, None] & pending[None, :]
    has_dup = jnp.any(dup, axis=1)
    dup_pos = jnp.argmax(dup, axis=1)
    fresh = mask & ~has_dup
    append_pos = buf["count"] + jnp.cumsum(fresh.astype(jnp.int32)) - 1
    # sentinel destination B drops the write (pads and nothing else);
    # last_sync is deliberately untouched — only a flush rewrites model
    # rows, so only flush_reset may move it (the documented contract)
    dest = jnp.where(mask, jnp.where(has_dup, dup_pos, append_pos), bcap)
    rows = _pad_rows(rows.astype(buf["upd"].dtype), buf["upd"].shape[1])
    upd = (
        buf["upd"].at[dest].set(rows, mode="drop")
        if scatter is None
        else scatter(buf["upd"], dest, rows)
    )
    return dict(
        buf,
        upd=upd,
        idx=buf["idx"].at[dest].set(idx, mode="drop"),
        ver=buf["ver"].at[dest].set(base_ver, mode="drop"),
        count=buf["count"] + jnp.sum(fresh.astype(jnp.int32)),
    )


def staleness(buf):
    """(B,) int32 per-slot staleness ``tau = version - ver`` (>= 0)."""
    return jnp.maximum(buf["version"] - buf["ver"], 0)


def staleness_weights(buf, m: int, alpha: float):
    """(B,) float32 flush weights ``valid * (1 + tau) ** -alpha``.

    These multiply the masked aggregation rules' columns in place of the
    binary mask (empty slots get exactly 0, like pad slots); the rules'
    own row renormalization turns them into convex combinations.
    """
    tau = staleness(buf).astype(jnp.float32)
    w = (1.0 + tau) ** (-float(alpha))
    return jnp.where(valid_mask(buf, m), w, 0.0)


def flush_reset(buf, m: int):
    """Post-flush buffer: version bumped, all slots cleared.

    Only ``idx`` and ``count`` are reset (slot validity is ``idx < m``);
    the ``upd``/``ver`` payloads of cleared slots keep stale garbage by
    design — nothing may read a slot whose idx is the sentinel.
    ``last_sync`` of the applied clients is raised to the NEW version:
    their model rows were just rewritten by the flush, so their next
    upload's base is this version.
    """
    new_version = buf["version"] + 1
    synced = buf["last_sync"].at[buf["idx"]].set(
        jnp.full_like(buf["ver"], new_version), mode="drop"
    )
    return dict(
        buf,
        idx=jnp.full_like(buf["idx"], m),
        count=jnp.zeros_like(buf["count"]),
        version=new_version,
        last_sync=synced,
    )


def flush_metrics(flushed, applied, tau, weights, fill):
    """Device-scalar round metrics shared by every async strategy body.

    Args:
      flushed: () bool — did this round apply the buffer.
      applied: () int32 — uploads applied (0 on deposit-only rounds).
      tau: (B,) int32 per-slot staleness at flush time.
      weights: (B,) float32 the flush weights (0 on empty slots).
      fill: () int32 buffer occupancy AFTER the round.
    """
    live = weights > 0
    wsum = jnp.maximum(jnp.sum(live.astype(jnp.float32)), 1.0)
    return {
        "flushed": flushed.astype(jnp.int32),
        "applied": jnp.where(flushed, applied, 0),
        "buffer_fill": fill,
        "tau_max": jnp.where(flushed, jnp.max(jnp.where(live, tau, 0)), 0),
        "tau_mean": jnp.where(
            flushed,
            jnp.sum(jnp.where(live, tau, 0).astype(jnp.float32)) / wsum,
            0.0,
        ),
    }
