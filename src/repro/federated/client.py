"""Client-side local optimization (vmapped across the client axis).

``make_local_sgd`` builds the paper's ClientUpdate procedure: E epochs of
minibatch SGD (η=0.1, β=0.9 heavy-ball momentum, fresh optimizer each
round), as a jit/scan program. A ``grad_hook`` lets baselines inject
per-step gradient corrections (FedProx proximal term, SCAFFOLD control
variates, Ditto/pFedMe regularizers) without duplicating the loop.

Memory knob: ``make_federated_local_sgd(..., chunk_size=C)`` replaces the
monolithic client vmap with a sequential ``lax.map`` over ⌈m/C⌉ chunks of
C clients each, so peak activation memory is O(C) instead of O(m) while
per-client results stay identical (same per-client PRNG keys). Use it to
scale the client axis (or a sampled cohort) to thousands of clients on a
single host; leave it ``None`` for the fastest fully-parallel path.

Parallel knob: ``make_federated_local_sgd(..., mesh=...)`` shards the
client/slot axis across a 1-D device mesh (see
:mod:`repro.federated.mesh`): each device runs the vmapped local SGD on
its own block of rows under ``shard_map`` and the per-row results are
all-gathered back, so cohort wall-time scales down with the shard count
instead of growing linearly with cohort size. ``chunk_size`` composes —
chunking applies *within* each device's shard. Results match
``mesh=None`` within float32 round-off (see
:func:`repro.federated.mesh.shard_clients` for why not bit-exact).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.loader import epoch_batches
from repro.federated import mesh as mesh_lib
from repro.optim import sgd_init, sgd_update


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_loss(apply_fn):
    def loss(params, x, y):
        return cross_entropy(apply_fn(params, x), y)
    return loss


def make_local_sgd(apply_fn, *, lr=0.1, momentum=0.9, epochs=1,
                   batch_size=50, grad_hook=None):
    """Returns local_sgd(params, x, y, key, hook_state) -> (params, hook_state).

    hook_state is an arbitrary pytree threaded through every SGD step and
    passed to ``grad_hook(grads, params, hook_state) -> (grads, hook_state)``.
    """
    loss = make_loss(apply_fn)
    grad_fn = jax.grad(loss)

    def local_sgd(params, x, y, key, hook_state=None):
        def one_epoch(carry, ekey):
            params, mom, hstate = carry
            xb, yb = epoch_batches(ekey, x, y, batch_size)

            def step(c, batch):
                params, mom, hstate = c
                bx, by = batch
                g = grad_fn(params, bx, by)
                if grad_hook is not None:
                    g, hstate = grad_hook(g, params, hstate)
                params, mom = sgd_update(g, mom, params, lr=lr,
                                         momentum=momentum)
                return (params, mom, hstate), None

            (params, mom, hstate), _ = jax.lax.scan(
                step, (params, mom, hstate), (xb, yb)
            )
            return (params, mom, hstate), None

        mom = sgd_init(params, momentum=momentum)
        (params, _, hook_state), _ = jax.lax.scan(
            one_epoch, (params, mom, hook_state), jax.random.split(key, epochs)
        )
        return params, hook_state

    return local_sgd


def client_vmap(fn, *, chunk_size=None, mesh=None):
    """vmap ``fn`` over a shared leading client axis of every argument.

    With ``chunk_size=C`` the client axis is instead processed as a
    sequential ``lax.map`` over chunks of C vmapped clients (last chunk
    padded by repeating index 0; padding results are discarded), bounding
    peak memory by the chunk instead of the full axis while keeping
    per-client results identical to the monolithic vmap. Arguments that
    are ``None`` (empty pytrees) pass through unmapped.

    With ``mesh`` (a :mod:`repro.federated.mesh` knob: Mesh | int |
    ``"auto"``) the client axis is partitioned across the mesh's devices
    under ``shard_map``: each device runs the chunked vmap on its own
    block and the per-row results are all-gathered back to full
    replicated arrays (matching the unsharded vmap within f32 round-off;
    see :func:`repro.federated.mesh.shard_clients`). Chunking applies
    *within* each shard. An axis not divisible by the shard count falls
    back to the unsharded path — the cohort engine pads slot counts to a shard
    multiple (:func:`repro.federated.mesh.pad_cohort`) so the masked
    round always shards; a dense m that the mesh doesn't divide simply
    stays single-device.
    """
    mesh = mesh_lib.resolve(mesh)
    vfn = jax.vmap(fn)

    def block(args):
        m = jax.tree.leaves(args)[0].shape[0]
        if chunk_size is None or m <= chunk_size:
            return vfn(*args)

        nc = -(-m // chunk_size)
        pad = nc * chunk_size - m

        def prep(t):
            def leaf(a):
                if pad:
                    a = jnp.concatenate(
                        [a, jnp.repeat(a[:1], pad, axis=0)], axis=0)
                return a.reshape((nc, chunk_size) + a.shape[1:])
            return jax.tree.map(leaf, t)

        def unprep(t):
            return jax.tree.map(
                lambda a: a.reshape((nc * chunk_size,) + a.shape[2:])[:m], t)

        return unprep(jax.lax.map(lambda chunk: vfn(*chunk), prep(args)))

    def mapped(*args):
        m = jax.tree.leaves(args)[0].shape[0]
        if mesh is not None and m % mesh_lib.num_shards(mesh) == 0:
            return mesh_lib.shard_clients(
                lambda *local_args: block(local_args), mesh)(*args)
        return block(args)

    return mapped


def make_federated_local_sgd(apply_fn, *, chunk_size=None, mesh=None, **kw):
    """:func:`client_vmap` of ``make_local_sgd`` over the client axis.

    Returns fed(stacked_params, x, y, key, hook_state) -> (params, hook_state);
    hook_state leaves, when present, must carry a leading client axis.
    ``chunk_size`` bounds peak memory and ``mesh`` shards the client axis
    across devices (see :func:`client_vmap`).
    """
    local = make_local_sgd(apply_fn, **kw)
    run = client_vmap(local, chunk_size=chunk_size, mesh=mesh)

    def fed(stacked_params, x, y, key, hook_state=None, *, keys=None):
        # ``keys`` overrides the default split(key, m) per-row derivation
        # with precomputed per-row keys — the masked cohort engine passes
        # client-indexed keys so a slot's randomness is independent of the
        # cohort's slot count (padding invariance).
        if keys is None:
            keys = jax.random.split(key, x.shape[0])
        return run(stacked_params, x, y, keys, hook_state)

    return fed


def full_gradients(apply_fn, stacked_params, x, y):
    """Per-client full-batch gradients (the special round's upload)."""
    loss = make_loss(apply_fn)
    return jax.vmap(jax.grad(loss))(stacked_params, x, y)


def minibatch_gradients(apply_fn, stacked_params, xb, yb):
    """Gradients on a fixed minibatch partition: xb (m, K, B, ...)."""
    loss = make_loss(apply_fn)
    g = jax.vmap(jax.vmap(jax.grad(loss), in_axes=(None, 0, 0)))(
        stacked_params, xb, yb
    )
    return g  # leaves: (m, K, ...)


def evaluate(apply_fn, stacked_params, x_test, y_test, *, batch=None,
             mesh=None):
    """Per-client test accuracy. Returns (m,) accuracies.

    ``batch`` bounds the client axis via :func:`client_vmap`'s
    ``chunk_size`` path: accuracies are computed as a sequential
    ``lax.map`` over chunks of that many clients, so peak activation
    memory is O(batch · test_set) instead of O(m · test_set). ``None``
    keeps the fully-parallel vmap (identical results). ``mesh`` shards
    the client axis across devices; logits then match the unsharded
    pass only within f32 round-off (see :func:`client_vmap`), so a
    near-tied argmax can in principle flip a prediction.
    """

    def acc_one(params, x, y):
        logits = apply_fn(params, x)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    return client_vmap(acc_one, chunk_size=batch, mesh=mesh)(
        stacked_params, x_test, y_test)
