"""Client-side local optimization (vmapped across the client axis).

``make_local_sgd`` builds the paper's ClientUpdate procedure: E epochs of
minibatch SGD (η=0.1, β=0.9 heavy-ball momentum, fresh optimizer each
round), as a jit/scan program. A ``grad_hook`` lets baselines inject
per-step gradient corrections (FedProx proximal term, SCAFFOLD control
variates, Ditto/pFedMe regularizers) without duplicating the loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.loader import epoch_batches
from repro.optim import sgd_init, sgd_update


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_loss(apply_fn):
    def loss(params, x, y):
        return cross_entropy(apply_fn(params, x), y)
    return loss


def make_local_sgd(apply_fn, *, lr=0.1, momentum=0.9, epochs=1,
                   batch_size=50, grad_hook=None):
    """Returns local_sgd(params, x, y, key, hook_state) -> (params, hook_state).

    hook_state is an arbitrary pytree threaded through every SGD step and
    passed to ``grad_hook(grads, params, hook_state) -> (grads, hook_state)``.
    """
    loss = make_loss(apply_fn)
    grad_fn = jax.grad(loss)

    def local_sgd(params, x, y, key, hook_state=None):
        def one_epoch(carry, ekey):
            params, mom, hstate = carry
            xb, yb = epoch_batches(ekey, x, y, batch_size)

            def step(c, batch):
                params, mom, hstate = c
                bx, by = batch
                g = grad_fn(params, bx, by)
                if grad_hook is not None:
                    g, hstate = grad_hook(g, params, hstate)
                params, mom = sgd_update(g, mom, params, lr=lr,
                                         momentum=momentum)
                return (params, mom, hstate), None

            (params, mom, hstate), _ = jax.lax.scan(
                step, (params, mom, hstate), (xb, yb)
            )
            return (params, mom, hstate), None

        mom = sgd_init(params, momentum=momentum)
        (params, _, hook_state), _ = jax.lax.scan(
            one_epoch, (params, mom, hook_state), jax.random.split(key, epochs)
        )
        return params, hook_state

    return local_sgd


def make_federated_local_sgd(apply_fn, **kw):
    """vmap of ``make_local_sgd`` over the leading client axis.

    Returns fed(stacked_params, x, y, key, hook_state) -> (params, hook_state);
    hook_state leaves, when present, must carry a leading client axis.
    """
    local = make_local_sgd(apply_fn, **kw)

    def fed(stacked_params, x, y, key, hook_state=None):
        m = x.shape[0]
        keys = jax.random.split(key, m)
        axes = (0, 0, 0, 0, None if hook_state is None else 0)
        return jax.vmap(local, in_axes=axes)(stacked_params, x, y, keys,
                                             hook_state)

    return fed


def full_gradients(apply_fn, stacked_params, x, y):
    """Per-client full-batch gradients (the special round's upload)."""
    loss = make_loss(apply_fn)
    return jax.vmap(jax.grad(loss))(stacked_params, x, y)


def minibatch_gradients(apply_fn, stacked_params, xb, yb):
    """Gradients on a fixed minibatch partition: xb (m, K, B, ...)."""
    loss = make_loss(apply_fn)
    g = jax.vmap(jax.vmap(jax.grad(loss), in_axes=(None, 0, 0)))(
        stacked_params, xb, yb
    )
    return g  # leaves: (m, K, ...)


def evaluate(apply_fn, stacked_params, x_test, y_test, *, batch=None):
    """Per-client test accuracy. Returns (m,) accuracies."""

    def acc_one(params, x, y):
        logits = apply_fn(params, x)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    return jax.vmap(acc_one)(stacked_params, x_test, y_test)
