"""Federated simulation engine: rounds loop + per-round evaluation."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List

import jax
import numpy as np

from repro.federated.client import evaluate


@dataclasses.dataclass
class History:
    strategy: str
    rounds: List[int]
    avg_acc: List[float]
    worst_acc: List[float]
    metrics: List[Dict[str, Any]]
    wall_s: float = 0.0

    @property
    def final_avg(self):
        return self.avg_acc[-1]

    @property
    def final_worst(self):
        return self.worst_acc[-1]

    @property
    def best_avg(self):
        return max(self.avg_acc)


def run(strategy, apply_fn, data, key, *, rounds: int, eval_every: int = 1,
        verbose: bool = False) -> History:
    t0 = time.time()
    key, ikey = jax.random.split(key)
    state = strategy.init(ikey, data)
    hist = History(strategy.name, [], [], [], [])

    def do_eval(rnd, metrics):
        accs = np.asarray(
            evaluate(apply_fn, strategy.eval_params(state), data.x_test,
                     data.y_test)
        )
        hist.rounds.append(rnd)
        hist.avg_acc.append(float(accs.mean()))
        hist.worst_acc.append(float(accs.min()))
        hist.metrics.append(metrics)
        if verbose:
            print(f"[{strategy.name}] round {rnd:4d} "
                  f"avg={accs.mean():.4f} worst={accs.min():.4f}")

    metrics: Dict[str, Any] = {}
    for rnd in range(1, rounds + 1):
        key, rkey = jax.random.split(key)
        state, metrics = strategy.round(state, data, rkey)
        if rnd % eval_every == 0 or rnd == rounds:
            do_eval(rnd, metrics)
    hist.wall_s = time.time() - t0
    return hist


def run_trials(make_strategy, apply_fn, data_fn, *, trials: int, rounds: int,
               seed: int = 0, eval_every: int = 1):
    """Average over independent trials (paper reports 5-trial means)."""
    finals, worsts, hists = [], [], []
    for t in range(trials):
        key = jax.random.PRNGKey(seed + 1000 * t)
        dkey, skey = jax.random.split(key)
        data = data_fn(dkey)
        strat = make_strategy(t)
        h = run(strat, apply_fn, data, skey, rounds=rounds,
                eval_every=eval_every)
        finals.append(h.best_avg)
        worsts.append(max(h.worst_acc))
        hists.append(h)
    return {
        "avg_mean": float(np.mean(finals)),
        "avg_std": float(np.std(finals)),
        "worst_mean": float(np.mean(worsts)),
        "histories": hists,
    }
