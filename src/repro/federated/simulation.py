"""Federated simulation engine: rounds loop + per-round evaluation.

Partial participation: ``run(..., participation=ParticipationConfig(...))``
draws a fixed-shape padded cohort per round (see
:mod:`repro.federated.participation`) and passes it to
``strategy.round(state, data, key, cohort)``. The cohort sampler uses its
own numpy seed stream, so the jax round keys — and hence the
``fraction=1.0`` trajectory — are identical to the dense engine's.
Because every cohort of a policy has the same slot count, the jitted
round compiles exactly once even when the availability sampler's
eligible set varies.

Round metrics flow through untouched: strategies running the streaming
W refresh (``FedConfig.w_refresh``) report the per-client ``staleness``
vector plus ``staleness_max``/``staleness_mean`` device scalars each
cohort round; ``verbose=True`` prints the scalar pair.

Timing: ``strategy.round`` is warmed up once (result discarded) before the
wall-clock timer starts, so ``History.wall_s`` measures steady-state
rounds, not XLA compilation. The warm-up key is ``fold_in``-derived and
does not consume the round key stream; the warm-up runs on a *copy* of
the state because the cohort round donates its stacked buffers. The
per-round evaluation passes are timed separately into ``History.eval_s``
and EXCLUDED from ``wall_s`` — eval frequency is a measurement choice,
not a property of the round engine, and benchmark consumers comparing
engines by ``wall_s`` must not see it.

Evaluation: ``eval_chunk`` bounds the client axis of the per-round
accuracy pass with the same ``lax.map`` machinery as training, so eval
no longer materializes O(m · test_set) activations at once; pass
``eval_mesh`` (typically the same knob as ``FedConfig.mesh``) to shard
that pass across devices instead.

Sharding: a strategy built with ``FedConfig(mesh=...)`` (see
:mod:`repro.federated.mesh`) runs its cohort local SGD partitioned
across devices; the rounds loop itself is mesh-agnostic — the round
dispatcher pads slot counts to a shard multiple internally, and every
padded cohort of a policy still has ONE static shape, so the
one-compilation guarantee and the warm-up logic below hold unchanged
(sharded results match the unsharded engine within f32 round-off).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated import participation as part
from repro.federated.client import evaluate


@dataclasses.dataclass
class History:
    """Per-run eval trajectory + timing split.

    ``wall_s`` is the steady-state ROUND time only (warm-up/compilation
    excluded by the warm-up call, evaluation excluded by construction);
    ``eval_s`` holds the accumulated evaluation time separately.
    """

    strategy: str
    rounds: List[int]
    avg_acc: List[float]
    worst_acc: List[float]
    metrics: List[Dict[str, Any]]
    wall_s: float = 0.0
    eval_s: float = 0.0

    @property
    def final_avg(self):
        return self.avg_acc[-1]

    @property
    def final_worst(self):
        return self.worst_acc[-1]

    @property
    def best_avg(self):
        return max(self.avg_acc)

    @property
    def paired_best(self):
        """(avg, worst) evaluated at the argmax-average round.

        Tables 1/2 pair average and worst-user accuracy of ONE model;
        taking max() of each list independently would mix two different
        rounds' models.
        """
        i = int(np.argmax(self.avg_acc))
        return self.avg_acc[i], self.worst_acc[i]


def donation_safe_copy(state):
    """Copy the device-array leaves so a donating round can't eat them.

    The masked cohort round donates its stacked state buffers
    (``donate_argnums``) — the (m, ·) params trees AND, with the
    streaming W refresh on, the Δ/σ²/gradient-proxy/staleness buffers in
    ``state["refresh"]`` — so any caller of ``strategy.round`` that keeps
    the pre-round state alive — warm-ups, A/B comparisons from one start
    state, benchmarks — must run the round on a copy. This is the
    sanctioned helper for that (it copies every ``jax.Array`` leaf, the
    refresh buffers included).
    """
    return jax.tree.map(
        lambda x: x.copy() if isinstance(x, jax.Array) else x, state)


_donation_safe_copy = donation_safe_copy  # backward-compatible alias


@jax.jit
def _client_rows_finite(stacked):
    """(m,) bool: every leaf of client i's eval params is finite."""
    def leaf_finite(x):
        return jnp.all(jnp.isfinite(x.astype(jnp.float32)),
                       axis=tuple(range(1, x.ndim)))
    leaves = [leaf_finite(x) for x in jax.tree.leaves(stacked)]
    return jnp.all(jnp.stack(leaves, axis=0), axis=0)


def _check_finite_state(strategy, state, rnd):
    """Fail fast on non-finite models instead of silently training on
    NaNs for the rest of the run. Raises with the round index and the
    offending client rows; runs only at eval rounds (one host sync) and
    stands down when the strategy itself injects faults
    (``Strategy.injects_faults`` — the finite guard absorbs those)."""
    finite = np.asarray(_client_rows_finite(strategy.eval_params(state)))
    if not finite.all():
        bad = np.nonzero(~finite)[0].tolist()
        raise RuntimeError(
            f"non-finite model state after round {rnd} "
            f"(strategy {strategy.name!r}, client rows {bad}): a NaN/Inf "
            "upload leaked into aggregation. Enable FedConfig.faults / "
            "FedConfig.robust for guarded degradation, or pass "
            "check_finite=False to simulation.run to opt out")


def run(strategy, apply_fn, data, key, *, rounds: int, eval_every: int = 1,
        verbose: bool = False, participation: part.ParticipationConfig | None
        = None, warmup: bool = True, eval_chunk: int | None = None,
        eval_mesh=None, check_finite: bool | None = None,
        selection=None) -> History:
    m = data.num_clients
    if selection is not None:
        # Pareto-biased cohort draws (FedConfig.selection): rewrite the
        # participation policy to the pareto sampler carrying the per-
        # client bias factors; the strategy never draws cohorts itself
        participation = part.with_selection(participation, selection)
    key, ikey = jax.random.split(key)
    state = strategy.init(ikey, data)
    hist = History(strategy.name, [], [], [], [])
    # None = on unless the strategy deliberately injects faults (its
    # finite guard owns degradation there; raising would defeat it)
    if check_finite is None:
        check_finite = not strategy.injects_faults

    if warmup:  # compile strategy.round outside the timed region
        wcohort = part.sample_cohort(participation, 1, m, data.n)
        if wcohort is not None and len(wcohort) == 0:
            # round 1 is all-offline; every cohort of a policy shares one
            # compiled shape, so warm up with a synthetic one-member
            # cohort of the same slot count instead of skipping (which
            # would push the compile into the timed region)
            idx = np.full(wcohort.num_slots, m, np.int32)
            idx[0] = 0
            mask = np.zeros(wcohort.num_slots, bool)
            mask[0] = True
            wcohort = part.Cohort(indices=idx, mask=mask)
        wstate, _ = strategy.round(
            donation_safe_copy(state), data,
            jax.random.fold_in(key, 0x5EED), wcohort)
        jax.block_until_ready(wstate)
        del wstate

    t0 = time.time()

    def do_eval(rnd, metrics):
        if check_finite:
            _check_finite_state(strategy, state, rnd)
        te = time.time()
        accs = np.asarray(
            evaluate(apply_fn, strategy.eval_params(state), data.x_test,
                     data.y_test, batch=eval_chunk, mesh=eval_mesh)
        )
        hist.eval_s += time.time() - te
        hist.rounds.append(rnd)
        hist.avg_acc.append(float(accs.mean()))
        hist.worst_acc.append(float(accs.min()))
        hist.metrics.append(metrics)
        if verbose:
            stale = ("" if "staleness_max" not in metrics else
                     f" stale_max={int(metrics['staleness_max'])}"
                     f" stale_mean={float(metrics['staleness_mean']):.1f}")
            print(f"[{strategy.name}] round {rnd:4d} "
                  f"avg={accs.mean():.4f} worst={accs.min():.4f} "
                  f"cohort={metrics.get('cohort_size', m)}{stale}")

    metrics: Dict[str, Any] = {}
    for rnd in range(1, rounds + 1):
        key, rkey = jax.random.split(key)
        cohort = part.sample_cohort(participation, rnd, m, data.n)
        if cohort is not None and len(cohort) == 0:
            # nobody available this round: the server idles and no
            # training/aggregation runs — but time still passes for
            # per-client bookkeeping (e.g. the streaming W refresh's
            # staleness counters), which the strategy's skip hook owns.
            # Skipping state entirely here used to freeze the counters
            # for rounds nobody attends.
            if strategy.skip_round is not None:
                state = strategy.skip_round(state)
            metrics = {"streams": 0, "cohort_size": 0, "skipped": True}
        else:
            state, metrics = strategy.round(state, data, rkey, cohort)
        if rnd % eval_every == 0 or rnd == rounds:
            do_eval(rnd, metrics)
    hist.wall_s = time.time() - t0 - hist.eval_s
    return hist


def run_trials(make_strategy, apply_fn, data_fn, *, trials: int, rounds: int,
               seed: int = 0, eval_every: int = 1, participation=None,
               selection=None):
    """Average over independent trials (paper reports 5-trial means).

    The reported (avg, worst) pair comes from one model per trial — the
    argmax-average eval round — matching how Tables 1/2 pair them.
    """
    finals, worsts, hists = [], [], []
    for t in range(trials):
        key = jax.random.PRNGKey(seed + 1000 * t)
        dkey, skey = jax.random.split(key)
        data = data_fn(dkey)
        strat = make_strategy(t)
        h = run(strat, apply_fn, data, skey, rounds=rounds,
                eval_every=eval_every, participation=participation,
                selection=selection)
        avg, worst = h.paired_best
        finals.append(avg)
        worsts.append(worst)
        hists.append(h)
    return {
        "avg_mean": float(np.mean(finals)),
        "avg_std": float(np.std(finals)),
        "worst_mean": float(np.mean(worsts)),
        # the paper's worst-node headline metric needs its spread too —
        # reporting avg_std without worst_std hid the (typically much
        # larger) variance of the minimum
        "worst_std": float(np.std(worsts)),
        "histories": hists,
    }
