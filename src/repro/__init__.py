"""repro — User-Centric Federated Learning on multi-pod TPU meshes (JAX)."""
__version__ = "1.0.0"
