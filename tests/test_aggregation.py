"""Aggregation-rule tests (Eq. 1/8, §IV-B clustered group-cast)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.baselines.common import group_average, group_mixing_matrix


def _stacked(seed, m):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(m, 3, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(m, 7)).astype(np.float32)),
    }


def test_fedavg_is_weighted_mean():
    m = 5
    stacked = _stacked(0, m)
    n = jnp.asarray([1.0, 2.0, 3.0, 4.0, 10.0])
    out = aggregation.fedavg(stacked, n)
    wts = np.asarray(n) / np.asarray(n).sum()
    for key in stacked:
        want = np.tensordot(wts, np.asarray(stacked[key]), axes=(0, 0))
        got = np.asarray(out[key])
        assert got.shape == stacked[key].shape  # broadcast back to clients
        for i in range(m):
            np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-6)


def test_identity_w_is_local_training():
    m = 4
    stacked = _stacked(1, m)
    out = aggregation.user_centric(stacked, jnp.eye(m))
    for key in stacked:
        np.testing.assert_allclose(np.asarray(out[key]),
                                   np.asarray(stacked[key]), rtol=1e-6)


def test_user_centric_matches_manual_einsum():
    m = 6
    stacked = _stacked(2, m)
    rng = np.random.default_rng(3)
    w = rng.dirichlet(np.ones(m), size=m).astype(np.float32)
    out = aggregation.user_centric(stacked, jnp.asarray(w))
    for key in stacked:
        want = np.einsum("ij,j...->i...", w, np.asarray(stacked[key]))
        np.testing.assert_allclose(np.asarray(out[key]), want,
                                   rtol=1e-4, atol=1e-5)


def test_clustered_with_m_clusters_equals_user_centric():
    """m_t = m with singleton clusters reproduces full personalization."""
    m = 5
    stacked = _stacked(4, m)
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.dirichlet(np.ones(m), size=m).astype(np.float32))
    labels = jnp.arange(m)
    full = aggregation.user_centric(stacked, w)
    clus = aggregation.clustered(stacked, w, labels, m)
    for key in stacked:
        np.testing.assert_allclose(np.asarray(clus[key]),
                                   np.asarray(full[key]), rtol=1e-4,
                                   atol=1e-5)


def test_clustered_members_share_models():
    m = 6
    stacked = _stacked(6, m)
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.dirichlet(np.ones(m), size=m).astype(np.float32))
    labels = jnp.asarray([0, 0, 0, 1, 1, 1])
    out = aggregation.clustered(stacked, w, labels, 2)
    for key in stacked:
        arr = np.asarray(out[key])
        np.testing.assert_allclose(arr[0], arr[1], rtol=1e-6)
        np.testing.assert_allclose(arr[3], arr[5], rtol=1e-6)
        assert np.abs(arr[0] - arr[3]).max() > 1e-4


def test_group_average_blockwise():
    m = 4
    stacked = _stacked(8, m)
    assignment = jnp.asarray([0, 0, 1, 1])
    n = jnp.ones((m,))
    out = group_average(stacked, assignment, n)
    for key in stacked:
        arr = np.asarray(out[key])
        src = np.asarray(stacked[key])
        np.testing.assert_allclose(arr[0], (src[0] + src[1]) / 2, rtol=1e-5)
        np.testing.assert_allclose(arr[2], (src[2] + src[3]) / 2, rtol=1e-5)


def test_group_mixing_matrix_row_stochastic():
    assignment = jnp.asarray([0, 1, 0, 2, 1])
    n = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
    w = np.asarray(group_mixing_matrix(assignment, n))
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-6)
    assert w[0, 1] == 0 and w[0, 2] > 0


# ------------------------------------------------- cohort (partial-part.)

def _random_cohorts(rng, m):
    """A spread of cohort sizes including the degenerate and full ones."""
    for c in {1, 2, max(2, m // 2), m - 1, m}:
        yield jnp.asarray(np.sort(rng.choice(m, size=c, replace=False))
                          .astype(np.int32))


def test_cohort_mixing_matrix_row_stochastic():
    """Property sweep: sliced+renormalized rows sum to 1, stay >= 0."""
    for seed in range(20):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(3, 12))
        w = jnp.asarray(rng.dirichlet(np.ones(m), size=m).astype(np.float32))
        for cohort in _random_cohorts(rng, m):
            wc = np.asarray(aggregation.cohort_mixing_matrix(w, cohort))
            assert wc.shape == (len(cohort), len(cohort))
            assert (wc >= 0).all()
            np.testing.assert_allclose(wc.sum(axis=1), 1.0, rtol=1e-5)


def test_cohort_mixing_matrix_degenerate_row_falls_back_to_self():
    """A participant with all its W mass on absent clients keeps itself."""
    w = jnp.asarray([[0.0, 0.0, 1.0, 0.0],
                     [0.0, 0.5, 0.0, 0.5],
                     [1.0, 0.0, 0.0, 0.0],
                     [0.0, 0.5, 0.0, 0.5]], jnp.float32)
    cohort = jnp.asarray([0, 1, 3])  # client 0's whole row sits on absent 2
    wc = np.asarray(aggregation.cohort_mixing_matrix(w, cohort))
    np.testing.assert_allclose(wc[0], [1.0, 0.0, 0.0])  # identity fallback
    np.testing.assert_allclose(wc.sum(axis=1), 1.0, rtol=1e-6)


def test_user_centric_cohort_full_cohort_is_user_centric():
    m = 6
    stacked = _stacked(10, m)
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.dirichlet(np.ones(m), size=m).astype(np.float32))
    cohort = jnp.arange(m)
    full = aggregation.user_centric(stacked, w)
    coh = aggregation.user_centric_cohort(stacked, w, cohort)
    for key in stacked:
        np.testing.assert_allclose(np.asarray(coh[key]),
                                   np.asarray(full[key]), rtol=1e-5,
                                   atol=1e-6)


def test_user_centric_cohort_matches_manual():
    m = 7
    stacked = _stacked(12, m)
    rng = np.random.default_rng(13)
    w = rng.dirichlet(np.ones(m), size=m).astype(np.float32)
    cohort = np.asarray([0, 2, 5], np.int32)
    sub = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[cohort]), stacked)
    out = aggregation.user_centric_cohort(sub, jnp.asarray(w),
                                          jnp.asarray(cohort))
    wc = w[np.ix_(cohort, cohort)]
    wc = wc / wc.sum(axis=1, keepdims=True)
    for key in stacked:
        want = np.einsum("ij,j...->i...", wc, np.asarray(stacked[key])[cohort])
        np.testing.assert_allclose(np.asarray(out[key]), want, rtol=1e-4,
                                   atol=1e-5)


def test_fedavg_cohort_weighted_mean_broadcast_to_all():
    m = 6
    stacked = _stacked(14, m)
    cohort = np.asarray([1, 3, 4], np.int32)
    sub = jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[cohort]), stacked)
    n_c = jnp.asarray([2.0, 1.0, 1.0])
    out = aggregation.fedavg_cohort(sub, n_c, m)
    wts = np.asarray([0.5, 0.25, 0.25])
    for key in stacked:
        want = np.tensordot(wts, np.asarray(stacked[key])[cohort],
                            axes=(0, 0))
        got = np.asarray(out[key])
        assert got.shape == stacked[key].shape  # broadcast to all m
        for i in range(m):
            np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-6)


def test_clustered_cohort_full_cohort_matches_clustered():
    m = 6
    stacked = _stacked(15, m)
    rng = np.random.default_rng(16)
    w = jnp.asarray(rng.dirichlet(np.ones(m), size=m).astype(np.float32))
    labels = jnp.asarray([0, 0, 1, 1, 0, 1])
    full = aggregation.clustered(stacked, w, labels, 2)
    coh = aggregation.clustered_cohort(stacked, w, labels, 2, jnp.arange(m))
    for key in stacked:
        np.testing.assert_allclose(np.asarray(coh[key]),
                                   np.asarray(full[key]), rtol=1e-4,
                                   atol=1e-5)


def test_clustered_cohort_degenerate_rule_keeps_own_update():
    """A lone-cluster participant whose W mass is on absent clients keeps
    its own locally-updated model (mirrors cohort_mixing_matrix)."""
    m = 4
    stacked = _stacked(19, m)
    w = jnp.asarray([[0.0, 0.0, 1.0, 0.0],   # client 0: all mass on absent 2
                     [0.0, 0.5, 0.0, 0.5],
                     [1.0, 0.0, 0.0, 0.0],
                     [0.0, 0.5, 0.0, 0.5]], jnp.float32)
    labels = jnp.asarray([0, 1, 1, 1])       # client 0 alone in cluster 0
    cohort = jnp.asarray([0, 1, 3])
    sub = jax.tree.map(lambda x: x[cohort], stacked)
    out = aggregation.clustered_cohort(sub, w, labels, 2, cohort)
    for key in stacked:
        arr = np.asarray(out[key])
        np.testing.assert_allclose(arr[0], np.asarray(stacked[key])[0],
                                   rtol=1e-6)  # kept own update, not zeros
        assert np.abs(arr[1]).max() > 0


def test_clustered_cohort_members_share_models():
    m = 6
    stacked = _stacked(17, m)
    rng = np.random.default_rng(18)
    w = jnp.asarray(rng.dirichlet(np.ones(m), size=m).astype(np.float32))
    labels = jnp.asarray([0, 0, 0, 1, 1, 1])
    cohort = jnp.asarray([0, 1, 3, 5])
    sub = jax.tree.map(lambda x: x[cohort], stacked)
    out = aggregation.clustered_cohort(sub, w, labels, 2, cohort)
    for key in stacked:
        arr = np.asarray(out[key])
        np.testing.assert_allclose(arr[0], arr[1], rtol=1e-6)  # cluster 0
        np.testing.assert_allclose(arr[2], arr[3], rtol=1e-6)  # cluster 1
        assert np.abs(arr[0] - arr[2]).max() > 1e-4
