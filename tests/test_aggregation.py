"""Aggregation-rule tests (Eq. 1/8, §IV-B clustered group-cast)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.baselines.common import group_average, group_mixing_matrix


def _stacked(seed, m):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(m, 3, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(m, 7)).astype(np.float32)),
    }


def test_fedavg_is_weighted_mean():
    m = 5
    stacked = _stacked(0, m)
    n = jnp.asarray([1.0, 2.0, 3.0, 4.0, 10.0])
    out = aggregation.fedavg(stacked, n)
    wts = np.asarray(n) / np.asarray(n).sum()
    for key in stacked:
        want = np.tensordot(wts, np.asarray(stacked[key]), axes=(0, 0))
        got = np.asarray(out[key])
        assert got.shape == stacked[key].shape  # broadcast back to clients
        for i in range(m):
            np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-6)


def test_identity_w_is_local_training():
    m = 4
    stacked = _stacked(1, m)
    out = aggregation.user_centric(stacked, jnp.eye(m))
    for key in stacked:
        np.testing.assert_allclose(np.asarray(out[key]),
                                   np.asarray(stacked[key]), rtol=1e-6)


def test_user_centric_matches_manual_einsum():
    m = 6
    stacked = _stacked(2, m)
    rng = np.random.default_rng(3)
    w = rng.dirichlet(np.ones(m), size=m).astype(np.float32)
    out = aggregation.user_centric(stacked, jnp.asarray(w))
    for key in stacked:
        want = np.einsum("ij,j...->i...", w, np.asarray(stacked[key]))
        np.testing.assert_allclose(np.asarray(out[key]), want,
                                   rtol=1e-4, atol=1e-5)


def test_clustered_with_m_clusters_equals_user_centric():
    """m_t = m with singleton clusters reproduces full personalization."""
    m = 5
    stacked = _stacked(4, m)
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.dirichlet(np.ones(m), size=m).astype(np.float32))
    labels = jnp.arange(m)
    full = aggregation.user_centric(stacked, w)
    clus = aggregation.clustered(stacked, w, labels, m)
    for key in stacked:
        np.testing.assert_allclose(np.asarray(clus[key]),
                                   np.asarray(full[key]), rtol=1e-4,
                                   atol=1e-5)


def test_clustered_members_share_models():
    m = 6
    stacked = _stacked(6, m)
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.dirichlet(np.ones(m), size=m).astype(np.float32))
    labels = jnp.asarray([0, 0, 0, 1, 1, 1])
    out = aggregation.clustered(stacked, w, labels, 2)
    for key in stacked:
        arr = np.asarray(out[key])
        np.testing.assert_allclose(arr[0], arr[1], rtol=1e-6)
        np.testing.assert_allclose(arr[3], arr[5], rtol=1e-6)
        assert np.abs(arr[0] - arr[3]).max() > 1e-4


def test_group_average_blockwise():
    m = 4
    stacked = _stacked(8, m)
    assignment = jnp.asarray([0, 0, 1, 1])
    n = jnp.ones((m,))
    out = group_average(stacked, assignment, n)
    for key in stacked:
        arr = np.asarray(out[key])
        src = np.asarray(stacked[key])
        np.testing.assert_allclose(arr[0], (src[0] + src[1]) / 2, rtol=1e-5)
        np.testing.assert_allclose(arr[2], (src[2] + src[3]) / 2, rtol=1e-5)


def test_group_mixing_matrix_row_stochastic():
    assignment = jnp.asarray([0, 1, 0, 2, 1])
    n = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
    w = np.asarray(group_mixing_matrix(assignment, n))
    np.testing.assert_allclose(w.sum(1), 1.0, rtol=1e-6)
    assert w[0, 1] == 0 and w[0, 2] > 0
