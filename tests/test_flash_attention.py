"""Flash-attention Pallas kernel vs jnp reference: shape/dtype/mask sweep
(interpret mode; TPU is the execution target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def ref_attn(q, k, v, causal=True, window=None, softcap=None):
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    kx = jnp.repeat(k, g, axis=1)
    vx = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * dh ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vx.astype(jnp.float32)).astype(q.dtype)


CASES = [
    (4, 2, 256, 64, True, None, None),   # GQA causal
    (4, 4, 200, 64, True, None, 30.0),   # MHA + gemma softcap, ragged S
    (8, 2, 384, 128, True, 128, None),   # sliding window
    (2, 2, 100, 80, False, None, None),  # bidirectional, odd dims
]


@pytest.mark.parametrize("hq,hkv,sq,dh,causal,window,cap", CASES)
def test_flash_matches_reference(hq, hkv, sq, dh, causal, window, cap):
    rng = np.random.default_rng(hq * 1000 + sq)
    q = jnp.asarray(rng.normal(size=(2, hq, sq, dh)).astype("float32"))
    k = jnp.asarray(rng.normal(size=(2, hkv, sq, dh)).astype("float32"))
    v = jnp.asarray(rng.normal(size=(2, hkv, sq, dh)).astype("float32"))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=cap, interpret=True)
    want = ref_attn(q, k, v, causal, window, cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)).astype("float32")).astype(dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)).astype("float32")).astype(dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)).astype("float32")).astype(dtype)
    out = flash_attention(q, k, v, interpret=True)
    want = ref_attn(q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32))
    tol = 1e-3 if dtype == jnp.float32 else 3e-2
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


def test_flash_block_sweep():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 2, 300, 64)).astype("float32"))
    k = jnp.asarray(rng.normal(size=(1, 1, 300, 64)).astype("float32"))
    v = jnp.asarray(rng.normal(size=(1, 1, 300, 64)).astype("float32"))
    want = ref_attn(q, k, v)
    for bq, bk in [(128, 128), (128, 256), (256, 128)]:
        out = flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-3, atol=2e-4)
