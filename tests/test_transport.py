"""Quantized uplink transport tests (``FedConfig.transport``).

Covers the transport contract end to end:

  * per-chunk quantization error bound (int8: half a step of
    ``max|chunk|/127``; fp8-e4m3: 3 mantissa bits, ≤ max|chunk|/16);
  * exact zeros on all-zero chunks (the slab's aligned tail);
  * error-feedback telescoping — on a constant delta the T-round applied
    sum is ``T·delta`` up to the single residual ``ef_T``, i.e. one
    quantization step, not T of them;
  * config validation (kind / chunk / divisibility / make_stage typing);
  * strategy integration — every schema-declaring strategy grows a
    schema-width ``ef`` slab and stays within float drift of the raw-f32
    wire over 3 cohort rounds; only ``ucfl_parallel`` raises
    NotImplementedError at construction; ``transport=None`` runs carry
    NO ef/ef_dl state and are deterministic (two runs are bit-equal);
  * composition: transport under ``w_refresh`` and under the
    buffered-async server both run in one jitted shape.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, load_ci_profile, st
from repro.core import FedConfig, REGISTRY, ucfl
from repro.core.similarity import RefreshConfig
from repro.data import synthetic
from repro.federated import transport
from repro.federated.async_buffer import AsyncConfig
from repro.federated.transport import TransportConfig
from repro.models import lenet

load_ci_profile(max_examples=20)

INT8 = TransportConfig("int8")
FP8 = TransportConfig("fp8")

# every strategy that declares a WireSchema supports the quantized wire;
# only ucfl_parallel (no single upload slab) refuses at construction
SUPPORTED = ("ucfl", "clustered", "fedavg", "fedprox", "local", "oracle",
             "scaffold", "ditto", "pfedme", "fedfomo", "cfl")
REJECTED = ("ucfl_parallel",)


# ----------------------------------------------------------- quantization
def _chunk_steps(x, cfg):
    """Per-element max|chunk|, same shape as x."""
    x = np.asarray(x)
    xs = x.reshape(x.shape[:-1] + (-1, cfg.chunk))
    peak = np.abs(xs).max(-1, keepdims=True)
    return np.broadcast_to(peak, xs.shape).reshape(x.shape)


@pytest.mark.parametrize("shape", [(256,), (3, 256), (2, 3, 128)])
def test_int8_error_bound(shape):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32)) * 7.0
    err = np.abs(np.asarray(transport.roundtrip(x, INT8) - x))
    step = _chunk_steps(x, INT8) / 127.0
    assert (err <= 0.5 * step + 1e-7).all()


def test_fp8_error_bound():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
    err = np.abs(np.asarray(transport.roundtrip(x, FP8) - x))
    # e4m3: 3 mantissa bits -> relative step <= 2^-3, so after per-chunk
    # rescale the absolute error is <= max|chunk|/16 (half a step)
    assert (err <= _chunk_steps(x, FP8) / 16.0 + 1e-7).all()


@pytest.mark.parametrize("cfg", [INT8, FP8])
def test_zero_chunks_exact(cfg):
    # the slab's aligned tail is all-zero chunks: must decode to exact 0
    x = jnp.zeros((3, 256), jnp.float32)
    np.testing.assert_array_equal(np.asarray(transport.roundtrip(x, cfg)),
                                  0.0)


@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_int8_error_bound_property(seed):
    rng = np.random.default_rng(seed)
    scale = 10.0 ** rng.uniform(-3, 3)
    x = jnp.asarray(rng.normal(size=(2, 256)).astype(np.float32)) * scale
    err = np.abs(np.asarray(transport.roundtrip(x, INT8) - x))
    step = _chunk_steps(x, INT8) / 127.0
    assert (err <= 0.5 * step + 1e-6 * scale).all()


def test_error_feedback_telescopes():
    rng = np.random.default_rng(2)
    delta = jnp.asarray(rng.normal(size=(3, 256)).astype(np.float32))
    stage = transport.make_stage(INT8)
    pre = jnp.zeros_like(delta)
    ef = jnp.zeros_like(delta)
    total = np.zeros(delta.shape, np.float32)
    rounds = 17
    for _ in range(rounds):
        post_prime, ef = stage(pre, pre + delta, ef)
        total += np.asarray(post_prime - pre)
    # sum of applied updates = rounds*delta - ef_T: ONE residual, bounded
    # by a single quantization step — compression never accumulates bias
    step = _chunk_steps(delta, INT8) / 127.0
    err = np.abs(total - rounds * np.asarray(delta))
    assert (err <= step + 1e-5).all()
    np.testing.assert_allclose(err, np.abs(np.asarray(ef)), atol=1e-5)


# ------------------------------------------------------------- validation
def test_config_validation():
    with pytest.raises(ValueError, match="kind"):
        TransportConfig("int4")
    with pytest.raises(ValueError, match="positive"):
        TransportConfig("int8", chunk=0)
    with pytest.raises(ValueError, match="does not divide"):
        transport.quantize(jnp.zeros((2, 100)), TransportConfig(chunk=64))
    assert transport.make_stage(None) is None
    with pytest.raises(TypeError, match="TransportConfig"):
        transport.make_stage("int8")


# ------------------------------------------------- strategy integration
@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(3)
    dkey, mkey, skey = jax.random.split(key, 3)
    data = synthetic.label_shift(dkey, m=6, n=60, n_test=20, num_classes=6,
                                 alpha=0.4, hw=(16, 16))
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    return data, params0, skey


def _make(name, params0, cfg):
    if name == "clustered":
        return ucfl.make_ucfl(lenet.apply, params0, cfg, num_streams=2,
                              var_batch_size=10)
    if name in ("ucfl", "ucfl_parallel"):
        return REGISTRY[name](lenet.apply, params0, cfg, var_batch_size=10)
    return REGISTRY[name](lenet.apply, params0, cfg)


def _run_rounds(strat, data, skey, rounds=3):
    cohort = np.arange(data.num_clients, dtype=np.int32)
    state = strat.init(jax.random.fold_in(skey, 1), data)
    key = skey
    for _ in range(rounds):
        key, rkey = jax.random.split(key)
        state, _ = strat.round(state, data, rkey, cohort)
    return state


@pytest.mark.parametrize("name", SUPPORTED)
def test_supported_close_to_raw_wire(name):
    data, params0, skey = _setup()
    cfg = FedConfig(batch_size=30)
    raw = _run_rounds(_make(name, params0, cfg), data, skey)
    assert "ef" not in raw and "ef_dl" not in raw
    for tcfg, tol in ((INT8, 2e-3), (FP8, 1e-2)):
        qcfg = FedConfig(batch_size=30, transport=tcfg)
        strat = _make(name, params0, qcfg)
        schema = strat.wire_schema
        q = _run_rounds(strat, data, skey)
        # the ef slab is schema-width: one EF slice per uplink stream
        # (scaffold's is 2× the model slab — delta AND control_delta)
        assert q["ef"].shape == (q["params"].shape[0],
                                 schema.width_aligned("uplink"))
        assert float(jnp.abs(q["ef"]).max()) > 0.0
        diff = float(jnp.abs(q["params"] - raw["params"]).max())
        assert diff <= tol, (name, tcfg.kind, diff)


@pytest.mark.parametrize("name", REJECTED)
def test_rejected_at_construction(name):
    _, params0, _ = _setup()
    with pytest.raises(NotImplementedError, match="transport"):
        _make(name, params0, FedConfig(batch_size=30, transport=INT8))


@pytest.mark.parametrize("name", ("fedavg", "scaffold", "pfedme"))
def test_transport_none_bit_exact_and_ef_free(name):
    data, params0, skey = _setup()
    cfg = FedConfig(batch_size=30, transport=None)
    a = _run_rounds(_make(name, params0, cfg), data, skey)
    b = _run_rounds(_make(name, params0, cfg), data, skey)
    assert "ef" not in a and "ef_dl" not in a
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_transport_under_w_refresh():
    data, params0, skey = _setup()
    cfg = FedConfig(batch_size=30, transport=INT8,
                    w_refresh=RefreshConfig())
    state = _run_rounds(_make("ucfl", params0, cfg), data, skey)
    assert "ef" in state and "refresh" in state
    for leaf in jax.tree.leaves(state):
        assert bool(jnp.isfinite(jnp.asarray(leaf, jnp.float32)).all())


def test_transport_under_async_buffer():
    data, params0, skey = _setup()
    cfg = FedConfig(batch_size=30, transport=INT8,
                    async_buffer=AsyncConfig(flush_k=3))
    state = _run_rounds(_make("fedavg", params0, cfg), data, skey,
                        rounds=4)
    assert "ef" in state and "abuf" in state
    assert bool(jnp.isfinite(state["params"]).all())
