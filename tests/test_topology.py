"""Two-tier hierarchical round engine tests.

Covers the topology PR's guarantees:
  (a) ``FedConfig.topology=None`` is BIT-EXACT with the default config
      for every supporting strategy — the knob is strictly opt-in.
  (b) a two-tier round (per-edge tier-1 masked mix, tier-2 combine at
      the PS) matches the flat round within float association
      (rtol=1e-5) for fedavg, fedprox, and clustered ucfl — the tiered
      rules factorize the flat linear mixes exactly.
  (c) one compiled round shape: a varying-availability trace under a
      tiered strategy still hits ONE masked-round compilation (the edge
      partition is a static-shape argsort/scatter inside the jit), and
      the tiered round composes with a device mesh.
  (d) :func:`repro.federated.topology.edge_partition` preserves the
      cohort invariants per edge: real slots form a prefix, members stay
      strictly increasing, every real cohort slot lands on exactly one
      edge, pads carry sentinels (property-tested under hypothesis).
  (e) the ``pareto`` sampler (``FedConfig.selection``): zero-mass
      clients are never drawn, cohorts obey the padded-prefix contract,
      and the fairness lane bounds every positive-mass client's
      selection gap to ``n_pos`` rounds.
  (f) capability boundaries: strategies whose PS rule cannot factorize
      over per-edge partial sums reject the knob at CONSTRUCTION with a
      NotImplementedError capability note; topology x shard_state /
      async_buffer raise likewise; the dense (cohort=None) path raises
      ValueError; a non-Topology value raises TypeError.

Run multi-device on CPU with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    JAX_PLATFORMS=cpu PYTHONPATH=src python -m pytest tests/test_topology.py
"""
import functools

import jax
import numpy as np
import pytest

from repro.core import FedConfig, REGISTRY, ucfl
from repro.core.similarity import RefreshConfig
from repro.data import synthetic
from repro.federated import participation as pp
from repro.federated import simulation
from repro.federated import topology as topo_lib
from repro.federated.async_buffer import AsyncConfig
from repro.federated.participation import (Cohort, ParticipationConfig,
                                           SelectionConfig)
from repro.federated.topology import Topology
from repro.models import lenet
from tests.hypothesis_compat import HAVE_HYPOTHESIS, given, load_ci_profile, st

load_ci_profile(max_examples=25)

NDEV = jax.device_count()


@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(17)
    dkey, mkey = jax.random.split(key)
    data = synthetic.concept_shift(dkey, m=8, n=120, n_test=30,
                                   num_classes=6, groups=2, hw=(16, 16),
                                   channels=1, noise=1.0)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    return data, params0


def _cfg(**kw):
    return FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=40, **kw)


def _make(name, params0, cfg):
    if name == "clustered":
        return ucfl.make_ucfl(lenet.apply, params0, cfg, num_streams=2,
                              var_batch_size=40)
    return REGISTRY[name](lenet.apply, params0, cfg)


def _leaves(strat, state):
    return [np.asarray(x) for x in jax.tree.leaves(strat.eval_params(state))]


_COHORT = Cohort(indices=np.asarray([1, 4, 6, 8], np.int32),
                 mask=np.asarray([1, 1, 1, 0], bool))
_TOPO3 = Topology.contiguous(8, 3)
TIERED = ("fedavg", "fedprox", "clustered")


# ----------------------------------------------------- Topology validation

def test_topology_validates():
    with pytest.raises(ValueError, match="num_edges"):
        Topology((0, 0), 0)
    with pytest.raises(ValueError, match="edge ids"):
        Topology((0, 3), 2)
    with pytest.raises(ValueError, match="at least one client"):
        Topology((), 2)


def test_topology_builders():
    t = Topology.from_labels([1, 0, 2, 1])
    assert t.num_edges == 3 and t.num_clients == 4
    t = Topology.contiguous(8, 3)
    assert t.num_clients == 8 and set(t.edge_of) == {0, 1, 2}
    # contiguous blocks: edge ids are nondecreasing
    assert list(t.edge_of) == sorted(t.edge_of)
    # slots bound: min(cohort slots, largest edge population)
    assert t.slots_per_edge(2) == 2
    assert t.slots_per_edge(8) == max(
        np.bincount(np.asarray(t.edge_of)))
    with pytest.raises(ValueError, match="assigns 8 clients"):
        t.check_clients(5, "fedavg")


# --------------------------------------------- (a) topology=None bit-exact

@pytest.mark.parametrize("name", TIERED)
def test_topology_none_bit_exact(name):
    """``topology=None`` must be indistinguishable from the default —
    same strategy, same round, bit-for-bit."""
    data, params0 = _setup()
    rkey = jax.random.PRNGKey(101)
    base = _make(name, params0, _cfg())
    none = _make(name, params0, _cfg(topology=None))
    s0 = base.init(jax.random.PRNGKey(3), data)
    s0n = none.init(jax.random.PRNGKey(3), data)
    sb, _ = base.round(simulation.donation_safe_copy(s0), data, rkey, _COHORT)
    sn, _ = none.round(simulation.donation_safe_copy(s0n), data, rkey,
                       _COHORT)
    for a, b in zip(_leaves(base, sb), _leaves(none, sn)):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------ (b) tiered == flat mix

@pytest.mark.parametrize("name", TIERED)
def test_tiered_matches_flat(name):
    """The two-tier factorization equals the flat mix up to float
    association (normalized per-edge partial sums, tier-2 reweight)."""
    data, params0 = _setup()
    rkey = jax.random.PRNGKey(101)
    flat = _make(name, params0, _cfg())
    tier = _make(name, params0, _cfg(topology=_TOPO3))
    s0 = flat.init(jax.random.PRNGKey(3), data)
    s0t = tier.init(jax.random.PRNGKey(3), data)
    sf, mf = flat.round(simulation.donation_safe_copy(s0), data, rkey,
                        _COHORT)
    st_, mt = tier.round(simulation.donation_safe_copy(s0t), data, rkey,
                         _COHORT)
    assert int(mf["streams"]) == int(mt["streams"])
    for a, b in zip(_leaves(flat, sf), _leaves(tier, st_)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_tiered_composes_with_w_refresh():
    """The streaming W refresh feeds the SAME tiered serve — one code
    path; the refreshed round must run and stay finite."""
    data, params0 = _setup()
    strat = ucfl.make_ucfl(lenet.apply, params0,
                           _cfg(topology=_TOPO3, w_refresh=RefreshConfig()),
                           num_streams=2, var_batch_size=40)
    s0 = strat.init(jax.random.PRNGKey(3), data)
    s1, _ = strat.round(s0, data, jax.random.PRNGKey(101), _COHORT)
    for leaf in _leaves(strat, s1):
        assert np.isfinite(leaf).all()


def test_tiered_multi_round_stays_close_to_flat():
    """Association error must not compound over a training run: after 4
    rounds the tiered clustered trajectory still tracks flat."""
    data, params0 = _setup()
    flat = _make("clustered", params0, _cfg())
    tier = _make("clustered", params0, _cfg(topology=_TOPO3))
    sf = flat.init(jax.random.PRNGKey(3), data)
    st_ = tier.init(jax.random.PRNGKey(3), data)
    key = jax.random.PRNGKey(7)
    for rnd in range(1, 5):
        key, rkey = jax.random.split(key)
        co = pp.sample_cohort(ParticipationConfig(cohort_size=5, seed=9),
                              rnd, data.num_clients, data.n)
        sf, _ = flat.round(sf, data, rkey, co)
        st_, _ = tier.round(st_, data, rkey, co)
    for a, b in zip(_leaves(flat, sf), _leaves(tier, st_)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# --------------------------------------------- (c) one-compilation guard

@pytest.mark.parametrize("name", ["fedavg", "clustered"])
def test_tiered_availability_compiles_once(name):
    """Varying eligible-set sizes under a tiered strategy must reuse ONE
    compiled masked round — the edge partition is shape-static."""
    data, params0 = _setup()
    m = data.num_clients
    trace = np.zeros((m, 3), bool)
    trace[:4, 0] = True
    trace[:2, 1] = True
    trace[:, 2] = True
    part = ParticipationConfig(cohort_size=4, sampler="availability",
                               availability=trace)
    strat = _make(name, params0, _cfg(topology=Topology.contiguous(m, 2)))
    assert strat.round.masked_jit is not None
    simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                   rounds=6, eval_every=6, participation=part)
    assert strat.round.masked_jit._cache_size() == 1


@pytest.mark.skipif(NDEV < 8,
                    reason="needs 8 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
def test_tiered_composes_with_mesh():
    """Replicated-mesh local SGD + the tiered mix: matches flat within
    the sharding tolerance and compiles once."""
    data, params0 = _setup()
    rkey = jax.random.PRNGKey(101)
    flat = _make("fedavg", params0, _cfg())
    tier = REGISTRY["fedavg"](lenet.apply, params0,
                              _cfg(topology=_TOPO3, mesh="auto"))
    s0 = flat.init(jax.random.PRNGKey(3), data)
    s0t = tier.init(jax.random.PRNGKey(3), data)
    sf, _ = flat.round(simulation.donation_safe_copy(s0), data, rkey,
                       _COHORT)
    st_, _ = tier.round(simulation.donation_safe_copy(s0t), data, rkey,
                        _COHORT)
    for a, b in zip(_leaves(flat, sf), _leaves(tier, st_)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


# ------------------------------------------- (d) edge_partition invariants

def _check_partition(edge_of, num_edges, idx, mask):
    m = len(edge_of)
    c = idx.shape[0]
    topo = Topology(tuple(edge_of), num_edges)
    slots = topo.slots_per_edge(c)
    eidx, emask, eslot = jax.jit(
        topo_lib.edge_partition, static_argnums=(1, 2))(
        topo.edge_array(), num_edges, slots, idx, mask)
    eidx, emask, eslot = (np.asarray(eidx), np.asarray(emask),
                          np.asarray(eslot))
    assert eidx.shape == emask.shape == eslot.shape == (num_edges, slots)
    seen = []
    for e in range(num_edges):
        mk = emask[e]
        # real slots form a prefix
        assert not np.any(mk[1:] & ~mk[:-1])
        members = eidx[e][mk]
        # members strictly increasing, all genuinely on this edge
        if members.size > 1:
            assert np.all(np.diff(members) > 0)
        assert all(edge_of[i] == e for i in members)
        # eslot maps back to the cohort slot holding the same client
        assert np.array_equal(idx[eslot[e][mk]], members)
        # pads carry the sentinels
        assert np.all(eidx[e][~mk] == m)
        assert np.all(eslot[e][~mk] == c)
        seen.extend(members.tolist())
    # every real cohort member lands on exactly one edge
    assert sorted(seen) == sorted(idx[mask].tolist())


def test_edge_partition_concrete():
    idx = np.asarray([0, 2, 3, 7, 8, 8], np.int32)
    mask = np.asarray([1, 1, 1, 1, 0, 0], bool)
    _check_partition([0, 0, 1, 2, 1, 0, 2, 1], 3, idx, mask)
    # an edge with no cohort members, and an all-pad cohort
    _check_partition([0, 0, 0, 0, 0, 0, 0, 2], 3, idx, mask)
    _check_partition([0, 1] * 4, 2,
                     np.full(4, 8, np.int32), np.zeros(4, bool))


if HAVE_HYPOTHESIS:
    @given(st.data())
    def test_edge_partition_property(data_st):
        m = data_st.draw(st.integers(2, 12), label="m")
        num_edges = data_st.draw(st.integers(1, 5), label="E")
        edge_of = data_st.draw(
            st.lists(st.integers(0, num_edges - 1), min_size=m, max_size=m),
            label="edge_of")
        c = data_st.draw(st.integers(1, m), label="c")
        take = data_st.draw(st.integers(0, c), label="take")
        members = data_st.draw(
            st.lists(st.integers(0, m - 1), min_size=take, max_size=take,
                     unique=True), label="members")
        idx = np.full(c, m, np.int32)
        idx[:take] = np.sort(np.asarray(members, np.int32))
        mask = np.zeros(c, bool)
        mask[:take] = True
        _check_partition(edge_of, num_edges, idx, mask)
else:  # pragma: no cover - env-dependent
    @given(st.none())
    def test_edge_partition_property(_):
        pass


# ------------------------------------------------ (e) pareto selection

def _schedule_members(cfg, rounds, m, n=None):
    return [co.members for co in pp.cohort_schedule(cfg, rounds, m, n)]


def test_pareto_never_draws_zero_mass():
    m = 10
    mass = np.asarray([0, 0, 1, 1, 1, 1, 2, 2, 0, 3], float)
    cfg = ParticipationConfig(
        cohort_size=4, sampler="pareto", seed=5,
        selection=SelectionConfig(compute=mass, bias=2.0))
    dead = {0, 1, 8}
    for members in _schedule_members(cfg, 30, m):
        assert not (set(members.tolist()) & dead)


def test_pareto_fairness_lane_bounds_starvation():
    """Every statically-positive client is selected at least once per
    n_pos rounds — the deterministic lane's worst case."""
    m = 8
    speeds = np.geomspace(0.05, 20.0, m)  # 400x spread: heavy starvation
    cfg = ParticipationConfig(
        cohort_size=2, sampler="pareto", seed=5,
        selection=SelectionConfig(compute=speeds, bias=4.0))
    sched = _schedule_members(cfg, m, m)
    seen = set()
    for members in sched:
        seen |= set(members.tolist())
    assert seen == set(range(m))


def test_pareto_without_fairness_lane_starves():
    """Same sharp bias, lane off: the slowest client is starved within
    the window the lane would have covered — the lane is load-bearing."""
    m = 8
    speeds = np.geomspace(0.05, 20.0, m)
    cfg = ParticipationConfig(
        cohort_size=2, sampler="pareto", seed=5,
        selection=SelectionConfig(compute=speeds, bias=4.0,
                                  fairness_lane=False))
    seen = set()
    for members in _schedule_members(cfg, m, m):
        seen |= set(members.tolist())
    assert 0 not in seen


def test_pareto_battery_gating_and_padding():
    """Battery-gated clients carry zero mass that phase; when fewer than
    cohort_size clients have mass the cohort pads availability-style."""
    m = 6
    battery = np.zeros((m, 2), bool)
    battery[:2, 0] = True   # phase 0: only clients 0, 1
    battery[:, 1] = True    # phase 1: everyone
    cfg = ParticipationConfig(
        cohort_size=4, sampler="pareto", seed=5,
        selection=SelectionConfig(battery=battery))
    sched = pp.cohort_schedule(cfg, 2, m)
    assert sched[0].num_slots == 4 and len(sched[0]) == 2
    assert set(sched[0].members.tolist()) == {0, 1}
    assert len(sched[1]) == 4


def test_pareto_config_validation():
    with pytest.raises(ValueError, match="bias"):
        SelectionConfig(bias=0.0)
    with pytest.raises(ValueError, match="nonnegative"):
        SelectionConfig(compute=np.asarray([1.0, -1.0]))
    with pytest.raises(ValueError, match="SelectionConfig"):
        ParticipationConfig(sampler="pareto")
    with pytest.raises(ValueError, match="data_value"):
        SelectionConfig(data_value=True).static_mass(4)


def test_with_selection_threads_policy():
    sel = SelectionConfig(bias=2.0)
    assert pp.with_selection(None, None) is None
    got = pp.with_selection(None, sel)
    assert got.sampler == "pareto" and got.selection is sel
    base = ParticipationConfig(cohort_size=3, seed=9)
    got = pp.with_selection(base, sel)
    assert got.cohort_size == 3 and got.seed == 9
    assert got.sampler == "pareto" and got.selection is sel


if HAVE_HYPOTHESIS:
    @given(st.data())
    def test_pareto_cohort_contract_property(data_st):
        """Any mass profile yields a valid padded cohort: prefix mask,
        strictly increasing members, only positive-mass clients."""
        m = data_st.draw(st.integers(2, 12), label="m")
        c = data_st.draw(st.integers(1, m), label="c")
        mass = np.asarray(data_st.draw(
            st.lists(st.floats(0.0, 10.0), min_size=m, max_size=m),
            label="mass"))
        bias = data_st.draw(st.floats(0.25, 4.0), label="bias")
        cfg = ParticipationConfig(
            cohort_size=c, sampler="pareto", seed=3,
            selection=SelectionConfig(compute=mass, bias=bias))
        for rnd in (1, 2, 7):
            co = pp.sample_cohort(cfg, rnd, m)  # Cohort.__post_init__
            assert co.num_slots == c            # validates the contract
            assert all(mass[i] > 0 for i in co.members)
else:  # pragma: no cover - env-dependent
    @given(st.none())
    def test_pareto_cohort_contract_property(_):
        pass


# --------------------------------------------- (f) capability boundaries

UNSUPPORTED = ("scaffold", "ditto", "pfedme", "fedfomo", "local", "cfl",
               "oracle", "ucfl", "ucfl_parallel")


@pytest.mark.parametrize("name", UNSUPPORTED)
def test_unsupported_strategy_raises_at_construction(name):
    _, params0 = _setup()
    kw = {"var_batch_size": 40} if name.startswith("ucfl") else {}
    with pytest.raises(NotImplementedError, match="topology"):
        REGISTRY[name](lenet.apply, params0, FedConfig(topology=_TOPO3),
                       **kw)


@pytest.mark.parametrize("kw", [dict(shard_state=True),
                                dict(async_buffer=AsyncConfig(flush_k=2))])
def test_noncomposable_knobs_raise(kw):
    _, params0 = _setup()
    with pytest.raises(NotImplementedError, match="topology"):
        REGISTRY["fedavg"](lenet.apply, params0,
                           FedConfig(topology=_TOPO3, **kw))


def test_dense_path_rejects_topology():
    data, params0 = _setup()
    strat = _make("fedavg", params0, _cfg(topology=_TOPO3))
    s0 = strat.init(jax.random.PRNGKey(3), data)
    with pytest.raises(ValueError, match="dense"):
        strat.round(s0, data, jax.random.PRNGKey(101), None)


def test_non_topology_value_raises_typeerror():
    _, params0 = _setup()
    with pytest.raises(TypeError, match="Topology"):
        REGISTRY["fedavg"](lenet.apply, params0,
                           FedConfig(topology=(0, 0, 1, 1)))


def test_topology_client_count_mismatch():
    data, params0 = _setup()
    strat = _make("fedavg", params0, _cfg(topology=Topology.contiguous(5, 2)))
    with pytest.raises(ValueError, match="5 clients"):
        strat.init(jax.random.PRNGKey(3), data)
