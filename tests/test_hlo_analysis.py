"""Unit tests for the trip-count-aware HLO analyzer (roofline engine)."""
import textwrap

from repro.launch import hlo_analysis, roofline


HLO = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8] get-tuple-element(%p), index=1
      %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8] all-reduce(%d), channel_id=1, replica_groups=[4,2]<=[8], to_apply=%add
      ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
    }

    %cond (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %c = s32[] constant(10)
      ROOT %lt = pred[] compare(%i2, %c), direction=LT
    }

    ENTRY %main (a: f32[8,8]) -> f32[8,8] {
      %a = f32[8,8] parameter(0)
      %zero = s32[] constant(0)
      %tup = (s32[], f32[8,8]) tuple(%zero, %a)
      %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body
      %ag = f32[16,8] all-gather(%a), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}
      ROOT %out = f32[8,8] get-tuple-element(%w), index=1
    }
""")


def test_while_trip_count_and_dot_flops():
    ana = hlo_analysis.analyze_text(HLO, total_chips=8)
    assert ana.while_trip_counts == [10]
    # dot inside the loop: 2*8*8*8 = 1024 flops × 10 trips
    assert ana.dot_flops == 1024 * 10


def test_collectives_scaled_by_trips():
    ana = hlo_analysis.analyze_text(HLO, total_chips=8)
    ar = ana.collectives["all-reduce"]
    assert ar["count"] == 10
    # 8*8*4 bytes result; ring: 2*(s-1)/s with s=2
    assert abs(ar["moved_bytes"] - 10 * 2 * 256 * 0.5) < 1e-6
    ag = ana.collectives["all-gather"]
    assert ag["count"] == 1
    assert abs(ag["moved_bytes"] - 16 * 8 * 4 * 0.5) < 1e-6


def test_known_trip_count_backend_config_preferred():
    hlo = HLO.replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}',
    )
    ana = hlo_analysis.analyze_text(hlo, total_chips=8)
    assert ana.while_trip_counts == [7]


def test_roofline_terms_math():
    r = roofline.Roofline(
        arch="x", shape="train_4k", mesh="single", chips=256,
        agg="user_centric",
        hlo_flops_per_chip=197e12, hlo_bytes_per_chip=819e9,
        collective_bytes_per_chip=50e9, collectives={},
        model_flops_total=197e12 * 256, param_count=10, active_params=10,
        memory_analysis={},
    )
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert abs(r.useful_flops_ratio - 1.0) < 1e-9


def test_model_flops_moe_active():
    from repro import configs

    cfg = configs.get("mixtral-8x7b")
    n_total = 47_000_000_000
    n_active = roofline.active_param_count(cfg, n_total)
    # top-2 of 8 experts: ~ (47 − 32·6·3·4096·14336/1e9 ...) well below total
    assert n_active < n_total * 0.35
