"""Fixed-shape masked cohort engine tests.

Covers the PR 2 guarantees:
  (a) a padded cohort (masked sentinel slots) reproduces the unpadded
      cohort round bit-for-bit for all 11 strategies — pad columns carry
      exact zero weight and per-slot PRNG keys are client-indexed, so
      padding cannot perturb a real slot. (On CPU the comparison is
      exact; f32 associativity could in principle differ on backends
      that tile reductions differently — if this ever trips on an
      accelerator, the documented fallback is allclose at 1e-6.)

      Documented PRNG change: PR 1 derived a cohort's per-client keys as
      split(key, c) — a function of the cohort SIZE, which is
      incompatible with shape-stable padding (split is not prefix-stable
      in its count). The engine now uses client-indexed keys,
      split(key, m)[cohort], so partial-cohort trajectories intentionally
      differ from PR 1's; what is preserved bit-for-bit is (i) the dense
      fraction=1.0 path, (ii) full-cohort == dense (now exact, it was
      only allclose in PR 1), and (iii) padded == unpadded within the
      new engine.
  (b) ONE round compilation across an availability trace whose
      eligible-set size varies (the pre-padding engine re-jitted per
      distinct size, inside the timed region).
  (c) the chunked collaboration round and chunked evaluation match their
      monolithic counterparts.
  (d) the clustered downlink stream count is computed on device and
      matches the host-side np.unique it replaced.
"""
import functools

import jax
import numpy as np
import pytest

from repro.core import FedConfig, REGISTRY, ucfl
from repro.data import synthetic
from repro.federated import client as fedclient
from repro.federated import simulation
from repro.federated.participation import Cohort, ParticipationConfig
from repro.models import lenet


@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(17)
    dkey, mkey = jax.random.split(key)
    data = synthetic.concept_shift(dkey, m=8, n=120, n_test=30,
                                   num_classes=6, groups=2, hw=(16, 16),
                                   channels=1, noise=1.0)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=40)
    return data, params0, cfg


def _make(name, params0, cfg):
    if name == "clustered":
        return ucfl.make_ucfl(lenet.apply, params0, cfg, num_streams=2,
                              var_batch_size=40)
    if name in ("ucfl", "ucfl_parallel"):
        return REGISTRY[name](lenet.apply, params0, cfg, var_batch_size=40)
    if name in ("scaffold", "pfedme"):
        return REGISTRY[name](lenet.apply, params0)
    return REGISTRY[name](lenet.apply, params0, cfg)


def _leaves(strat, state):
    return [np.asarray(x) for x in jax.tree.leaves(strat.eval_params(state))]


# ------------------------------------------------------- (a) bit-exactness

@pytest.mark.parametrize("name", sorted(REGISTRY) + ["clustered"])
def test_padded_cohort_bit_exact_vs_unpadded(name):
    """Pad slots must be invisible: same members, extra masked sentinel
    slots, identical results — bit-for-bit."""
    data, params0, cfg = _setup()
    strat = _make(name, params0, cfg)
    state = strat.init(jax.random.PRNGKey(3), data)
    rkey = jax.random.PRNGKey(101)
    members = np.asarray([1, 4, 6], np.int32)
    padded = Cohort(indices=np.asarray([1, 4, 6, 8, 8], np.int32),
                    mask=np.asarray([1, 1, 1, 0, 0], bool))
    # the masked round donates its stacked buffers: run each variant on a
    # copy of the shared start state
    s_u, m_u = strat.round(simulation.donation_safe_copy(state), data,
                           rkey, members)
    s_p, m_p = strat.round(simulation.donation_safe_copy(state), data,
                           rkey, padded)
    assert m_u["cohort_size"] == m_p["cohort_size"] == 3
    for a, b in zip(_leaves(strat, s_u), _leaves(strat, s_p)):
        np.testing.assert_array_equal(a, b)


def test_padded_full_cohort_matches_dense_exactly():
    """A full-membership cohort reproduces the dense path EXACTLY for the
    fedavg family: client-indexed slot keys equal the dense split(key, m)."""
    data, params0, cfg = _setup()
    strat = _make("fedavg", params0, cfg)
    state = strat.init(jax.random.PRNGKey(3), data)
    rkey = jax.random.PRNGKey(101)
    s_d, _ = strat.round(simulation.donation_safe_copy(state), data, rkey)
    s_f, _ = strat.round(simulation.donation_safe_copy(state), data, rkey,
                         np.arange(data.num_clients, dtype=np.int32))
    for a, b in zip(_leaves(strat, s_d), _leaves(strat, s_f)):
        np.testing.assert_array_equal(a, b)


# --------------------------------------------------- (b) recompile guard

@pytest.mark.parametrize("name", ["fedavg", "ucfl"])
def test_availability_trace_compiles_round_exactly_once(name):
    """Varying eligible-set sizes (4, 2, 8, ... of cohort_size=4) must hit
    ONE compiled masked-round shape thanks to the padded slots."""
    data, params0, cfg = _setup()
    m = data.num_clients
    trace = np.zeros((m, 3), bool)
    trace[:4, 0] = True   # 4 eligible
    trace[:2, 1] = True   # 2 eligible (padded)
    trace[:, 2] = True    # 8 eligible (subsampled to 4)
    part = ParticipationConfig(cohort_size=4, sampler="availability",
                               availability=trace)
    strat = _make(name, params0, cfg)
    assert strat.round.masked_jit is not None
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=6, eval_every=6, participation=part)
    sizes = [mt["cohort_size"] for mt in h.metrics]
    assert h.metrics[-1]["cohort_size"] in (2, 4)
    assert strat.round.masked_jit._cache_size() == 1, sizes


def test_warmup_covers_empty_first_phase():
    """An all-offline round 1 must not skip the warm-up: the engine warms
    a synthetic one-member cohort of the same slot shape, so the first
    real round hits an already-compiled masked round."""
    data, params0, cfg = _setup()
    m = data.num_clients
    trace = np.zeros((m, 3), bool)
    trace[:3, 1] = True   # phase 0 all-offline, phase 1 has 3 up
    trace[:, 2] = True
    part = ParticipationConfig(cohort_size=4, sampler="availability",
                               availability=trace)
    strat = _make("fedavg", params0, cfg)
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=3, eval_every=3, participation=part)
    assert h.metrics[-1]["cohort_size"] == 4
    assert strat.round.masked_jit._cache_size() == 1


# ------------------------------------------- (c) chunked collab and eval

def test_chunked_collaboration_matches_monolithic():
    data, params0, _ = _setup()
    mono = ucfl.compute_collaboration(lenet.apply, params0, data,
                                      var_batch_size=40)
    for chunk in (3, 4, 8):
        chunked = ucfl.compute_collaboration(lenet.apply, params0, data,
                                             var_batch_size=40,
                                             chunk_size=chunk)
        for key in ("full_grads", "sigma_sq", "delta", "W"):
            np.testing.assert_allclose(np.asarray(chunked[key]),
                                       np.asarray(mono[key]),
                                       rtol=1e-5, atol=1e-6)


def test_chunked_evaluate_matches_vmap():
    data, params0, _ = _setup()
    stacked = jax.tree.map(
        lambda x: jax.numpy.broadcast_to(
            x, (data.num_clients,) + x.shape) + 0.0, params0)
    dense = np.asarray(fedclient.evaluate(lenet.apply, stacked, data.x_test,
                                          data.y_test))
    for batch in (3, 4, 8, 16):
        chunked = np.asarray(fedclient.evaluate(
            lenet.apply, stacked, data.x_test, data.y_test, batch=batch))
        np.testing.assert_array_equal(dense, chunked)


def test_fedavg_masked_mix_empty_cohort_keeps_previous_model():
    """An all-masked cohort must not NaN/zero the state: zero weight mass
    falls back to the previous model (the engine skips such rounds, but
    direct strategy.round callers get safe semantics too)."""
    from repro.core.baselines.common import fedavg_masked_mix
    import jax.numpy as jnp

    m, c = 6, 3
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(m, 4)).astype(np.float32))}
    updated = {"w": jnp.asarray(rng.normal(size=(c, 4)).astype(np.float32))}
    idx = jnp.full((c,), m, jnp.int32)     # all sentinel
    mask = jnp.zeros((c,), bool)
    n = jnp.ones((m,), jnp.float32)
    out = fedavg_masked_mix(params, updated, idx, mask, n)["w"]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(params["w"]))


def test_fedavg_masked_mix_weights_by_global_n():
    """Regression: sentinel clamping must use n's length (m), not the
    cohort-stacked params' leading axis — pFedMe passes cohort-stacked
    local copies as params, and clamping against c mis-gathered n."""
    from repro.core.baselines.common import fedavg_masked_mix
    import jax.numpy as jnp

    m, c = 8, 3
    rng = np.random.default_rng(0)
    n = jnp.asarray(np.r_[np.ones(m - 1), 100.0].astype(np.float32))
    idx = jnp.asarray([2, 5, 7], jnp.int32)  # client 7 holds ~97% of n mass
    mask = jnp.ones(c, bool)
    updated = {"w": jnp.asarray(rng.normal(size=(c, 4)).astype(np.float32))}
    cohort_params = {"w": jnp.zeros((c, 4), jnp.float32)}
    out = fedavg_masked_mix(cohort_params, updated, idx, mask, n)["w"]
    wts = np.asarray(n)[np.asarray(idx)]
    want = np.tensordot(wts / wts.sum(), np.asarray(updated["w"]), axes=(0, 0))
    assert out.shape == (c, 4)  # broadcast to the params' leading axis
    for i in range(c):
        np.testing.assert_allclose(np.asarray(out)[i], want, rtol=1e-5,
                                   atol=1e-6)


# ------------------------------------------ (d) device-side stream count

def test_clustered_streams_counted_on_device():
    data, params0, cfg = _setup()
    strat = _make("clustered", params0, cfg)
    state = strat.init(jax.random.PRNGKey(3), data)
    labels = np.asarray(state["labels"])
    cohort = np.asarray([0, 3, 5], np.int32)
    _, metrics = strat.round(simulation.donation_safe_copy(state), data,
                             jax.random.PRNGKey(5), cohort)
    want = np.unique(labels[cohort]).size
    assert isinstance(metrics["streams"], jax.Array)  # no host sync in-round
    assert int(metrics["streams"]) == want
