"""Synthetic federated data generators: structure and shift mechanics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import lm_synthetic, synthetic
from repro.data.loader import epoch_batches, fixed_partition


def test_label_shift_shapes_and_dirichlet_heterogeneity():
    data = synthetic.label_shift(jax.random.PRNGKey(0), m=6, n=100,
                                 n_test=20, num_classes=10, alpha=0.3,
                                 hw=(12, 12))
    assert data.x.shape == (6, 100, 12, 12, 1)
    assert data.y.shape == (6, 100)
    # low alpha ⇒ very different label histograms across clients
    hists = np.stack([np.bincount(np.asarray(data.y[i]), minlength=10)
                      for i in range(6)])
    tv = np.abs(hists / 100 - hists.mean(0) / 100).sum(1)
    assert tv.mean() > 0.3


def test_covariate_shift_rotates_groups():
    data = synthetic.covariate_label_shift(jax.random.PRNGKey(1), m=8, n=50,
                                           n_test=10, num_classes=5,
                                           alpha=100.0, groups=4, hw=(8, 8))
    assert set(np.asarray(data.group)) == {0, 1, 2, 3}
    # group g images are rot90^g of group 0's prototypes: statistics differ
    x0 = np.asarray(data.x[0])
    x1 = np.asarray(data.x[1])
    assert not np.allclose(x0.mean(0), x1.mean(0), atol=0.1)


def test_concept_shift_permutes_labels_consistently():
    data = synthetic.concept_shift(jax.random.PRNGKey(2), m=8, n=60,
                                   n_test=10, num_classes=6, groups=2,
                                   hw=(8, 8), channels=1, noise=0.0)
    # same-group clients share the permutation: noise=0 ⇒ same image →
    # same label within a group
    g = np.asarray(data.group)
    assert (g == np.arange(8) % 2).all()


def test_epoch_batches_partition():
    x = jnp.arange(10 * 3.0).reshape(10, 3)
    y = jnp.arange(10)
    xb, yb = epoch_batches(jax.random.PRNGKey(0), x, y, 3)
    assert xb.shape == (3, 3, 3) and yb.shape == (3, 3)
    flat = sorted(np.asarray(yb).reshape(-1).tolist())
    assert len(set(flat)) == 9  # no duplicates


def test_fixed_partition_deterministic():
    x = jnp.arange(12.0).reshape(12, 1)
    y = jnp.arange(12)
    xb1, _ = fixed_partition(x, y, 4)
    xb2, _ = fixed_partition(x, y, 4)
    np.testing.assert_array_equal(np.asarray(xb1), np.asarray(xb2))


def test_lm_chains_learnable_structure():
    chains = lm_synthetic.make_group_chains(jax.random.PRNGKey(0), 2, 16)
    batch = lm_synthetic.federated_lm_batch(jax.random.PRNGKey(1), chains,
                                            m=4, batch=2, seq=32, noise=0.0)
    toks = np.asarray(batch["tokens"])
    labs = np.asarray(batch["labels"])
    assert toks.shape == (4, 2, 32)
    # noiseless: label = chain[token] for each client's group chain
    for i in range(4):
        chain = np.asarray(chains[i % 2])
        assert (labs[i] == chain[toks[i]]).all()
