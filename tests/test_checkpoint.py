"""Checkpoint save/restore roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.models import lenet


def test_roundtrip(tmp_path):
    params = lenet.init(jax.random.PRNGKey(0), input_hw=(16, 16),
                        channels=1, num_classes=5)
    path = os.path.join(tmp_path, "ckpt.msgpack")
    checkpoint.save(path, params)
    like = jax.tree.map(jnp.zeros_like, params)
    restored = checkpoint.restore(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_shape_mismatch(tmp_path):
    params = {"w": jnp.ones((3, 3))}
    path = os.path.join(tmp_path, "c.msgpack")
    checkpoint.save(path, params)
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"w": jnp.ones((4, 4))})


def test_restore_rejects_leaf_count_mismatch(tmp_path):
    params = {"w": jnp.ones((3,)), "b": jnp.ones((2,))}
    path = os.path.join(tmp_path, "c.msgpack")
    checkpoint.save(path, params)
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"w": jnp.ones((3,))})


def test_atomic_overwrite(tmp_path):
    path = os.path.join(tmp_path, "c.msgpack")
    checkpoint.save(path, {"w": jnp.ones((2,))})
    checkpoint.save(path, {"w": 2 * jnp.ones((2,))})
    out = checkpoint.restore(path, {"w": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["w"]), [2.0, 2.0])


# ------------------------------------------------------- crash safety

def test_crash_mid_write_keeps_previous_checkpoint(tmp_path, monkeypatch):
    """A save that dies before the atomic rename must leave the previous
    checkpoint readable and untouched, and clean up its temp file."""
    from repro.checkpoint import io as ckpt_io

    path = os.path.join(tmp_path, "c.msgpack")
    checkpoint.save(path, {"w": jnp.ones((2,))})

    def boom(src, dst):
        raise OSError("simulated crash before rename")

    monkeypatch.setattr(ckpt_io.os, "replace", boom)
    with pytest.raises(OSError, match="simulated crash"):
        checkpoint.save(path, {"w": 9 * jnp.ones((2,))})
    monkeypatch.undo()

    out = checkpoint.restore(path, {"w": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["w"]), [1.0, 1.0])
    leftovers = [f for f in os.listdir(tmp_path) if ".tmp." in f]
    assert leftovers == []  # failed save unlinked its temp file


def test_restore_ignores_orphaned_tmp_files(tmp_path):
    """A crash AFTER fsync but BEFORE unlink leaves an orphaned temp file
    next to the checkpoint; resume must read the real file only."""
    path = os.path.join(tmp_path, "c.msgpack")
    checkpoint.save(path, {"w": 3 * jnp.ones((2,))})
    with open(path + ".tmp.99999.deadbeef", "wb") as f:
        f.write(b"half-written garbage from a crashed saver")
    out = checkpoint.restore(path, {"w": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["w"]), [3.0, 3.0])


def test_concurrent_savers_never_clobber(tmp_path):
    """Unique temp names: two interleaved savers each complete their own
    atomic rename; the destination is always one COMPLETE payload."""
    from repro.checkpoint import io as ckpt_io

    path = os.path.join(tmp_path, "c.msgpack")
    real_replace = os.replace
    pending = []

    def defer(src, dst):  # hold the first saver's rename until the second's
        pending.append((src, dst))
        if len(pending) == 2:
            for s, d in reversed(pending):
                real_replace(s, d)

    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(ckpt_io.os, "replace", defer)
        checkpoint.save(path, {"w": 1 * jnp.ones((2,))})
        checkpoint.save(path, {"w": 2 * jnp.ones((2,))})
    out = checkpoint.restore(path, {"w": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["w"]), [1.0, 1.0])
