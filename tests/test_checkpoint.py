"""Checkpoint save/restore roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.models import lenet


def test_roundtrip(tmp_path):
    params = lenet.init(jax.random.PRNGKey(0), input_hw=(16, 16),
                        channels=1, num_classes=5)
    path = os.path.join(tmp_path, "ckpt.msgpack")
    checkpoint.save(path, params)
    like = jax.tree.map(jnp.zeros_like, params)
    restored = checkpoint.restore(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_shape_mismatch(tmp_path):
    params = {"w": jnp.ones((3, 3))}
    path = os.path.join(tmp_path, "c.msgpack")
    checkpoint.save(path, params)
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"w": jnp.ones((4, 4))})


def test_restore_rejects_leaf_count_mismatch(tmp_path):
    params = {"w": jnp.ones((3,)), "b": jnp.ones((2,))}
    path = os.path.join(tmp_path, "c.msgpack")
    checkpoint.save(path, params)
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"w": jnp.ones((3,))})


def test_atomic_overwrite(tmp_path):
    path = os.path.join(tmp_path, "c.msgpack")
    checkpoint.save(path, {"w": jnp.ones((2,))})
    checkpoint.save(path, {"w": 2 * jnp.ones((2,))})
    out = checkpoint.restore(path, {"w": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["w"]), [2.0, 2.0])
