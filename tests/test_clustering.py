"""K-means / silhouette / Alg-2 stream-selection tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clustering


def _blobs(key, k, per, f=8, spread=0.05):
    centers = jax.random.normal(key, (k, f)) * 3
    pts = jnp.concatenate([
        centers[i] + spread * jax.random.normal(
            jax.random.fold_in(key, i), (per, f))
        for i in range(k)
    ])
    labels = jnp.repeat(jnp.arange(k), per)
    return pts, labels


def test_kmeans_recovers_blobs():
    key = jax.random.PRNGKey(0)
    pts, true = _blobs(key, 3, 10)
    res = clustering.kmeans(jax.random.PRNGKey(1), pts, 3)
    got = np.asarray(res.labels)
    # same-cluster iff same true label (up to relabeling)
    for a in range(30):
        for b in range(30):
            assert (got[a] == got[b]) == (int(true[a]) == int(true[b]))


def test_silhouette_high_for_separated_low_for_random():
    key = jax.random.PRNGKey(2)
    pts, true = _blobs(key, 4, 8)
    s_good = float(clustering.silhouette_score(pts, true))
    rand_labels = jax.random.randint(jax.random.PRNGKey(3), (32,), 0, 4)
    s_bad = float(clustering.silhouette_score(pts, rand_labels))
    assert -1.0 <= s_bad <= s_good <= 1.0
    assert s_good > 0.8
    assert s_good - s_bad > 0.3


def test_silhouette_peaks_at_true_k():
    """Fig. 4 behaviour: k-sweep silhouette peaks at the true cluster #."""
    key = jax.random.PRNGKey(4)
    pts, _ = _blobs(key, 4, 8)
    scores = {}
    for k in range(2, 8):
        res = clustering.kmeans(jax.random.PRNGKey(k), pts, k)
        scores[k] = float(clustering.silhouette_score(pts, res.labels))
    assert max(scores, key=scores.get) == 4


def test_choose_num_streams_alg2():
    key = jax.random.PRNGKey(5)
    pts, _ = _blobs(key, 3, 8)
    best_k, results = clustering.choose_num_streams(
        jax.random.PRNGKey(6), pts, k_max=6)
    assert best_k == 3
    assert set(results) == {2, 3, 4, 5, 6}


def test_kmeans_inertia_decreases_with_k():
    key = jax.random.PRNGKey(7)
    pts = jax.random.normal(key, (40, 6))
    prev = None
    for k in (2, 4, 8, 16):
        res = clustering.kmeans(jax.random.PRNGKey(k), pts, k, iters=30)
        val = float(res.inertia)
        if prev is not None:
            assert val <= prev * 1.05  # monotone up to seeding noise
        prev = val
