"""Buffered-async cohort engine tests.

Covers the PR 5 guarantees:
  (a) buffer math — :mod:`repro.federated.async_buffer` deposits are
      fixed-shape and pad-invisible, a client re-depositing before a
      flush replaces its pending upload in place (indices stay unique),
      staleness weights are ``(1+τ)^{-α}`` on valid slots and exactly 0
      on empty ones, and a flush resets the buffer / bumps the server
      version / re-syncs the applied clients.
  (b) engine — with ``flush_k=1`` the buffer is flushed fresh every
      round, and the async ucfl round (and its whole trajectory) is
      BIT-EXACT with the barrier masked round over the same cohorts and
      keys (the buffer slot count equals the cohort slot count, so even
      the matmul shapes agree); the FedAvg-family delta form matches
      within float round-off (θ + Σ w̃(u − θ) vs Σ w̃ u). With
      ``flush_k > c`` a round deposits without touching params, and the
      eventual flush applies uploads from several rounds with the right
      staleness. ``async_buffer=None`` is the untouched barrier engine.
  (c) one compiled round — the availability sampler's varying eligible
      sets hit ONE compiled async round (deposit-only and flush rounds
      share the shape via lax.cond), matching the barrier engine's
      guarantee — also under ``FedConfig.mesh``.
  (d) dispatch — strategies without a buffered aggregation rule raise at
      construction; the dense ``cohort=None`` path refuses to run async;
      ``async_buffer`` + ``w_refresh`` is rejected (documented in ucfl).
  (e) traces — the diurnal/battery availability-trace generators emit
      deterministic (m, period) booleans where every client is up at
      least once.

The CI ``multi-device`` job re-runs this file under 8 forced host
devices, so the mesh path is exercised at both 1 and 8 shards.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedConfig, ucfl
from repro.core.baselines.fedavg import make_fedavg
from repro.core.baselines.scaffold import make_scaffold
from repro.core.similarity import RefreshConfig
from repro.data import synthetic
from repro.federated import async_buffer, simulation
from repro.federated.participation import (ParticipationConfig,
                                           battery_trace, diurnal_trace)
from repro.models import lenet


@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(17)
    dkey, mkey = jax.random.split(key)
    data = synthetic.concept_shift(dkey, m=8, n=120, n_test=30,
                                   num_classes=6, groups=2, hw=(16, 16),
                                   channels=1, noise=1.0)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    return data, params0


def _make(acfg, *, num_streams=None, mesh=None):
    data, params0 = _setup()
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=40,
                    async_buffer=acfg, mesh=mesh)
    return ucfl.make_ucfl(lenet.apply, params0, cfg, num_streams=num_streams,
                          var_batch_size=40)


def _leaves(strat, state):
    return [np.asarray(x) for x in jax.tree.leaves(strat.eval_params(state))]


# ----------------------------------------------------------- (a) buffer math

def test_async_config_validation():
    with pytest.raises(ValueError):
        async_buffer.AsyncConfig(flush_k=0)
    with pytest.raises(ValueError):
        async_buffer.AsyncConfig(alpha=-0.5)
    cfg = async_buffer.AsyncConfig(flush_k=3, alpha=0.0)  # no discount ok
    assert cfg.capacity(slots=4) == 6  # K-1 pending + one cohort


def _rows(vals, d=3):
    return jnp.asarray(np.outer(vals, np.ones(d)), jnp.float32)


def test_deposit_appends_and_pads_invisible():
    m = 6
    cfg = async_buffer.AsyncConfig(flush_k=3)
    b0 = async_buffer.init_buffer(cfg, m, slots=4, dim=3)
    rows = _rows([1.0, 2.0])
    a = async_buffer.deposit(
        b0, rows, jnp.asarray([1, 4], jnp.int32), jnp.ones(2, bool),
        jnp.zeros(2, jnp.int32), m)
    padded_rows = jnp.concatenate([rows, jnp.full((2, 3), 99.0)], axis=0)
    b = async_buffer.deposit(
        async_buffer.init_buffer(cfg, m, slots=4, dim=3), padded_rows,
        jnp.asarray([1, 4, m, m], jnp.int32),
        jnp.asarray([1, 1, 0, 0], bool), jnp.zeros(4, jnp.int32), m)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert int(a["count"]) == 2
    assert np.asarray(a["idx"]).tolist()[:2] == [1, 4]
    assert np.asarray(async_buffer.valid_mask(a, m)).tolist() == \
        [True, True] + [False] * 4


def test_deposit_dedupe_replaces_latest():
    m = 6
    cfg = async_buffer.AsyncConfig(flush_k=4)
    buf = async_buffer.init_buffer(cfg, m, slots=2, dim=3)
    buf = async_buffer.deposit(
        buf, _rows([1.0, 2.0]), jnp.asarray([1, 4], jnp.int32),
        jnp.ones(2, bool), jnp.zeros(2, jnp.int32), m)
    # client 4 uploads again before any flush: replaced in place
    buf = async_buffer.deposit(
        buf, _rows([7.0, 3.0]), jnp.asarray([4, 5], jnp.int32),
        jnp.ones(2, bool), jnp.zeros(2, jnp.int32), m)
    assert int(buf["count"]) == 3
    idx = np.asarray(buf["idx"]).tolist()
    assert idx[:3] == [1, 4, 5]  # slots: 1, 4 (replaced in place), 5
    np.testing.assert_allclose(np.asarray(buf["upd"])[1, :3], 7.0)
    # the aligned-width tail past dim stays zero
    assert not np.asarray(buf["upd"])[:, 3:].any()
    # indices stay unique among valid slots
    valid = np.asarray(async_buffer.valid_mask(buf, m))
    assert len(set(np.asarray(buf["idx"])[valid])) == int(valid.sum())


def test_buffer_rows_at_aligned_width():
    """init_buffer allocates upd at the 128-aligned width so a flat-state
    flush always takes the slab kernel's aliased zero-copy path; deposit
    zero-pads rows into it (tail zeroes checked in the dedupe test)."""
    from repro.kernels import ops
    cfg = async_buffer.AsyncConfig(flush_k=3)
    buf = async_buffer.init_buffer(cfg, 6, slots=4, dim=300)
    assert buf["upd"].shape == (cfg.capacity(4), ops.aligned_dim(300))
    assert ops.aligned_dim(300) == 384


def test_staleness_weights_and_reset():
    m = 6
    cfg = async_buffer.AsyncConfig(flush_k=2, alpha=1.0)
    buf = async_buffer.init_buffer(cfg, m, slots=2, dim=3)
    buf = dict(buf, version=jnp.asarray(3, jnp.int32))
    buf = async_buffer.deposit(
        buf, _rows([1.0, 2.0]), jnp.asarray([1, 4], jnp.int32),
        jnp.ones(2, bool), jnp.asarray([3, 1], jnp.int32), m)
    tau = np.asarray(async_buffer.staleness(buf))
    assert tau[:2].tolist() == [0, 2]
    w = np.asarray(async_buffer.staleness_weights(buf, m, cfg.alpha))
    np.testing.assert_allclose(w[:2], [1.0, 1.0 / 3.0])
    assert (w[2:] == 0.0).all()  # empty slots carry exactly zero weight

    out = async_buffer.flush_reset(buf, m)
    assert int(out["version"]) == 4
    assert int(out["count"]) == 0
    assert np.asarray(out["idx"]).tolist() == [m] * 3
    ls = np.asarray(out["last_sync"]).tolist()
    assert ls[1] == 4 and ls[4] == 4  # applied clients synced to new version
    assert ls[0] == 0


# --------------------------------------------------------------- (b) engine

def test_async_flush1_bit_exact_with_barrier_round():
    data, _ = _setup()
    cohort = np.asarray([1, 4, 6], np.int32)
    sync = _make(None)
    asy = _make(async_buffer.AsyncConfig(flush_k=1, alpha=0.5))
    ss = sync.init(jax.random.PRNGKey(3), data)
    sa = asy.init(jax.random.PRNGKey(3), data)
    rs, ms = sync.round(ss, data, jax.random.PRNGKey(5), cohort)
    ra, ma = asy.round(sa, data, jax.random.PRNGKey(5), cohort)
    for a, b in zip(_leaves(sync, rs), _leaves(asy, ra)):
        np.testing.assert_array_equal(a, b)
    assert int(ma["flushed"]) == 1 and int(ma["applied"]) == 3
    assert int(ma["tau_max"]) == 0
    assert int(ma["streams"]) == int(ms["streams"]) == 3


def test_async_clustered_flush1_bit_exact_with_barrier_round():
    data, _ = _setup()
    cohort = np.asarray([1, 4, 6], np.int32)
    sync = _make(None, num_streams=2)
    asy = _make(async_buffer.AsyncConfig(flush_k=1), num_streams=2)
    rs, ms = sync.round(sync.init(jax.random.PRNGKey(3), data), data,
                        jax.random.PRNGKey(5), cohort)
    ra, ma = asy.round(asy.init(jax.random.PRNGKey(3), data), data,
                       jax.random.PRNGKey(5), cohort)
    for a, b in zip(_leaves(sync, rs), _leaves(asy, ra)):
        np.testing.assert_array_equal(a, b)
    assert int(ma["streams"]) == int(ms["streams"])


def test_async_flush1_trajectory_bit_exact_with_barrier():
    """flush_k=1 applies every round's deposits fresh — the whole
    trajectory must reproduce the barrier engine bit-for-bit (same
    cohorts, same client-indexed keys, τ = 0 weights everywhere)."""
    data, _ = _setup()
    part = ParticipationConfig(cohort_size=3, seed=2)
    hs = simulation.run(_make(None), lenet.apply, data,
                        jax.random.PRNGKey(1), rounds=4, eval_every=1,
                        participation=part)
    ha = simulation.run(_make(async_buffer.AsyncConfig(flush_k=1)),
                        lenet.apply, data, jax.random.PRNGKey(1), rounds=4,
                        eval_every=1, participation=part)
    assert hs.avg_acc == ha.avg_acc
    assert hs.worst_acc == ha.worst_acc


def test_async_fedavg_flush1_matches_barrier_round():
    data, params0 = _setup()
    cohort = np.asarray([1, 4, 6], np.int32)
    sync = make_fedavg(lenet.apply, params0, FedConfig(batch_size=40))
    asy = make_fedavg(lenet.apply, params0, FedConfig(
        batch_size=40, async_buffer=async_buffer.AsyncConfig(flush_k=1)))
    rs, _ = sync.round(sync.init(jax.random.PRNGKey(3), data), data,
                       jax.random.PRNGKey(5), cohort)
    ra, ma = asy.round(asy.init(jax.random.PRNGKey(3), data), data,
                       jax.random.PRNGKey(5), cohort)
    # delta form: θ + Σ w̃ (u − θ) equals Σ w̃ u only up to float re-
    # association, so allclose rather than bit-exact
    for a, b in zip(_leaves(sync, rs), _leaves(asy, ra)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    assert int(ma["streams"]) == 1 and int(ma["flushed"]) == 1


def test_async_deposit_only_round_keeps_params():
    data, _ = _setup()
    asy = _make(async_buffer.AsyncConfig(flush_k=4))
    state = asy.init(jax.random.PRNGKey(3), data)
    before = _leaves(asy, state)
    cohort = np.asarray([1, 4, 6], np.int32)
    s1, m1 = asy.round(state, data, jax.random.PRNGKey(5), cohort)
    assert int(m1["flushed"]) == 0 and int(m1["applied"]) == 0
    assert int(m1["buffer_fill"]) == 3 and int(m1["streams"]) == 0
    for a, b in zip(before, _leaves(asy, s1)):
        np.testing.assert_array_equal(a, b)


def test_async_flush_applies_across_rounds_with_staleness():
    """Uploads banked over rounds flush together; clients whose base
    model predates the last flush carry τ > 0."""
    data, _ = _setup()
    asy = _make(async_buffer.AsyncConfig(flush_k=2, alpha=0.5))
    state = asy.init(jax.random.PRNGKey(3), data)
    # round 1: clients {1, 4} flush immediately -> version 1
    state, m1 = asy.round(state, data, jax.random.PRNGKey(5),
                          np.asarray([1, 4], np.int32))
    assert int(m1["flushed"]) == 1 and int(m1["tau_max"]) == 0
    # round 2: client {2} deposits only (base version 0)
    state, m2 = asy.round(state, data, jax.random.PRNGKey(6),
                          np.asarray([2], np.int32))
    assert int(m2["flushed"]) == 0 and int(m2["buffer_fill"]) == 1
    # round 3: client {6} arrives -> flush of {2, 6}, both trained from
    # version-0 rows while the server is at version 1 -> τ = 1
    state, m3 = asy.round(state, data, jax.random.PRNGKey(7),
                          np.asarray([6], np.int32))
    assert int(m3["flushed"]) == 1 and int(m3["applied"]) == 2
    assert int(m3["tau_max"]) == 1
    assert float(m3["tau_mean"]) == pytest.approx(1.0)
    assert int(np.asarray(state["abuf"]["version"])) == 2


def test_async_absent_clients_keep_models():
    data, _ = _setup()
    asy = _make(async_buffer.AsyncConfig(flush_k=2))
    state = asy.init(jax.random.PRNGKey(3), data)
    before = _leaves(asy, state)
    cohort = np.asarray([1, 4, 6], np.int32)
    absent = np.asarray([0, 2, 3, 5, 7])
    s1, m1 = asy.round(state, data, jax.random.PRNGKey(5), cohort)
    assert int(m1["flushed"]) == 1
    for a, b in zip(before, _leaves(asy, s1)):
        np.testing.assert_array_equal(a[absent], b[absent])
        assert np.abs(a[cohort] - b[cohort]).max() > 0


def test_async_buffer_none_is_the_barrier_engine():
    """The default stays the PR 4 engine — FedConfig() and an explicit
    async_buffer=None build the identical dispatch."""
    data, _ = _setup()
    a = _make(None)
    cfg_default = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=40)
    assert cfg_default.async_buffer is None
    cohort = np.asarray([1, 4, 6], np.int32)
    ra, _ = a.round(a.init(jax.random.PRNGKey(3), data), data,
                    jax.random.PRNGKey(5), cohort)
    b = ucfl.make_ucfl(lenet.apply, _setup()[1], cfg_default,
                       var_batch_size=40)
    rb, _ = b.round(b.init(jax.random.PRNGKey(3), data), data,
                    jax.random.PRNGKey(5), cohort)
    for x, y in zip(_leaves(a, ra), _leaves(b, rb)):
        np.testing.assert_array_equal(x, y)


# --------------------------------------------------- (c) one compiled round

@pytest.mark.parametrize("mesh", [None, "auto"])
def test_async_availability_one_compile(mesh):
    data, _ = _setup()
    m = data.num_clients
    trace = np.zeros((m, 4), bool)
    trace[:4, 0] = True   # 4 eligible
    trace[:1, 1] = True   # 1 eligible (deposit-only under flush_k=3)
    trace[:, 2] = True    # 8 eligible (subsampled)
    # phase 3: nobody online -> the engine skips the round entirely
    part = ParticipationConfig(cohort_size=4, sampler="availability",
                               availability=trace)
    strat = _make(async_buffer.AsyncConfig(flush_k=3), mesh=mesh)
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=8, eval_every=8, participation=part)
    assert strat.round.masked_jit._cache_size() == 1
    flushes = [mt.get("flushed") for mt in h.metrics]
    assert h.metrics[-1].get("skipped", False) or flushes


def test_async_under_mesh_matches_unsharded():
    data, _ = _setup()
    a = _make(async_buffer.AsyncConfig(flush_k=2))
    b = _make(async_buffer.AsyncConfig(flush_k=2), mesh="auto")
    sa = a.init(jax.random.PRNGKey(3), data)
    sb = b.init(jax.random.PRNGKey(3), data)
    cohort = np.asarray([1, 4, 6], np.int32)
    ra, ma = a.round(sa, data, jax.random.PRNGKey(5), cohort)
    rb, mb = b.round(sb, data, jax.random.PRNGKey(5), cohort)
    assert int(ma["applied"]) == int(mb["applied"]) == 3
    # sharded local SGD matches unsharded within f32 round-off (see
    # tests/test_sharded_cohort.py for why not bit-exact)
    for x, y in zip(_leaves(a, ra), _leaves(b, rb)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)
    # buffer bookkeeping is integer state and must agree exactly — except
    # for the slot COUNT, which scales with the mesh-padded cohort (B =
    # flush_k - 1 + padded slots), so compare shape-independent fields
    # plus the set of pending clients (empty after this flush in both)
    for k in ("count", "version", "last_sync"):
        np.testing.assert_array_equal(np.asarray(ra["abuf"][k]),
                                      np.asarray(rb["abuf"][k]))
    for st in (ra, rb):
        assert not np.asarray(
            async_buffer.valid_mask(st["abuf"], data.num_clients)).any()


# ------------------------------------------------------------- (d) dispatch

def test_async_unsupported_strategy_raises():
    _, params0 = _setup()
    with pytest.raises(NotImplementedError):
        make_scaffold(lenet.apply, params0, FedConfig(
            async_buffer=async_buffer.AsyncConfig()))


def test_async_dense_path_raises():
    data, _ = _setup()
    asy = _make(async_buffer.AsyncConfig(flush_k=2))
    state = asy.init(jax.random.PRNGKey(3), data)
    with pytest.raises(ValueError):
        asy.round(state, data, jax.random.PRNGKey(5), None)


def test_async_with_w_refresh_raises():
    _, params0 = _setup()
    with pytest.raises(ValueError):
        ucfl.make_ucfl(lenet.apply, params0, FedConfig(
            w_refresh=RefreshConfig(),
            async_buffer=async_buffer.AsyncConfig()))


# --------------------------------------------------------------- (e) traces

@pytest.mark.parametrize("gen,kw", [
    (diurnal_trace, {}),
    (diurnal_trace, {"spread": False, "peak": 0.7, "trough": 0.2}),
    (battery_trace, {"duty": 2, "recharge": 3}),
    (battery_trace, {"duty": 1, "recharge": 0}),
])
def test_trace_generators_contract(gen, kw):
    t = gen(12, 8, seed=4, **kw)
    assert t.shape == (12, 8) and t.dtype == bool
    assert t.any(axis=1).all()  # every client is up somewhere
    np.testing.assert_array_equal(t, gen(12, 8, seed=4, **kw))  # determinism


def test_trace_generator_validation():
    with pytest.raises(ValueError):
        diurnal_trace(4, 8, peak=0.2, trough=0.5)
    with pytest.raises(ValueError):
        battery_trace(4, 8, duty=0)


def test_battery_trace_duty_cycle_structure():
    t = battery_trace(6, 10, duty=2, recharge=3, seed=0)
    # every client's up-fraction matches its duty cycle within one phase
    per_client = t.sum(axis=1)
    assert per_client.min() >= 1
    assert per_client.max() <= 10 * 2 // 5 + 2
