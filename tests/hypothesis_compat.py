"""Optional-hypothesis shim for the property-test modules.

The offline CPU container does not ship ``hypothesis``; importing it at
module scope used to abort collection of *every* test in the file. This
shim degrades each ``@given(...)`` test to a precise skip when hypothesis
is unavailable while leaving the plain parametrized tests runnable.
"""
from __future__ import annotations

import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
    given = hypothesis.given
except ImportError:  # pragma: no cover - depends on environment
    hypothesis = None
    HAVE_HYPOTHESIS = False

    class _LazyStrategies:
        """Stands in for ``hypothesis.strategies`` inside @given(...) args."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _LazyStrategies()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed in this environment "
                   "(offline container); property test skipped"
        )


def load_ci_profile(*, max_examples: int, suppress_too_slow: bool = False):
    """Register/load the deterministic CI profile (no-op without hypothesis)."""
    if not HAVE_HYPOTHESIS:
        return
    kw = dict(deadline=None, max_examples=max_examples)
    if suppress_too_slow:
        kw["suppress_health_check"] = [hypothesis.HealthCheck.too_slow]
    hypothesis.settings.register_profile("ci", **kw)
    hypothesis.settings.load_profile("ci")
