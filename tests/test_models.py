"""Model-component equivalence tests: MoE dispatch vs dense oracle, SSD
chunked-vs-sequential, attention decode-vs-forward, rolling SWA cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention, moe, ssm
from repro.models.attention import AttnConfig


def test_moe_sorted_dispatch_matches_dense_oracle():
    cfg = moe.MoEConfig(d_model=32, d_ff=48, num_experts=4, top_k=2,
                        capacity_factor=8.0)  # big cf → no drops
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    y, aux = moe.apply(p, x, cfg)
    y_ref = moe.apply_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    cfg = moe.MoEConfig(d_model=16, d_ff=32, num_experts=4, top_k=1,
                        capacity_factor=1.0)
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 16))
    y, _ = moe.apply(p, x, cfg)
    assert bool(jnp.isfinite(y).all())


@pytest.mark.parametrize("seq,chunk", [(12, 4), (16, 16), (32, 8)])
def test_ssd_chunked_equals_sequential_decode(seq, chunk):
    cfg = ssm.SSMConfig(d_model=16, state=8, headdim=4, expand=2, chunk=chunk)
    p = ssm.init(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, seq, 16))
    y, cache = ssm.forward(p, x, cfg)
    c = ssm.init_cache(2, cfg)
    ys = []
    for t in range(seq):
        yt, c = ssm.decode(p, x[:, t: t + 1], c, cfg)
        ys.append(yt)
    yd = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(y), rtol=1e-3,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(c["h"]), np.asarray(cache["h"]),
                               rtol=1e-4, atol=1e-5)


def _mk_attn(window=None, hq=4, hkv=2):
    return AttnConfig(d_model=32, num_heads=hq, num_kv_heads=hkv,
                      head_dim=8, logit_softcap=None)


def test_attention_decode_matches_forward():
    cfg = _mk_attn()
    p = attention.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32))
    pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
    y_full, _ = attention.forward(p, x, pos, cfg)
    cache = attention.init_cache(2, 10, cfg, jnp.float32)
    outs = []
    for t in range(10):
        o, cache = attention.decode(p, x[:, t: t + 1], cache,
                                    jnp.asarray(t), cfg)
        outs.append(o)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=1e-3, atol=1e-4)


def test_sliding_window_rolling_cache_matches_forward():
    """Decode through a rolling window-cache == windowed forward."""
    cfg = _mk_attn()
    window = 4
    p = attention.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 32))
    pos = jnp.broadcast_to(jnp.arange(12)[None], (1, 12))
    y_full, _ = attention.forward(p, x, pos, cfg, window=window)
    cache = attention.init_cache(1, window, cfg, jnp.float32)  # W slots only
    outs = []
    for t in range(12):
        o, cache = attention.decode(p, x[:, t: t + 1], cache,
                                    jnp.asarray(t), cfg, window=window)
        outs.append(o)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=1e-3, atol=1e-4)


def test_softcap_bounds_logit_influence():
    cfg = dataclasses.replace(_mk_attn(), logit_softcap=5.0)
    p = attention.init(jax.random.PRNGKey(0), cfg)
    x = 100.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 6, 32))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (1, 6))
    y, _ = attention.forward(p, x, pos, cfg)
    assert bool(jnp.isfinite(y).all())
