"""End-to-end behaviour tests for the paper's system (small scale, CPU).

These validate the paper's ordinal claims on synthetic federated tasks:
under concept shift the proposed user-centric aggregation beats FedAvg,
tracks the oracle, and the collaboration matrix recovers the ground-truth
group structure.
"""
import functools

import jax
import numpy as np
import pytest

from repro.core import FedConfig, REGISTRY, clustering, ucfl
from repro.data import synthetic
from repro.federated import simulation
from repro.models import lenet


@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(42)
    dkey, mkey = jax.random.split(key)
    data = synthetic.concept_shift(dkey, m=8, n=160, n_test=40,
                                   num_classes=6, groups=2, hw=(16, 16),
                                   channels=1, noise=1.0)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=40)
    return data, params0, cfg


def _run(strategy, rounds=8):
    data, params0, cfg = _setup()
    return simulation.run(strategy, lenet.apply, data,
                          jax.random.PRNGKey(7), rounds=rounds,
                          eval_every=rounds)


def test_ucfl_beats_fedavg_under_concept_shift():
    data, params0, cfg = _setup()
    h_ucfl = _run(ucfl.make_ucfl(lenet.apply, params0, cfg,
                                 var_batch_size=40))
    h_fa = _run(REGISTRY["fedavg"](lenet.apply, params0, cfg))
    assert h_ucfl.final_avg > h_fa.final_avg + 0.2


def test_ucfl_matches_oracle():
    data, params0, cfg = _setup()
    h_ucfl = _run(ucfl.make_ucfl(lenet.apply, params0, cfg,
                                 var_batch_size=40))
    h_or = _run(REGISTRY["oracle"](lenet.apply, params0, cfg))
    assert h_ucfl.final_avg >= h_or.final_avg - 0.05


def test_clustered_variant_matches_full_personalization():
    data, params0, cfg = _setup()
    h_k2 = _run(ucfl.make_ucfl(lenet.apply, params0, cfg, num_streams=2,
                               var_batch_size=40))
    h_full = _run(ucfl.make_ucfl(lenet.apply, params0, cfg,
                                 var_batch_size=40))
    assert h_k2.final_avg >= h_full.final_avg - 0.05


def test_collaboration_matrix_recovers_groups():
    data, params0, cfg = _setup()
    collab = ucfl.compute_collaboration(lenet.apply, params0, data,
                                        var_batch_size=40)
    w = np.asarray(collab["W"])
    groups = np.asarray(data.group)
    same = (groups[:, None] == groups[None, :])
    assert w[same].sum() > 5 * w[~same].sum()


def test_silhouette_detects_two_groups():
    data, params0, cfg = _setup()
    collab = ucfl.compute_collaboration(lenet.apply, params0, data,
                                        var_batch_size=40)
    scores = {}
    for k in range(2, 6):
        res = clustering.kmeans(jax.random.PRNGKey(k), collab["W"], k)
        scores[k] = float(clustering.silhouette_score(collab["W"],
                                                      res.labels))
    assert max(scores, key=scores.get) == 2


def test_worst_user_improves_with_personalization():
    data, params0, cfg = _setup()
    h_ucfl = _run(ucfl.make_ucfl(lenet.apply, params0, cfg,
                                 var_batch_size=40))
    h_fa = _run(REGISTRY["fedavg"](lenet.apply, params0, cfg))
    assert h_ucfl.final_worst > h_fa.final_worst


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_every_strategy_runs_and_is_finite(name):
    data, params0, cfg = _setup()
    make = REGISTRY[name]
    if name in ("scaffold", "pfedme"):
        strat = make(lenet.apply, params0)
    else:
        strat = make(lenet.apply, params0, cfg)
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=2, eval_every=2)
    assert 0.0 <= h.final_avg <= 1.0
