"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same
family (≤2 scan layers + pattern, d_model ≤ 128, ≤4 experts) and runs one
forward + one train-gradient step + one cached decode step on CPU,
asserting output shapes and finiteness. The FULL configs are exercised
only by the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import registry

ARCHS = sorted(configs.ARCHITECTURES)


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones(
            (b, cfg.num_patches, cfg.patch_embed_dim), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((b, cfg.encoder_seq, cfg.d_model),
                                   jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = configs.get(arch).reduced()
    model = registry.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    want_s = 32 + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, want_s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = configs.get(arch).reduced()
    model = registry.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.init_cache(2, 64)
    logits, new_caches = model.decode_step(
        params, caches, jnp.ones((2, 1), jnp.int32), jnp.asarray(3))
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert (jax.tree.structure(new_caches) == jax.tree.structure(caches))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(arch):
    """An SGD step along the gradient must reduce the batch loss.

    Backtracking over a few step sizes: a fixed lr=0.5 overshoots on the
    sharper reduced configs (e.g. kimi's dense-first MoE) even though the
    gradient is a perfectly good descent direction.
    """
    cfg = configs.get(arch).reduced()
    model = registry.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss0, grads = jax.value_and_grad(model.loss)(params, batch)
    losses = []
    for lr in (0.5, 0.1, 0.02):
        params2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        losses.append(float(model.loss(params2, batch)))
        if losses[-1] < float(loss0):
            break
    assert min(losses) < float(loss0), (float(loss0), losses)


@pytest.mark.parametrize("arch", ["gemma2-9b", "qwen2-7b", "phi3-medium-14b",
                                  "whisper-large-v3", "internvl2-1b"])
def test_head_padding_exactness(arch):
    """for_mesh() padding must not change the model's function."""
    cfg = configs.get(arch).reduced()
    # reduced heads: re-impose the awkward full-scale ratios
    awkward = {"gemma2-9b": (4, 2), "qwen2-7b": (7, 1),
               "phi3-medium-14b": (5, 5), "whisper-large-v3": (5, 5),
               "internvl2-1b": (7, 1)}
    hq, hkv = awkward[arch]
    cfg = cfg.reduced(num_heads=hq, num_kv_heads=hkv)
    padded = cfg.for_mesh(4)
    m0 = registry.build(cfg)
    m1 = registry.build(padded)
    p0 = m0.init(jax.random.PRNGKey(0))
    p1 = m1.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l0, _ = m0.forward(p0, batch)
    l1, _ = m1.forward(p1, batch)
    v = cfg.vocab_size
    assert float(jnp.abs(l0[..., :v] - l1[..., :v]).max()) < 2e-3
