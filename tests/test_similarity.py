"""Property + unit tests for the collaboration-coefficient machinery
(Eq. 9/10) — the paper's claimed limit behaviors are encoded here."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import similarity

hypothesis.settings.register_profile("ci", deadline=None, max_examples=25)
hypothesis.settings.load_profile("ci")


def _rand_inputs(seed, m, d=32, k=4):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(m, k, d)).astype(np.float32))
    n = jnp.asarray(rng.integers(10, 1000, size=(m,)).astype(np.float32))
    return g, n


@hypothesis.given(m=st.integers(2, 10), seed=st.integers(0, 2**31 - 1))
def test_weights_row_stochastic(m, seed):
    g, n = _rand_inputs(seed, m)
    out = similarity.collaboration_round(g, n)
    w = np.asarray(out["W"])
    assert (w >= 0).all()
    np.testing.assert_allclose(w.sum(axis=1), 1.0, rtol=1e-5)


def test_homogeneous_clients_fall_back_to_fedavg():
    """Identical gradient distributions + equal n ⇒ near-uniform W."""
    rng = np.random.default_rng(0)
    m, k, d = 6, 8, 64
    base = rng.normal(size=(1, 1, d)) * 0.01
    g = jnp.asarray((base + rng.normal(size=(m, k, d))).astype(np.float32))
    n = jnp.full((m,), 100.0)
    out = similarity.collaboration_round(g, n)
    w = np.asarray(out["W"])
    # every entry close to 1/m (Δ between full grads ≈ within-client noise)
    assert np.abs(w - 1.0 / m).max() < 0.15


def test_zero_variance_degenerates_to_local():
    """σ→0 (infinite data): client trusts only itself (paper §IV-A)."""
    m, d = 4, 16
    rng = np.random.default_rng(1)
    full = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    delta = similarity.pairwise_delta(full, impl="ref")
    w = similarity.mixing_weights(delta, jnp.zeros((m,)), jnp.full((m,), 10.0))
    np.testing.assert_allclose(np.asarray(w), np.eye(m), atol=1e-6)


def test_cluster_structure_detected():
    """Two gradient clusters ⇒ block-diagonal-ish W."""
    rng = np.random.default_rng(2)
    m, k, d = 8, 6, 64
    dir_a = rng.normal(size=d)
    dir_b = -dir_a
    g = np.zeros((m, k, d), np.float32)
    for i in range(m):
        center = dir_a if i < m // 2 else dir_b
        g[i] = center + 0.05 * rng.normal(size=(k, d))
    out = similarity.collaboration_round(jnp.asarray(g),
                                         jnp.full((m,), 100.0))
    w = np.asarray(out["W"])
    same = w[:m // 2, :m // 2].sum() + w[m // 2:, m // 2:].sum()
    cross = w[:m // 2, m // 2:].sum() + w[m // 2:, :m // 2].sum()
    assert same > 10 * cross


def test_dataset_size_bias():
    """With identical distributions, larger-n clients get more weight."""
    rng = np.random.default_rng(3)
    m, k, d = 4, 6, 32
    g = jnp.asarray(rng.normal(size=(m, k, d)).astype(np.float32) * 0.01
                    + rng.normal(size=(1, 1, d)).astype(np.float32))
    n = jnp.asarray([10.0, 10.0, 10.0, 1000.0])
    out = similarity.collaboration_round(g, n)
    w = np.asarray(out["W"])
    assert (w[:, 3] > w[:, 0]).all()


def test_sigma_sq_nonnegative_and_zero_for_identical():
    d, k = 16, 4
    g_same = jnp.ones((k, d))
    assert float(similarity.sigma_sq(g_same, jnp.ones((d,)))) == 0.0
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    assert float(similarity.sigma_sq(g, jnp.mean(g, 0))) >= 0.0
