"""Property + unit tests for the collaboration-coefficient machinery
(Eq. 9/10) — the paper's claimed limit behaviors are encoded here."""
import jax
import jax.numpy as jnp
import numpy as np

from hypothesis_compat import given, load_ci_profile, st
from repro.core import aggregation, similarity

load_ci_profile(max_examples=25)


def _rand_inputs(seed, m, d=32, k=4):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(m, k, d)).astype(np.float32))
    n = jnp.asarray(rng.integers(10, 1000, size=(m,)).astype(np.float32))
    return g, n


@given(m=st.integers(2, 10), seed=st.integers(0, 2**31 - 1))
def test_weights_row_stochastic(m, seed):
    g, n = _rand_inputs(seed, m)
    out = similarity.collaboration_round(g, n)
    w = np.asarray(out["W"])
    assert (w >= 0).all()
    np.testing.assert_allclose(w.sum(axis=1), 1.0, rtol=1e-5)


def test_homogeneous_clients_fall_back_to_fedavg():
    """Identical gradient distributions + equal n ⇒ near-uniform W."""
    rng = np.random.default_rng(0)
    m, k, d = 6, 8, 64
    base = rng.normal(size=(1, 1, d)) * 0.01
    g = jnp.asarray((base + rng.normal(size=(m, k, d))).astype(np.float32))
    n = jnp.full((m,), 100.0)
    out = similarity.collaboration_round(g, n)
    w = np.asarray(out["W"])
    # every entry close to 1/m (Δ between full grads ≈ within-client noise)
    assert np.abs(w - 1.0 / m).max() < 0.15


def test_zero_variance_degenerates_to_local():
    """σ→0 (infinite data): client trusts only itself (paper §IV-A)."""
    m, d = 4, 16
    rng = np.random.default_rng(1)
    full = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    delta = similarity.pairwise_delta(full, impl="ref")
    w = similarity.mixing_weights(delta, jnp.zeros((m,)), jnp.full((m,), 10.0))
    np.testing.assert_allclose(np.asarray(w), np.eye(m), atol=1e-6)


def test_cluster_structure_detected():
    """Two gradient clusters ⇒ block-diagonal-ish W."""
    rng = np.random.default_rng(2)
    m, k, d = 8, 6, 64
    dir_a = rng.normal(size=d)
    dir_b = -dir_a
    g = np.zeros((m, k, d), np.float32)
    for i in range(m):
        center = dir_a if i < m // 2 else dir_b
        g[i] = center + 0.05 * rng.normal(size=(k, d))
    out = similarity.collaboration_round(jnp.asarray(g),
                                         jnp.full((m,), 100.0))
    w = np.asarray(out["W"])
    same = w[:m // 2, :m // 2].sum() + w[m // 2:, m // 2:].sum()
    cross = w[:m // 2, m // 2:].sum() + w[m // 2:, :m // 2].sum()
    assert same > 10 * cross


def test_dataset_size_bias():
    """With identical distributions, larger-n clients get more weight."""
    rng = np.random.default_rng(3)
    m, k, d = 4, 6, 32
    g = jnp.asarray(rng.normal(size=(m, k, d)).astype(np.float32) * 0.01
                    + rng.normal(size=(1, 1, d)).astype(np.float32))
    n = jnp.asarray([10.0, 10.0, 10.0, 1000.0])
    out = similarity.collaboration_round(g, n)
    w = np.asarray(out["W"])
    assert (w[:, 3] > w[:, 0]).all()


@given(m=st.integers(3, 10), seed=st.integers(0, 2**31 - 1))
def test_cohort_sliced_weights_stay_row_stochastic(m, seed):
    """Eq. 9's W sliced to any cohort and renormalized is row-stochastic."""
    g, n = _rand_inputs(seed, m)
    w = similarity.collaboration_round(g, n)["W"]
    rng = np.random.default_rng(seed)
    c = int(rng.integers(1, m + 1))
    cohort = jnp.asarray(
        np.sort(rng.choice(m, size=c, replace=False)).astype(np.int32))
    wc = np.asarray(aggregation.cohort_mixing_matrix(w, cohort))
    assert (wc >= 0).all()
    np.testing.assert_allclose(wc.sum(axis=1), 1.0, rtol=1e-5)


def test_cohort_sliced_weights_row_stochastic_sweep():
    """Non-hypothesis fallback of the property above (always runs)."""
    for seed in range(10):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(3, 10))
        g, n = _rand_inputs(seed, m)
        w = similarity.collaboration_round(g, n)["W"]
        c = int(rng.integers(1, m + 1))
        cohort = jnp.asarray(
            np.sort(rng.choice(m, size=c, replace=False)).astype(np.int32))
        wc = np.asarray(aggregation.cohort_mixing_matrix(w, cohort))
        assert (wc >= 0).all()
        np.testing.assert_allclose(wc.sum(axis=1), 1.0, rtol=1e-5)


def test_sigma_sq_nonnegative_and_zero_for_identical():
    d, k = 16, 4
    g_same = jnp.ones((k, d))
    assert float(similarity.sigma_sq(g_same, jnp.ones((d,)))) == 0.0
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    assert float(similarity.sigma_sq(g, jnp.mean(g, 0))) >= 0.0
