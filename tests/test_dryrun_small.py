"""Launch-layer integration: lower + compile the federated train/serve
steps on a small 8-host-device mesh, in a SUBPROCESS (this process must
keep seeing exactly 1 device — forcing device count is process-global).

This is the CI-sized replica of the 512-chip production dry-run.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.configs.base import InputShape
    from repro.launch import dryrun, mesh as meshlib, roofline

    arch, kind = "%s", "%s"
    cfg = configs.get(arch).reduced()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    shape = InputShape("t", 64, 8, kind)
    compiled, meta = dryrun.lower_one(cfg, shape, mesh, agg="user_centric")
    roof = roofline.analyze(compiled, cfg, shape, mesh_name="test",
                            chips=8, agg="user_centric",
                            abs_params_one=meta["abs_params_one"])
    print(json.dumps({
        "flops": roof.hlo_flops_per_chip,
        "coll": roof.collective_bytes_per_chip,
        "dom": roof.dominant,
    }))
""")


@pytest.mark.parametrize("arch,kind", [
    ("stablelm-1.6b", "train"),
    ("mamba2-1.3b", "train"),
    ("mixtral-8x7b", "train"),
    ("gemma2-9b", "decode"),
    ("zamba2-2.7b", "decode"),
    ("whisper-large-v3", "prefill"),
])
def test_small_mesh_lower_compile(arch, kind):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT % (arch, kind)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flops"] > 0
    if kind == "train":
        # the user-centric mixing collective must be present
        assert res["coll"] > 0
