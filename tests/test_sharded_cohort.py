"""Sharded cohort execution over a device mesh.

Run multi-device on CPU with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    JAX_PLATFORMS=cpu PYTHONPATH=src python -m pytest tests/test_sharded_cohort.py

(the CI ``multi-device`` job does exactly this). On a single device the
mesh degenerates to one shard — the shard_map code path is still
exercised, just without real partitioning.

Guarantees covered:
  (a) a sharded round (``FedConfig(mesh=...)``) matches the ``mesh=None``
      round for ucfl, fedavg, and the stateful scaffold/ditto baselines.
      Documented tolerance: sentinel-slot padding is bit-exact, but
      shard_map changes the *local* batch shape each device sees, and
      XLA picks conv/matmul reduction tilings per shape — observed
      differences are ulp-level (~1e-7 relative), so the comparison is
      allclose(rtol=1e-5, atol=1e-6), the same tolerance the chunked
      collaboration test uses. With one device (or one shard) results
      are bit-exact.
  (b) slot counts not divisible by the shard count are padded up by the
      dispatcher (sentinel slots, bit-invisible) and the padded count is
      static, so varying availability cohorts under a fixed mesh reuse
      ONE compiled round.
  (c) ``chunk_size`` composes with sharding (chunking within the shard).
  (d) the mesh helpers: knob resolution, slot padding, shardings.
"""
import functools

import jax
import numpy as np
import pytest

from repro.core import FedConfig, REGISTRY, ucfl
from repro.data import synthetic
from repro.federated import client as fedclient
from repro.federated import mesh as mesh_lib
from repro.federated import simulation
from repro.federated.participation import (Cohort, ParticipationConfig,
                                           pad_slots)
from repro.models import lenet

NDEV = jax.device_count()


@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(17)
    dkey, mkey = jax.random.split(key)
    data = synthetic.concept_shift(dkey, m=8, n=120, n_test=30,
                                   num_classes=6, groups=2, hw=(16, 16),
                                   channels=1, noise=1.0)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    return data, params0


def _make(name, params0, *, mesh=None, chunk_size=None):
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=40,
                    chunk_size=chunk_size, mesh=mesh)
    if name == "ucfl":
        return ucfl.make_ucfl(lenet.apply, params0, cfg, var_batch_size=40)
    if name in ("scaffold", "pfedme"):
        return REGISTRY[name](lenet.apply, params0,
                              FedConfig(lr=0.01, momentum=0.0,
                                        epochs=5 if name == "scaffold" else 1,
                                        batch_size=40, chunk_size=chunk_size,
                                        mesh=mesh))
    return REGISTRY[name](lenet.apply, params0, cfg)


def _leaves(strat, state):
    return [np.asarray(x) for x in jax.tree.leaves(strat.eval_params(state))]


def _assert_equiv(a, b):
    for x, y in zip(a, b):
        if NDEV == 1:  # one shard: identical local shapes, bit-exact
            np.testing.assert_array_equal(x, y)
        else:  # documented tolerance, see module docstring
            np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


# ------------------------------------------------- (d) mesh helper units

def test_resolve_knob():
    assert mesh_lib.resolve(None) is None
    m = mesh_lib.resolve("auto")
    assert mesh_lib.num_shards(m) == NDEV
    assert m.axis_names == (mesh_lib.AXIS,)
    assert mesh_lib.num_shards(mesh_lib.resolve(1)) == 1
    assert mesh_lib.resolve(m) is m
    with pytest.raises(ValueError):
        mesh_lib.resolve(NDEV + 1)


def test_pad_to_shards():
    assert mesh_lib.pad_to_shards(3, 1) == 3
    assert mesh_lib.pad_to_shards(3, 4) == 4
    assert mesh_lib.pad_to_shards(8, 4) == 8
    assert mesh_lib.pad_to_shards(9, 4) == 12


def test_pad_slots_is_sentinel_extension():
    c = Cohort(indices=np.asarray([1, 4, 6], np.int32),
               mask=np.asarray([1, 1, 1], bool))
    p = pad_slots(c, 8, m=8)
    assert p.num_slots == 8 and len(p) == 3
    np.testing.assert_array_equal(p.indices, [1, 4, 6, 8, 8, 8, 8, 8])
    np.testing.assert_array_equal(p.mask, [1, 1, 1, 0, 0, 0, 0, 0])
    assert pad_slots(c, 3, m=8) is c  # no-op when already that size


def test_slot_sharding_specs():
    mesh = mesh_lib.resolve("auto")
    slot = mesh_lib.slot_sharding(mesh)
    rep = mesh_lib.replicated_sharding(mesh)
    assert slot.spec == jax.sharding.PartitionSpec(mesh_lib.AXIS)
    assert rep.spec == jax.sharding.PartitionSpec()
    # the slot sharding actually partitions a slot-axis array
    x = jax.device_put(np.zeros((NDEV * 2, 3), np.float32), slot)
    assert len({d for s in x.addressable_shards for d in [s.device]}) == NDEV


# ------------------------------- (a) sharded vs unsharded round results

@pytest.mark.parametrize("name", ["ucfl", "fedavg", "scaffold", "ditto",
                                  "pfedme"])
def test_sharded_round_matches_unsharded(name):
    """Same init key, same cohort, same round key: the mesh must be
    invisible up to the documented float tolerance. Uses a 3-member
    cohort so the dispatcher must pad slots up to the shard multiple."""
    data, params0 = _setup()
    a = _make(name, params0)            # mesh=None reference
    b = _make(name, params0, mesh="auto")
    sa = a.init(jax.random.PRNGKey(3), data)
    sb = b.init(jax.random.PRNGKey(3), data)
    _assert_equiv(_leaves(a, sa), _leaves(b, sb))  # sharded collaboration

    cohort = np.asarray([1, 4, 6], np.int32)
    rkey = jax.random.PRNGKey(101)
    ra, ma = a.round(simulation.donation_safe_copy(sa), data, rkey, cohort)
    rb, mb = b.round(simulation.donation_safe_copy(sb), data, rkey, cohort)
    assert ma["cohort_size"] == mb["cohort_size"] == 3
    _assert_equiv(_leaves(a, ra), _leaves(b, rb))

    # dense path (m divisible by the shard count shards too; otherwise it
    # falls back to the unsharded vmap — equal either way)
    da, _ = a.round(simulation.donation_safe_copy(sa), data, rkey)
    db, _ = b.round(simulation.donation_safe_copy(sb), data, rkey)
    _assert_equiv(_leaves(a, da), _leaves(b, db))


def test_chunk_size_composes_with_sharding():
    """chunk_size chunks within each device's shard; results still match
    the monolithic unsharded round."""
    data, params0 = _setup()
    a = _make("fedavg", params0)
    b = _make("fedavg", params0, mesh="auto", chunk_size=1)
    sa = a.init(jax.random.PRNGKey(3), data)
    sb = b.init(jax.random.PRNGKey(3), data)
    rkey = jax.random.PRNGKey(7)
    cohort = np.asarray([0, 2, 3, 5, 6], np.int32)
    ra, _ = a.round(simulation.donation_safe_copy(sa), data, rkey, cohort)
    rb, _ = b.round(simulation.donation_safe_copy(sb), data, rkey, cohort)
    # chunking reshapes the local batch (1 vs 5 rows) even on one device,
    # so this comparison is always at the documented float tolerance
    for x, y in zip(_leaves(a, ra), _leaves(b, rb)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


def test_sharded_collaboration_matches_monolithic():
    data, params0 = _setup()
    mono = ucfl.compute_collaboration(lenet.apply, params0, data,
                                      var_batch_size=40)
    shard = ucfl.compute_collaboration(lenet.apply, params0, data,
                                       var_batch_size=40, mesh="auto")
    for key in ("full_grads", "sigma_sq", "delta", "W"):
        np.testing.assert_allclose(np.asarray(shard[key]),
                                   np.asarray(mono[key]),
                                   rtol=1e-5, atol=1e-6)


def test_sharded_evaluate_matches():
    data, params0 = _setup()
    stacked = jax.tree.map(
        lambda x: jax.numpy.broadcast_to(
            x, (data.num_clients,) + x.shape) + 0.0, params0)
    dense = np.asarray(fedclient.evaluate(lenet.apply, stacked, data.x_test,
                                          data.y_test))
    shard = np.asarray(fedclient.evaluate(lenet.apply, stacked, data.x_test,
                                          data.y_test, mesh="auto"))
    # logits differ at ulp level under the mesh (local batch shape changes
    # XLA's reduction tiling), so a near-tied argmax could flip one test
    # point: allow at most one flipped prediction per client (1/n_test)
    np.testing.assert_allclose(dense, shard,
                               atol=1.0 / data.x_test.shape[1] + 1e-7)


# ------------------------------------ (b) recompile guard under a mesh

def test_availability_trace_one_compile_under_mesh():
    """Varying eligible-set sizes with a fixed mesh must reuse ONE
    compiled round: the dispatcher's shard-multiple padding is static."""
    data, params0 = _setup()
    m = data.num_clients
    trace = np.zeros((m, 3), bool)
    trace[:4, 0] = True
    trace[:2, 1] = True
    trace[:, 2] = True
    part = ParticipationConfig(cohort_size=3, sampler="availability",
                               availability=trace)
    strat = _make("fedavg", params0, mesh="auto")
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=6, eval_every=6, participation=part)
    assert h.metrics[-1]["cohort_size"] in (2, 3)
    assert strat.round.masked_jit._cache_size() == 1


def test_simulation_trajectory_matches_unsharded():
    """A short availability run under the mesh reproduces the unsharded
    accuracy trajectory (same cohorts, same keys)."""
    data, params0 = _setup()
    part = ParticipationConfig(cohort_size=3, seed=5)
    hs = []
    for mesh in (None, "auto"):
        strat = _make("fedavg", params0, mesh=mesh)
        hs.append(simulation.run(strat, lenet.apply, data,
                                 jax.random.PRNGKey(11), rounds=2,
                                 eval_every=1, participation=part,
                                 eval_mesh=mesh))
    assert [m["cohort_size"] for m in hs[0].metrics] == \
        [m["cohort_size"] for m in hs[1].metrics]
    # atol covers one argmax flip per client in the sharded eval pass
    np.testing.assert_allclose(hs[0].avg_acc, hs[1].avg_acc,
                               atol=1.0 / data.x_test.shape[1] + 1e-6)
