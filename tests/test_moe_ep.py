"""Expert-parallel shard_map MoE vs the dense oracle (subprocess: needs
multiple host devices; this process must keep seeing 1)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.models import moe

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = moe.MoEConfig(d_model=32, d_ff=64, num_experts=8, top_k=2,
                        capacity_factor=8.0, ep_axis="data")
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
    y_ref = moe.apply_reference(p, x, dataclasses.replace(cfg, ep_axis=None))
    moe.set_ep_mesh(mesh)
    y_ep, aux = jax.jit(
        lambda p, x: moe.apply_expert_parallel(p, x, cfg, cf2=8.0))(p, x)
    err = float(jnp.abs(y_ep - y_ref).max())
    assert err < 1e-4, err
    g = jax.grad(
        lambda p: moe.apply_expert_parallel(p, x, cfg, cf2=8.0)[0].sum())(p)
    assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
    assert float(aux) > 0
    print("EP_OK", err)
""")


def test_expert_parallel_matches_dense_oracle():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "EP_OK" in out.stdout
