"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, load_ci_profile, st
from repro.kernels import ops, ref

load_ci_profile(max_examples=20, suppress_too_slow=True)


SHAPES_MIX = [(1, 1, 128), (4, 8, 300), (16, 16, 1024), (5, 7, 97),
              (32, 32, 2048), (3, 20, 513)]


@pytest.mark.parametrize("k,m,d", SHAPES_MIX)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mix_aggregate_matches_oracle(k, m, d, dtype):
    rng = np.random.default_rng(k * 100 + m)
    w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32)).astype(dtype)
    got = ops.mix_aggregate(w, t, impl="interpret")
    want = ref.mix_aggregate(w, t)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)
    assert got.dtype == t.dtype


@pytest.mark.parametrize("block_d", [128, 256, 2048])
def test_mix_aggregate_block_sweep(block_d):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(6, 6)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(6, 777)).astype(np.float32))
    got = ops.mix_aggregate(w, t, impl="interpret", block_d=block_d)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.mix_aggregate(w, t)),
                               rtol=1e-5, atol=1e-5)


SHAPES_SCATTER = [(8, 3, 128), (16, 6, 300), (9, 4, 513), (32, 5, 2048),
                  (8, 8, 777)]


def _scatter_case(m, c, d, pads, rng):
    w = jnp.asarray(rng.normal(size=(c, c)).astype(np.float32))
    if pads:
        w = w * jnp.asarray(np.arange(c) < c - pads, np.float32)[None, :]
    theta = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    full = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    real = np.sort(rng.choice(m, size=c - pads, replace=False))
    idx = jnp.asarray(np.concatenate([real, [m] * pads]).astype(np.int32))
    mask = jnp.asarray(np.arange(c) < c - pads)
    return w, theta, idx, mask, full, real


@pytest.mark.parametrize("m,c,d", SHAPES_SCATTER)
@pytest.mark.parametrize("pads", [0, 2])
def test_masked_mix_scatter_matches_oracle(m, c, d, pads):
    if pads >= c:
        pytest.skip("needs at least one real slot")
    rng = np.random.default_rng(m * 100 + c + pads)
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, pads, rng)
    # ref first: the pallas path donates `full` on backends that support
    # buffer donation
    want = ref.masked_mix_scatter(w, theta, idx, mask, full)
    got = ops.masked_mix_scatter(w, theta, idx, mask, jnp.array(full),
                                 impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_masked_mix_scatter_untouched_rows_identical():
    """Rows outside the cohort never move — bit-identical, not just close."""
    rng = np.random.default_rng(0)
    m, c, d = 16, 5, 300
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, 1, rng)
    before = np.asarray(full).copy()
    out = np.asarray(ops.masked_mix_scatter(w, theta, idx, mask,
                                            jnp.array(full),
                                            impl="interpret"))
    absent = np.setdiff1d(np.arange(m), real)
    np.testing.assert_array_equal(out[absent], before[absent])
    assert np.abs(out[real] - before[real]).max() > 0


def test_masked_mix_scatter_equals_mix_then_scatter():
    """The fusion must equal mix_aggregate + row scatter on real slots."""
    rng = np.random.default_rng(3)
    m, c, d = 12, 4, 257
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, 0, rng)
    mixed = np.asarray(ref.mix_aggregate(w, theta))
    want = np.asarray(full).copy()
    want[real] = mixed
    got = np.asarray(ref.masked_mix_scatter(w, theta, idx, mask, full))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("m,d", [(2, 64), (8, 500), (16, 4096), (9, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_delta_matches_oracle(m, d, dtype):
    rng = np.random.default_rng(m)
    g = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32)).astype(dtype)
    got = ops.pairwise_delta(g, impl="interpret")
    want = ref.pairwise_delta(g)
    tol = 1e-3 * d if dtype == jnp.bfloat16 else 1e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=tol)


@pytest.mark.parametrize("m,k,f", [(4, 2, 4), (20, 4, 20), (100, 7, 100),
                                   (9, 3, 17)])
def test_kmeans_assign_matches_oracle(m, k, f):
    rng = np.random.default_rng(m + k)
    p = jnp.asarray(rng.normal(size=(m, f)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, f)).astype(np.float32))
    l1, d1 = ops.kmeans_assign(p, c, impl="interpret")
    l2, d2 = ref.kmeans_assign(p, c)
    assert np.array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-4, atol=1e-4)


@given(
    m=st.integers(2, 12), d=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_delta_properties(m, d, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    delta = np.asarray(ops.pairwise_delta(g, impl="interpret"))
    assert delta.shape == (m, m)
    # symmetric, nonnegative, zero diagonal
    np.testing.assert_allclose(delta, delta.T, rtol=1e-4, atol=1e-4)
    assert (delta >= 0).all()
    np.testing.assert_allclose(np.diag(delta), 0.0, atol=1e-3 * d)


@given(
    k=st.integers(1, 8), m=st.integers(1, 8), seed=st.integers(0, 2**31 - 1)
)
def test_mix_aggregate_linearity(k, m, seed):
    """Mixing is linear: mix(W, a+b) == mix(W, a) + mix(W, b)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float32))
    a = jnp.asarray(rng.normal(size=(m, 130)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(m, 130)).astype(np.float32))
    lhs = ops.mix_aggregate(w, a + b, impl="interpret")
    rhs = (ops.mix_aggregate(w, a, impl="interpret")
           + ops.mix_aggregate(w, b, impl="interpret"))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


def test_mix_aggregate_row_stochastic_preserves_constant():
    """A row-stochastic W maps constant models to the same constant."""
    m = 8
    w = jnp.ones((m, m)) / m
    t = jnp.full((m, 257), 3.25, jnp.float32)
    out = ops.mix_aggregate(w, t, impl="interpret")
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-6)
