"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, load_ci_profile, st
from repro.kernels import ops, ref

load_ci_profile(max_examples=20, suppress_too_slow=True)


SHAPES_MIX = [(1, 1, 128), (4, 8, 300), (16, 16, 1024), (5, 7, 97),
              (32, 32, 2048), (3, 20, 513)]


@pytest.mark.parametrize("k,m,d", SHAPES_MIX)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mix_aggregate_matches_oracle(k, m, d, dtype):
    rng = np.random.default_rng(k * 100 + m)
    w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32)).astype(dtype)
    got = ops.mix_aggregate(w, t, impl="interpret")
    want = ref.mix_aggregate(w, t)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)
    assert got.dtype == t.dtype


@pytest.mark.parametrize("block_d", [128, 256, 2048])
def test_mix_aggregate_block_sweep(block_d):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(6, 6)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(6, 777)).astype(np.float32))
    got = ops.mix_aggregate(w, t, impl="interpret", block_d=block_d)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.mix_aggregate(w, t)),
                               rtol=1e-5, atol=1e-5)


SHAPES_SCATTER = [(8, 3, 128), (16, 6, 300), (9, 4, 513), (32, 5, 2048),
                  (8, 8, 777)]


def _scatter_case(m, c, d, pads, rng):
    w = jnp.asarray(rng.normal(size=(c, c)).astype(np.float32))
    if pads:
        w = w * jnp.asarray(np.arange(c) < c - pads, np.float32)[None, :]
    theta = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    full = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    real = np.sort(rng.choice(m, size=c - pads, replace=False))
    idx = jnp.asarray(np.concatenate([real, [m] * pads]).astype(np.int32))
    mask = jnp.asarray(np.arange(c) < c - pads)
    return w, theta, idx, mask, full, real


@pytest.mark.parametrize("m,c,d", SHAPES_SCATTER)
@pytest.mark.parametrize("pads", [0, 2])
def test_masked_mix_scatter_matches_oracle(m, c, d, pads):
    if pads >= c:
        pytest.skip("needs at least one real slot")
    rng = np.random.default_rng(m * 100 + c + pads)
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, pads, rng)
    # ref first: the pallas path donates `full` on backends that support
    # buffer donation
    want = ref.masked_mix_scatter(w, theta, idx, mask, full)
    got = ops.masked_mix_scatter(w, theta, idx, mask, jnp.array(full),
                                 impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_masked_mix_scatter_untouched_rows_identical():
    """Rows outside the cohort never move — bit-identical, not just close."""
    rng = np.random.default_rng(0)
    m, c, d = 16, 5, 300
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, 1, rng)
    before = np.asarray(full).copy()
    out = np.asarray(ops.masked_mix_scatter(w, theta, idx, mask,
                                            jnp.array(full),
                                            impl="interpret"))
    absent = np.setdiff1d(np.arange(m), real)
    np.testing.assert_array_equal(out[absent], before[absent])
    assert np.abs(out[real] - before[real]).max() > 0


def test_masked_mix_scatter_equals_mix_then_scatter():
    """The fusion must equal mix_aggregate + row scatter on real slots."""
    rng = np.random.default_rng(3)
    m, c, d = 12, 4, 257
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, 0, rng)
    mixed = np.asarray(ref.mix_aggregate(w, theta))
    want = np.asarray(full).copy()
    want[real] = mixed
    got = np.asarray(ref.masked_mix_scatter(w, theta, idx, mask, full))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ------------------------------------------- HBM-resident cohort variant
#
# CI's multi-device job re-runs this file under 8 forced host devices, so
# the interpret-mode kernels are exercised at both 1 and 8 devices.

SHAPES_HBM = [(8, 3, 128), (16, 6, 300), (9, 4, 513), (32, 5, 2048),
              (8, 8, 777), (4, 6, 128)]  # (4, 6, ·): c > m


@pytest.mark.parametrize("m,c,d", SHAPES_HBM)
@pytest.mark.parametrize("pads", [0, 2])
def test_hbm_mix_scatter_matches_slab_and_oracle(m, c, d, pads):
    if c - pads > m:
        pytest.skip("more real slots than clients")
    rng = np.random.default_rng(m * 1000 + c * 10 + pads)
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, pads, rng)
    want = ref.masked_mix_scatter(w, theta, idx, mask, full)
    slab = ops.masked_mix_scatter(w, theta, idx, mask, jnp.array(full),
                                  impl="interpret_slab")
    got = ops.masked_mix_scatter(w, theta, idx, mask, jnp.array(full),
                                 impl="interpret_hbm")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(slab),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("variant", ["interpret_slab", "interpret_hbm"])
def test_mix_scatter_all_pad_cohort_is_identity(variant):
    """A sentinel-only cohort (every slot padded) must not move a byte."""
    m, c, d = 10, 4, 257
    rng = np.random.default_rng(7)
    w, theta, idx, mask, full, _ = _scatter_case(m, c, d, c, rng)
    assert not np.asarray(mask).any()
    out = ops.masked_mix_scatter(w, theta, idx, mask, jnp.array(full),
                                 impl=variant)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(full))


def test_hbm_mix_scatter_untouched_rows_identical():
    """The HBM kernel never DMAs a non-cohort row — bit-identical."""
    rng = np.random.default_rng(1)
    m, c, d = 16, 5, 300
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, 1, rng)
    before = np.asarray(full).copy()
    out = np.asarray(ops.masked_mix_scatter(w, theta, idx, mask,
                                            jnp.array(full),
                                            impl="interpret_hbm"))
    absent = np.setdiff1d(np.arange(m), real)
    np.testing.assert_array_equal(out[absent], before[absent])
    assert np.abs(out[real] - before[real]).max() > 0


@pytest.mark.parametrize("m,c,d", [(8, 3, 128), (9, 4, 513), (4, 6, 300)])
def test_cohort_gather_matches_take(m, c, d):
    """The per-row DMA gather is bit-identical to clamped jnp.take,
    including pad sentinels (>= m) and duplicate indices."""
    rng = np.random.default_rng(m + c)
    full = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    idx = jnp.asarray(
        np.concatenate([rng.integers(0, m, size=c - 2), [0, m]]), jnp.int32)
    got = ops.cohort_gather(full, idx, impl="interpret")
    want = ref.cohort_gather(full, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(want),
        np.asarray(jnp.take(full, jnp.minimum(idx, m - 1), axis=0)))


def test_kernel_shape_contracts_raise():
    """Both scatter kernels reject malformed shapes with ValueError (not
    assert — the contract must survive python -O)."""
    from repro.kernels.masked_gather_mix_scatter import (
        cohort_gather_pallas, masked_gather_mix_scatter_pallas)
    from repro.kernels.masked_mix_scatter import masked_mix_scatter_pallas

    w = jnp.zeros((3, 3))
    theta = jnp.zeros((3, 16))
    full = jnp.zeros((8, 16))
    idx = jnp.zeros((3,), jnp.int32)
    mask = jnp.ones((3,), bool)
    for kernel in (masked_mix_scatter_pallas,
                   masked_gather_mix_scatter_pallas):
        with pytest.raises(ValueError):
            kernel(jnp.zeros((3, 2)), theta, idx, mask, jnp.array(full),
                   interpret=True)
        with pytest.raises(ValueError):
            kernel(w, jnp.zeros((3, 8)), idx, mask, jnp.array(full),
                   interpret=True)
        with pytest.raises(ValueError):
            kernel(w, theta, jnp.zeros((4,), jnp.int32), mask,
                   jnp.array(full), interpret=True)
        with pytest.raises(ValueError):
            kernel(w, theta, idx, mask, jnp.zeros((8, 16, 1)),
                   interpret=True)
    with pytest.raises(ValueError):
        cohort_gather_pallas(jnp.zeros((8,)), idx, interpret=True)
    with pytest.raises(ValueError):
        cohort_gather_pallas(full, jnp.zeros((3, 1), jnp.int32),
                             interpret=True)


def test_aligned_dim_and_zero_copy_bound():
    """aligned_dim rounds to the 128 lane multiple, and state created at
    that width (8-multiple rows) takes the slab kernel's aliased
    zero-copy path — no O(m·d) padding copy."""
    from repro.kernels.masked_mix_scatter import padding_copy_needed

    assert ops.aligned_dim(1) == 128
    assert ops.aligned_dim(128) == 128
    assert ops.aligned_dim(129) == 256
    assert padding_copy_needed(8, 3, 300)  # unaligned d forces the copy
    assert not padding_copy_needed(8, 3, ops.aligned_dim(300))


def test_lenet_label_shift_buffer_takes_zero_copy_path():
    """Regression for the aligned-width satellite: the LeNet/label-shift
    bench config's flat upload width, created at ``ops.aligned_dim``
    (as ``async_buffer.init_buffer`` now does), never needs the O(m·d)
    zero-pad copy — the aliased kernel path always applies."""
    from repro.kernels.masked_mix_scatter import padding_copy_needed
    from repro.models import lenet

    params0 = lenet.init(jax.random.PRNGKey(0), input_hw=(16, 16),
                         channels=1, num_classes=8)
    d = sum(x.size for x in jax.tree.leaves(params0))
    assert padding_copy_needed(8, 4, d)  # the raw LeNet dim is unaligned
    assert not padding_copy_needed(8, 4, ops.aligned_dim(d))


@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 24),
       c=st.integers(1, 8), d=st.integers(1, 300), pads=st.integers(0, 8),
       hbm=st.booleans())
def test_mix_scatter_noncohort_rows_property(seed, m, c, d, pads, hbm):
    """Both kernel variants leave non-cohort rows bit-identical and match
    the oracle on cohort rows — any shape, any pad count (including the
    all-pad cohort), c > m allowed."""
    pads = min(pads, c)
    if c - pads > m:
        pads = c - m
    rng = np.random.default_rng(seed)
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, pads, rng)
    impl = "interpret_hbm" if hbm else "interpret_slab"
    out = np.asarray(ops.masked_mix_scatter(w, theta, idx, mask,
                                            jnp.array(full), impl=impl))
    absent = np.setdiff1d(np.arange(m), real)
    np.testing.assert_array_equal(out[absent], np.asarray(full)[absent])
    want = np.asarray(ref.masked_mix_scatter(w, theta, idx, mask, full))
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 12),
       c=st.integers(1, 6), d=st.integers(2, 100), pads=st.integers(0, 6))
def test_mix_scatter_flat_property(seed, m, c, d, pads):
    """aggregation.mix_scatter_flat leaves non-cohort rows bit-identical
    on the single-leaf slab state, and a wider flat_c (tail columns past
    the state dim, even garbage) changes nothing."""
    from repro.core import aggregation

    pads = min(pads, c)
    if c - pads > m:
        pads = c - m
    rng = np.random.default_rng(seed)
    w, theta, idx, mask, full, real = _scatter_case(m, c, d, pads, rng)
    tree = {"slab": jnp.asarray(full)}
    out = aggregation.mix_scatter_flat(tree, theta, w, idx, mask,
                                       impl="ref")
    wide = jnp.concatenate(
        [theta, jnp.full((c, ops.aligned_dim(d) + 128 - d), 99.0)],
        axis=1)
    out_wide = aggregation.mix_scatter_flat(tree, wide, w, idx, mask,
                                            impl="ref")
    absent = np.setdiff1d(np.arange(m), real)
    a, b = np.asarray(out["slab"]), np.asarray(out_wide["slab"])
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a[absent], np.asarray(full)[absent])


def test_mix_scatter_multi_leaf_state_raises():
    """The slab engine is the contract: a multi-leaf stacked state on the
    mix path is a caller error, not a fallback."""
    from repro.core import aggregation

    rng = np.random.default_rng(0)
    w, theta, idx, mask, full, _ = _scatter_case(6, 3, 10, 0, rng)
    tree = {"a": full[:, :5], "b": full[:, 5:]}
    with pytest.raises(ValueError, match="multi-leaf stacked state"):
        aggregation.mix_scatter_flat(tree, theta, w, idx, mask, impl="ref")
    with pytest.raises(ValueError, match="multi-leaf stacked state"):
        aggregation.mix_scatter(
            tree, {"a": theta[:, :5], "b": theta[:, 5:]}, w, idx, mask,
            impl="ref")


def test_masked_mix_scatter_width_mismatch_raises():
    """ops.masked_mix_scatter refuses an upload whose width disagrees
    with the state slab (a layout-table/slab mismatch), as a ValueError
    rather than a kernel-shape assert."""
    rng = np.random.default_rng(1)
    w, theta, idx, mask, full, _ = _scatter_case(6, 3, 10, 0, rng)
    with pytest.raises(ValueError, match="layout table and the slab"):
        ops.masked_mix_scatter(w, theta[:, :6], idx, mask,
                               jnp.array(full), impl="ref")


@pytest.mark.parametrize("m,d", [(2, 64), (8, 500), (16, 4096), (9, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_delta_matches_oracle(m, d, dtype):
    rng = np.random.default_rng(m)
    g = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32)).astype(dtype)
    got = ops.pairwise_delta(g, impl="interpret")
    want = ref.pairwise_delta(g)
    tol = 1e-3 * d if dtype == jnp.bfloat16 else 1e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=tol)


@pytest.mark.parametrize("m,k,f", [(4, 2, 4), (20, 4, 20), (100, 7, 100),
                                   (9, 3, 17)])
def test_kmeans_assign_matches_oracle(m, k, f):
    rng = np.random.default_rng(m + k)
    p = jnp.asarray(rng.normal(size=(m, f)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, f)).astype(np.float32))
    l1, d1 = ops.kmeans_assign(p, c, impl="interpret")
    l2, d2 = ref.kmeans_assign(p, c)
    assert np.array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-4, atol=1e-4)


@given(
    m=st.integers(2, 12), d=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_delta_properties(m, d, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    delta = np.asarray(ops.pairwise_delta(g, impl="interpret"))
    assert delta.shape == (m, m)
    # symmetric, nonnegative, zero diagonal
    np.testing.assert_allclose(delta, delta.T, rtol=1e-4, atol=1e-4)
    assert (delta >= 0).all()
    np.testing.assert_allclose(np.diag(delta), 0.0, atol=1e-3 * d)


@given(
    k=st.integers(1, 8), m=st.integers(1, 8), seed=st.integers(0, 2**31 - 1)
)
def test_mix_aggregate_linearity(k, m, seed):
    """Mixing is linear: mix(W, a+b) == mix(W, a) + mix(W, b)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float32))
    a = jnp.asarray(rng.normal(size=(m, 130)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(m, 130)).astype(np.float32))
    lhs = ops.mix_aggregate(w, a + b, impl="interpret")
    rhs = (ops.mix_aggregate(w, a, impl="interpret")
           + ops.mix_aggregate(w, b, impl="interpret"))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


def test_mix_aggregate_row_stochastic_preserves_constant():
    """A row-stochastic W maps constant models to the same constant."""
    m = 8
    w = jnp.ones((m, m)) / m
    t = jnp.full((m, 257), 3.25, jnp.float32)
    out = ops.mix_aggregate(w, t, impl="interpret")
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-6)
