"""Federated fine-tuning of a tiny multi-leaf transformer (CI smoke).

The flat-slab state layout promises "any apply_fn, one slab": a strategy
never sees a model's pytree except at the apply boundary, so a deep
attention/MLP transformer must run through the masked cohort engine
exactly like LeNet does — raveled once into a single ``(m, d_aligned)``
float32 matrix, mixed by the fused ``masked_mix_scatter`` kernel, with
no per-leaf gather/scatter loop. This suite pins that end to end on CPU
(the ``transformer-smoke`` CI job):

  * the UCFL strategy state is the slab — a rank-2 float32 array whose
    width is 128-lane aligned, NOT a stacked pytree;
  * the round actually takes the fused kernel path (the
    ``ops.masked_mix_scatter`` entry point is traced during the first
    cohort round — counted via monkeypatch);
  * three masked cohort rounds of federated fine-tuning DECREASE the
    training loss of a last-token classification task;
  * the int8 uplink transport composes with the transformer slab.

The model is the dense architecture's ``reduced()`` smoke config (2
scanned layers, d_model 128, vocab 512 — ~0.4M params, 15 leaves), with
last-token class logits as the ``apply_fn`` adapter; labels are the
sequence's final token mod C, which a one-layer attention lookup learns
within a round or two.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import FedConfig, ucfl
from repro.data.synthetic import FederatedData
from repro.federated.client import cross_entropy
from repro.federated.transport import TransportConfig
from repro.kernels import ops
from repro.models import transformer

NUM_CLASSES = 8


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = configs.get("qwen2-7b").reduced()

    def apply_fn(params, x):
        logits, _ = transformer.forward(params, {"tokens": x}, cfg)
        return logits[:, -1, :NUM_CLASSES]

    key = jax.random.PRNGKey(0)
    pkey, dkey = jax.random.split(key)
    params0 = transformer.init(pkey, cfg)
    m, n, seq = 4, 24, 8
    toks = jax.random.randint(dkey, (m, n + 8, seq), 1, cfg.vocab_size)
    y = (toks[..., -1] % NUM_CLASSES).astype(jnp.int32)
    data = FederatedData(x=toks[:, :n], y=y[:, :n],
                         x_test=toks[:, n:], y_test=y[:, n:],
                         group=jnp.zeros((m,), jnp.int32),
                         n=jnp.full((m,), n, jnp.int32))
    return apply_fn, params0, data


def _mean_train_loss(strat, apply_fn, state, data):
    def one(p, x, y):
        return cross_entropy(apply_fn(p, x), y)

    return float(jax.vmap(one)(strat.eval_params(state), data.x,
                               data.y).mean())


def _run(transport=None, rounds=3):
    apply_fn, params0, data = _setup()
    fcfg = FedConfig(lr=0.05, momentum=0.9, epochs=1, batch_size=12,
                     transport=transport)
    strat = ucfl.make_ucfl(apply_fn, params0, fcfg, var_batch_size=12)
    state = strat.init(jax.random.PRNGKey(1), data)
    cohort = np.arange(data.num_clients, dtype=np.int32)
    key = jax.random.PRNGKey(2)
    for _ in range(rounds):
        key, rkey = jax.random.split(key)
        state, _ = strat.round(state, data, rkey, cohort)
    return strat, apply_fn, state, data


def test_transformer_trains_on_flat_slab_fused_path(monkeypatch):
    apply_fn, params0, data = _setup()
    calls = []
    real = ops.masked_mix_scatter
    monkeypatch.setattr(
        ops, "masked_mix_scatter",
        lambda *a, **k: calls.append(1) or real(*a, **k))

    fcfg = FedConfig(lr=0.05, momentum=0.9, epochs=1, batch_size=12)
    strat = ucfl.make_ucfl(apply_fn, params0, fcfg, var_batch_size=12)
    state = strat.init(jax.random.PRNGKey(1), data)

    # the state IS the slab: one rank-2 f32 matrix, lane-aligned width
    slab = state["params"]
    assert slab.ndim == 2 and slab.shape[0] == data.num_clients
    assert slab.dtype == jnp.float32
    assert slab.shape[1] % ops.ALIGN == 0

    loss0 = _mean_train_loss(strat, apply_fn, state, data)
    cohort = np.arange(data.num_clients, dtype=np.int32)
    key = jax.random.PRNGKey(2)
    for _ in range(3):
        key, rkey = jax.random.split(key)
        state, _ = strat.round(state, data, rkey, cohort)
    loss1 = _mean_train_loss(strat, apply_fn, state, data)

    # the masked round traced through the fused kernel entry point
    # (counted at trace time — one compile, so one call)
    assert len(calls) >= 1
    assert loss1 < loss0, (loss0, loss1)
    assert loss1 < 0.5 * loss0, (loss0, loss1)
    assert state["params"].shape == slab.shape


def test_transformer_int8_transport_composes():
    strat, apply_fn, state, data = _run(TransportConfig("int8"), rounds=2)
    assert "ef" in state and state["ef"].shape == state["params"].shape
    assert float(jnp.abs(state["ef"]).max()) > 0.0
    assert bool(jnp.isfinite(state["params"]).all())
    loss = _mean_train_loss(strat, apply_fn, state, data)
    assert np.isfinite(loss)
