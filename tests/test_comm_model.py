"""§V-D communication/straggler model tests."""
import math

import pytest

from repro.core import comm_model as cm


def test_harmonic():
    assert cm.harmonic(1) == 1.0
    assert abs(cm.harmonic(4) - (1 + 0.5 + 1 / 3 + 0.25)) < 1e-12


def test_straggler_penalty_grows_with_m():
    t10 = cm.expected_compute_time(cm.SystemParams(m=10, inv_mu=1.0))
    t100 = cm.expected_compute_time(cm.SystemParams(m=100, inv_mu=1.0))
    assert t100 > t10
    assert cm.expected_compute_time(cm.SystemParams(m=100, inv_mu=0.0)) == 1.0


def test_round_time_scheme_ordering():
    """broadcast ≤ groupcast(k) ≤ unicast for k ≤ m (paper Fig. 5 logic)."""
    p = cm.SystemParams(m=20, rho=4.0)
    b = cm.round_time(p, "broadcast")
    g = cm.round_time(p, "groupcast", num_streams=4)
    u = cm.round_time(p, "unicast")
    assert b <= g <= u
    assert u - b == (p.m - 1) * p.t_dl


def test_asymmetric_uplink_amortizes_personalization():
    """With slow UL (ρ=4), unicast overhead is relatively smaller —
    the paper's core wireless argument."""
    fast = cm.SystemParams(m=20, rho=1.0)
    slow = cm.SystemParams(m=20, rho=4.0)
    rel_fast = cm.round_time(fast, "unicast") / cm.round_time(fast, "broadcast")
    rel_slow = cm.round_time(slow, "unicast") / cm.round_time(slow, "broadcast")
    assert rel_slow < rel_fast


def test_downlink_bytes():
    mb = 10_000_000
    assert cm.downlink_bytes_per_round(mb, "broadcast", 20) == mb
    assert cm.downlink_bytes_per_round(mb, "groupcast", 20, 4) == 4 * mb
    assert cm.downlink_bytes_per_round(mb, "unicast", 20) == 20 * mb
    with pytest.raises(ValueError):
        cm.downlink_bytes_per_round(mb, "nope", 20)


def test_ici_counterpart_ordering():
    mb = 10_000_000
    fa = cm.ici_collective_bytes(mb, "broadcast", 16)
    cl = cm.ici_collective_bytes(mb, "groupcast", 16, 4)
    uc = cm.ici_collective_bytes(mb, "unicast", 16)
    assert fa < cl < uc


def test_rounds_to_time_cumulative():
    p = cm.SystemParams(m=8)
    ts = cm.rounds_to_time(p, "broadcast", 5)
    assert len(ts) == 5
    diffs = [b - a for a, b in zip(ts, ts[1:])]
    assert all(abs(d - diffs[0]) < 1e-9 for d in diffs)


def test_groupcast_without_streams_raises_not_asserts():
    """The three groupcast sites must raise a real ValueError — a bare
    assert is stripped under ``python -O`` and groupcast would silently
    misprice (regression: comm_model used asserts in all three)."""
    p = cm.SystemParams(m=20)
    with pytest.raises(ValueError):
        cm.round_time(p, "groupcast")
    with pytest.raises(ValueError):
        cm.downlink_bytes_per_round(1000, "groupcast", 20)
    with pytest.raises(ValueError):
        cm.ici_collective_bytes(1000, "groupcast", 20)
    with pytest.raises(ValueError):
        cm.async_round_time(p, "groupcast", cohort_size=8, flush_k=2)


def test_expected_kth_compute_time_order_statistics():
    p = cm.SystemParams(m=16, inv_mu=1.0)
    # k = c recovers the straggler max; k < c is strictly cheaper and
    # monotone in k
    assert cm.expected_kth_compute_time(p, 16) == \
        pytest.approx(cm.expected_compute_time(p))
    ts = [cm.expected_kth_compute_time(p, k) for k in range(1, 17)]
    assert all(a < b for a, b in zip(ts, ts[1:]))
    # E[min of c] = T_min + 1/(c mu)
    assert ts[0] == pytest.approx(p.t_min + 1.0 / 16)
    # reliable clients (inv_mu = 0): every order statistic is T_min
    rel = cm.SystemParams(m=16, inv_mu=0.0)
    assert cm.expected_kth_compute_time(rel, 3) == rel.t_min


def test_async_round_time_beats_barrier_iff_flush_early():
    p = cm.SystemParams(m=20, rho=4.0, inv_mu=2.0)
    sync = cm.round_time(p, "unicast", cohort_size=10)
    asy = cm.async_round_time(p, "unicast", cohort_size=10, flush_k=4)
    assert asy < sync  # fewer arrivals waited on AND fewer streams served
    # flush_k >= c with the full batch applied degrades to the barrier
    assert cm.async_round_time(p, "unicast", cohort_size=10, flush_k=10,
                               applied=10) == pytest.approx(sync)
    # deposit-only rounds span their arrivals but serve nothing
    idle = cm.async_round_time(p, "unicast", cohort_size=10, flush_k=99,
                               applied=0)
    assert idle == pytest.approx(sync - 10 * p.t_dl)


def test_async_round_time_schemes_and_applied_batch():
    p = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0)
    b = cm.async_round_time(p, "broadcast", cohort_size=8, flush_k=2,
                            applied=5)
    g = cm.async_round_time(p, "groupcast", num_streams=3, cohort_size=8,
                            flush_k=2, applied=5)
    u = cm.async_round_time(p, "unicast", cohort_size=8, flush_k=2,
                            applied=5)
    assert b <= g <= u
    assert u - b == 4 * p.t_dl  # 5 applied streams vs 1 broadcast


def test_sample_arrival_times_model():
    import numpy as np

    p = cm.SystemParams(m=400, rho=4.0, t_dl=1.0, t_min=1.0, inv_mu=2.0)
    rng = np.random.default_rng(0)
    t = cm.sample_arrival_times(p, rng, cohort_size=200)
    assert t.shape == (200,)
    floor = p.t_dl + p.t_min + p.rho * p.t_dl
    assert (t >= floor).all()
    assert t.mean() == pytest.approx(floor + p.inv_mu, rel=0.2)
    # reliable fleet: deterministic arrivals
    rel = cm.SystemParams(m=10, inv_mu=0.0)
    tr = cm.sample_arrival_times(rel, rng)
    assert np.allclose(tr, rel.t_dl + rel.t_min + rel.rho * rel.t_dl)
    # the k-th sampled order statistic tracks its analytic expectation
    ks = np.sort(t)
    k = 50
    want = p.t_dl + p.rho * p.t_dl + cm.expected_kth_compute_time(
        p, k, cohort_size=200)
    assert ks[k - 1] == pytest.approx(want, rel=0.2)


def test_deadline_inf_bit_identical_to_round_time():
    """deadline=inf must reproduce round_time EXACTLY (same float ops):
    the expected order-statistic profile's max is the H_c straggler
    mean round_time charges."""
    p = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0)
    for scheme, k in (("broadcast", None), ("groupcast", 3),
                      ("unicast", None), ("client_mixing", None)):
        base = cm.round_time(p, scheme, k, cohort_size=8)
        t, dropped = cm.deadline_round_time(p, scheme, k, cohort_size=8)
        assert t == base, (scheme, t, base)
        assert dropped.shape == (8,) and not dropped.any()


def test_deadline_censors_and_prices_stragglers():
    p = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0)
    c = 8
    dl = cm.expected_kth_compute_time(p, c - 2, c)
    t, dropped = cm.deadline_round_time(p, "unicast", cohort_size=c,
                                        deadline=dl)
    assert dropped.sum() == 2  # the two slowest expected arrivals cut
    # survivors' unicast downlink + deadline wait + uplink
    assert t == pytest.approx((c - 2) * p.t_dl + dl + p.rho * p.t_dl)
    assert t < cm.round_time(p, "unicast", cohort_size=c)


def test_deadline_all_dropped_degrades_to_skip_round():
    p = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0)
    t, dropped = cm.deadline_round_time(p, "unicast", cohort_size=4,
                                        deadline=0.5 * p.t_min)
    assert dropped.all()
    assert t == pytest.approx(0.5 * p.t_min)  # wait out the deadline


def test_deadline_realized_compute_vector():
    p = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0)
    compute = [1.0, 5.0, 2.0, 7.0]
    t, dropped = cm.deadline_round_time(p, "broadcast", cohort_size=4,
                                        deadline=4.0, compute=compute)
    assert list(dropped) == [False, True, False, True]
    assert t == pytest.approx(p.t_dl + 4.0 + p.rho * p.t_dl)


# ------------------------------------------------- per-tier link budgets

def test_tierparams_validates():
    with pytest.raises(ValueError):
        cm.TierParams(num_edges=0)
    with pytest.raises(ValueError):
        cm.TierParams(num_edges=2, backhaul_dl=-0.1)
    with pytest.raises(ValueError):
        cm.TierParams(num_edges=2, backhaul_rho=0.0)
    with pytest.raises(ValueError):
        cm.TierParams(num_edges=2, congestion=-1.0)


def test_free_backhaul_is_bit_identical_to_flat():
    """The flat-equivalence contract: tiers=None and the degenerate
    TierParams(backhaul_dl=0, congestion=0) price every round the same
    — a free backhaul collapses the two tiers into one."""
    flat = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0)
    free = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0,
                           tiers=cm.TierParams(4, backhaul_dl=0.0,
                                               congestion=0.0))
    for scheme, k in (("broadcast", None), ("groupcast", 3)):
        assert cm.round_time(free, scheme, k, cohort_size=8) == \
            cm.round_time(flat, scheme, k, cohort_size=8)
        tf, df = cm.deadline_round_time(flat, scheme, k, cohort_size=8,
                                        deadline=3.0)
        tt, dt = cm.deadline_round_time(free, scheme, k, cohort_size=8,
                                        deadline=3.0)
        assert tt == tf and list(dt) == list(df)
        assert cm.async_round_time(free, scheme, k, cohort_size=8,
                                   flush_k=3) == \
            cm.async_round_time(flat, scheme, k, cohort_size=8, flush_k=3)


def test_backhaul_budget_raises_round_price():
    flat = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0)
    tier = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0,
                           tiers=cm.TierParams(4, backhaul_dl=0.25))
    for scheme, k in (("broadcast", None), ("groupcast", 3)):
        assert cm.round_time(tier, scheme, k, cohort_size=8) > \
            cm.round_time(flat, scheme, k, cohort_size=8)


def test_congestion_monotone_and_inert_with_one_edge():
    def price(gamma, edges=4):
        p = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0,
                            tiers=cm.TierParams(edges, congestion=gamma))
        return cm.round_time(p, "groupcast", 3, cohort_size=8)

    ts = [price(g) for g in (0.0, 0.5, 1.0, 2.0)]
    assert all(a < b for a, b in zip(ts, ts[1:]))
    # a single edge has no simultaneous PS links to congest
    assert price(0.0, edges=1) == price(5.0, edges=1)


def test_tiered_pricing_rejects_per_client_schemes():
    """unicast/client_mixing PS rules read every cohort column — they do
    not factorize over edge aggregates; pricing must refuse like the
    engine's capability guard does."""
    p = cm.SystemParams(m=20, rho=4.0, inv_mu=1.0,
                        tiers=cm.TierParams(4))
    for scheme in ("unicast", "client_mixing"):
        with pytest.raises(ValueError, match="tier"):
            cm.round_time(p, scheme, cohort_size=8)
        with pytest.raises(ValueError, match="tier"):
            cm.async_round_time(p, scheme, cohort_size=8, flush_k=3)


def test_ps_uplink_bytes_tiered_vs_flat():
    """The headline counter: flat ships c client uploads through the PS
    link, tiered ships e·k edge aggregates — c/(e·k) fewer bytes."""
    mb, m, c = 4_000, 20, 12
    flat = cm.ps_uplink_bytes_per_round(mb, "groupcast", m, num_streams=2,
                                        cohort_size=c)
    assert flat == cm.uplink_bytes_per_round(mb, "groupcast", m,
                                             cohort_size=c)
    tier = cm.ps_uplink_bytes_per_round(mb, "groupcast", m, num_streams=2,
                                        cohort_size=c, num_edges=2)
    assert flat == 3 * tier  # c=12 uploads vs e·k = 4 aggregates
    # broadcast policies ship ONE aggregate per edge
    assert cm.ps_uplink_bytes_per_round(mb, "broadcast", m, cohort_size=c,
                                        num_edges=2) == 2 * mb
    # more edges than cohort members: only the active ones transact
    assert cm.ps_uplink_bytes_per_round(mb, "broadcast", m, cohort_size=3,
                                        num_edges=64) == 3 * mb


def test_ps_downlink_bytes_tiered_replication():
    """PS egress REPLICATES across edges (e·k streams) — tiered downlink
    can exceed the flat single broadcast; the counter must say so."""
    mb, m, c = 4_000, 20, 12
    assert cm.ps_downlink_bytes_per_round(mb, "broadcast", m,
                                          cohort_size=c) == mb
    assert cm.ps_downlink_bytes_per_round(mb, "broadcast", m, cohort_size=c,
                                          num_edges=4) == 4 * mb
    flat_g = cm.ps_downlink_bytes_per_round(mb, "groupcast", m,
                                            num_streams=2, cohort_size=c)
    assert flat_g == 2 * mb
    assert cm.ps_downlink_bytes_per_round(mb, "groupcast", m, num_streams=2,
                                          cohort_size=c, num_edges=4) == \
        4 * 2 * mb


def test_ps_bytes_flat_equals_plain_counters():
    """num_edges=None must collapse to the flat per-round counters."""
    mb, m, c = 4_000, 20, 8
    assert cm.ps_uplink_bytes_per_round(mb, "groupcast", m, num_streams=3,
                                        cohort_size=c) == \
        cm.uplink_bytes_per_round(mb, "groupcast", m, cohort_size=c)
    assert cm.ps_downlink_bytes_per_round(mb, "unicast", m,
                                          cohort_size=c) == \
        cm.downlink_bytes_per_round(mb, "unicast", m, cohort_size=c)
