"""§V-D communication/straggler model tests."""
import math

import pytest

from repro.core import comm_model as cm


def test_harmonic():
    assert cm.harmonic(1) == 1.0
    assert abs(cm.harmonic(4) - (1 + 0.5 + 1 / 3 + 0.25)) < 1e-12


def test_straggler_penalty_grows_with_m():
    t10 = cm.expected_compute_time(cm.SystemParams(m=10, inv_mu=1.0))
    t100 = cm.expected_compute_time(cm.SystemParams(m=100, inv_mu=1.0))
    assert t100 > t10
    assert cm.expected_compute_time(cm.SystemParams(m=100, inv_mu=0.0)) == 1.0


def test_round_time_scheme_ordering():
    """broadcast ≤ groupcast(k) ≤ unicast for k ≤ m (paper Fig. 5 logic)."""
    p = cm.SystemParams(m=20, rho=4.0)
    b = cm.round_time(p, "broadcast")
    g = cm.round_time(p, "groupcast", num_streams=4)
    u = cm.round_time(p, "unicast")
    assert b <= g <= u
    assert u - b == (p.m - 1) * p.t_dl


def test_asymmetric_uplink_amortizes_personalization():
    """With slow UL (ρ=4), unicast overhead is relatively smaller —
    the paper's core wireless argument."""
    fast = cm.SystemParams(m=20, rho=1.0)
    slow = cm.SystemParams(m=20, rho=4.0)
    rel_fast = cm.round_time(fast, "unicast") / cm.round_time(fast, "broadcast")
    rel_slow = cm.round_time(slow, "unicast") / cm.round_time(slow, "broadcast")
    assert rel_slow < rel_fast


def test_downlink_bytes():
    mb = 10_000_000
    assert cm.downlink_bytes_per_round(mb, "broadcast", 20) == mb
    assert cm.downlink_bytes_per_round(mb, "groupcast", 20, 4) == 4 * mb
    assert cm.downlink_bytes_per_round(mb, "unicast", 20) == 20 * mb
    with pytest.raises(ValueError):
        cm.downlink_bytes_per_round(mb, "nope", 20)


def test_ici_counterpart_ordering():
    mb = 10_000_000
    fa = cm.ici_collective_bytes(mb, "broadcast", 16)
    cl = cm.ici_collective_bytes(mb, "groupcast", 16, 4)
    uc = cm.ici_collective_bytes(mb, "unicast", 16)
    assert fa < cl < uc


def test_rounds_to_time_cumulative():
    p = cm.SystemParams(m=8)
    ts = cm.rounds_to_time(p, "broadcast", 5)
    assert len(ts) == 5
    diffs = [b - a for a, b in zip(ts, ts[1:])]
    assert all(abs(d - diffs[0]) < 1e-9 for d in diffs)
