"""WireSchema tests — the declared-stream wire layer (PR 9).

Pins the schema contract on top of the single-slab transport tests:

  * geometry — stream widths align per-stream (odd widths round up to
    the 128 lane, zero-width streams vanish), ``slices`` tile the
    concatenated slab in declaration order, ``width`` prices TRUE
    coordinates while ``width_aligned`` sizes the slab;
  * per-stream round-trip (hypothesis) — a mixed delta/raw/delta schema
    quantizes each ``delta`` slice within the int8 bound while the
    ``raw`` slice passes through BIT-EXACT with a zero EF slice;
  * per-stream error feedback — scaffold's two uplink streams telescope
    independently: on constant per-stream deltas of very different
    magnitude each stream's applied sum is within ONE of its own
    quantization steps (a shared EF would leak the big stream's error
    into the small one);
  * construction-time validation — a chunk that does not divide a
    stream's aligned width raises at ``make_wire_stage`` naming the
    strategy, the stream and both widths; ucfl_parallel raises the ONE
    uniform capability error pointing at the capability matrix;
  * engine composition — fedavg's compressed DOWNLINK carries a
    ``(1, Σ)`` server-side EF row that activates; the per-stream finite
    guard demotes a slot when ANY stream goes non-finite (NaN in
    scaffold's control stream kills the model half too); the streaming
    W-refresh under a quantized wire estimates Δ/σ² from the
    DEQUANTIZED uploads only — W stays close to the raw-wire refresh
    while the model trajectory visibly carries quantization drift.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, load_ci_profile, st
from repro.core import FedConfig, REGISTRY, ucfl
from repro.core.similarity import RefreshConfig
from repro.data import synthetic
from repro.federated import faults as faults_lib
from repro.federated import transport
from repro.federated.transport import Stream, TransportConfig, WireSchema
from repro.models import lenet

load_ci_profile(max_examples=20)

INT8 = TransportConfig("int8")

# odd + zero + odd widths: 100 -> 128, 0 -> 0, 130 -> 256
MIXED = WireSchema(
    "mixed",
    uplink=(Stream("a", 100), Stream("gap", 0), Stream("b", 130,
                                                       coding="raw"),
            Stream("c", 130)),
)


# --------------------------------------------------------------- geometry
def test_stream_alignment_and_slices():
    assert Stream("a", 100).width_aligned == 128
    assert Stream("gap", 0).width_aligned == 0
    assert Stream("b", 130).width_aligned == 256
    assert MIXED.width("uplink") == 100 + 0 + 130 + 130
    assert MIXED.width_aligned("uplink") == 128 + 0 + 256 + 256
    assert MIXED.slices("uplink") == ((0, 128), (128, 128), (128, 384),
                                      (384, 640))
    assert MIXED.streams("downlink") == ()
    with pytest.raises(ValueError, match="direction"):
        MIXED.streams("sideways")


def test_stream_validation():
    with pytest.raises(ValueError, match="coding"):
        Stream("x", 8, coding="zip")
    with pytest.raises(ValueError, match=">= 0"):
        Stream("x", -1)


def test_single_stream_stage_is_make_stage():
    # the one-delta schema compiles to the EXACT pre-schema stage: the
    # single-slab trajectories of PR 8 stay bit-identical
    schema = transport.single_delta_schema("fedavg", 300)
    stage = transport.make_wire_stage(schema, INT8, "uplink")
    ref = transport.make_stage(INT8)
    rng = np.random.default_rng(0)
    pre = jnp.asarray(rng.normal(size=(3, 384)).astype(np.float32))
    post = jnp.asarray(rng.normal(size=(3, 384)).astype(np.float32))
    ef = jnp.zeros_like(pre)
    (a, ea), (b, eb) = stage(pre, post, ef), ref(pre, post, ef)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ea), np.asarray(eb))


def test_raw_only_direction_has_no_stage():
    schema = WireSchema("cfl_like", downlink=(Stream("centroids", 130,
                                                     coding="raw"),))
    assert transport.make_wire_stage(schema, INT8, "downlink") is None
    assert transport.make_wire_stage(schema, None, "downlink") is None


# ------------------------------------------------- per-stream round-trip
def _chunk_steps(x, cfg):
    x = np.asarray(x)
    xs = x.reshape(x.shape[:-1] + (-1, cfg.chunk))
    peak = np.abs(xs).max(-1, keepdims=True)
    return np.broadcast_to(peak, xs.shape).reshape(x.shape)


@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_per_stream_roundtrip_property(seed):
    rng = np.random.default_rng(seed)
    w = MIXED.width_aligned("uplink")
    # wildly different per-stream scales: a shared quantizer would let
    # the loud stream's step swamp the quiet one
    pre = jnp.asarray(rng.normal(size=(2, w)).astype(np.float32))
    post = pre.at[..., :128].add(
        jnp.asarray(rng.normal(size=(2, 128)).astype(np.float32)) * 100.0)
    post = post.at[..., 128:].add(
        jnp.asarray(rng.normal(size=(2, w - 128)).astype(np.float32)) * 0.01)
    ef = jnp.zeros_like(pre)
    stage = transport.make_wire_stage(MIXED, INT8, "uplink")
    out, ef2 = stage(pre, post, ef)
    applied = np.asarray(out - pre)
    delta = np.asarray(post - pre)
    for s, (lo, hi) in zip(MIXED.streams("uplink"), MIXED.slices("uplink")):
        if s.coding == "raw":
            # bit-exact pass-through, EF slice stays zero
            np.testing.assert_array_equal(applied[..., lo:hi],
                                          delta[..., lo:hi])
            np.testing.assert_array_equal(np.asarray(ef2)[..., lo:hi], 0.0)
        elif hi > lo:
            step = _chunk_steps(delta[..., lo:hi], INT8) / 127.0
            err = np.abs(applied[..., lo:hi] - delta[..., lo:hi])
            assert (err <= 0.5 * step + 1e-6 * (1 + step)).all(), s.name
            # the stream's EF is exactly its own residual
            np.testing.assert_allclose(
                np.asarray(ef2)[..., lo:hi],
                delta[..., lo:hi] - applied[..., lo:hi], atol=1e-6)


def test_per_stream_ef_telescopes_scaffold():
    # scaffold's two-stream uplink: constant deltas of very different
    # magnitude per stream; each stream's T-round applied sum must land
    # within ONE of ITS OWN quantization steps of T·delta
    schema = WireSchema("scaffold",
                        uplink=(Stream("delta", 256),
                                Stream("control_delta", 256)))
    stage = transport.make_wire_stage(schema, INT8, "uplink")
    rng = np.random.default_rng(7)
    d_model = rng.normal(size=(3, 256)).astype(np.float32) * 50.0
    d_ctrl = rng.normal(size=(3, 256)).astype(np.float32) * 1e-3
    delta = jnp.asarray(np.concatenate([d_model, d_ctrl], axis=-1))
    pre = jnp.zeros_like(delta)
    ef = jnp.zeros_like(delta)
    total = np.zeros(delta.shape, np.float32)
    rounds = 17
    for _ in range(rounds):
        out, ef = stage(pre, pre + delta, ef)
        total += np.asarray(out - pre)
    for d, (lo, hi) in zip((d_model, d_ctrl), schema.slices("uplink")):
        step = _chunk_steps(d, INT8) / 127.0
        err = np.abs(total[..., lo:hi] - rounds * d)
        assert (err <= step + 1e-5 * (1 + np.abs(d))).all()


# ------------------------------------------------------------- validation
def test_chunk_mismatch_names_strategy_and_widths():
    # chunk=192 divides the first stream's 384-wide slice but not the
    # second's 256: the error must name the OFFENDING stream, not slot 0
    schema = WireSchema("scaffold",
                        uplink=(Stream("delta", 300),
                                Stream("control_delta", 250)))
    with pytest.raises(ValueError) as exc:
        transport.make_wire_stage(schema, TransportConfig(chunk=192),
                                  "uplink")
    msg = str(exc.value)
    for needle in ("scaffold", "control_delta", "250", "256", "192",
                   "does not divide"):
        assert needle in msg, (needle, msg)


def test_ucfl_parallel_uniform_capability_error():
    with pytest.raises(NotImplementedError,
                       match="transport.*capability matrix"):
        transport.unsupported(INT8, "ucfl_parallel", "no single slab")
    assert transport.unsupported(None, "ucfl_parallel", "off is fine") is None


# ------------------------------------------------------------ composition
@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(11)
    dkey, mkey, skey = jax.random.split(key, 3)
    data = synthetic.label_shift(dkey, m=6, n=60, n_test=20, num_classes=6,
                                 alpha=0.4, hw=(16, 16))
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    return data, params0, skey


def _run(strat, data, skey, rounds=3):
    cohort = np.arange(data.num_clients, dtype=np.int32)
    state = strat.init(jax.random.fold_in(skey, 1), data)
    key = skey
    for _ in range(rounds):
        key, rkey = jax.random.split(key)
        state, _ = strat.round(state, data, rkey, cohort)
    return state


def test_fedavg_downlink_ef_row_activates():
    data, params0, skey = _setup()
    strat = REGISTRY["fedavg"](lenet.apply, params0,
                               FedConfig(batch_size=30, transport=INT8))
    schema = strat.wire_schema
    state = _run(strat, data, skey)
    assert state["ef_dl"].shape == (1, schema.width_aligned("downlink"))
    assert float(jnp.abs(state["ef_dl"]).max()) > 0.0


def test_scaffold_state_matches_two_stream_schema():
    data, params0, skey = _setup()
    strat = REGISTRY["scaffold"](lenet.apply, params0,
                                 FedConfig(batch_size=30, transport=INT8))
    schema = strat.wire_schema
    m = data.num_clients
    state = _run(strat, data, skey)
    d_al = state["params"].shape[1]
    assert schema.width_aligned("uplink") == 2 * d_al
    assert state["ef"].shape == (m, 2 * d_al)
    assert state["ef_dl"].shape == (1, 2 * d_al)
    # both stream halves carry residual: each wire stream really ran
    # through its own quantizer
    assert float(jnp.abs(state["ef"][:, :d_al]).max()) > 0.0
    assert float(jnp.abs(state["ef"][:, d_al:]).max()) > 0.0


def test_finite_guard_demotes_per_stream():
    schema = WireSchema("scaffold",
                        uplink=(Stream("delta", 128),
                                Stream("control_delta", 128)))
    m, c = 6, 4
    rng = np.random.default_rng(3)
    flat = rng.normal(size=(c, 256)).astype(np.float32)
    flat[1, 200] = np.nan  # NaN in the CONTROL stream only
    idx = jnp.asarray([0, 1, 2, m], jnp.int32)
    mask = jnp.asarray([True, True, True, False])
    out, idx2, mask2 = faults_lib.finite_guard(jnp.asarray(flat), idx, mask,
                                               m, schema)
    # the whole slot is demoted — model half included — and zeroed
    np.testing.assert_array_equal(np.asarray(mask2),
                                  [True, False, True, False])
    np.testing.assert_array_equal(np.asarray(idx2), [0, m, 2, m])
    np.testing.assert_array_equal(np.asarray(out)[1], 0.0)
    # identical to the schema-less whole-row guard
    out_b, idx_b, mask_b = faults_lib.finite_guard(jnp.asarray(flat), idx,
                                                   mask, m, None)
    np.testing.assert_array_equal(np.asarray(mask2), np.asarray(mask_b))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_b))


def test_refresh_sees_dequantized_uploads_only():
    # Δ/σ² under a quantized wire: the refresh consumes the DEQUANTIZED
    # uploads (what the server received) — W stays close to the raw-wire
    # refresh, while the params trajectory visibly drifts (the wire was
    # really quantized). A refresh reading raw client state would be a
    # contract break this pin exists to catch.
    data, params0, skey = _setup()

    def run(tcfg):
        cfg = FedConfig(batch_size=30, transport=tcfg,
                        w_refresh=RefreshConfig())
        strat = ucfl.make_ucfl(lenet.apply, params0, cfg, var_batch_size=10)
        return _run(strat, data, skey)

    raw, q = run(None), run(INT8)
    assert "ef" in q and "refresh" in q and "ef" not in raw
    dW = float(jnp.abs(q["W"] - raw["W"]).max())
    dP = float(jnp.abs(q["params"] - raw["params"]).max())
    assert dP > 0.0  # quantization really touched the wire
    assert dW <= 0.15, dW  # ...but the refresh stats track the raw run
    for leaf in jax.tree.leaves(q):
        assert bool(jnp.isfinite(jnp.asarray(leaf, jnp.float32)).all())
