"""LayoutTable (flat-slab client state) round-trip and contract tests.

The slab engine's correctness rests on three properties of
:class:`repro.core.flat.LayoutTable` (the "layout-table contract"):

  * ``ravel → unravel`` is bit-exact for any pytree (including zero-size
    leaves, scalars, and widths that are not 128-multiples) under any
    leading shape — ``()``, ``(c,)``, ``(m, c)``;
  * the ``dim_aligned − dim`` tail columns of a ravelled matrix are
    exactly zero (column-independent mixes then can't see them);
  * ``unravel`` restores each leaf's template dtype and raises on a
    matrix narrower than the layout (slab/template mismatch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, load_ci_profile, st
from repro.core import flat
from repro.kernels import ops

load_ci_profile(max_examples=25)


def _tree_from_shapes(shapes, seed=0, dtypes=None):
    rng = np.random.default_rng(seed)
    dtypes = dtypes or [jnp.float32] * len(shapes)
    return {
        f"leaf{i:02d}": jnp.asarray(
            rng.normal(size=s).astype(np.float32)).astype(dt)
        for i, (s, dt) in enumerate(zip(shapes, dtypes))
    }


SHAPE_SETS = [
    [(4, 3), (7,), (2, 2, 2)],          # generic multi-leaf
    [(97,)],                            # non-128-multiple width
    [(128,), (128, 2)],                 # exact lane multiples
    [(0, 3), (5,)],                     # zero-size leaf
    [(), (3,)],                         # scalar leaf
    [(1,)],                             # minimal
]


@pytest.mark.parametrize("shapes", SHAPE_SETS)
@pytest.mark.parametrize("lead", [(), (3,), (2, 4)])
def test_ravel_unravel_roundtrip(shapes, lead):
    tree = _tree_from_shapes(shapes)
    layout = flat.LayoutTable.build(tree)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, lead + x.shape) + 0.0, tree)
    mat = layout.ravel(stacked)
    assert mat.shape == lead + (layout.dim_aligned,)
    assert mat.dtype == jnp.float32
    back = layout.unravel(mat)
    for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("shapes", SHAPE_SETS)
def test_alignment_and_zero_tail(shapes):
    tree = _tree_from_shapes(shapes)
    layout = flat.LayoutTable.build(tree)
    assert layout.dim == sum(int(np.prod(s)) for s in shapes)
    assert layout.dim_aligned == ops.aligned_dim(layout.dim)
    assert layout.dim_aligned % ops.ALIGN == 0 or layout.dim_aligned == 0
    mat = np.asarray(layout.ravel(tree))
    np.testing.assert_array_equal(mat[layout.dim:], 0.0)


def test_unravel_restores_dtypes_exactly():
    # bf16 -> f32 widening is exact, so the round-trip must be too
    tree = _tree_from_shapes([(6, 2), (9,)],
                             dtypes=[jnp.bfloat16, jnp.float32])
    layout = flat.LayoutTable.build(tree)
    back = layout.unravel(layout.ravel(tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_unravel_ignores_tail_garbage():
    # unravel only reads the first `dim` columns: junk in the aligned
    # tail (e.g. a transport EF slab reused as scratch) must not leak
    tree = _tree_from_shapes([(5, 3), (7,)])
    layout = flat.LayoutTable.build(tree)
    mat = layout.ravel(tree)
    junk = mat.at[..., layout.dim:].set(123.0)
    for a, b in zip(jax.tree.leaves(tree),
                    jax.tree.leaves(layout.unravel(junk))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unravel_too_narrow_raises():
    layout = flat.LayoutTable.build(_tree_from_shapes([(10,)]))
    with pytest.raises(ValueError, match="different template"):
        layout.unravel(jnp.zeros((3, 4)))


def test_build_empty_tree_raises():
    with pytest.raises(ValueError, match="empty params tree"):
        flat.LayoutTable.build({})


def test_slab_broadcast():
    tree = _tree_from_shapes([(4, 3), (5,)])
    layout = flat.LayoutTable.build(tree)
    slab = layout.slab(tree, 6)
    assert slab.shape == (6, layout.dim_aligned)
    vec = np.asarray(layout.ravel(tree))
    for row in np.asarray(slab):
        np.testing.assert_array_equal(row, vec)


@given(
    shapes=st.lists(
        st.lists(st.integers(min_value=0, max_value=5),
                 min_size=0, max_size=3),
        min_size=1, max_size=5),
    lead=st.sampled_from([(), (2,), (3, 2)]),
)
def test_roundtrip_property(shapes, lead):
    tree = _tree_from_shapes([tuple(s) for s in shapes], seed=1)
    layout = flat.LayoutTable.build(tree)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, lead + x.shape) + 0.0, tree)
    mat = layout.ravel(stacked)
    assert mat.shape == lead + (layout.dim_aligned,)
    np.testing.assert_array_equal(
        np.asarray(mat)[..., layout.dim:], 0.0)
    for a, b in zip(jax.tree.leaves(stacked),
                    jax.tree.leaves(layout.unravel(mat))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hypothesis_marker():
    # keeps the skip reason visible in -rs output when hypothesis is absent
    assert HAVE_HYPOTHESIS in (True, False)
