"""Row-sharded server state (``FedConfig.shard_state``) tests.

Covers the HBM/row-sharding PR guarantees:
  (a) equivalence — with ``shard_state=True`` every strategy's masked
      round matches the replicated round within f32 round-off: the state
      lives row-sharded over the ``clients`` mesh (device k owns rows
      [k·m/s, (k+1)·m/s)), the cohort gather is a per-shard take + psum
      (exact — one owner per row) and the mix/scatter runs per shard on
      localized indices with the same sentinel-drop contract.
  (b) async — the sharded pending buffer (each device owns B/shards
      slots, deposits scatter into the owner shard, a flush all-gathers
      the (B, d) updates as the ONLY model-sized collective) reproduces
      the replicated async trajectory.
  (c) one compiled round — shard_state keeps the single-compilation
      guarantee under the availability sampler, barrier and async.
  (d) dispatch — shard_state without a mesh, the dense ``cohort=None``
      path, and ``ucfl_parallel`` all raise with actionable messages.

The file is device-count agnostic: under 1 device the sharding is the
degenerate identity, CI's multi-device job re-runs it under 8 forced
host devices where m=8 puts exactly one client row per device.
"""
import functools

import jax
import numpy as np
import pytest

from repro.core import FedConfig, ucfl
from repro.core.strategy import REGISTRY
from repro.data import synthetic
from repro.federated import async_buffer, mesh as mesh_lib, simulation
from repro.federated.participation import ParticipationConfig
from repro.models import lenet

STRATEGIES = ["cfl", "ditto", "fedavg", "fedfomo", "fedprox", "local",
              "oracle", "pfedme", "scaffold", "ucfl"]


@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(17)
    dkey, mkey = jax.random.split(key)
    data = synthetic.concept_shift(dkey, m=8, n=120, n_test=30,
                                   num_classes=6, groups=2, hw=(16, 16),
                                   channels=1, noise=1.0)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    return data, params0


def _make(name, *, shard=False, acfg=None, **cfg_kw):
    data, params0 = _setup()
    cfg = FedConfig(batch_size=40, async_buffer=acfg,
                    mesh="auto" if shard else None, shard_state=shard,
                    **cfg_kw)
    kw = {"var_batch_size": 40} if name == "ucfl" else {}
    return REGISTRY[name](lenet.apply, params0, cfg, **kw)


def _leaves(strat, state):
    return [np.asarray(x) for x in jax.tree.leaves(strat.eval_params(state))]


# ---------------------------------------------------------- (a) equivalence

@pytest.mark.parametrize("name", STRATEGIES)
def test_shard_state_matches_replicated(name):
    data, _ = _setup()
    cohort = np.asarray([1, 4, 6], np.int32)
    a = _make(name)
    b = _make(name, shard=True)
    ra, _ = a.round(a.init(jax.random.PRNGKey(3), data), data,
                    jax.random.PRNGKey(5), cohort)
    rb, _ = b.round(b.init(jax.random.PRNGKey(3), data), data,
                    jax.random.PRNGKey(5), cohort)
    for x, y in zip(_leaves(a, ra), _leaves(b, rb)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


def test_shard_state_rows_actually_sharded():
    """The committed params really live row-sharded: each device's
    addressable shard holds m/shards rows (no silent replication)."""
    data, _ = _setup()
    strat = _make("fedavg", shard=True)
    state = strat.init(jax.random.PRNGKey(3), data)
    state, _ = strat.round(state, data, jax.random.PRNGKey(5),
                           np.asarray([1, 4, 6], np.int32))
    mesh = mesh_lib.resolve("auto")
    shards = mesh_lib.num_shards(mesh)
    m = data.num_clients
    for leaf in jax.tree.leaves(state["params"]):
        rows = {s.data.shape[0] for s in leaf.addressable_shards}
        assert rows == {m // shards}


def test_shard_state_absent_clients_bit_identical():
    """Non-cohort rows never cross a device boundary — they stay
    bit-identical across a sharded round."""
    data, _ = _setup()
    strat = _make("local", shard=True)  # scatter-only: cohort rows move
    state = strat.init(jax.random.PRNGKey(3), data)
    before = _leaves(strat, state)
    cohort = np.asarray([1, 4, 6], np.int32)
    absent = np.asarray([0, 2, 3, 5, 7])
    s1, _ = strat.round(state, data, jax.random.PRNGKey(5), cohort)
    for a, b in zip(before, _leaves(strat, s1)):
        np.testing.assert_array_equal(a[absent], b[absent])
        assert np.abs(a[cohort] - b[cohort]).max() > 0


def test_shard_state_composes_with_w_refresh():
    from repro.core.similarity import RefreshConfig
    data, _ = _setup()
    cohort = np.asarray([1, 4, 6], np.int32)
    a = _make("ucfl", w_refresh=RefreshConfig())
    b = _make("ucfl", shard=True, w_refresh=RefreshConfig())
    sa = a.init(jax.random.PRNGKey(3), data)
    sb = b.init(jax.random.PRNGKey(3), data)
    for r in range(2):
        sa, _ = a.round(sa, data, jax.random.PRNGKey(5 + r), cohort)
        sb, _ = b.round(sb, data, jax.random.PRNGKey(5 + r), cohort)
    for x, y in zip(_leaves(a, sa), _leaves(b, sb)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------- (b) async

@pytest.mark.parametrize("name", ["ucfl", "fedavg"])
def test_shard_state_async_trajectory_matches_replicated(name):
    data, _ = _setup()
    acfg = async_buffer.AsyncConfig(flush_k=2)
    a = _make(name, acfg=acfg)
    b = _make(name, shard=True, acfg=acfg)
    sa = a.init(jax.random.PRNGKey(3), data)
    sb = b.init(jax.random.PRNGKey(3), data)
    cohorts = [np.asarray([1, 4, 6], np.int32), np.asarray([2], np.int32),
               np.asarray([0, 5], np.int32)]
    for r, co in enumerate(cohorts):
        sa, ma = a.round(sa, data, jax.random.PRNGKey(5 + r), co)
        sb, mb = b.round(sb, data, jax.random.PRNGKey(5 + r), co)
        assert int(ma["flushed"]) == int(mb["flushed"])
        assert int(ma["applied"]) == int(mb["applied"])
    for x, y in zip(_leaves(a, sa), _leaves(b, sb)):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


def test_shard_state_buffer_padded_to_shard_multiple():
    """The pending buffer's slot count is padded so every device owns
    B/shards slots, and the upd rows sit at the 128-aligned width."""
    from repro.kernels import ops
    data, _ = _setup()
    strat = _make("ucfl", shard=True,
                  acfg=async_buffer.AsyncConfig(flush_k=3))
    state = strat.init(jax.random.PRNGKey(3), data)
    state, _ = strat.round(state, data, jax.random.PRNGKey(5),
                           np.asarray([1, 4], np.int32))
    upd = state["abuf"]["upd"]
    shards = mesh_lib.num_shards(mesh_lib.resolve("auto"))
    assert upd.shape[0] % shards == 0
    assert upd.shape[1] == ops.aligned_dim(upd.shape[1])
    rows = {s.data.shape[0] for s in upd.addressable_shards}
    assert rows == {upd.shape[0] // shards}


# --------------------------------------------------- (c) one compiled round

@pytest.mark.parametrize("acfg", [None, async_buffer.AsyncConfig(flush_k=3)])
def test_shard_state_availability_one_compile(acfg):
    data, _ = _setup()
    m = data.num_clients
    trace = np.zeros((m, 4), bool)
    trace[:4, 0] = True
    trace[:1, 1] = True
    trace[:, 2] = True
    part = ParticipationConfig(cohort_size=4, sampler="availability",
                               availability=trace)
    strat = _make("ucfl", shard=True, acfg=acfg, lr=0.1, momentum=0.9,
                  epochs=1)
    simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                   rounds=8, eval_every=8, participation=part)
    assert strat.round.masked_jit._cache_size() == 1


# ------------------------------------------------------------- (d) dispatch

def test_shard_state_requires_mesh():
    _, params0 = _setup()
    with pytest.raises(ValueError, match="requires a mesh"):
        ucfl.make_ucfl(lenet.apply, params0, FedConfig(shard_state=True))


def test_shard_state_dense_path_raises():
    data, _ = _setup()
    strat = _make("fedavg", shard=True)
    state = strat.init(jax.random.PRNGKey(3), data)
    with pytest.raises(ValueError, match="cohort rounds"):
        strat.round(state, data, jax.random.PRNGKey(5), None)


def test_ucfl_parallel_rejects_shard_state():
    _, params0 = _setup()
    with pytest.raises(NotImplementedError):
        ucfl.make_ucfl_parallel(lenet.apply, params0,
                                FedConfig(mesh="auto", shard_state=True))
