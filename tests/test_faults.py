"""Fault-injection harness + Byzantine-robust masked aggregation tests.

Pins the robustness PR's contracts:

  (a) OFF means OFF — ``FedConfig.faults=None, robust=None`` is the
      default and every strategy body keeps its pre-existing trace, so a
      zero-fault active stage (``byzantine_frac=0, drop_rate=0``) and
      each robust rule at its neutral parameter (``trim_k=0``,
      ``clip=inf``, ``multi_krum`` with ``q >= c``) must reproduce the
      plain engine BIT-FOR-BIT.
  (b) one compiled round shape holds with faults + robust rules on —
      across an availability trace (and under ``mesh=8`` when the host
      exposes 8 devices) the masked round compiles exactly once.
  (c) graceful degradation — an all-NaN upload round (or an all-dropped
      round) demotes every slot to a masked pad slot and leaves the
      params bit-identical (skip-round semantics), instead of poisoning
      the stacked state.
  (d) fail-fast — ``simulation.run`` raises a diagnostic RuntimeError
      (round, strategy, offending client rows) when a NaN leaks into
      state WITHOUT faults enabled, and stands down when the strategy
      injects faults itself.
  (e) robust-rule properties (hypothesis when available): trimmed-mean
      permutation invariance, coordinate-median breakdown under
      ≤ ⌊(c_real−1)/2⌋ arbitrary rows, norm-clip idempotence on in-norm
      rows.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, load_ci_profile, st
from repro.core import FedConfig, REGISTRY, aggregation
from repro.core.aggregation import RobustConfig
from repro.data import synthetic
from repro.federated import faults as fl
from repro.federated import simulation
from repro.federated.async_buffer import AsyncConfig
from repro.federated.participation import ParticipationConfig
from repro.models import lenet

load_ci_profile(max_examples=25)


@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(29)
    dkey, mkey = jax.random.split(key)
    data = synthetic.label_shift(dkey, m=8, n=96, n_test=24,
                                 num_classes=6, hw=(12, 12))
    params0 = lenet.init(mkey, input_hw=(12, 12), channels=1, num_classes=6)
    return data, params0


def _make(name, params0, *, faults=None, robust=None, **kw):
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=32,
                    faults=faults, robust=robust, **kw)
    if name in ("ucfl", "ucfl_parallel"):
        return REGISTRY[name](lenet.apply, params0, cfg, var_batch_size=32)
    if name in ("scaffold", "pfedme"):
        return REGISTRY[name](lenet.apply, params0, cfg=cfg)
    return REGISTRY[name](lenet.apply, params0, cfg)


def _leaves(strat, state):
    return [np.asarray(x) for x in jax.tree.leaves(strat.eval_params(state))]


def _one_round(strat, data, members=(0, 2, 3, 5, 6)):
    state = strat.init(jax.random.PRNGKey(3), data)
    cohort = np.asarray(members, np.int32)
    new, _ = strat.round(state, data, jax.random.PRNGKey(101), cohort)
    return new


# ----------------------------------------------------- (a) off means off

@pytest.mark.parametrize("name", ["ucfl", "fedavg", "ditto", "cfl"])
def test_zero_fault_stage_bit_exact(name):
    """An ACTIVE stage with nothing to inject (0 attackers, 0 drops) must
    leave the round bit-identical to the plain engine — the finite guard
    on finite uploads is a where-keep."""
    data, params0 = _setup()
    plain = _one_round(_make(name, params0), data)
    nofault = fl.FaultConfig(seed=0, byzantine_frac=0.0, drop_rate=0.0)
    staged = _one_round(_make(name, params0, faults=nofault), data)
    s = _make(name, params0)
    for a, b in zip(_leaves(s, plain), _leaves(s, staged)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("robust", [
    RobustConfig(rule="trimmed_mean", trim_k=0),
    RobustConfig(rule="norm_clip", clip=float("inf")),
    RobustConfig(rule="multi_krum", f=1, q=64),
])
def test_neutral_robust_rule_bit_exact(robust):
    data, params0 = _setup()
    plain = _one_round(_make("ucfl", params0), data)
    staged = _one_round(_make("ucfl", params0, robust=robust), data)
    s = _make("ucfl", params0)
    for a, b in zip(_leaves(s, plain), _leaves(s, staged)):
        np.testing.assert_array_equal(a, b)


def test_attacker_mask_deterministic():
    cfg = fl.FaultConfig(seed=5, byzantine_frac=0.25)
    a = np.asarray(fl.attacker_mask(cfg, 16))
    b = np.asarray(fl.attacker_mask(cfg, 16))
    np.testing.assert_array_equal(a, b)
    assert a.sum() == fl.num_attackers(cfg, 16) == 4
    c = np.asarray(fl.attacker_mask(
        fl.FaultConfig(seed=6, byzantine_frac=0.25), 16))
    assert c.sum() == 4  # same count, (very likely) different set


def test_dense_path_raises():
    data, params0 = _setup()
    strat = _make("fedavg", params0,
                  faults=fl.FaultConfig(byzantine_frac=0.25))
    state = strat.init(jax.random.PRNGKey(3), data)
    with pytest.raises(ValueError, match="cohort rounds"):
        strat.round(state, data, jax.random.PRNGKey(101))


def test_ucfl_parallel_rejects_faults():
    data, params0 = _setup()
    with pytest.raises(NotImplementedError):
        _make("ucfl_parallel", params0,
              faults=fl.FaultConfig(byzantine_frac=0.25))


# ------------------------------------------------- (b) recompile guards

def test_faults_robust_availability_compiles_once():
    data, params0 = _setup()
    m = data.num_clients
    trace = np.zeros((m, 3), bool)
    trace[:4, 0] = True
    trace[:2, 1] = True
    trace[:, 2] = True
    part = ParticipationConfig(cohort_size=4, sampler="availability",
                               availability=trace)
    strat = _make("ucfl", params0,
                  faults=fl.FaultConfig(byzantine_frac=0.25, drop_rate=0.1),
                  robust=RobustConfig(rule="trimmed_mean", trim_k=1))
    assert strat.round.masked_jit is not None
    assert strat.injects_faults
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=6, eval_every=6, participation=part)
    sizes = [mt["cohort_size"] for mt in h.metrics]
    assert strat.round.masked_jit._cache_size() == 1, sizes


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_faults_robust_sharded_compiles_once():
    data, params0 = _setup()
    part = ParticipationConfig(cohort_size=4)
    strat = _make("ucfl", params0, mesh=8,
                  faults=fl.FaultConfig(byzantine_frac=0.25, drop_rate=0.1),
                  robust=RobustConfig(rule="trimmed_mean", trim_k=1))
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=4, eval_every=4, participation=part)
    assert np.isfinite(h.avg_acc[-1])
    assert strat.round.masked_jit._cache_size() == 1


# ------------------------------------------- (c) graceful degradation

@pytest.mark.parametrize("faults", [
    fl.FaultConfig(byzantine_frac=1.0, attack="nan"),
    fl.FaultConfig(drop_rate=1.0),
], ids=["all_nan", "all_dropped"])
def test_total_loss_round_keeps_params(faults):
    """Every slot demoted (NaN-guarded or dropped) == nobody uploaded:
    the round must leave the stacked params bit-identical."""
    data, params0 = _setup()
    strat = _make("ucfl", params0, faults=faults)
    state = strat.init(jax.random.PRNGKey(3), data)
    before = _leaves(strat, simulation.donation_safe_copy(state))
    new, _ = strat.round(state, data, jax.random.PRNGKey(101),
                         np.asarray([0, 2, 5], np.int32))
    for a, b in zip(before, _leaves(strat, new)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("attack", ["sign_flip", "scaled_noise", "nan",
                                    "inf"])
def test_attacks_stay_finite_under_trimmed_mean(attack):
    data, params0 = _setup()
    strat = _make("ucfl", params0,
                  faults=fl.FaultConfig(byzantine_frac=0.25, attack=attack),
                  robust=RobustConfig(rule="trimmed_mean", trim_k=2))
    state = strat.init(jax.random.PRNGKey(3), data)
    for rnd in range(3):
        state, _ = strat.round(state, data, jax.random.PRNGKey(rnd),
                               np.arange(data.num_clients, dtype=np.int32))
    for leaf in _leaves(strat, state):
        assert np.isfinite(leaf).all()


def test_sign_flip_actually_perturbs():
    """The attack must not be a silent no-op: with no defense the round
    output differs from the clean round's."""
    data, params0 = _setup()
    clean = _one_round(_make("ucfl", params0), data)
    hit = _one_round(
        _make("ucfl", params0,
              faults=fl.FaultConfig(byzantine_frac=0.5,
                                    attack="sign_flip")), data)
    s = _make("ucfl", params0)
    assert any(not np.array_equal(a, b)
               for a, b in zip(_leaves(s, clean), _leaves(s, hit)))


def test_fedavg_async_with_faults_smoke():
    data, params0 = _setup()
    strat = _make("fedavg", params0,
                  faults=fl.FaultConfig(byzantine_frac=0.25, attack="nan"),
                  robust=RobustConfig(rule="median"),
                  async_buffer=AsyncConfig(flush_k=2))
    state = strat.init(jax.random.PRNGKey(3), data)
    for rnd in range(3):
        state, _ = strat.round(state, data, jax.random.PRNGKey(rnd),
                               np.asarray([0, 1, 4, 6], np.int32))
    for leaf in _leaves(strat, state):
        assert np.isfinite(leaf).all()


# ------------------------------------------------------- (d) fail-fast

def test_check_finite_state_raises_with_diagnostics():
    """The guard names the round, the strategy, and the offending client
    rows — the triage a silent NaN run never gave."""
    data, params0 = _setup()
    strat = _make("ucfl", params0)
    state = strat.init(jax.random.PRNGKey(3), data)
    def poison(x):
        a = np.array(x, np.float32)
        a[3] = np.nan
        return a

    state["params"] = jax.tree.map(poison, state["params"])
    with pytest.raises(RuntimeError, match=r"round 7.*client rows \[3\]"):
        simulation._check_finite_state(strat, state, 7)


def test_check_finite_state_passes_on_finite():
    data, params0 = _setup()
    strat = _make("ucfl", params0)
    state = strat.init(jax.random.PRNGKey(3), data)
    simulation._check_finite_state(strat, state, 1)  # must not raise


def test_simulation_stands_down_when_strategy_injects():
    """A faults-enabled strategy owns degradation: run() must not raise
    even while attackers shoot NaNs (the finite guard absorbs them)."""
    data, params0 = _setup()
    strat = _make("ucfl", params0,
                  faults=fl.FaultConfig(byzantine_frac=0.25, attack="nan"))
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=2, eval_every=2,
                       participation=ParticipationConfig(cohort_size=4))
    assert np.isfinite(h.avg_acc[-1])


# -------------------------------------- (e) robust-rule property tests

def _slab(seed, c=6, d=5, scale=1.0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(c, d)).astype(np.float32) * scale


def test_finite_guard_zeroes_and_demotes():
    flat = _slab(0)
    flat[2, 1] = np.nan
    flat[4, 3] = np.inf
    idx = np.arange(6, dtype=np.int32)
    mask = np.ones(6, bool)
    out, idx2, mask2 = fl.finite_guard(jnp.asarray(flat), jnp.asarray(idx),
                                       jnp.asarray(mask), 8)
    out, idx2, mask2 = np.asarray(out), np.asarray(idx2), np.asarray(mask2)
    assert np.isfinite(out).all()  # rows ZEROED, not just demoted: 0*NaN
    np.testing.assert_array_equal(mask2, [1, 1, 0, 1, 0, 1])
    np.testing.assert_array_equal(idx2, [0, 1, 8, 3, 8, 5])
    np.testing.assert_array_equal(out[[0, 1, 3, 5]], flat[[0, 1, 3, 5]])


def test_trimmed_stage_demotes_supermajority_outlier():
    """A sign-flip-style row (outlier in every coordinate) is demoted to
    a masked pad slot by the trimmed_mean stage — winsorizing its values
    alone would leave its full (c, c) mix weight pointed at the inlier
    boundary; honest rows (outliers only in scattered coordinates) keep
    their slots."""
    flat = _slab(3, c=6, d=64)
    flat[1] = -50.0 * np.abs(flat[1]) - 50.0  # below every honest value
    idx = np.arange(6, dtype=np.int32)
    mask = np.ones(6, bool)
    stage = aggregation.robust_stage(
        RobustConfig(rule="trimmed_mean", trim_k=1))
    out, idx2, mask2 = stage(jnp.asarray(flat), jnp.asarray(idx),
                             jnp.asarray(mask), 8)
    mask2, idx2 = np.asarray(mask2), np.asarray(idx2)
    np.testing.assert_array_equal(mask2, [1, 0, 1, 1, 1, 1])
    assert idx2[1] == 8 and (idx2[mask2] == idx[mask2]).all()
    # surviving rows are winsorized into the inlier range, not re-meaned
    out = np.asarray(out)
    assert np.isfinite(out).all()


@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 2))
def test_trimmed_mean_permutation_invariant(seed, trim_k):
    flat = _slab(seed)
    mask = np.asarray([1, 1, 1, 1, 0, 1], bool)
    perm = np.random.default_rng(seed + 1).permutation(6)
    a = np.asarray(aggregation.masked_trimmed_mean(
        jnp.asarray(flat), jnp.asarray(mask), trim_k))
    b = np.asarray(aggregation.masked_trimmed_mean(
        jnp.asarray(flat[perm]), jnp.asarray(mask[perm]), trim_k))
    np.testing.assert_allclose(a[perm], b, rtol=1e-6, atol=1e-6)


@given(st.integers(0, 2 ** 31 - 1), st.floats(1.0, 1e6))
def test_median_breakdown_bounded_by_honest_range(seed, evil_scale):
    """≤ ⌊(c_real−1)/2⌋ arbitrary rows cannot push the coordinate median
    outside the honest rows' coordinate-wise range."""
    rng = np.random.default_rng(seed)
    c, d = 7, 4
    flat = rng.normal(size=(c, d)).astype(np.float32)
    n_evil = (c - 1) // 2
    evil = rng.permutation(c)[:n_evil]
    honest = np.setdiff1d(np.arange(c), evil)
    flat[evil] = rng.normal(size=(n_evil, d)).astype(np.float32) * evil_scale
    mask = np.ones(c, bool)
    out = np.asarray(aggregation.masked_median_rows(
        jnp.asarray(flat), jnp.asarray(mask)))
    lo = flat[honest].min(axis=0)
    hi = flat[honest].max(axis=0)
    assert (out[honest[0]] >= lo - 1e-5).all()
    assert (out[honest[0]] <= hi + 1e-5).all()


@given(st.integers(0, 2 ** 31 - 1))
def test_norm_clip_noop_on_inlier_rows(seed):
    """Rows already within the clip radius pass through BIT-exactly."""
    flat = _slab(seed, scale=0.1)
    mask = np.ones(6, bool)
    out = np.asarray(aggregation.masked_norm_clip(
        jnp.asarray(flat), jnp.asarray(mask), 1e6))
    np.testing.assert_array_equal(out, flat)


@given(st.integers(0, 2 ** 31 - 1))
def test_multi_krum_keeps_central_drops_outlier(seed):
    flat = _slab(seed, scale=0.5)
    flat[3] += 100.0  # gross outlier
    idx = np.arange(6, dtype=np.int32)
    mask = np.ones(6, bool)
    _, idx2, mask2 = aggregation.robust_stage(
        RobustConfig(rule="multi_krum", f=1))(jnp.asarray(flat),
                                              jnp.asarray(idx),
                                              jnp.asarray(mask), 8)
    idx2, mask2 = np.asarray(idx2), np.asarray(mask2)
    assert not mask2[3] and idx2[3] == 8  # outlier demoted to pad slot
    assert mask2.sum() == 5  # keeps c_real − f
