"""Streaming W-refresh subsystem tests.

Covers the PR 4 guarantees:
  (a) buffer math — :func:`repro.core.similarity.streaming_refresh`
      touches exactly the observed clients' rows/columns (Δ̂ stays
      symmetric with a zero diagonal, unobserved pairs keep their
      values), the direction buffer stays on the unit sphere, staleness
      counters advance/reset correctly, and pad slots are bit-invisible.
      Engine-level padded-vs-unpadded equivalence is allclose (1e-6)
      rather than bit-exact for the refresh path ONLY: Δ̂ rows are a
      (c, d) × (d, m) matmul and XLA picks its reduction tiling per slot
      count (observed ulp-level, ~2e-9 — the same phenomenon as the
      shard_map tolerance in tests/test_sharded_cohort.py). The masked
      rules themselves are exact, and the no-refresh engine keeps its
      bit-exact padding guarantee untouched (tests/test_masked_cohort.py).
  (b) engine threading — a refresh-enabled ucfl round updates
      ``state["W"]``/``state["refresh"]`` and reports staleness metrics;
      the dense (``cohort=None``) path never refreshes; absent clients
      keep their models; ``state["collab"]`` stays intact (the refresh
      buffers are donated, the collaboration statistics are not).
  (c) one compiled round — the availability sampler's varying eligible
      sets hit ONE compiled masked round with refresh on
      (``round.masked_jit._cache_size() == 1``), matching the
      no-refresh engine's guarantee.
  (d) mesh — ``FedConfig(mesh=...)`` composes with the refresh (it runs
      on the replicated post-all-gather updates): results match the
      unsharded run within the documented float tolerance, and the
      recompile guard holds. The CI ``multi-device`` job runs this file
      under 8 forced host devices.
  (e) communication — refreshing W consumes the uploads the cohort
      already sends: per-round uplink bytes are identical for stale-W
      and refreshed-W runs (the §V-D comm model pins this).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedConfig, aggregation, comm_model as cm, similarity, ucfl
from repro.core.similarity import RefreshConfig
from repro.data import synthetic
from repro.federated import simulation
from repro.federated.participation import Cohort, ParticipationConfig
from repro.models import lenet


@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(17)
    dkey, mkey = jax.random.split(key)
    data = synthetic.concept_shift(dkey, m=8, n=120, n_test=30,
                                   num_classes=6, groups=2, hw=(16, 16),
                                   channels=1, noise=1.0)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    return data, params0


def _make(refresh=RefreshConfig(), *, num_streams=None, mesh=None,
          parallel=False):
    data, params0 = _setup()
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=40,
                    w_refresh=refresh, mesh=mesh)
    if parallel:
        return ucfl.make_ucfl_parallel(lenet.apply, params0, cfg,
                                       var_batch_size=40)
    return ucfl.make_ucfl(lenet.apply, params0, cfg, num_streams=num_streams,
                          var_batch_size=40)


def _leaves(strat, state):
    return [np.asarray(x) for x in jax.tree.leaves(strat.eval_params(state))]


# ----------------------------------------------------------- (a) buffer math

def _toy_refresh(m=5, d=4, seed=0):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(m, d)).astype(np.float32)
    collab = {
        "full_grads": jnp.asarray(g),
        "sigma_sq": jnp.asarray(rng.uniform(0.1, 0.5, m).astype(np.float32)),
        "delta": None,  # unused by init_refresh_state
    }
    return similarity.init_refresh_state(collab, m)


def test_refresh_config_validation():
    with pytest.raises(ValueError):
        RefreshConfig(alpha=0.0)
    with pytest.raises(ValueError):
        RefreshConfig(sigma_alpha=1.5)
    RefreshConfig(alpha=1.0, sigma_alpha=1.0)  # replace-mode is legal


def test_init_refresh_state_is_normalized():
    r = _toy_refresh()
    g = np.asarray(r["grads"])
    np.testing.assert_allclose(np.linalg.norm(g, axis=-1), 1.0, rtol=1e-6)
    d = np.asarray(r["delta"])
    np.testing.assert_allclose(d, d.T, atol=1e-6)
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-6)
    # delta really is the unit-direction distance 2(1 - cos)
    np.testing.assert_allclose(d, ((g[:, None] - g[None, :]) ** 2).sum(-1),
                               atol=1e-5)
    assert np.asarray(r["staleness"]).tolist() == [0] * 5


def test_streaming_refresh_touches_only_observed_rows():
    m = 5
    r = _toy_refresh(m=m)
    rng = np.random.default_rng(3)
    obs = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    idx = jnp.asarray([1, 3], jnp.int32)
    mask = jnp.ones(2, bool)
    n = jnp.ones(m, jnp.float32)
    new, w = similarity.streaming_refresh(
        r, obs, idx, mask, n, cfg=RefreshConfig(alpha=0.5, sigma_alpha=0.5))

    d0, d1 = np.asarray(r["delta"]), np.asarray(new["delta"])
    touched = np.zeros((m, m), bool)
    touched[[1, 3], :] = True
    touched[:, [1, 3]] = True
    np.testing.assert_array_equal(d1[~touched], d0[~touched])
    assert np.abs(d1[touched] - d0[touched]).max() > 0
    np.testing.assert_allclose(d1, d1.T, atol=1e-6)  # still symmetric
    np.testing.assert_allclose(np.diag(d1), 0.0, atol=1e-6)

    g1 = np.asarray(new["grads"])
    np.testing.assert_array_equal(g1[[0, 2, 4]],
                                  np.asarray(r["grads"])[[0, 2, 4]])
    np.testing.assert_allclose(np.linalg.norm(g1, axis=-1), 1.0, rtol=1e-6)

    s0, s1 = np.asarray(r["sigma_sq"]), np.asarray(new["sigma_sq"])
    np.testing.assert_array_equal(s1[[0, 2, 4]], s0[[0, 2, 4]])
    assert (s1[[1, 3]] != s0[[1, 3]]).all()

    assert np.asarray(new["staleness"]).tolist() == [1, 0, 1, 0, 1]
    wn = np.asarray(w)
    assert (wn >= 0).all()
    np.testing.assert_allclose(wn.sum(axis=1), 1.0, rtol=1e-5)


def test_streaming_refresh_pad_slots_invisible():
    """The padded cohort must produce bit-identical buffers and W."""
    m = 5
    rng = np.random.default_rng(7)
    obs2 = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    obs4 = jnp.concatenate([obs2, jnp.full((2, 4), 99.0)], axis=0)
    n = jnp.ones(m, jnp.float32)
    cfg = RefreshConfig()
    a, wa = similarity.streaming_refresh(
        _toy_refresh(m=m), obs2, jnp.asarray([0, 2], jnp.int32),
        jnp.ones(2, bool), n, cfg=cfg)
    b, wb = similarity.streaming_refresh(
        _toy_refresh(m=m), obs4, jnp.asarray([0, 2, m, m], jnp.int32),
        jnp.asarray([1, 1, 0, 0], bool), n, cfg=cfg)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))


def test_masked_ewma_rows_blend():
    buf = jnp.zeros((4, 2), jnp.float32)
    obs = jnp.ones((2, 2), jnp.float32)
    out = aggregation.masked_ewma_rows(
        buf, obs, jnp.asarray([1, 4], jnp.int32),
        jnp.asarray([True, False], bool), 0.25)
    want = np.zeros((4, 2), np.float32)
    want[1] = 0.25
    np.testing.assert_allclose(np.asarray(out), want)


def test_staleness_update_resets_only_real_slots():
    stale = jnp.asarray([5, 0, 2, 7], jnp.int32)
    out = aggregation.staleness_update(
        stale, jnp.asarray([1, 3, 4], jnp.int32),
        jnp.asarray([True, False, False], bool))
    assert np.asarray(out).tolist() == [6, 0, 3, 8]


# ------------------------------------------------------ (b) engine threading

def test_refresh_round_updates_state_and_metrics():
    data, _ = _setup()
    strat = _make()
    state = strat.init(jax.random.PRNGKey(3), data)
    assert "refresh" in state
    w0 = np.asarray(state["W"]).copy()
    collab0 = {k: np.asarray(v).copy() for k, v in state["collab"].items()}
    cohort = np.asarray([1, 4, 6], np.int32)

    state, metrics = strat.round(state, data, jax.random.PRNGKey(5), cohort)
    assert metrics["cohort_size"] == 3
    assert int(metrics["staleness_max"]) == 1
    assert np.asarray(metrics["staleness"]).tolist() == \
        [1, 0, 1, 1, 0, 1, 0, 1]
    assert abs(np.asarray(state["W"]) - w0).max() > 0  # W refreshed
    # the collaboration statistics are NOT donated away by the refresh
    for k, v in collab0.items():
        np.testing.assert_array_equal(np.asarray(state["collab"][k]), v)
    # a second round advances staleness for the still-absent clients
    state, metrics = strat.round(state, data, jax.random.PRNGKey(6),
                                 np.asarray([0, 1], np.int32))
    assert np.asarray(metrics["staleness"]).tolist() == \
        [0, 0, 2, 2, 1, 2, 1, 2]


def test_dense_path_never_refreshes():
    """cohort=None must stay the paper's compute-W-once engine even with
    the refresh knob on — bit-exact with a refresh-disabled strategy."""
    data, _ = _setup()
    a = _make(refresh=None)
    b = _make()
    sa = a.init(jax.random.PRNGKey(3), data)
    sb = b.init(jax.random.PRNGKey(3), data)
    w0 = np.asarray(sb["W"]).copy()
    stale0 = np.asarray(sb["refresh"]["staleness"]).copy()
    ra, _ = a.round(sa, data, jax.random.PRNGKey(9))
    rb, _ = b.round(sb, data, jax.random.PRNGKey(9))
    for x, y in zip(_leaves(a, ra), _leaves(b, rb)):
        np.testing.assert_array_equal(x, y)
    np.testing.assert_array_equal(np.asarray(rb["W"]), w0)
    np.testing.assert_array_equal(np.asarray(rb["refresh"]["staleness"]),
                                  stale0)


@pytest.mark.parametrize("kind", ["ucfl", "clustered", "parallel"])
def test_refresh_padded_cohort_bit_exact(kind):
    data, _ = _setup()
    strat = (_make(parallel=True) if kind == "parallel"
             else _make(num_streams=2 if kind == "clustered" else None))
    state = strat.init(jax.random.PRNGKey(3), data)
    rkey = jax.random.PRNGKey(101)
    members = np.asarray([1, 4, 6], np.int32)
    padded = Cohort(indices=np.asarray([1, 4, 6, 8, 8], np.int32),
                    mask=np.asarray([1, 1, 1, 0, 0], bool))
    s_u, m_u = strat.round(simulation.donation_safe_copy(state), data,
                           rkey, members)
    s_p, m_p = strat.round(simulation.donation_safe_copy(state), data,
                           rkey, padded)
    assert m_u["cohort_size"] == m_p["cohort_size"] == 3
    # allclose, not bit-exact: see the module docstring (XLA retiles the
    # (c, d) Δ̂ matmul per slot count; observed differences are ulp-level)
    tol = dict(rtol=1e-6, atol=1e-6)
    for a, b in zip(_leaves(strat, s_u), _leaves(strat, s_p)):
        np.testing.assert_allclose(a, b, **tol)
    np.testing.assert_allclose(np.asarray(s_u["W"]), np.asarray(s_p["W"]),
                               **tol)
    np.testing.assert_array_equal(np.asarray(s_u["refresh"]["staleness"]),
                                  np.asarray(s_p["refresh"]["staleness"]))
    for k in ("grads", "sigma_sq", "delta"):
        np.testing.assert_allclose(np.asarray(s_u["refresh"][k]),
                                   np.asarray(s_p["refresh"][k]), **tol)


def test_absent_clients_keep_model_under_refresh():
    data, _ = _setup()
    strat = _make()
    state = strat.init(jax.random.PRNGKey(3), data)
    before = [np.asarray(x) for x in
              jax.tree.leaves(strat.eval_params(state))]
    cohort = np.asarray([1, 4, 6], np.int32)
    absent = np.asarray([0, 2, 3, 5, 7])
    new_state, _ = strat.round(state, data, jax.random.PRNGKey(5), cohort)
    for a, b in zip(before, _leaves(strat, new_state)):
        np.testing.assert_array_equal(a[absent], b[absent])
        assert np.abs(a[cohort] - b[cohort]).max() > 0


# --------------------------------------------------- (c) one compiled round

@pytest.mark.parametrize("kind", ["ucfl", "clustered"])
def test_refresh_availability_one_compile(kind):
    data, _ = _setup()
    m = data.num_clients
    trace = np.zeros((m, 3), bool)
    trace[:4, 0] = True   # 4 eligible
    trace[:2, 1] = True   # 2 eligible (padded)
    trace[:, 2] = True    # 8 eligible (subsampled)
    part = ParticipationConfig(cohort_size=4, sampler="availability",
                               availability=trace)
    strat = _make(num_streams=2 if kind == "clustered" else None)
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=6, eval_every=6, participation=part)
    assert strat.round.masked_jit._cache_size() == 1
    assert int(h.metrics[-1]["staleness_max"]) > 0


def test_skipped_round_still_ages_staleness():
    """Regression: an all-offline round between two refresh rounds used
    to freeze the staleness counters — ``simulation.run`` set
    ``{"skipped": True}`` without touching strategy state. The
    ``Strategy.skip_round`` hook now advances them, so a client absent
    for rounds 1..3 (one of them attended by nobody) reports staleness 3,
    not 2."""
    data, _ = _setup()
    m = data.num_clients
    trace = np.zeros((m, 3), bool)
    trace[:4, 0] = True   # round 1: clients 0-3 eligible
    #                       round 2: nobody online -> engine skips
    trace[:2, 2] = True   # round 3: clients 0-1 eligible
    part = ParticipationConfig(cohort_size=2, sampler="availability",
                               availability=trace)
    strat = _make()
    assert strat.skip_round is not None
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=3, eval_every=1, participation=part)
    assert h.metrics[1].get("skipped") is True
    # clients 4..7 are never eligible: 3 rounds passed, all 3 must count
    assert int(h.metrics[-1]["staleness_max"]) == 3
    stale = np.asarray(h.metrics[-1]["staleness"])
    assert (stale[4:] == 3).all()

    # the hook itself: only the counters move
    state = strat.init(jax.random.PRNGKey(3), data)
    w0 = np.asarray(state["W"]).copy()
    skipped = strat.skip_round(state)
    np.testing.assert_array_equal(
        np.asarray(skipped["refresh"]["staleness"]),
        np.asarray(state["refresh"]["staleness"]) + 1)
    np.testing.assert_array_equal(np.asarray(skipped["W"]), w0)

    # no-refresh strategies have nothing to age on a skipped round
    assert _make(refresh=None).skip_round is None


# ------------------------------------------------------------------ (d) mesh

def test_refresh_under_mesh_matches_unsharded():
    """The refresh runs on the replicated post-all-gather updates, so a
    meshed round must match mesh=None within the sharding tolerance
    documented in tests/test_sharded_cohort.py — relaxed to 1e-4 here
    because Eq. 9's exp/softmax amplifies the ulp-level local-SGD
    tiling differences into the refreshed W (observed ~3e-5 relative at
    8 shards)."""
    data, _ = _setup()
    a = _make()
    b = _make(mesh="auto")
    sa = a.init(jax.random.PRNGKey(3), data)
    sb = b.init(jax.random.PRNGKey(3), data)
    rkey = jax.random.PRNGKey(101)
    cohort = np.asarray([1, 4, 6], np.int32)
    ra, ma = a.round(simulation.donation_safe_copy(sa), data, rkey, cohort)
    rb, mb = b.round(simulation.donation_safe_copy(sb), data, rkey, cohort)
    assert np.asarray(ma["staleness"]).tolist() == \
        np.asarray(mb["staleness"]).tolist()
    tol = dict(rtol=1e-4, atol=1e-6)
    for x, y in zip(_leaves(a, ra), _leaves(b, rb)):
        np.testing.assert_allclose(x, y, **tol)
    np.testing.assert_allclose(np.asarray(ra["W"]), np.asarray(rb["W"]),
                               **tol)
    np.testing.assert_allclose(np.asarray(ra["refresh"]["delta"]),
                               np.asarray(rb["refresh"]["delta"]),
                               rtol=1e-4, atol=1e-5)


def test_refresh_availability_one_compile_under_mesh():
    data, _ = _setup()
    m = data.num_clients
    trace = np.zeros((m, 3), bool)
    trace[:4, 0] = True
    trace[:2, 1] = True
    trace[:, 2] = True
    part = ParticipationConfig(cohort_size=3, sampler="availability",
                               availability=trace)
    strat = _make(mesh="auto")
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=6, eval_every=6, participation=part)
    assert h.metrics[-1]["cohort_size"] in (2, 3)
    assert strat.round.masked_jit._cache_size() == 1


# --------------------------------------------------------- (e) communication

def test_uplink_bytes_unchanged_by_refresh():
    """The refresh consumes the c uploads the cohort already sends: the
    §V-D uplink cost is a function of the cohort size alone, identical
    for stale-W and refreshed-W rounds of every scheme."""
    model_bytes = 1234
    for scheme in ("broadcast", "groupcast", "unicast", "client_mixing"):
        stale = cm.uplink_bytes_per_round(model_bytes, scheme, 20,
                                          cohort_size=5)
        assert stale == 5 * model_bytes
        # no refresh parameter exists to change it — same call, same bytes
        assert cm.uplink_bytes_per_round(model_bytes, scheme, 20,
                                         cohort_size=5) == stale
    assert cm.uplink_bytes_per_round(8, "unicast", 6) == 6 * 8  # dense
    with pytest.raises(ValueError):
        cm.uplink_bytes_per_round(8, "nope", 6)


def test_refresh_metrics_report_no_extra_upload():
    """Engine-level pin: a refreshed round's metrics carry staleness
    telemetry but no additional upload accounting — cohort_size (what
    the comm model prices the uplink by) matches the stale run's."""
    data, _ = _setup()
    cohort = np.asarray([1, 4, 6], np.int32)
    sizes = {}
    for label, refresh in (("stale", None), ("refreshed", RefreshConfig())):
        strat = _make(refresh=refresh)
        state = strat.init(jax.random.PRNGKey(3), data)
        _, metrics = strat.round(state, data, jax.random.PRNGKey(5), cohort)
        sizes[label] = metrics["cohort_size"]
    assert sizes["stale"] == sizes["refreshed"] == 3
