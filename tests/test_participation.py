"""Partial-participation engine tests.

Covers: (a) the fraction=1.0 regression — the explicit-cohort round path
must reproduce the dense full-participation path for ucfl, fedavg, and
clustered ucfl; (b) sampler contracts; (c) absent clients keeping their
last model; (d) the chunked client axis matching the monolithic vmap; and
(e) the m=128 / fraction=0.1 / chunk_size=16 scale target on CPU.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedConfig, REGISTRY, ucfl
from repro.data import synthetic
from repro.federated import client as fedclient
from repro.federated import simulation
from repro.federated.participation import (Cohort, ParticipationConfig,
                                           as_cohort, pad_slots,
                                           sample_cohort)
from tests.hypothesis_compat import HAVE_HYPOTHESIS, given, st  # noqa: F401
from repro.models import lenet


@functools.lru_cache(maxsize=1)
def _setup():
    key = jax.random.PRNGKey(17)
    dkey, mkey = jax.random.split(key)
    data = synthetic.concept_shift(dkey, m=8, n=120, n_test=30,
                                   num_classes=6, groups=2, hw=(16, 16),
                                   channels=1, noise=1.0)
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=6)
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=40)
    return data, params0, cfg


def _make(name, params0, cfg):
    if name == "ucfl":
        return ucfl.make_ucfl(lenet.apply, params0, cfg, var_batch_size=40)
    if name == "clustered":
        return ucfl.make_ucfl(lenet.apply, params0, cfg, num_streams=2,
                              var_batch_size=40)
    return REGISTRY[name](lenet.apply, params0, cfg)


# ---------------------------------------------------------------- regression

@pytest.mark.parametrize("name", ["ucfl", "fedavg", "clustered"])
def test_full_cohort_matches_dense_path(name):
    """round(..., cohort=arange(m)) == round(..., cohort=None) per round."""
    data, params0, cfg = _setup()
    strat = _make(name, params0, cfg)
    state_a = strat.init(jax.random.PRNGKey(3), data)
    state_b = state_a
    cohort = np.arange(data.num_clients, dtype=np.int32)
    for rnd in range(2):
        rkey = jax.random.PRNGKey(100 + rnd)
        state_a, _ = strat.round(state_a, data, rkey)
        state_b, _ = strat.round(state_b, data, rkey, cohort)
        for a, b in zip(jax.tree.leaves(strat.eval_params(state_a)),
                        jax.tree.leaves(strat.eval_params(state_b))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_full_cohort_matches_dense_path_all_strategies(name):
    """One-round full-cohort equivalence for every registered strategy —
    locks in the 8 hand-rewritten baseline cohort paths too."""
    data, params0, cfg = _setup()
    make = REGISTRY[name]
    strat = (make(lenet.apply, params0) if name in ("scaffold", "pfedme")
             else make(lenet.apply, params0, cfg))
    state = strat.init(jax.random.PRNGKey(3), data)
    rkey = jax.random.PRNGKey(101)
    state_a, _ = strat.round(state, data, rkey)
    state_b, _ = strat.round(state, data, rkey,
                             np.arange(data.num_clients, dtype=np.int32))
    for a, b in zip(jax.tree.leaves(strat.eval_params(state_a)),
                    jax.tree.leaves(strat.eval_params(state_b))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_fraction_one_is_dense_fast_path():
    """fraction=1.0 resolves to cohort=None — bit-exact by construction."""
    cfg = ParticipationConfig(fraction=1.0)
    assert sample_cohort(cfg, 1, 16) is None
    assert sample_cohort(None, 1, 16) is None


# ------------------------------------------------------------------ samplers

def test_uniform_sampler_contract():
    cfg = ParticipationConfig(fraction=0.25)
    for rnd in range(1, 6):
        c = sample_cohort(cfg, rnd, 32)
        assert c.num_slots == 8 and len(c) == 8  # no pad slots needed
        assert c.indices.dtype == np.int32 and c.mask.all()
        assert (np.diff(c.members) > 0).all()  # sorted, unique
        assert c.members.min() >= 0 and c.members.max() < 32
    # reproducible for a fixed round, different across rounds
    np.testing.assert_array_equal(sample_cohort(cfg, 3, 32).indices,
                                  sample_cohort(cfg, 3, 32).indices)
    assert not np.array_equal(sample_cohort(cfg, 1, 32).indices,
                              sample_cohort(cfg, 2, 32).indices)


def test_weighted_sampler_biases_by_n():
    cfg = ParticipationConfig(cohort_size=4, sampler="weighted")
    n = np.asarray([1.0] * 15 + [1000.0])
    hits = sum(15 in sample_cohort(cfg, r, 16, n).members
               for r in range(1, 101))
    assert hits > 95  # client 15 holds ~98.5% of the mass


def test_round_robin_covers_everyone():
    cfg = ParticipationConfig(cohort_size=3, sampler="round_robin")
    seen = set()
    for rnd in range(1, 5):  # ceil(10/3) = 4 rounds for full coverage
        seen.update(sample_cohort(cfg, rnd, 10).members.tolist())
    assert seen == set(range(10))


def test_availability_sampler_respects_trace():
    trace = np.zeros((6, 2), bool)
    trace[:3, 0] = True  # clients 0..2 up on even phases
    trace[3:, 1] = True  # clients 3..5 up on odd phases
    cfg = ParticipationConfig(cohort_size=2, sampler="availability",
                              availability=trace)
    assert set(sample_cohort(cfg, 1, 6).members) <= {0, 1, 2}  # (rnd-1)%2==0
    assert set(sample_cohort(cfg, 2, 6).members) <= {3, 4, 5}


def test_availability_pads_to_fixed_shape():
    """Short eligible sets are padded with masked sentinel slots, so every
    round presents ONE static cohort shape to jit."""
    trace = np.zeros((8, 2), bool)
    trace[:2, 0] = True   # only 2 of 8 up on phase 0
    trace[:, 1] = True    # everyone up on phase 1
    cfg = ParticipationConfig(cohort_size=5, sampler="availability",
                              availability=trace)
    short, full = sample_cohort(cfg, 1, 8), sample_cohort(cfg, 2, 8)
    assert short.num_slots == full.num_slots == 5
    assert len(short) == 2 and len(full) == 5
    np.testing.assert_array_equal(short.indices[2:], [8, 8, 8])  # sentinel m
    np.testing.assert_array_equal(short.mask, [1, 1, 0, 0, 0])
    np.testing.assert_array_equal(short.members, [0, 1])


def test_availability_nobody_online_skips_round():
    """An all-offline phase yields an all-masked cohort and the engine
    idles."""
    trace = np.zeros((8, 2), bool)
    trace[:, 0] = True  # everyone up on phase 0, nobody on phase 1
    cfg = ParticipationConfig(cohort_size=3, sampler="availability",
                              availability=trace)
    assert len(sample_cohort(cfg, 2, 8)) == 0

    data, params0, fcfg = _setup()
    strat = _make("fedavg", params0, fcfg)
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=2, eval_every=1, participation=cfg)
    assert h.metrics[0]["cohort_size"] == 3  # phase 0: trained
    assert h.metrics[1] == {"streams": 0, "cohort_size": 0, "skipped": True}
    # the skipped round must not change any model
    assert h.avg_acc[1] == h.avg_acc[0]


def test_config_validation():
    with pytest.raises(ValueError):
        ParticipationConfig(fraction=0.0)
    with pytest.raises(ValueError):
        ParticipationConfig(sampler="nope")
    with pytest.raises(ValueError):
        ParticipationConfig(sampler="availability")


@pytest.mark.parametrize("fraction,m,want", [
    # half-way fractions: int(round(...)) banker's-rounded these DOWN
    # (0.25*10 = 2.5 -> 2); the explicit ceil rule provisions at least
    # the requested participation fraction
    (0.25, 10, 3),
    (0.5, 5, 3),
    (0.75, 10, 8),
    (0.05, 10, 1),
    (0.125, 4, 1),
    # exact targets stay exact, including ones float fuzz pushes just
    # above an integer (0.1 * 130 == 13.000000000000002)
    (0.5, 8, 4),
    (0.1, 130, 13),
    (0.1, 128, 13),
    (1.0, 7, 7),
])
def test_resolve_size_ceil_rule(fraction, m, want):
    assert ParticipationConfig(fraction=fraction).resolve_size(m) == want


def test_resolve_size_explicit_cohort_size_clamps():
    assert ParticipationConfig(cohort_size=5).resolve_size(3) == 3
    assert ParticipationConfig(cohort_size=5).resolve_size(20) == 5


def test_pad_slots_rejects_shrinking():
    c = Cohort(indices=np.asarray([1, 4, 6], np.int32),
               mask=np.ones(3, bool))
    assert pad_slots(c, 3, m=8) is c  # equal size stays a no-op
    with pytest.raises(ValueError, match="only extends"):
        pad_slots(c, 2, m=8)


def test_weighted_sampler_all_zero_sizes_raises():
    cfg = ParticipationConfig(cohort_size=2, sampler="weighted")
    with pytest.raises(ValueError, match="zero dataset size"):
        sample_cohort(cfg, 1, 4, np.zeros(4))


def test_weighted_sampler_few_positive_takes_them_all():
    """Fewer positive-mass clients than slots: the whole positive set
    participates and the remaining slots are masked pads (rng.choice
    used to crash; a renormalized p used to emit NaNs on sum 0)."""
    cfg = ParticipationConfig(cohort_size=4, sampler="weighted")
    n = np.asarray([0.0, 3.0, 0.0, 0.0, 2.0, 0.0])
    c = sample_cohort(cfg, 1, 6, n)
    assert c.num_slots == 4 and len(c) == 2
    np.testing.assert_array_equal(c.members, [1, 4])
    np.testing.assert_array_equal(c.indices[2:], [6, 6])


def test_weighted_sampler_never_draws_zero_mass_clients():
    cfg = ParticipationConfig(cohort_size=2, sampler="weighted")
    n = np.asarray([1.0, 0.0, 1.0, 0.0, 1.0, 0.0])
    for rnd in range(1, 30):
        assert set(sample_cohort(cfg, rnd, 6, n).members) <= {0, 2, 4}


# ------------------------------------------------------- cohort invariants

def test_cohort_validates_shapes_and_prefix():
    with pytest.raises(ValueError, match="same length"):
        Cohort(indices=np.asarray([1, 2, 3], np.int32),
               mask=np.asarray([True, True], bool))
    with pytest.raises(ValueError, match="sorted prefix"):
        Cohort(indices=np.asarray([1, 8, 3], np.int32),
               mask=np.asarray([True, False, True], bool))
    with pytest.raises(ValueError, match="strictly increasing"):
        Cohort(indices=np.asarray([4, 1, 8], np.int32),
               mask=np.asarray([True, True, False], bool))
    with pytest.raises(ValueError, match="strictly increasing"):
        Cohort(indices=np.asarray([4, 4], np.int32),
               mask=np.asarray([True, True], bool))


@given(st.sets(st.integers(min_value=0, max_value=31), min_size=1,
               max_size=16),
       st.integers(min_value=0, max_value=8))
def test_pad_slots_and_as_cohort_preserve_members(members, extra):
    members = np.sort(np.asarray(sorted(members), np.int32))
    m = 32
    c = as_cohort(members, m)
    np.testing.assert_array_equal(c.members, members)  # as_cohort exact
    p = pad_slots(c, c.num_slots + extra, m)
    np.testing.assert_array_equal(p.members, members)  # padding exact
    assert p.num_slots == c.num_slots + extra
    assert not p.mask[len(members):].any()
    assert (p.indices[len(members):] == m).all()


# ------------------------------------------------------- engine invariants

def test_absent_clients_keep_last_model():
    data, params0, cfg = _setup()
    strat = _make("ucfl", params0, cfg)
    state = strat.init(jax.random.PRNGKey(3), data)
    # snapshot to host BEFORE the round: the masked round donates the
    # stacked-params buffer, so the device copy dies with the call
    before = [np.asarray(leaf) for leaf in
              jax.tree.leaves(strat.eval_params(state))]
    cohort = np.asarray([1, 4, 6], np.int32)
    absent = np.asarray([0, 2, 3, 5, 7])
    new_state, metrics = strat.round(state, data, jax.random.PRNGKey(5),
                                     cohort)
    after = strat.eval_params(new_state)
    assert metrics["cohort_size"] == 3
    for a, b in zip(before, jax.tree.leaves(after)):
        np.testing.assert_array_equal(a[absent], np.asarray(b)[absent])
        assert np.abs(a[cohort] - np.asarray(b)[cohort]).max() > 0


def test_partial_run_all_strategies_finite():
    data, params0, cfg = _setup()
    part = ParticipationConfig(fraction=0.5)
    for name in sorted(REGISTRY):
        make = REGISTRY[name]
        strat = (make(lenet.apply, params0) if name in ("scaffold", "pfedme")
                 else make(lenet.apply, params0, cfg))
        h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                           rounds=2, eval_every=2, participation=part)
        assert 0.0 <= h.final_avg <= 1.0
        assert h.metrics[-1]["cohort_size"] == 4


# ------------------------------------------------------------------ chunking

def test_chunked_local_sgd_matches_vmap():
    data, params0, cfg = _setup()
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (data.num_clients,) + x.shape) + 0.0,
        params0)
    key = jax.random.PRNGKey(9)
    dense = fedclient.make_federated_local_sgd(
        lenet.apply, lr=0.1, momentum=0.9, epochs=1, batch_size=40)
    for chunk in (3, 4, 8, 16):  # non-dividing, dividing, exact, oversize
        chunked = fedclient.make_federated_local_sgd(
            lenet.apply, lr=0.1, momentum=0.9, epochs=1, batch_size=40,
            chunk_size=chunk)
        a, _ = dense(stacked, data.x, data.y, key)
        b, _ = chunked(stacked, data.x, data.y, key)
        for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                       rtol=1e-6, atol=1e-7)


def test_pfedme_honors_chunk_size():
    """pfedme's custom client loop must respect the FedConfig memory knob."""
    data, params0, _ = _setup()
    dense = REGISTRY["pfedme"](lenet.apply, params0)
    chunked = REGISTRY["pfedme"](
        lenet.apply, params0,
        FedConfig(lr=0.01, momentum=0.0, epochs=1, batch_size=20,
                  chunk_size=3))
    sa = dense.init(jax.random.PRNGKey(3), data)
    sb = chunked.init(jax.random.PRNGKey(3), data)
    sa, _ = dense.round(sa, data, jax.random.PRNGKey(5))
    sb, _ = chunked.round(sb, data, jax.random.PRNGKey(5))
    for a, b in zip(jax.tree.leaves(dense.eval_params(sa)),
                    jax.tree.leaves(chunked.eval_params(sb))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                                   atol=1e-7)


def test_scale_target_m128_fraction01_chunk16():
    """The acceptance-scale run: m=128, fraction=0.1, chunk_size=16."""
    key = jax.random.PRNGKey(0)
    dkey, mkey = jax.random.split(key)
    data = synthetic.label_shift(dkey, m=128, n=50, n_test=10,
                                 num_classes=4, alpha=1.0, hw=(16, 16))
    params0 = lenet.init(mkey, input_hw=(16, 16), channels=1, num_classes=4)
    cfg = FedConfig(lr=0.1, momentum=0.9, epochs=1, batch_size=25,
                    chunk_size=16)
    strat = REGISTRY["fedavg"](lenet.apply, params0, cfg)
    part = ParticipationConfig(fraction=0.1)
    h = simulation.run(strat, lenet.apply, data, jax.random.PRNGKey(1),
                       rounds=2, eval_every=2, participation=part,
                       warmup=False)
    assert h.metrics[-1]["cohort_size"] == 13
    assert 0.0 <= h.final_avg <= 1.0


# ------------------------------------------------- reporting satellites

def test_wall_s_excludes_eval_time(monkeypatch):
    """History.wall_s must measure steady-state ROUNDS only — eval
    frequency is a measurement choice, and it used to leak into the
    timer. A deliberately slow (stubbed) evaluate must land in eval_s,
    not wall_s."""
    import time
    import types

    from repro.core.strategy import Strategy

    def slow_eval(apply_fn, params, x, y, batch=None, mesh=None):
        time.sleep(0.2)
        return np.zeros(4)

    monkeypatch.setattr(simulation, "evaluate", slow_eval)
    strat = Strategy("stub", init=lambda key, data: {"p": jnp.zeros(())},
                     round=lambda s, d, k, c=None: (s, {"streams": 0}),
                     eval_params=lambda s: s["p"])
    data = types.SimpleNamespace(num_clients=4, n=np.ones(4), x=None,
                                 y=None, x_test=None, y_test=None)
    h = simulation.run(strat, None, data, jax.random.PRNGKey(0), rounds=3,
                       eval_every=1, warmup=False)
    assert h.eval_s >= 0.55            # three stubbed eval passes
    assert h.wall_s < h.eval_s / 2     # rounds are trivial next to them
    assert len(h.avg_acc) == 3


def test_run_trials_reports_worst_std():
    """The paper's worst-node headline metric ships with its spread:
    run_trials must report worst_std alongside avg_std (regression — it
    silently dropped it)."""
    data_fn = functools.partial(
        synthetic.label_shift, m=4, n=40, n_test=10, num_classes=4,
        alpha=0.4, hw=(16, 16))
    params0 = lenet.init(jax.random.PRNGKey(0), input_hw=(16, 16),
                         channels=1, num_classes=4)
    res = simulation.run_trials(
        lambda t: REGISTRY["fedavg"](lenet.apply, params0,
                                     FedConfig(batch_size=20)),
        lenet.apply, lambda key: data_fn(key), trials=2, rounds=2)
    assert set(res) >= {"avg_mean", "avg_std", "worst_mean", "worst_std"}
    worsts = [h.paired_best[1] for h in res["histories"]]
    assert res["worst_std"] == pytest.approx(float(np.std(worsts)))
    assert res["worst_std"] >= 0.0
